//! RaPP from the command line: predict latency / throughput for any zoo
//! model and (batch, sm, quota) — comparing the trained GNN (native Rust
//! forward), the AOT-compiled HLO forward via PJRT, the DIPPM baseline, and
//! the ground-truth perf model.
//!
//!     make artifacts && cargo run --release --example rapp_predict -- \
//!         --model resnet152 --batch 8 --sm 0.35 --quota 0.6

use has_gpu::model::zoo::{zoo_graph, zoo_names, ZooModel};
use has_gpu::perf::PerfModel;
use has_gpu::rapp::dippm::DippmPredictor;
use has_gpu::rapp::features::{extract, FeatureMode};
use has_gpu::rapp::{LatencyPredictor, PredictQuery, RappPredictor};
use has_gpu::runtime::{PjrtRapp, PjrtRuntime};
use has_gpu::util::cli::Cli;
use std::path::PathBuf;
use std::sync::Arc;

fn main() -> anyhow::Result<()> {
    let args = Cli::new("rapp_predict", "RaPP latency prediction CLI")
        .opt("model", "resnet152", "zoo model name")
        .opt("batch", "8", "batch size")
        .opt("sm", "0.5", "SM partition fraction (0..1]")
        .opt("quota", "0.6", "time quota fraction (0..1]")
        .parse();
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    anyhow::ensure!(dir.join("rapp_weights.json").exists(), "run `make artifacts` first");

    let model = args.get("model");
    let Some(zoo) = ZooModel::from_name(model) else {
        anyhow::bail!("unknown model '{model}'; available: {:?}", zoo_names());
    };
    let g = zoo_graph(zoo);
    let (batch, sm, quota) = (
        args.get_usize("batch") as u32,
        args.get_f64("sm"),
        args.get_f64("quota"),
    );

    let pm = PerfModel::default();
    let rapp = RappPredictor::load(&dir.join("rapp_weights.json"), pm.clone())?;
    let dippm = DippmPredictor::load(&dir.join("dippm_weights.json"), pm.clone())?;

    let truth = pm.latency(&g, batch, sm, quota);
    let query = PredictQuery::new(&g, batch, sm, quota);
    let p_rapp = rapp.latency(query);
    let p_dippm = dippm.latency(query);

    // The same prediction through the AOT-compiled HLO (PJRT path).
    let runtime = Arc::new(PjrtRuntime::new()?);
    let pjrt = PjrtRapp::new(
        runtime,
        dir.join("rapp.hlo.txt"),
        rapp.weights.mode.f_op(),
        rapp.weights.mode.f_g(),
    );
    let feats = extract(&g, batch, sm, quota, &pm, FeatureMode::Full);
    let p_hlo = (pjrt.forward(&feats)? as f64).exp() / 1e3;

    println!("{model} @ batch={batch} sm={sm:.2} quota={quota:.2}");
    println!("  ground truth         : {:8.3} ms", truth * 1e3);
    println!(
        "  RaPP (native rust)   : {:8.3} ms  ({:+.1}%)",
        p_rapp * 1e3,
        (p_rapp / truth - 1.0) * 100.0
    );
    println!(
        "  RaPP (PJRT HLO)      : {:8.3} ms  ({:+.1}%)",
        p_hlo * 1e3,
        (p_hlo / truth - 1.0) * 100.0
    );
    println!(
        "  DIPPM (static-only)  : {:8.3} ms  ({:+.1}%)",
        p_dippm * 1e3,
        (p_dippm / truth - 1.0) * 100.0
    );
    println!(
        "  throughput capability: {:8.1} req/s  (paper: C = batch x quota / t_raw)",
        rapp.capacity(query)
    );
    Ok(())
}
