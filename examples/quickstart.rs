//! Quickstart: start a real HAS-GPU server over the AOT artifacts, send a
//! few requests, and print what happened at every layer.
//!
//!     make artifacts && cargo run --release --example quickstart
//!
//! This is the smallest end-to-end path: Rust gateway → dynamic batcher →
//! vGPU time-token scheduler → PJRT execution of the JAX+Pallas model.

use has_gpu::autoscaler::{HybridAutoscaler, HybridConfig};
use has_gpu::cluster::FunctionSpec;
use has_gpu::gateway::{Server, ServerConfig};
use has_gpu::model::zoo::{zoo_graph, ZooModel};
use has_gpu::rapp::OraclePredictor;
use std::path::PathBuf;
use std::sync::Arc;
use std::time::Duration;

fn main() -> anyhow::Result<()> {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    anyhow::ensure!(
        dir.join("manifest.json").exists(),
        "run `make artifacts` first"
    );

    // One serverless inference function: the small CNN artifact, cost-managed
    // against the mobilenet-class graph.
    let functions = vec![FunctionSpec {
        name: "cnn_s".into(),
        graph: zoo_graph(ZooModel::MobileNetV2),
        slo: 0.5,
        batch: 8,
        artifact: None, // resolved via artifacts/manifest.json
    }];

    println!("starting HAS-GPU server (2 simulated GPUs, PJRT CPU backend)…");
    let server = Server::start(
        &dir,
        functions,
        Box::new(HybridAutoscaler::new(HybridConfig::default())),
        Arc::new(OraclePredictor::default()),
        ServerConfig::default(),
    )?;

    // A single request.
    let rx = server.submit("cnn_s", vec![0.5f32; 3 * 32 * 32])?;
    let reply = rx.recv_timeout(Duration::from_secs(30))?;
    println!(
        "single request: logits[0..3]={:?} latency={:?} (tokens {:?}, exec {:?})",
        &reply.output[..3],
        reply.latency,
        reply.token_wait,
        reply.exec_time
    );

    // A burst: dynamic batching + token scheduling kick in.
    let rxs: Vec<_> = (0..32)
        .map(|i| server.submit("cnn_s", vec![i as f32 / 32.0; 3 * 32 * 32]))
        .collect::<anyhow::Result<_>>()?;
    let mut max_batch = 0;
    for rx in rxs {
        let r = rx.recv_timeout(Duration::from_secs(30))?;
        max_batch = max_batch.max(r.batch_size);
    }
    println!("burst of 32: max dynamic batch = {max_batch}");

    let report = server.report();
    println!(
        "served={} cost=${:.6} pod layout (fn, sm permille, quota permille) = {:?}",
        report.functions["cnn_s"].served(),
        report.costs.cost_of("cnn_s"),
        server.pod_layout()
    );
    server.shutdown();
    println!("quickstart OK");
    Ok(())
}
