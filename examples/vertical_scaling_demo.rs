//! Vertical-scaling mechanics, isolated: one pod on one vGPU, live quota
//! re-writes through the device file + token scheduler while a synthetic
//! kernel stream runs — shows latency responding to the quota within one
//! window boundary and the SM-alignment rule preventing fragmentation.
//!
//!     cargo run --release --example vertical_scaling_demo

use has_gpu::cluster::{ClusterState, FunctionSpec, GpuId, Reconfigurator, ScalingAction};
use has_gpu::cluster::reconfigurator::place_pod;
use has_gpu::model::zoo::{zoo_graph, ZooModel};
use has_gpu::perf::PerfModel;
use has_gpu::vgpu::ClientId;
use std::time::Instant;

fn main() -> anyhow::Result<()> {
    let pm = PerfModel::default();
    let mut cluster = ClusterState::new(1, pm.dev.mem_cap);
    cluster.register_function(FunctionSpec {
        name: "resnet50".into(),
        graph: zoo_graph(ZooModel::ResNet50),
        slo: 0.1,
        batch: 4,
        artifact: None,
    });
    let mut recon = Reconfigurator::new(&cluster, 1).with_token_schedulers(1, 0.005);

    let pod = place_pod(&mut recon, &mut cluster, &pm, "resnet50", GpuId(0), 500, 200, 4, 0.0)
        .map_err(|e| anyhow::anyhow!("{e}"))?;
    let client = ClientId(pod.0);
    let sched = recon.token_scheduler(GpuId(0)).unwrap().clone();

    println!("pod placed: sm=500 permille, quota=200 permille (window 5ms)");
    println!("streaming 30 batches at each quota level; watching wall-clock dilation:\n");

    for &quota in &[200u32, 400, 800, 1000, 300] {
        recon
            .apply(
                &mut cluster,
                &pm,
                &ScalingAction::SetQuota { pod, quota },
                0.0,
            )
            .map_err(|e| anyhow::anyhow!("{e}"))?;
        // The re-write lands at the next window boundary (Fig. 2 semantics).
        std::thread::sleep(std::time::Duration::from_millis(12));
        let cost = pm.raw_graph_time(&zoo_graph(ZooModel::ResNet50), 4, 0.5);
        // Kernel-granular acquisition (libhas semantics): ~1.25ms chunks.
        let chunk = 0.00125;
        let t0 = Instant::now();
        for _ in 0..30 {
            let mut rem = cost;
            while rem > 0.0 {
                sched.acquire(client, rem.min(chunk)).unwrap();
                rem -= chunk;
            }
        }
        let elapsed = t0.elapsed().as_secs_f64();
        let raw = 30.0 * cost;
        println!(
            "quota={quota:4} permille  modelled-gpu-time={:6.1}ms  wall={:7.1}ms  dilation={:.2}x  (expected ~{:.2}x)",
            raw * 1e3,
            elapsed * 1e3,
            elapsed / raw,
            1.0 / (quota as f64 / 1000.0)
        );
    }

    // SM alignment: a 4th distinct partition size is rejected, reuse is not.
    println!("\nSM-alignment (Fig. 2): distinct partition classes are bounded");
    let g = cluster.gpu_mut(GpuId(0));
    let mut next_id = 1000u64;
    for &(sm, expect) in &[(250u32, true), (100, true), (150, false), (250, true)] {
        let ok = g.admissible(sm, 100).is_ok();
        println!(
            "  request sm={sm:4} permille -> {}",
            if ok { "admit" } else { "REJECT (class limit)" }
        );
        assert_eq!(ok, expect);
        if ok {
            next_id += 1;
            g.attach(ClientId(next_id), sm, 100, 1e8).unwrap();
        }
    }
    println!("\nvertical_scaling_demo OK");
    Ok(())
}
