//! End-to-end driver (DESIGN.md §5): serve a compressed Azure-style trace
//! through the full real-mode stack — every request executes the AOT HLO
//! (JAX L2 + Pallas L1) via PJRT, gated by vGPU time tokens, scaled by the
//! hybrid autoscaler — and report latency / throughput / SLO / cost.
//!
//!     make artifacts && cargo run --release --example serve_azure_trace -- --seconds 60
//!
//! Results for the recorded run live in EXPERIMENTS.md.

use has_gpu::autoscaler::{HybridAutoscaler, ScalingPolicy};
use has_gpu::cluster::FunctionSpec;
use has_gpu::expt::PlatformRegistry;
use has_gpu::gateway::{Server, ServerConfig};
use has_gpu::model::zoo::{zoo_graph, ZooModel};
use has_gpu::rapp::{OraclePredictor, RappPredictor};
use has_gpu::util::cli::Cli;
use has_gpu::util::prng::Pcg64;
use has_gpu::workload::{Preset, TraceGen};
use std::path::PathBuf;
use std::sync::Arc;
use std::time::{Duration, Instant};

fn main() -> anyhow::Result<()> {
    let registry = PlatformRegistry::default();
    let args = Cli::new("serve_azure_trace", "real-mode trace serving demo")
        .opt_dyn("platform", "has-gpu", registry.cli_help())
        .opt(
            "keep-alive",
            "inf",
            "idle-pod keep-alive horizon in seconds for hybrid platforms \
             (inf = keep the last replica resident forever)",
        )
        .opt("seconds", "45", "trace length in (real) seconds")
        .opt("rps", "60", "mean request rate")
        .opt("seed", "7", "workload seed")
        .flag("oracle", "use the perf-model oracle instead of trained RaPP")
        .parse();
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    anyhow::ensure!(dir.join("manifest.json").exists(), "run `make artifacts` first");

    // Three real servable functions mapped to zoo graphs for control-plane
    // accounting.
    let functions = vec![
        FunctionSpec {
            name: "cnn_s".into(),
            graph: zoo_graph(ZooModel::MobileNetV2),
            slo: 0.4,
            batch: 8,
            artifact: None,
        },
        FunctionSpec {
            name: "mlp_s".into(),
            graph: zoo_graph(ZooModel::DlrmSmall),
            slo: 0.3,
            batch: 16,
            artifact: None,
        },
        FunctionSpec {
            name: "attn_s".into(),
            graph: zoo_graph(ZooModel::BertTiny),
            slo: 0.4,
            batch: 8,
            artifact: None,
        },
    ];
    let input_dims = [("cnn_s", 3 * 32 * 32), ("mlp_s", 784), ("attn_s", 16 * 32)];

    // Predictor: trained RaPP (the paper's control loop) or the oracle.
    let predictor: Arc<dyn has_gpu::rapp::LatencyPredictor> = if args.has_flag("oracle") {
        Arc::new(OraclePredictor::default())
    } else {
        Arc::new(RappPredictor::load(
            &dir.join("rapp_weights.json"),
            has_gpu::perf::PerfModel::default(),
        )?)
    };

    // Resolve the serving platform through the registry — the same
    // case-insensitive lookup and name menu as `has-gpu expt`.
    let platform = args.get("platform");
    let Some(spec) = registry.get(platform) else {
        anyhow::bail!(
            "unknown platform '{platform}'; registered: {}",
            registry.names().join(", ")
        );
    };
    let keep_alive_raw = args.get("keep-alive");
    let keep_alive = if keep_alive_raw.eq_ignore_ascii_case("inf") {
        f64::INFINITY
    } else {
        keep_alive_raw
            .parse::<f64>()
            .map_err(|_| anyhow::anyhow!("bad --keep-alive '{keep_alive_raw}' (seconds or 'inf')"))?
    };
    anyhow::ensure!(keep_alive > 0.0, "--keep-alive must be positive");
    // Hybrid-family platforms get the real-mode cooldown plus the
    // keep-alive knob; everything else serves through its stock policy.
    let policy: Box<dyn ScalingPolicy> = match &spec.hybrid {
        Some(cfg) => {
            let mut cfg = cfg.clone();
            cfg.cooldown = 5.0;
            cfg.keep_alive = keep_alive;
            Box::new(HybridAutoscaler::named(spec.name.clone(), cfg))
        }
        None => spec.policy(),
    };

    let server = Server::start(
        &dir,
        functions.clone(),
        policy,
        predictor,
        ServerConfig {
            n_gpus: 2,
            tick: Duration::from_millis(500),
            ..ServerConfig::default()
        },
    )?;

    // Synthesize a compressed Azure-style trace and replay it open-loop.
    let seconds = args.get_usize("seconds");
    let names: Vec<&str> = functions.iter().map(|f| f.name.as_str()).collect();
    let trace = TraceGen::preset(
        Preset::Standard,
        args.get_u64("seed"),
        seconds,
        args.get_f64("rps"),
    )
    .generate(&names);
    println!("replaying {seconds}s trace (open loop)…");
    let mut rng = Pcg64::seeded(args.get_u64("seed"));
    let t0 = Instant::now();
    let mut pending = Vec::new();
    let mut sent = 0u64;
    for sec in 0..seconds {
        // Draw each function's arrivals for this second (function-major, so
        // the RNG consumption order — and thus the trace — is unchanged),
        // then merge into one time-sorted stream. Replaying function-by-
        // function submitted cross-function timestamps out of order: an
        // earlier arrival of a later-iterated function was paced against a
        // clock that had already passed it.
        let mut batch: Vec<(f64, usize)> = Vec::new();
        for (fi, f) in functions.iter().enumerate() {
            for at in trace.arrivals(&f.name, sec, &mut rng) {
                batch.push((at, fi));
            }
        }
        batch.sort_by(|a, b| a.0.total_cmp(&b.0));
        for (at, fi) in batch {
            let f = &functions[fi];
            let dim = input_dims.iter().find(|(n, _)| *n == f.name).unwrap().1;
            // Busy-wait-free pacing.
            let target = Duration::from_secs_f64(at);
            if let Some(sleep) = target.checked_sub(t0.elapsed()) {
                std::thread::sleep(sleep);
            }
            pending.push(server.submit(&f.name, vec![0.3f32; dim])?);
            sent += 1;
        }
        pending.retain(|rx| rx.try_recv().is_err());
        if sec % 10 == 9 {
            println!(
                "t={:3}s sent={sent} in-flight={} pods={:?}",
                sec + 1,
                pending.len(),
                server.pod_layout().len()
            );
        }
    }
    std::thread::sleep(Duration::from_secs(2));

    let report = server.report();
    println!("\n=== end-to-end real-mode results ({:.1}s) ===", report.duration);
    for f in &functions {
        let m = &report.functions[&f.name];
        let mut s = m.latency_summary();
        if s.is_empty() {
            continue;
        }
        println!(
            "{:8} served={:6} p50={:6.1}ms p95={:7.1}ms p99={:7.1}ms slo-viol={:.3} cost/1k=${:.4}",
            f.name,
            m.served(),
            s.p50() * 1e3,
            s.p95() * 1e3,
            s.p99() * 1e3,
            m.violation_rate(f.slo),
            report.costs.cost_per_1k(&f.name, m.served()),
        );
    }
    println!(
        "throughput={:.1} req/s  vertical-ups={}  horizontal-ups={}  total-cost=${:.5}",
        report.total_served() as f64 / report.duration,
        report.vertical_ups,
        report.horizontal_ups,
        report.costs.total_cost()
    );
    server.shutdown();
    Ok(())
}
