"""Operator-graph IR (Python mirror of ``rust/src/model/``) and the random
model-graph sampler used to build the RaPP training corpus.

The JSON schema, op-kind order, and every numeric formula are a cross-language
contract with the Rust side; ``artifacts/golden/perf_golden.json`` pins both
implementations (see ``aot.py::write_golden`` and
``rust/tests/artifact_parity.rs``).
"""

from __future__ import annotations

import random
from dataclasses import dataclass, field

# Op-kind order IS the one-hot feature layout — keep in sync with
# rust/src/model/mod.rs::OpKind.
OP_KINDS = [
    "conv2d",
    "dense",
    "matmul",
    "batch_norm",
    "layer_norm",
    "relu",
    "gelu",
    "softmax",
    "pool",
    "add",
    "embed",
    "attention",
]
NUM_OP_KINDS = len(OP_KINDS)
KIND_INDEX = {k: i for i, k in enumerate(OP_KINDS)}

# Shared with rust/src/model/builders.rs::MAX_NODES and runtime::RAPP_MAX_NODES.
MAX_NODES = 64

COMPUTE_BOUND = {"conv2d", "dense", "matmul", "attention"}


@dataclass
class OpNode:
    kind: str
    flops: float
    bytes: float
    params: float
    kernels: int = 1
    kernel: int = 0
    stride: int = 0
    cin: int = 0
    cout: int = 0
    spatial: int = 0


@dataclass
class OpGraph:
    name: str
    family: str
    nodes: list[OpNode] = field(default_factory=list)
    edges: list[tuple[int, int]] = field(default_factory=list)

    # ---- aggregates (mirror rust OpGraph) --------------------------------

    def total_flops(self, batch: int) -> float:
        return sum(n.flops for n in self.nodes) * batch

    def total_bytes(self, batch: int) -> float:
        act = sum(n.bytes for n in self.nodes)
        return act * batch + 4.0 * self.total_params()

    def total_params(self) -> float:
        return sum(n.params for n in self.nodes)

    def count_kind(self, kind: str) -> int:
        return sum(1 for n in self.nodes if n.kind == kind)

    def depth(self) -> int:
        d = [1] * len(self.nodes)
        for s, t in self.edges:
            d[t] = max(d[t], d[s] + 1)
        return max(d) if d else 0

    def validate(self) -> None:
        for s, t in self.edges:
            assert s < t < len(self.nodes), f"bad edge ({s},{t}) in {self.name}"
        assert self.nodes, f"empty graph {self.name}"
        assert len(self.nodes) <= MAX_NODES, f"{self.name}: {len(self.nodes)} nodes"

    # ---- JSON (contract with rust OpGraph::{to,from}_json) ---------------

    def to_json(self) -> dict:
        return {
            "name": self.name,
            "family": self.family,
            "nodes": [
                {
                    "kind": n.kind,
                    "flops": n.flops,
                    "bytes": n.bytes,
                    "params": n.params,
                    "kernels": n.kernels,
                    "kernel": n.kernel,
                    "stride": n.stride,
                    "cin": n.cin,
                    "cout": n.cout,
                    "spatial": n.spatial,
                }
                for n in self.nodes
            ],
            "edges": [[s, t] for s, t in self.edges],
        }

    @staticmethod
    def from_json(j: dict) -> "OpGraph":
        g = OpGraph(name=j["name"], family=j["family"])
        for n in j["nodes"]:
            g.nodes.append(
                OpNode(
                    kind=n["kind"],
                    flops=float(n["flops"]),
                    bytes=float(n["bytes"]),
                    params=float(n["params"]),
                    kernels=int(n["kernels"]),
                    kernel=int(n["kernel"]),
                    stride=int(n["stride"]),
                    cin=int(n["cin"]),
                    cout=int(n["cout"]),
                    spatial=int(n["spatial"]),
                )
            )
        g.edges = [(int(s), int(t)) for s, t in j["edges"]]
        g.validate()
        return g


# ---- builder helpers (formulas mirror rust GraphBuilder) -------------------


class Builder:
    def __init__(self, name: str, family: str):
        self.g = OpGraph(name=name, family=family)

    def push(self, node: OpNode, deps: list[int]) -> int:
        idx = len(self.g.nodes)
        self.g.nodes.append(node)
        for d in deps:
            assert d < idx
            self.g.edges.append((d, idx))
        return idx

    def conv(self, deps, k, cin, cout, out_side, stride, repeat=1) -> int:
        out_elems = float(cout) * float(out_side) ** 2
        flops = 2.0 * float(k) ** 2 * cin * out_elems * repeat
        byts = 4.0 * (cin * (float(out_side) * stride) ** 2 + out_elems) * repeat
        params = float(k) ** 2 * cin * cout * repeat
        return self.push(
            OpNode("conv2d", flops, byts, params, max(repeat, 1), k, stride, cin, cout, out_side),
            deps,
        )

    def dense(self, deps, nin, nout) -> int:
        return self.push(
            OpNode(
                "dense",
                2.0 * nin * nout,
                4.0 * (nin + nout),
                float(nin) * nout + nout,
                1,
                0,
                0,
                nin,
                nout,
                1,
            ),
            deps,
        )

    def elemwise(self, deps, kind, elems, params=0.0, kernels=1) -> int:
        fpe = {"gelu": 8.0, "softmax": 5.0, "layer_norm": 4.0, "batch_norm": 4.0}.get(kind, 1.0)
        n = OpNode(kind, fpe * elems, 8.0 * elems, params, max(kernels, 1))
        return self.push(n, deps)

    def pool(self, deps, c, side, window) -> int:
        elems = float(c) * float(side) ** 2
        return self.push(
            OpNode(
                "pool",
                elems * float(window) ** 2,
                4.0 * elems * (float(window) ** 2 + 1.0),
                0.0,
                1,
                window,
                window,
                c,
                c,
                side,
            ),
            deps,
        )

    def attention(self, deps, seq, dim) -> int:
        s, d = float(seq), float(dim)
        proj = 4.0 * 2.0 * s * d * d
        attn = 2.0 * 2.0 * s * s * d
        return self.push(
            OpNode(
                "attention",
                proj + attn,
                4.0 * (3.0 * s * d + s * s),
                4.0 * d * d,
                6,
                0,
                0,
                dim,
                dim,
                seq,
            ),
            deps,
        )

    def embed(self, deps, vocab, dim, seq) -> int:
        return self.push(
            OpNode(
                "embed",
                float(seq),
                4.0 * seq * dim,
                float(vocab) * dim,
                1,
                0,
                0,
                vocab,
                dim,
                seq,
            ),
            deps,
        )

    def build(self) -> OpGraph:
        self.g.validate()
        return self.g


# ---- random model sampler ---------------------------------------------------


def sample_graph(rng: random.Random, idx: int) -> OpGraph:
    """Sample a random model graph from the CNN / MLP / transformer / recsys
    families the paper's benchmark covers. Structure and magnitudes bracket
    the zoo models so the Rust-side zoo graphs are in-distribution test
    points ("unseen models", Fig. 5)."""
    family = rng.choice(["cnn", "mlp", "transformer", "recsys"])
    b = Builder(f"rand_{family}_{idx}", family)
    if family == "cnn":
        side = rng.choice([112, 56, 56, 28])
        c = rng.choice([16, 24, 32, 48, 64])
        prev = b.conv([], rng.choice([3, 5, 7]), 3, c, side, 2)
        prev = b.elemwise([prev], rng.choice(["batch_norm", "layer_norm"]), c * side * side, 2.0 * c)
        n_stages = rng.randint(2, 5)
        for _ in range(n_stages):
            blocks = rng.randint(1, 6)
            cout = min(c * 2, 1024)
            side = max(side // 2, 4)
            conv = b.conv([prev], rng.choice([1, 3, 3, 5]), c, cout, side, 1, repeat=blocks)
            b.g.nodes[conv].kernels = blocks * rng.randint(1, 3)
            elems = float(cout) * side * side * blocks
            bn = b.elemwise([conv], "batch_norm", elems, 2.0 * cout, kernels=blocks)
            act = b.elemwise([bn], rng.choice(["relu", "gelu"]), elems, kernels=blocks)
            if rng.random() < 0.5:
                prev = b.elemwise([prev, act], "add", elems, kernels=blocks)
            else:
                prev = act
            c = cout
        gap = b.pool([prev], c, 1, 7)
        b.dense([gap], c, rng.choice([10, 100, 1000]))
    elif family == "mlp":
        dim = rng.choice([256, 512, 1024, 2048])
        prev = b.dense([], rng.choice([128, 784, 3072]), dim)
        for _ in range(rng.randint(2, 8)):
            act = b.elemwise([prev], rng.choice(["relu", "gelu"]), float(dim))
            prev = b.dense([act], dim, dim)
        b.dense([prev], dim, rng.choice([1, 10, 100]))
    elif family == "transformer":
        dim = rng.choice([128, 256, 384, 512])
        seq = rng.choice([32, 64, 128, 256])
        emb = b.embed([], rng.choice([8000, 30522, 50000]), dim, seq)
        prev = b.elemwise([emb], "layer_norm", float(seq * dim), 2.0 * dim)
        for _ in range(rng.randint(1, 6)):
            att = b.attention([prev], seq, dim)
            ln1 = b.elemwise([prev, att], "layer_norm", float(seq * dim), 2.0 * dim)
            ffn = b.push(
                OpNode(
                    "matmul",
                    2.0 * 2.0 * seq * dim * 4 * dim,
                    4.0 * (seq * dim * 5.0),
                    8.0 * dim * dim,
                    2,
                    0,
                    0,
                    dim,
                    dim,
                    seq,
                ),
                [ln1],
            )
            gelu = b.elemwise([ffn], "gelu", float(seq * 4 * dim))
            prev = b.elemwise([ln1, gelu], "layer_norm", float(seq * dim), 2.0 * dim)
        b.dense([prev], dim, rng.choice([2, 10]))
    else:  # recsys
        prev = b.dense([], 13, rng.choice([128, 256, 512]))
        r = b.elemwise([prev], "relu", 256.0)
        bot = b.dense([r], 256, 64)
        emb = b.embed([], rng.choice([50_000, 100_000, 500_000]), 64, rng.randint(8, 32))
        inter = b.push(
            OpNode("matmul", 2.0 * 27 * 27 * 64, 4.0 * (27 * 64 + 27 * 27), 0.0, 1, 0, 0, 64, 64, 27),
            [bot, emb],
        )
        prev = inter
        for _ in range(rng.randint(1, 4)):
            d = b.dense([prev], 256, 256)
            prev = b.elemwise([d], "relu", 256.0)
        out = b.dense([prev], 256, 1)
        b.elemwise([out], "softmax", 1.0)
    g = b.build()
    assert len(g.nodes) <= MAX_NODES, f"{g.name}: {len(g.nodes)}"
    return g


def golden_graph() -> OpGraph:
    """The fixed cross-language golden graph. The Rust parity test
    reconstructs this graph from the JSON embedded in the golden file; the
    numbers below are the single source of truth."""
    b = Builder("golden_tiny_cnn", "golden")
    c1 = b.conv([], 3, 3, 32, 56, 2)
    bn = b.elemwise([c1], "batch_norm", 32.0 * 56 * 56, 64.0)
    r1 = b.elemwise([bn], "relu", 32.0 * 56 * 56)
    c2 = b.conv([r1], 3, 32, 64, 28, 2, repeat=2)
    b.g.nodes[c2].kernels = 4
    a1 = b.elemwise([r1, c2], "add", 64.0 * 28 * 28)
    at = b.attention([a1], 49, 64)
    p1 = b.pool([at], 64, 1, 7)
    b.dense([p1], 64, 10)
    return b.build()
