"""RaPP training corpus (build-time).

Stands in for the paper's 53,400-sample PyTorch-model latency dataset: random
model graphs from the benchmark's families × random (batch, SM, quota)
configurations, labelled by the ground-truth perf model plus measurement
noise (the paper's labels come from real profiling runs, which also carry
run-to-run noise).

Storage is factored to keep the corpus small: per-(graph, batch) operator
feature blocks and per-graph adjacency are stored once; samples reference
them by index.
"""

from __future__ import annotations

import math
import random
from dataclasses import dataclass, field

import numpy as np

from .features import F_G_FULL, F_OP_FULL, extract, pad_for_hlo
from .opgraph import MAX_NODES, OpGraph, sample_graph
from .perfsim import PerfModel

BATCH_CHOICES = [1, 2, 4, 8, 16, 32]
# GPU-class throughput factors sampled into the corpus (the built-in
# catalog: t4, v100 reference, a100) — the trailing class feature column
# must vary during training or trained weights cannot respond to it.
CLASS_FACTORS = [0.4, 1.0, 2.0]
SM_GRID = [round(0.05 * i, 2) for i in range(1, 21)]
QUOTA_GRID = [round(0.05 * i, 2) for i in range(1, 21)]


@dataclass
class Corpus:
    """Factored dataset."""

    # Per (graph,batch) block index.
    op_feats: list[np.ndarray] = field(default_factory=list)  # [64, F_OP] padded
    adj: list[np.ndarray] = field(default_factory=list)  # [64, 64] per graph
    mask: list[np.ndarray] = field(default_factory=list)  # [64] per (graph,batch)? per graph
    # Samples: (block_idx, graph_idx, gfeats [F_G], y = ln(latency_ms))
    sample_block: list[int] = field(default_factory=list)
    sample_graph: list[int] = field(default_factory=list)
    gfeats: list[np.ndarray] = field(default_factory=list)
    y: list[float] = field(default_factory=list)

    def arrays(self, idx: np.ndarray):
        """Gather padded batch tensors for sample indices `idx`."""
        blocks = np.array([self.sample_block[i] for i in idx])
        graphs = np.array([self.sample_graph[i] for i in idx])
        x = np.stack([self.op_feats[b] for b in blocks])
        a = np.stack([self.adj[g] for g in graphs])
        m = np.stack([self.mask[g] for g in graphs])
        g = np.stack([self.gfeats[i] for i in idx])
        yy = np.array([self.y[i] for i in idx], dtype=np.float32)
        return x, a, m, g, yy

    def __len__(self) -> int:
        return len(self.y)


def build_corpus(
    graphs: list[OpGraph],
    configs_per_graph: int,
    perf: PerfModel,
    seed: int,
    noise_sigma: float = 0.03,
) -> Corpus:
    rng = random.Random(seed)
    nrng = np.random.default_rng(seed)
    corpus = Corpus()
    op_cache: dict = {}
    graph_cache: dict = {}
    block_of: dict[tuple[int, int], int] = {}  # (graph_idx, batch) -> block

    for gi, g in enumerate(graphs):
        # Per-graph adjacency + mask (batch-independent).
        of0, _, edges = extract(g, 1, 1.0, 1.0, perf, "rapp", op_cache, graph_cache)
        _, adj, mask = pad_for_hlo(of0, edges, F_OP_FULL)
        corpus.adj.append(adj)
        corpus.mask.append(mask)
        for _ in range(configs_per_graph):
            batch = rng.choice(BATCH_CHOICES)
            sm = rng.choice(SM_GRID)
            quota = rng.choice(QUOTA_GRID)
            class_factor = rng.choice(CLASS_FACTORS)
            key = (gi, batch)
            if key not in block_of:
                of, _, _ = extract(g, batch, sm, quota, perf, "rapp", op_cache, graph_cache)
                x, _, _ = pad_for_hlo(of, edges, F_OP_FULL)
                block_of[key] = len(corpus.op_feats)
                corpus.op_feats.append(x)
            # Graph features depend on (batch, sm, quota, class factor);
            # labels come from the class clock so the trained model learns
            # the trailing class column instead of seeing a constant 1.0.
            _, gf, _ = extract(
                g, batch, sm, quota, perf, "rapp", op_cache, graph_cache, class_factor
            )
            latency = perf.latency_class(g, batch, sm, quota, class_factor)
            noisy = latency * math.exp(nrng.normal(0.0, noise_sigma))
            corpus.sample_block.append(block_of[key])
            corpus.sample_graph.append(gi)
            corpus.gfeats.append(gf)
            corpus.y.append(math.log(noisy * 1e3))
    return corpus


def make_graphs(n: int, seed: int) -> list[OpGraph]:
    rng = random.Random(seed)
    return [sample_graph(rng, i) for i in range(n)]


def normalization(corpus: Corpus):
    """Masked mean/std for op features; mean/std for graph features."""
    xs = np.stack(corpus.op_feats)  # [B, 64, F]
    # A block's live rows = rows with any nonzero one-hot.
    live = xs[..., : 12].sum(axis=-1) > 0
    flat = xs[live]
    op_mean = flat.mean(axis=0)
    op_std = np.maximum(flat.std(axis=0), 1e-3)
    gs = np.stack(corpus.gfeats)
    g_mean = gs.mean(axis=0)
    g_std = np.maximum(gs.std(axis=0), 1e-3)
    return (
        op_mean.astype(np.float32),
        op_std.astype(np.float32),
        g_mean.astype(np.float32),
        g_std.astype(np.float32),
    )


def split_indices(n: int, seed: int, frac=(0.8, 0.1, 0.1)):
    rng = np.random.default_rng(seed)
    idx = rng.permutation(n)
    n_train = int(frac[0] * n)
    n_val = int(frac[1] * n)
    return idx[:n_train], idx[n_train : n_train + n_val], idx[n_train + n_val :]
