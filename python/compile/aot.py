"""AOT compilation driver: Python runs ONCE here; the Rust binary is
self-contained afterwards.

Produces into the artifacts directory:

  models/<name>_b<batch>.hlo.txt   — servable L2 models (Pallas L1 inside)
  rapp.hlo.txt                     — trained RaPP forward (Pallas GAT kernel)
  rapp_weights.json / dippm_weights.json / rapp_meta.json
  golden/perf_golden.json          — cross-language perf-model + feature +
                                     predictor parity pins
  manifest.json                    — index consumed by rust runtime::Manifest

Interchange is HLO *text*: jax ≥ 0.5 serialises HloModuleProto with 64-bit
instruction ids which xla_extension 0.5.1 rejects; the text parser reassigns
ids (see /opt/xla-example/README.md).
"""

from __future__ import annotations

import argparse
import json
import pathlib
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import features as feat
from . import model as models
from .opgraph import golden_graph
from .perfsim import PROFILE_SMS, PerfModel


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    # print_large_constants: the default printer elides big weight literals
    # as "{...}", which the HLO text parser on the Rust side silently turns
    # into garbage — weights MUST be printed in full.
    return comp.as_hlo_text(print_large_constants=True)


def lower_servables(out: pathlib.Path, log) -> list[dict]:
    (out / "models").mkdir(parents=True, exist_ok=True)
    entries = []
    for name, (input_dim, output_dim) in models.SERVABLE_MODELS.items():
        params = models.init_params(name)
        fn = models.MODEL_FNS[name]
        for batch in models.SERVABLE_BATCHES:
            spec = jax.ShapeDtypeStruct((batch, input_dim), jnp.float32)
            lowered = jax.jit(lambda x, fn=fn, params=params: (fn(params, x),)).lower(spec)
            text = to_hlo_text(lowered)
            rel = f"models/{name}_b{batch}.hlo.txt"
            (out / rel).write_text(text)
            entries.append(
                {
                    "name": name,
                    "path": rel,
                    "batch": batch,
                    "input_dim": input_dim,
                    "output_dim": output_dim,
                }
            )
            log(f"  lowered {rel} ({len(text) / 1e3:.0f} KB)")
    return entries


def lower_rapp(out: pathlib.Path, params, log) -> str:
    """Lower the trained RaPP forward (with the fused Pallas GAT kernel and
    baked-in weights) to HLO text for the Rust PjrtRapp."""
    jparams = {k: jnp.asarray(v) for k, v in params.items()}
    f_op = int(jparams["gat1_w"].shape[0])
    f_g = int(jparams["mlp_g_w"].shape[0])
    n = feat.MAX_NODES

    from .train_rapp import RESIDUAL_COL

    def fwd(x, adj, mask, gfeats):
        y = models.rapp_forward(
            jparams, x, adj, mask, gfeats, use_pallas=True, residual_col=RESIDUAL_COL
        )
        return (jnp.reshape(y, (1,)),)

    specs = (
        jax.ShapeDtypeStruct((n, f_op), jnp.float32),
        jax.ShapeDtypeStruct((n, n), jnp.float32),
        jax.ShapeDtypeStruct((n,), jnp.float32),
        jax.ShapeDtypeStruct((f_g,), jnp.float32),
    )
    lowered = jax.jit(fwd).lower(*specs)
    text = to_hlo_text(lowered)
    (out / "rapp.hlo.txt").write_text(text)
    log(f"  lowered rapp.hlo.txt ({len(text) / 1e3:.0f} KB)")
    return "rapp.hlo.txt"


def write_golden(out: pathlib.Path, rapp_params, log) -> None:
    """Cross-language parity pins. See rust/tests/artifact_parity.rs."""
    gdir = out / "golden"
    gdir.mkdir(parents=True, exist_ok=True)
    perf = PerfModel()
    g = golden_graph()

    configs = []
    for batch, sm, quota in [
        (1, 1.0, 1.0),
        (1, 0.5, 0.5),
        (4, 0.5, 0.6),
        (8, 0.25, 0.3),
        (16, 0.1, 0.9),
        (32, 0.05, 0.05),
        (32, 1.0, 0.2),
    ]:
        configs.append(
            {
                "batch": batch,
                "sm": sm,
                "quota": quota,
                "latency": perf.latency(g, batch, sm, quota),
                "raw_time": perf.raw_graph_time(g, batch, sm),
                "capacity": perf.capacity(g, batch, sm, quota),
            }
        )
    op_times = [
        [perf.op_time(node, 4, smp) for smp in PROFILE_SMS] for node in g.nodes
    ]
    op_f, g_f, _edges = feat.extract(g, 4, 0.5, 0.6, perf, "rapp")

    # Predictor parity: ref (= rust native semantics) forward on raw features.
    preds = []
    if rapp_params is not None:
        x, adj, mask = feat.pad_for_hlo(op_f, _edges, feat.F_OP_FULL)
        from .train_rapp import RESIDUAL_COL

        y = models.rapp_forward(
            {k: jnp.asarray(v) for k, v in rapp_params.items()},
            x,
            adj,
            mask,
            jnp.asarray(g_f),
            use_pallas=False,
            residual_col=RESIDUAL_COL,
        )
        preds.append(
            {"batch": 4, "sm": 0.5, "quota": 0.6, "ln_latency_ms": float(y)}
        )

    doc = {
        "graph": g.to_json(),
        "configs": configs,
        "profile_batch": 4,
        "op_times": op_times,
        "features_config": {"batch": 4, "sm": 0.5, "quota": 0.6},
        "op_features": np.asarray(op_f, dtype=np.float64).tolist(),
        "graph_features": np.asarray(g_f, dtype=np.float64).tolist(),
        "rapp_preds": preds,
    }
    (gdir / "perf_golden.json").write_text(json.dumps(doc))
    log(f"  wrote golden/perf_golden.json ({len(g.nodes)} nodes, {len(configs)} configs)")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="artifacts directory")
    ap.add_argument("--epochs", type=int, default=12)
    ap.add_argument("--graphs", type=int, default=120)
    ap.add_argument("--configs-per-graph", type=int, default=110)
    ap.add_argument("--seed", type=int, default=20260710)
    ap.add_argument(
        "--skip-train",
        action="store_true",
        help="reuse existing rapp_weights.json instead of retraining",
    )
    args = ap.parse_args()
    out = pathlib.Path(args.out).resolve()
    out.mkdir(parents=True, exist_ok=True)
    log = print
    t0 = time.time()

    log("[aot] lowering servable models …")
    entries = lower_servables(out, log)

    rapp_params = None
    if args.skip_train and (out / "rapp_weights.json").exists():
        log("[aot] --skip-train: loading existing rapp_weights.json")
        doc = json.loads((out / "rapp_weights.json").read_text())
        rapp_params = weights_to_params(doc)
    else:
        log("[aot] training RaPP + DIPPM …")
        from .train_rapp import run_training

        rapp_params, _meta = run_training(
            out,
            epochs=args.epochs,
            n_graphs=args.graphs,
            configs_per_graph=args.configs_per_graph,
            seed=args.seed,
            log=log,
        )

    log("[aot] exporting RaPP HLO …")
    rapp_rel = lower_rapp(out, rapp_params, log)

    log("[aot] writing golden parity files …")
    write_golden(out, rapp_params, log)

    manifest = {
        "models": entries,
        "rapp_hlo": rapp_rel,
        "rapp_weights": "rapp_weights.json",
        "dippm_weights": "dippm_weights.json",
    }
    (out / "manifest.json").write_text(json.dumps(manifest, indent=2))
    log(f"[aot] done in {time.time() - t0:.0f}s → {out}")


def weights_to_params(doc: dict) -> dict:
    """Inverse of train_rapp.export_weights (row-major [n_in, n_out])."""
    arch = doc["arch"]
    f_op, f_g, h = arch["f_op"], arch["f_g"], arch["hidden"]
    def mat(d, n_in, n_out):
        return np.array(d["w"], dtype=np.float32).reshape(n_in, n_out)
    p = {
        "op_mean": np.array(doc["norm"]["op_mean"], dtype=np.float32),
        "op_std": np.array(doc["norm"]["op_std"], dtype=np.float32),
        "g_mean": np.array(doc["norm"]["g_mean"], dtype=np.float32),
        "g_std": np.array(doc["norm"]["g_std"], dtype=np.float32),
        "gat1_w": mat(doc["gat1"], f_op, h),
        "gat1_b": np.array(doc["gat1"]["b"], dtype=np.float32),
        "gat1_asrc": np.array(doc["gat1"]["a_src"], dtype=np.float32),
        "gat1_adst": np.array(doc["gat1"]["a_dst"], dtype=np.float32),
        "gat2_w": mat(doc["gat2"], h, h),
        "gat2_b": np.array(doc["gat2"]["b"], dtype=np.float32),
        "gat2_asrc": np.array(doc["gat2"]["a_src"], dtype=np.float32),
        "gat2_adst": np.array(doc["gat2"]["a_dst"], dtype=np.float32),
        "mlp_g_w": mat(doc["mlp_g"], f_g, h),
        "mlp_g_b": np.array(doc["mlp_g"]["b"], dtype=np.float32),
        "head1_w": mat(doc["head1"], 2 * h, h),
        "head1_b": np.array(doc["head1"]["b"], dtype=np.float32),
        "head2_w": mat(doc["head2"], h, 1),
        "head2_b": np.array(doc["head2"]["b"], dtype=np.float32),
    }
    return p


if __name__ == "__main__":
    main()
