"""L2: the JAX models, built on the L1 Pallas kernels.

Two groups:

* **Servable inference functions** (`cnn_s`, `mlp_s`, `attn_s`) — the small
  real models the Rust serving plane executes on the request path. Each takes
  one flat `[batch, input_dim]` f32 tensor and returns `[batch, output_dim]`
  logits; ``aot.py`` lowers every (model, batch) pair to HLO text.
* **RaPP predictor forward** (`rapp_forward`) — the GAT + MLP latency
  predictor (padded fixed shapes) used for the AOT RaPP artifact and,
  through the differentiable ref-GAT variant, by ``train_rapp.py``.

Weight init is deterministic (seeded) so artifacts are reproducible builds.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from .kernels.conv2d import conv2d
from .kernels.gat import gat_layer
from .kernels.matmul import dense
from .kernels.ref import gat_layer_ref

# ---------------------------------------------------------------------------
# Servable models
# ---------------------------------------------------------------------------

SERVABLE_MODELS = {
    # name: (input_dim, output_dim)
    "cnn_s": (3 * 32 * 32, 10),
    "mlp_s": (784, 10),
    "attn_s": (16 * 32, 10),
}
SERVABLE_BATCHES = [1, 4, 8, 16]


def _init(rng: np.random.Generator, *shape) -> jnp.ndarray:
    fan_in = shape[0] if len(shape) > 1 else 1
    return jnp.array(
        rng.normal(0.0, (2.0 / max(fan_in, 1)) ** 0.5, size=shape), dtype=jnp.float32
    )


def init_params(name: str, seed: int = 0) -> dict:
    rng = np.random.default_rng(seed + hash(name) % 1000)
    if name == "cnn_s":
        return {
            "c1_w": _init(rng, 3, 3, 3, 16),
            "c1_b": jnp.zeros(16, jnp.float32),
            "c2_w": _init(rng, 3, 3, 16, 32),
            "c2_b": jnp.zeros(32, jnp.float32),
            "fc_w": _init(rng, 8 * 8 * 32, 10),
            "fc_b": jnp.zeros(10, jnp.float32),
        }
    if name == "mlp_s":
        return {
            "w1": _init(rng, 784, 256),
            "b1": jnp.zeros(256, jnp.float32),
            "w2": _init(rng, 256, 64),
            "b2": jnp.zeros(64, jnp.float32),
            "w3": _init(rng, 64, 10),
            "b3": jnp.zeros(10, jnp.float32),
        }
    if name == "attn_s":
        d = 32
        return {
            "wq": _init(rng, d, d),
            "wk": _init(rng, d, d),
            "wv": _init(rng, d, d),
            "wo": _init(rng, d, d),
            "fc_w": _init(rng, d, 10),
            "fc_b": jnp.zeros(10, jnp.float32),
        }
    raise ValueError(name)


def cnn_s(params: dict, x: jnp.ndarray) -> jnp.ndarray:
    """Small CNN classifier over 32×32×3 inputs; convs are Pallas im2col
    matmuls with fused bias+ReLU."""
    b = x.shape[0]
    img = x.reshape(b, 32, 32, 3)
    h = conv2d(img, params["c1_w"], params["c1_b"], stride=2, activation="relu")
    h = conv2d(h, params["c2_w"], params["c2_b"], stride=2, activation="relu")
    h = h.reshape(b, -1)
    return dense(h, params["fc_w"], params["fc_b"])


def mlp_s(params: dict, x: jnp.ndarray) -> jnp.ndarray:
    """3-layer MLP; every layer is the fused Pallas dense kernel."""
    h = dense(x, params["w1"], params["b1"], activation="relu")
    h = dense(h, params["w2"], params["b2"], activation="relu")
    return dense(h, params["w3"], params["b3"])


def attn_s(params: dict, x: jnp.ndarray) -> jnp.ndarray:
    """Tiny single-head attention encoder over 16 tokens of width 32;
    projections run through the Pallas matmul."""
    b = x.shape[0]
    seq, d = 16, 32
    tok = x.reshape(b * seq, d)
    q = dense(tok, params["wq"], jnp.zeros(d, jnp.float32)).reshape(b, seq, d)
    k = dense(tok, params["wk"], jnp.zeros(d, jnp.float32)).reshape(b, seq, d)
    v = dense(tok, params["wv"], jnp.zeros(d, jnp.float32)).reshape(b, seq, d)
    att = jax.nn.softmax(jnp.einsum("bsd,btd->bst", q, k) / jnp.sqrt(float(d)), axis=-1)
    ctx = jnp.einsum("bst,btd->bsd", att, v).reshape(b * seq, d)
    out = dense(ctx, params["wo"], jnp.zeros(d, jnp.float32)).reshape(b, seq, d)
    pooled = out.mean(axis=1)
    return dense(pooled, params["fc_w"], params["fc_b"])


MODEL_FNS = {"cnn_s": cnn_s, "mlp_s": mlp_s, "attn_s": attn_s}


# ---------------------------------------------------------------------------
# RaPP predictor forward (shapes contract: runtime::PjrtRapp)
# ---------------------------------------------------------------------------


def rapp_forward(
    params: dict, x, adj, mask, gfeats, *, use_pallas: bool = True, residual_col: int | None = None
):
    """Padded-graph forward: x [64, F_OP], adj [64, 64], mask [64],
    gfeats [F_G] → scalar ln(latency_ms). Normalisation is baked in
    (`params["op_mean"]`… come from training); the Rust PjrtRapp therefore
    feeds RAW features.

    With ``residual_col`` the head predicts a *correction* added to the raw
    anchor feature (the full-SM full-quota profiled latency) — shrinking the
    regression range from ~11 nats to the (sm, quota) adjustment. DIPPM has
    no profile columns, hence no anchor (None).
    """
    gat = gat_layer if use_pallas else gat_layer_ref
    xn = (x - params["op_mean"][None, :]) / params["op_std"][None, :]
    xn = xn * mask[:, None]  # zero out padding rows
    h1 = gat(xn, adj, params["gat1_w"], params["gat1_b"], params["gat1_asrc"], params["gat1_adst"])
    h2 = gat(h1, adj, params["gat2_w"], params["gat2_b"], params["gat2_asrc"], params["gat2_adst"])
    pooled = jnp.sum(h2 * mask[:, None], axis=0) / jnp.maximum(jnp.sum(mask), 1.0)
    gn = (gfeats - params["g_mean"]) / params["g_std"]
    gh = jnp.maximum(gn @ params["mlp_g_w"] + params["mlp_g_b"], 0.0)
    cat = jnp.concatenate([pooled, gh])
    hh = jnp.maximum(cat @ params["head1_w"] + params["head1_b"], 0.0)
    out = hh @ params["head2_w"][:, 0] + params["head2_b"][0]
    if residual_col is not None:
        out = out + gfeats[residual_col]
    return out


def rapp_init(f_op: int, f_g: int, hidden: int, seed: int = 0) -> dict:
    rng = np.random.default_rng(seed)
    def w(*shape):
        return _init(rng, *shape)
    return {
        "op_mean": jnp.zeros(f_op, jnp.float32),
        "op_std": jnp.ones(f_op, jnp.float32),
        "g_mean": jnp.zeros(f_g, jnp.float32),
        "g_std": jnp.ones(f_g, jnp.float32),
        "gat1_w": w(f_op, hidden),
        "gat1_b": jnp.zeros(hidden, jnp.float32),
        "gat1_asrc": w(hidden) * 0.3,
        "gat1_adst": w(hidden) * 0.3,
        "gat2_w": w(hidden, hidden),
        "gat2_b": jnp.zeros(hidden, jnp.float32),
        "gat2_asrc": w(hidden) * 0.3,
        "gat2_adst": w(hidden) * 0.3,
        "mlp_g_w": w(f_g, hidden),
        "mlp_g_b": jnp.zeros(hidden, jnp.float32),
        "head1_w": w(2 * hidden, hidden),
        "head1_b": jnp.zeros(hidden, jnp.float32),
        # Zero-init output head: with a residual anchor the initial
        # prediction IS the anchor; training only learns corrections.
        "head2_w": jnp.zeros((hidden, 1), jnp.float32),
        "head2_b": jnp.zeros(1, jnp.float32),
    }
