"""RaPP feature extraction — exact mirror of ``rust/src/rapp/features.rs``.

Layout contract (FeatureMode::Full):
  op features  (27): one-hot kind (12) | ln1p(flops·b/1e6) | ln1p(bytes/1e6)
                     | ln1p(params/1e6) | kernel/7 | stride/4 | cin/1024
                     | cout/1024 | spatial/256 | log2(b)/5
                     | 6 × ln1p(op_time(sm_p)·1e3)   [PROFILE_SMS, full quota]
  graph features (15): ln1p(Σflops/1e9) | ln1p(Σbytes/1e9) | ln1p(params/1e6)
                     | n_ops/64 | n_conv/32 | n_dense+matmul/32 | depth/64
                     | log2(b)/5 | sm | quota
                     | 5 × ln1p(latency(q_p)·1e3)    [PROFILE_QUOTAS, full SM]

StaticOnly (the DIPPM baseline) drops the runtime-prior columns.
"""

from __future__ import annotations

import math

import numpy as np

from .opgraph import KIND_INDEX, MAX_NODES, NUM_OP_KINDS, OpGraph
from .perfsim import PROFILE_QUOTAS, PROFILE_SMS, PerfModel

F_OP_STATIC = NUM_OP_KINDS + 9  # 21
F_OP_RUNTIME = len(PROFILE_SMS)  # 6
F_G_STATIC = 10
# Graph runtime priors: whole-graph latency at 5 quota probes (full SM), raw
# graph time at the 6 SM probes (full quota) — the paper's two profiling
# passes, aggregated to graph level — plus one derived **anchor** column: the
# separable analytic estimate ln(raw(sm)) + ln(dilation(q)) interpolated from
# the probes. The predictor head regresses the residual against this anchor.
F_G_RUNTIME = len(PROFILE_QUOTAS) + len(PROFILE_SMS) + 1  # 12

# Trailing dynamic column: the GPU-class throughput factor of the query
# (1.0 = the reference V100). Appended LAST in both modes so every
# pre-catalog column keeps its historical index (mirrors rust F_G_CLASS).
F_G_CLASS = 1

F_OP_FULL = F_OP_STATIC + F_OP_RUNTIME  # 27
F_G_FULL = F_G_STATIC + F_G_RUNTIME + F_G_CLASS  # 23


def f_dims(mode: str) -> tuple[int, int]:
    if mode == "rapp":
        return F_OP_FULL, F_G_FULL
    if mode == "dippm":
        return F_OP_STATIC, F_G_STATIC + F_G_CLASS
    raise ValueError(mode)


def extract(
    g: OpGraph,
    batch: int,
    sm: float,
    quota: float,
    perf: PerfModel,
    mode: str = "rapp",
    op_profile_cache: dict | None = None,
    graph_profile_cache: dict | None = None,
    class_factor: float = 1.0,
):
    """Returns (op_feats [n, F_OP] f32, graph_feats [F_G] f32, edges)."""
    full = mode == "rapp"
    b = float(batch)
    n = len(g.nodes)
    f_op, f_g = f_dims(mode)
    op = np.zeros((n, f_op), dtype=np.float32)
    for i, node in enumerate(g.nodes):
        op[i, KIND_INDEX[node.kind]] = 1.0
        op[i, 12] = math.log1p(node.flops * b / 1e6)
        op[i, 13] = math.log1p((node.bytes * b + 4.0 * node.params) / 1e6)
        op[i, 14] = math.log1p(node.params / 1e6)
        op[i, 15] = node.kernel / 7.0
        op[i, 16] = node.stride / 4.0
        op[i, 17] = node.cin / 1024.0
        op[i, 18] = node.cout / 1024.0
        op[i, 19] = node.spatial / 256.0
        op[i, 20] = math.log2(b) / 5.0
    if full:
        key = (g.name, batch)
        prof = None if op_profile_cache is None else op_profile_cache.get(key)
        if prof is None:
            prof = np.array(
                [
                    [math.log1p(perf.op_time(node, batch, smp) * 1e3) for smp in PROFILE_SMS]
                    for node in g.nodes
                ],
                dtype=np.float32,
            )
            if op_profile_cache is not None:
                op_profile_cache[key] = prof
        op[:, 21:27] = prof

    gf = np.zeros(f_g, dtype=np.float32)
    gf[0] = math.log1p(g.total_flops(batch) / 1e9)
    gf[1] = math.log1p(g.total_bytes(batch) / 1e9)
    gf[2] = math.log1p(g.total_params() / 1e6)
    gf[3] = n / 64.0
    gf[4] = g.count_kind("conv2d") / 32.0
    gf[5] = (g.count_kind("dense") + g.count_kind("matmul")) / 32.0
    gf[6] = g.depth() / 64.0
    gf[7] = math.log2(b) / 5.0
    gf[8] = sm
    gf[9] = quota
    if full:
        key = (g.name, batch)
        gprof = None if graph_profile_cache is None else graph_profile_cache.get(key)
        if gprof is None:
            gprof = np.array(
                [math.log1p(perf.latency(g, batch, 1.0, qp) * 1e3) for qp in PROFILE_QUOTAS]
                + [
                    math.log1p(perf.raw_graph_time(g, batch, smp) * 1e3)
                    for smp in PROFILE_SMS
                ],
                dtype=np.float32,
            )
            if graph_profile_cache is not None:
                graph_profile_cache[key] = gprof
        gf[10:21] = gprof
        gf[21] = anchor(g, op[:, 21:27], sm, quota, perf.dev.window, class_factor)
    gf[-1] = class_factor  # class column (last in both modes)
    return op, gf, list(g.edges)


def _interp(xs, ys, x: float) -> float:
    """Piecewise-linear interpolation with end clamping (mirrors rust)."""
    if x <= xs[0]:
        return ys[0]
    if x >= xs[-1]:
        return ys[-1]
    for i in range(len(xs) - 1):
        if x <= xs[i + 1]:
            t = (x - xs[i]) / (xs[i + 1] - xs[i])
            return ys[i] * (1.0 - t) + ys[i + 1] * t
    return ys[-1]


def anchor(
    g: OpGraph, op_prof, sm: float, quota: float, window: float, class_factor: float = 1.0
) -> float:
    """Probe-based analytic latency estimate: interpolate each op's profiled
    time (the 6 SM probes, columns 21..27 of the op features) to the query
    SM in ln-ln space, then replay the scheduler's own token-window
    mechanics (no-debt, kernel granularity — the system knows its window).
    The GNN head regresses the residual against this anchor: interpolation
    error near roofline kinks plus cross-model generalisation.

    Mirrors rust rapp::features::anchor exactly."""
    ln_sms = [math.log(s) for s in PROFILE_SMS]
    ln_sm = math.log(min(max(sm, 1e-3), 1.0))
    now = 0.0
    budget = quota * window
    boundary = window
    for i, node in enumerate(g.nodes):
        ln_t = _interp(ln_sms, [float(v) for v in op_prof[i]], ln_sm)
        t_est = math.expm1(ln_t) / 1e3 / class_factor  # invert ln1p(ms), class clock
        k = max(node.kernels, 1)
        d = t_est / k
        for _ in range(k):
            if boundary <= now:
                skipped = (now - boundary) // window + 1.0
                boundary += skipped * window
                budget = quota * window
            if budget <= 0.0:
                now = boundary
                boundary += window
                budget = quota * window
            now += d
            budget -= d
    # ln(ms), matching the regression target's transform exactly.
    return math.log(max(now * 1e3, 1e-9))


def pad_for_hlo(op_feats: np.ndarray, edges, f_op: int):
    """Pad to the fixed RAPP_MAX_NODES shapes consumed by the AOT HLO:
    x [64, F_OP], adj [64, 64] (symmetrised + self-loops on live nodes),
    mask [64]."""
    n = op_feats.shape[0]
    assert n <= MAX_NODES
    x = np.zeros((MAX_NODES, f_op), dtype=np.float32)
    x[:n] = op_feats
    adj = np.zeros((MAX_NODES, MAX_NODES), dtype=np.float32)
    # Self-loops on EVERY row (including padding) keep the masked softmax
    # well-defined in training gradients; padded rows are excluded from the
    # pooled output by `mask` regardless. Mirrored in rust runtime::PjrtRapp.
    np.fill_diagonal(adj, 1.0)
    for s, d in edges:
        adj[d, s] = 1.0
        adj[s, d] = 1.0
    mask = np.zeros(MAX_NODES, dtype=np.float32)
    mask[:n] = 1.0
    return x, adj, mask
