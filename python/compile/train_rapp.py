"""Train RaPP (GAT + MLP over operator/graph runtime features) and the DIPPM
static-feature baseline; export Rust-loadable weights + accuracy metadata.

Training uses the differentiable reference GAT (`ref.gat_layer_ref`); the
AOT artifact exported by ``aot.py`` swaps in the fused Pallas kernel — a
pytest parity check keeps both within float tolerance.

Outputs (into the artifacts dir):
  rapp_weights.json   — full-feature model, rust rapp::RappWeights schema
  dippm_weights.json  — static-only baseline, same schema (mode="dippm")
  rapp_meta.json      — MAPE on val/test/unseen for both models (Fig. 5 data)
"""

from __future__ import annotations

import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from . import dataset as ds
from .features import F_G_CLASS, F_G_FULL, F_G_STATIC, F_OP_FULL, F_OP_STATIC
from .model import rapp_forward, rapp_init
from .perfsim import PerfModel

HIDDEN = 48
# Anchor column for the residual target: the separable analytic estimate
# (features.anchor) — graph column 21 (the class-factor column sits after
# it, at the very end).
RESIDUAL_COL = 21


def _slice_mode(x, g, mode: str):
    """Full features → mode-specific views (DIPPM drops runtime columns but
    keeps the query configuration, incl. the trailing class column)."""
    if mode == "rapp":
        return x, g
    g_static = jnp.concatenate([g[..., :F_G_STATIC], g[..., -F_G_CLASS:]], axis=-1)
    return x[..., :F_OP_STATIC], g_static


def batched_forward(params, x, adj, mask, g, residual_col):
    return jax.vmap(
        lambda xi, ai, mi, gi: rapp_forward(
            params, xi, ai, mi, gi, use_pallas=False, residual_col=residual_col
        )
    )(x, adj, mask, g)


def loss_fn(params, x, adj, mask, g, y, residual_col):
    pred = batched_forward(params, x, adj, mask, g, residual_col)
    return jnp.mean((pred - y) ** 2)


FROZEN = {"op_mean", "op_std", "g_mean", "g_std"}


def adam_init(params):
    zeros = {k: jnp.zeros_like(v) for k, v in params.items()}
    return {"m": zeros, "v": {k: jnp.zeros_like(val) for k, val in params.items()}, "t": 0}


def adam_step(params, grads, state, lr, b1=0.9, b2=0.999, eps=1e-8):
    t = state["t"] + 1
    new_m, new_v, new_p = {}, {}, {}
    for k, p in params.items():
        if k in FROZEN:
            new_m[k] = state["m"][k]
            new_v[k] = state["v"][k]
            new_p[k] = p
            continue
        gk = grads[k]
        m = b1 * state["m"][k] + (1 - b1) * gk
        v = b2 * state["v"][k] + (1 - b2) * gk * gk
        mh = m / (1 - b1**t)
        vh = v / (1 - b2**t)
        new_p[k] = p - lr * mh / (jnp.sqrt(vh) + eps)
        new_m[k] = m
        new_v[k] = v
    return new_p, {"m": new_m, "v": new_v, "t": t}


def _residual_of(mode: str):
    return RESIDUAL_COL if mode == "rapp" else None


def mape_latency(params, corpus, idx, mode):
    """MAPE in latency space (the paper's Fig. 5 metric)."""
    total, count = 0.0, 0
    for lo in range(0, len(idx), 512):
        sub = idx[lo : lo + 512]
        x, a, m, g, y = corpus.arrays(sub)
        x, g = _slice_mode(x, g, mode)
        pred = np.asarray(batched_forward(params, x, a, m, g, _residual_of(mode)))
        lat_t = np.exp(y)
        lat_p = np.exp(pred)
        total += float(np.sum(np.abs(lat_t - lat_p) / lat_t))
        count += len(sub)
    return 100.0 * total / max(count, 1)


def train_model(mode: str, corpus, train_idx, val_idx, epochs, seed, log):
    f_op = F_OP_FULL if mode == "rapp" else F_OP_STATIC
    f_g = F_G_FULL if mode == "rapp" else F_G_STATIC + F_G_CLASS
    params = rapp_init(f_op, f_g, HIDDEN, seed=seed)
    # Bake normalisation (over train split features, mode-sliced; DIPPM
    # keeps the trailing class column alongside the static prefix).
    op_mean, op_std, g_mean, g_std = ds.normalization(corpus)
    def _g_view(v):
        if mode == "rapp":
            return v[:f_g]
        return np.concatenate([v[:F_G_STATIC], v[-F_G_CLASS:]])
    params["op_mean"] = jnp.array(op_mean[:f_op])
    params["op_std"] = jnp.array(op_std[:f_op])
    params["g_mean"] = jnp.array(_g_view(g_mean))
    params["g_std"] = jnp.array(_g_view(g_std))

    residual_col = _residual_of(mode)
    step = jax.jit(
        lambda p, s, x, a, m, g, y, lr: _train_step(p, s, x, a, m, g, y, lr, residual_col)
    )
    state = adam_init(params)
    rng = np.random.default_rng(seed)
    bs = 256
    n = len(train_idx)
    t0 = time.time()
    for epoch in range(epochs):
        order = rng.permutation(n)
        lr = 3e-3 * (0.85**epoch)
        losses = []
        for lo in range(0, n - bs + 1, bs):
            sub = train_idx[order[lo : lo + bs]]
            x, a, m, g, y = corpus.arrays(sub)
            x, g = _slice_mode(x, g, mode)
            params, state, lv = step(params, state, x, a, m, g, y, lr)
            losses.append(float(lv))
        vm = mape_latency(params, corpus, val_idx[:1024], mode)
        log(
            f"[{mode}] epoch {epoch + 1}/{epochs} loss={np.mean(losses):.4f} "
            f"val_mape={vm:.2f}% ({time.time() - t0:.0f}s)"
        )
    return params


def _train_step(params, state, x, a, m, g, y, lr, residual_col):
    lv, grads = jax.value_and_grad(loss_fn)(params, x, a, m, g, y, residual_col)
    params, state = adam_step(params, grads, state, lr)
    return params, state, lv


def export_weights(params, mode: str, path):
    """Write the rust rapp::RappWeights JSON schema."""
    f_op = int(params["gat1_w"].shape[0])
    f_g = int(params["mlp_g_w"].shape[0])
    def flat(k):
        return np.asarray(params[k], dtype=np.float64).reshape(-1).tolist()
    doc = {
        "arch": {
            "mode": mode,
            "hidden": HIDDEN,
            "f_op": f_op,
            "f_g": f_g,
            "residual_col": RESIDUAL_COL if mode == "rapp" else -1,
        },
        "norm": {
            "op_mean": flat("op_mean"),
            "op_std": flat("op_std"),
            "g_mean": flat("g_mean"),
            "g_std": flat("g_std"),
        },
        "gat1": {
            "w": flat("gat1_w"),
            "b": flat("gat1_b"),
            "a_src": flat("gat1_asrc"),
            "a_dst": flat("gat1_adst"),
        },
        "gat2": {
            "w": flat("gat2_w"),
            "b": flat("gat2_b"),
            "a_src": flat("gat2_asrc"),
            "a_dst": flat("gat2_adst"),
        },
        "mlp_g": {"w": flat("mlp_g_w"), "b": flat("mlp_g_b")},
        "head1": {"w": flat("head1_w"), "b": flat("head1_b")},
        "head2": {"w": flat("head2_w"), "b": flat("head2_b")},
    }
    with open(path, "w") as f:
        json.dump(doc, f)


def run_training(out_dir, epochs: int, n_graphs: int, configs_per_graph: int, seed: int, log=print):
    perf = PerfModel()
    log(f"sampling {n_graphs} training graphs + 20 unseen graphs …")
    graphs = ds.make_graphs(n_graphs, seed=seed)
    unseen_graphs = ds.make_graphs(20, seed=seed + 10_000)
    t0 = time.time()
    corpus = ds.build_corpus(graphs, configs_per_graph, perf, seed=seed + 1)
    unseen = ds.build_corpus(unseen_graphs, 60, perf, seed=seed + 2)
    log(f"corpus: {len(corpus)} samples (+{len(unseen)} unseen) in {time.time() - t0:.0f}s")
    train_idx, val_idx, test_idx = ds.split_indices(len(corpus), seed=seed + 3)
    meta = {"dataset": {"samples": len(corpus), "unseen": len(unseen), "graphs": n_graphs}}
    results = {}
    for mode in ["rapp", "dippm"]:
        params = train_model(mode, corpus, train_idx, val_idx, epochs, seed + 4, log)
        results[mode] = params
        meta[mode] = {
            "val_mape": mape_latency(params, corpus, val_idx, mode),
            "test_mape": mape_latency(params, corpus, test_idx, mode),
            "unseen_mape": mape_latency(params, unseen, np.arange(len(unseen)), mode),
        }
        log(f"[{mode}] final: {meta[mode]}")
        name = "rapp_weights.json" if mode == "rapp" else "dippm_weights.json"
        export_weights(params, mode, out_dir / name)
    with open(out_dir / "rapp_meta.json", "w") as f:
        json.dump(meta, f, indent=2)
    return results["rapp"], meta
