"""Ground-truth performance model — exact Python mirror of
``rust/src/perf.rs`` (formulas, constants, and summation order must match;
``artifacts/golden/perf_golden.json`` pins both sides to 1e-9 relative).

Used at build time only: RaPP training labels + runtime-prior features are
sampled from this surface, which stands in for the paper's V100 profiling
runs (see DESIGN.md §2 for the substitution argument).
"""

from __future__ import annotations

from dataclasses import dataclass

from .opgraph import COMPUTE_BOUND, OpGraph, OpNode

# Contract constants (rust/src/perf.rs).
SATURATION_FLOPS = 0.5e9
MIN_OCCUPANCY = 0.05

KIND_EFFICIENCY = {
    "conv2d": 0.62,
    "dense": 0.70,
    "matmul": 0.70,
    "attention": 0.55,
    "batch_norm": 0.18,
    "layer_norm": 0.18,
    "relu": 0.15,
    "add": 0.15,
    "gelu": 0.20,
    "softmax": 0.20,
    "pool": 0.25,
    "embed": 0.10,
}

PROFILE_SMS = [0.1, 0.2, 0.35, 0.5, 0.75, 1.0]
PROFILE_QUOTAS = [0.2, 0.4, 0.6, 0.8, 1.0]


@dataclass
class DeviceSpec:
    peak_flops: float = 14.0e12
    mem_bw: float = 900.0e9
    mem_cap: float = 16.0e9
    t_launch: float = 6.0e-6
    window: float = 0.005
    price_per_hour: float = 2.48


class PerfModel:
    def __init__(self, dev: DeviceSpec | None = None):
        self.dev = dev or DeviceSpec()

    def op_time(self, op: OpNode, batch: int, sm: float) -> float:
        k = float(max(op.kernels, 1))
        flops = op.flops * batch
        byts = op.bytes * batch + 4.0 * op.params
        occupancy = min(max((flops / k) / SATURATION_FLOPS, MIN_OCCUPANCY), 1.0)
        sm_eff = min(sm, occupancy)
        t_compute = flops / (self.dev.peak_flops * sm_eff * KIND_EFFICIENCY[op.kind])
        t_memory = byts / (self.dev.mem_bw * max(sm, 0.1))
        return max(t_compute, t_memory) + k * self.dev.t_launch

    def raw_graph_time(self, g: OpGraph, batch: int, sm: float) -> float:
        return sum(self.op_time(op, batch, sm) for op in g.nodes)

    def latency(self, g: OpGraph, batch: int, sm: float, q: float) -> float:
        """Token-window simulation at kernel granularity, no-debt semantics —
        statement-for-statement mirror of rust PerfModel::latency (the
        reference-class surface: latency_class at factor 1.0)."""
        return self.latency_class(g, batch, sm, q, 1.0)

    def latency_class(
        self, g: OpGraph, batch: int, sm: float, q: float, factor: float
    ) -> float:
        """Latency on a GPU class with relative throughput `factor` —
        kernels run on the class clock, the window is a scheduler constant.
        Mirrors rust PerfModel::latency_class (factor 1.0 is exact)."""
        w = self.dev.window
        now = 0.0
        budget = q * w
        boundary = w
        for op in g.nodes:
            k = max(op.kernels, 1)
            d = self.op_time(op, batch, sm) / k / factor
            for _ in range(k):
                if boundary <= now:
                    skipped = (now - boundary) // w + 1.0
                    boundary += skipped * w
                    budget = q * w
                if budget <= 0.0:
                    now = boundary
                    boundary += w
                    budget = q * w
                now += d
                budget -= d
        return now

    def capacity(self, g: OpGraph, batch: int, sm: float, q: float) -> float:
        return batch * q / self.raw_graph_time(g, batch, sm)

    def memory_bytes(self, g: OpGraph, batch: int) -> float:
        weights = 4.0 * g.total_params()
        peak_act = max((n.bytes for n in g.nodes), default=0.0) * batch * 2.0
        return (weights + peak_act) * 1.2 + 256e6
