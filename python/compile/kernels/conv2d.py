"""L1: conv2d as im2col + the tiled Pallas matmul.

The paper's GPU kernels tile convolutions over threadblocks; on TPU the same
insight — turn the convolution into a dense MXU contraction — is expressed as
im2col patch extraction (a layout transform XLA fuses into the surrounding
HLO) followed by the 128×128-tiled Pallas matmul, which is where the FLOPs
land. NHWC layout.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .matmul import matmul


def conv2d(
    x: jnp.ndarray,
    w: jnp.ndarray,
    b: jnp.ndarray | None = None,
    stride: int = 1,
    padding: str = "SAME",
    activation: str | None = None,
) -> jnp.ndarray:
    """x: [B, H, W, Cin], w: [kh, kw, Cin, Cout], b: [Cout]."""
    bsz, h, wdt, cin = x.shape
    kh, kw, cin2, cout = w.shape
    assert cin == cin2
    # Patch extraction (im2col). Output: [B, Ho, Wo, kh*kw*cin] with the
    # feature dim ordered (cin, kh, kw) — see lax docs.
    patches = jax.lax.conv_general_dilated_patches(
        x,
        filter_shape=(kh, kw),
        window_strides=(stride, stride),
        padding=padding,
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )
    _, ho, wo, pdim = patches.shape
    cols = patches.reshape(bsz * ho * wo, pdim)
    # Weight matrix in the matching (cin, kh, kw) order.
    wmat = jnp.transpose(w, (2, 0, 1, 3)).reshape(pdim, cout)
    out = matmul(cols, wmat, bias=b, activation=activation)
    return out.reshape(bsz, ho, wo, cout)
