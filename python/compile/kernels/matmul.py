"""L1 Pallas kernel: tiled matmul (+ fused bias / activation epilogue).

This is the compute hot-spot of every L2 model (dense layers, im2col convs,
attention projections). TPU-oriented design (DESIGN.md §Hardware-Adaptation):

* the grid tiles M×N into MXU-shaped 128×128 output blocks; each grid step
  keeps an (bm×K) LHS stripe and a (K×bn) RHS stripe in VMEM — the analogue
  of staging CUDA shared-memory tiles per threadblock;
* K is kept whole per block (our serving models have K ≤ 4096, so the
  VMEM footprint per step is ≤ 128·4096·4 B ≈ 2 MiB per operand — fits the
  16 MiB VMEM budget with double-buffering headroom);
* the epilogue (bias add + ReLU/GELU) is fused into the same kernel, saving
  one HBM round-trip per layer.

``interpret=True`` everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls; numerics are validated against ``ref.py`` by pytest.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# MXU-aligned default block edge.
BLOCK = 128


def _block_dim(d: int, target: int = BLOCK) -> int:
    return d if d <= target else target


def _pad_to(x: jnp.ndarray, rows: int, cols: int) -> jnp.ndarray:
    pr, pc = rows - x.shape[0], cols - x.shape[1]
    if pr == 0 and pc == 0:
        return x
    return jnp.pad(x, ((0, pr), (0, pc)))


def _epilogue(acc, bias, activation):
    if bias is not None:
        acc = acc + bias[None, :]
    if activation == "relu":
        acc = jnp.maximum(acc, 0.0)
    elif activation == "gelu":
        acc = jax.nn.gelu(acc)
    elif activation is not None:
        raise ValueError(f"unknown activation {activation!r}")
    return acc


def _mm_kernel(x_ref, y_ref, o_ref, *, activation, has_bias):
    """One (bm, bn) output tile: full-K contraction + fused epilogue."""
    x = x_ref[...]
    y = y_ref[...]
    acc = jnp.dot(x, y, preferred_element_type=jnp.float32)
    o_ref[...] = _epilogue(acc, None, activation) if not has_bias else acc


def _mm_bias_kernel(x_ref, y_ref, b_ref, o_ref, *, activation):
    x = x_ref[...]
    y = y_ref[...]
    acc = jnp.dot(x, y, preferred_element_type=jnp.float32)
    o_ref[...] = _epilogue(acc, b_ref[...], activation)


def matmul(
    x: jnp.ndarray,
    y: jnp.ndarray,
    bias: jnp.ndarray | None = None,
    activation: str | None = None,
) -> jnp.ndarray:
    """``activation(x @ y + bias)`` as a tiled Pallas kernel.

    x: [M, K] f32, y: [K, N] f32, bias: [N] f32 or None.
    """
    m, k = x.shape
    k2, n = y.shape
    assert k == k2, f"contraction mismatch {k} vs {k2}"
    bm, bn = _block_dim(m), _block_dim(n)
    mp = ((m + bm - 1) // bm) * bm
    np_ = ((n + bn - 1) // bn) * bn
    xp = _pad_to(x, mp, k)
    yp = _pad_to(y, k, np_)
    grid = (mp // bm, np_ // bn)

    if bias is not None:
        bp = jnp.pad(bias, (0, np_ - n)) if np_ != n else bias
        out = pl.pallas_call(
            functools.partial(_mm_bias_kernel, activation=activation),
            grid=grid,
            in_specs=[
                pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
                pl.BlockSpec((k, bn), lambda i, j: (0, j)),
                pl.BlockSpec((bn,), lambda i, j: (j,)),
            ],
            out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
            out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
            interpret=True,
        )(xp, yp, bp)
    else:
        out = pl.pallas_call(
            functools.partial(_mm_kernel, activation=activation, has_bias=False),
            grid=grid,
            in_specs=[
                pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
                pl.BlockSpec((k, bn), lambda i, j: (0, j)),
            ],
            out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
            out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
            interpret=True,
        )(xp, yp)
    return out[:m, :n]


def dense(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray, activation: str | None = None):
    """Dense layer over a batch: activation(x @ w + b)."""
    return matmul(x, w, bias=b, activation=activation)
