"""Pure-jnp correctness oracles for every Pallas kernel.

These are the CORE correctness signal of the L1 layer: pytest sweeps shapes,
strides and activations and asserts ``assert_allclose(kernel, ref)``. The
reference GAT is also the differentiable forward used by RaPP *training*
(the Pallas version is forward-only and ships in the AOT artifact; a parity
test keeps the two within float tolerance).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e9


def matmul_ref(x, y, bias=None, activation=None):
    out = x.astype(jnp.float32) @ y.astype(jnp.float32)
    if bias is not None:
        out = out + bias[None, :]
    if activation == "relu":
        out = jnp.maximum(out, 0.0)
    elif activation == "gelu":
        out = jax.nn.gelu(out)
    elif activation is not None:
        raise ValueError(activation)
    return out


def conv2d_ref(x, w, b=None, stride=1, padding="SAME", activation=None):
    out = jax.lax.conv_general_dilated(
        x,
        w,
        window_strides=(stride, stride),
        padding=padding,
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )
    if b is not None:
        out = out + b[None, None, None, :]
    if activation == "relu":
        out = jnp.maximum(out, 0.0)
    elif activation == "gelu":
        out = jax.nn.gelu(out)
    return out


def gat_layer_ref(x, adj, w, b, a_src, a_dst):
    """Masked single-head GAT layer; mirrors rust/src/rapp/nn.rs."""
    h = x @ w + b[None, :]
    s_src = h @ a_src
    s_dst = h @ a_dst
    e = s_src[:, None] + s_dst[None, :]
    e = jnp.where(e >= 0.0, e, 0.2 * e)
    e = jnp.where(adj > 0.0, e, NEG_INF)
    m = jnp.max(e, axis=1, keepdims=True)
    p = jnp.exp(e - m) * (adj > 0.0)
    z = jnp.sum(p, axis=1, keepdims=True)
    alpha = p / jnp.maximum(z, 1e-30)
    out = alpha @ h
    return jnp.where(out >= 0.0, out, jnp.exp(jnp.minimum(out, 0.0)) - 1.0)
