"""L1: fused GAT attention-aggregation Pallas kernel (RaPP's GNN hot-spot).

One kernel step computes, for the whole padded graph (RAPP_MAX_NODES = 64):

    h      = x @ W + b                      (MXU contraction)
    e_ij   = leaky_relu(a_src·h_i + a_dst·h_j)   masked by adj
    alpha  = softmax_j(e_ij)                (row-wise, masked)
    out_i  = elu(Σ_j alpha_ij · h_j)        (second MXU contraction)

The whole working set (64×64 attention matrix + 64×H features) is a few KiB —
a single VMEM-resident block, so the fusion saves three HBM round-trips vs.
the unfused reference. Semantics mirror ``rust/src/rapp/nn.rs`` exactly
(LeakyReLU slope 0.2, ELU output, softmax over in-neighbours ∪ self).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e9


def _gat_kernel(x_ref, adj_ref, w_ref, b_ref, asrc_ref, adst_ref, o_ref):
    x = x_ref[...]  # [N, F]
    adj = adj_ref[...]  # [N, N]; adj[i, j] = 1 ⇒ j is a neighbour of i
    h = jnp.dot(x, w_ref[...], preferred_element_type=jnp.float32) + b_ref[...][None, :]
    s_src = jnp.sum(h * asrc_ref[...][None, :], axis=1)  # [N]
    s_dst = jnp.sum(h * adst_ref[...][None, :], axis=1)  # [N]
    e = s_src[:, None] + s_dst[None, :]
    e = jnp.where(e >= 0.0, e, 0.2 * e)  # LeakyReLU(0.2)
    e = jnp.where(adj > 0.0, e, NEG_INF)
    # Stable masked softmax over rows.
    m = jnp.max(e, axis=1, keepdims=True)
    p = jnp.exp(e - m) * (adj > 0.0)
    z = jnp.sum(p, axis=1, keepdims=True)
    alpha = p / jnp.maximum(z, 1e-30)
    out = jnp.dot(alpha, h, preferred_element_type=jnp.float32)
    o_ref[...] = jnp.where(out >= 0.0, out, jnp.exp(jnp.minimum(out, 0.0)) - 1.0)  # ELU


def gat_layer(
    x: jnp.ndarray,
    adj: jnp.ndarray,
    w: jnp.ndarray,
    b: jnp.ndarray,
    a_src: jnp.ndarray,
    a_dst: jnp.ndarray,
) -> jnp.ndarray:
    """x: [N, F], adj: [N, N] (self-loops included on live rows),
    w: [F, H], b/a_src/a_dst: [H] → [N, H]."""
    n, f = x.shape
    h = w.shape[1]
    return pl.pallas_call(
        _gat_kernel,
        grid=(1,),
        in_specs=[
            pl.BlockSpec((n, f), lambda i: (0, 0)),
            pl.BlockSpec((n, n), lambda i: (0, 0)),
            pl.BlockSpec((f, h), lambda i: (0, 0)),
            pl.BlockSpec((h,), lambda i: (0,)),
            pl.BlockSpec((h,), lambda i: (0,)),
            pl.BlockSpec((h,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((n, h), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((n, h), jnp.float32),
        interpret=True,
    )(x, adj, w, b, a_src, a_dst)
