"""L1 correctness: Pallas kernels vs pure-jnp oracles, swept over shapes,
dtype edge magnitudes, strides, paddings, and activations."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile.kernels import ref
from compile.kernels.conv2d import conv2d
from compile.kernels.gat import gat_layer
from compile.kernels.matmul import dense, matmul


def rand(rng, *shape, scale=1.0):
    return jnp.array(rng.normal(0.0, scale, size=shape), dtype=jnp.float32)


# -- matmul ------------------------------------------------------------------

MM_SHAPES = [
    (1, 1, 1),
    (3, 7, 5),
    (16, 64, 10),
    (128, 128, 128),
    (130, 257, 64),  # forces padding on M and K-full blocks on odd dims
    (256, 100, 300),
    (1, 3072, 10),
]


@pytest.mark.parametrize("m,k,n", MM_SHAPES)
def test_matmul_matches_ref(m, k, n):
    rng = np.random.default_rng(m * 1000 + k * 10 + n)
    x, y = rand(rng, m, k), rand(rng, k, n)
    np.testing.assert_allclose(matmul(x, y), ref.matmul_ref(x, y), rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("activation", [None, "relu", "gelu"])
def test_matmul_fused_epilogue(activation):
    rng = np.random.default_rng(7)
    x, y, b = rand(rng, 50, 80), rand(rng, 80, 30), rand(rng, 30)
    got = matmul(x, y, bias=b, activation=activation)
    want = ref.matmul_ref(x, y, b, activation)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)


def test_matmul_large_magnitudes():
    rng = np.random.default_rng(11)
    x, y = rand(rng, 32, 32, scale=1e3), rand(rng, 32, 32, scale=1e-3)
    np.testing.assert_allclose(matmul(x, y), ref.matmul_ref(x, y), rtol=1e-4, atol=1e-4)


def test_matmul_rejects_mismatch():
    rng = np.random.default_rng(1)
    with pytest.raises(AssertionError):
        matmul(rand(rng, 4, 5), rand(rng, 6, 4))


def test_dense_is_matmul_bias():
    rng = np.random.default_rng(2)
    x, w, b = rand(rng, 9, 17), rand(rng, 17, 5), rand(rng, 5)
    np.testing.assert_allclose(
        dense(x, w, b, activation="relu"),
        ref.matmul_ref(x, w, b, "relu"),
        rtol=2e-4,
        atol=2e-4,
    )


def test_matmul_under_jit():
    rng = np.random.default_rng(3)
    x, y = rand(rng, 33, 65), rand(rng, 65, 17)
    got = jax.jit(matmul)(x, y)
    np.testing.assert_allclose(got, ref.matmul_ref(x, y), rtol=2e-4, atol=2e-4)


# -- conv2d ------------------------------------------------------------------

CONV_CASES = [
    # (batch, side, cin, cout, k, stride, padding)
    (1, 8, 3, 4, 3, 1, "SAME"),
    (2, 16, 3, 8, 3, 2, "SAME"),
    (4, 32, 3, 16, 3, 2, "SAME"),
    (1, 10, 5, 7, 5, 1, "VALID"),
    (2, 9, 2, 3, 1, 1, "SAME"),
]


@pytest.mark.parametrize("b,side,cin,cout,k,stride,padding", CONV_CASES)
def test_conv2d_matches_ref(b, side, cin, cout, k, stride, padding):
    rng = np.random.default_rng(b + side + cout)
    x = rand(rng, b, side, side, cin)
    w = rand(rng, k, k, cin, cout, scale=0.3)
    bias = rand(rng, cout, scale=0.1)
    got = conv2d(x, w, bias, stride=stride, padding=padding, activation="relu")
    want = ref.conv2d_ref(x, w, bias, stride=stride, padding=padding, activation="relu")
    np.testing.assert_allclose(got, want, rtol=3e-4, atol=3e-4)


# -- GAT ---------------------------------------------------------------------


def random_graph_tensors(rng, n, live, f, h):
    x = rand(rng, n, f)
    adj = np.zeros((n, n), dtype=np.float32)
    np.fill_diagonal(adj, 1.0)
    for _ in range(3 * live):
        a, b = rng.integers(0, live, 2)
        adj[a, b] = adj[b, a] = 1.0
    w = rand(rng, f, h, scale=0.3)
    bias = rand(rng, h, scale=0.1)
    a_src = rand(rng, h, scale=0.3)
    a_dst = rand(rng, h, scale=0.3)
    return x, jnp.array(adj), w, bias, a_src, a_dst


@pytest.mark.parametrize("live", [1, 5, 32, 64])
def test_gat_matches_ref(live):
    rng = np.random.default_rng(live)
    x, adj, w, b, asrc, adst = random_graph_tensors(rng, 64, live, 27, 32)
    got = gat_layer(x, adj, w, b, asrc, adst)
    want = ref.gat_layer_ref(x, adj, w, b, asrc, adst)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)


def test_gat_no_nan_with_isolated_nodes():
    rng = np.random.default_rng(9)
    x, adj, w, b, asrc, adst = random_graph_tensors(rng, 16, 2, 8, 4)
    out = gat_layer(x, adj, w, b, asrc, adst)
    assert not np.any(np.isnan(np.asarray(out)))


def test_gat_attention_is_convex_combination():
    # Identical node features ⇒ identical outputs regardless of topology.
    rng = np.random.default_rng(10)
    _, adj, w, b, asrc, adst = random_graph_tensors(rng, 8, 8, 6, 4)
    x = jnp.tile(rand(rng, 1, 6), (8, 1))
    out = np.asarray(gat_layer(x, adj, w, b, asrc, adst))
    np.testing.assert_allclose(out, np.tile(out[:1], (8, 1)), rtol=1e-5, atol=1e-6)
