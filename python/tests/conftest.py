import pathlib
import sys

# Run from python/ or repo root: make `compile` importable.
sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1]))
