import importlib.util
import pathlib
import sys

# Run from python/ or repo root: make `compile` importable.
sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1]))

# The whole suite exercises the JAX/Pallas build pipeline; without JAX the
# test modules cannot even import. Skip collection cleanly instead of
# erroring (the CI python job is non-blocking, but a tidy skip keeps local
# `pytest` usable on machines without JAX).
if importlib.util.find_spec("jax") is None:
    collect_ignore_glob = ["test_*.py"]
    print("JAX not installed - skipping the python/tests suite", file=sys.stderr)
