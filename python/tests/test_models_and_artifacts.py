"""L2 model shape checks and artifact-directory integrity (when built)."""

import json
import pathlib

import numpy as np
import jax.numpy as jnp
import pytest

from compile import model as m

ARTIFACTS = pathlib.Path(__file__).resolve().parents[2] / "artifacts"


@pytest.mark.parametrize("name", list(m.SERVABLE_MODELS))
@pytest.mark.parametrize("batch", [1, 4])
def test_servable_shapes(name, batch):
    input_dim, output_dim = m.SERVABLE_MODELS[name]
    params = m.init_params(name)
    rng = np.random.default_rng(5)
    x = jnp.array(rng.normal(size=(batch, input_dim)), dtype=jnp.float32)
    out = m.MODEL_FNS[name](params, x)
    assert out.shape == (batch, output_dim)
    assert not np.any(np.isnan(np.asarray(out)))


def test_servable_deterministic_params():
    a = m.init_params("cnn_s")
    b = m.init_params("cnn_s")
    np.testing.assert_array_equal(np.asarray(a["c1_w"]), np.asarray(b["c1_w"]))


@pytest.mark.skipif(not (ARTIFACTS / "manifest.json").exists(), reason="run `make artifacts` first")
class TestArtifacts:
    def test_manifest_lists_all_models(self):
        doc = json.loads((ARTIFACTS / "manifest.json").read_text())
        names = {e["name"] for e in doc["models"]}
        assert names == set(m.SERVABLE_MODELS)
        for e in doc["models"]:
            assert (ARTIFACTS / e["path"]).exists(), e["path"]
        assert (ARTIFACTS / doc["rapp_hlo"]).exists()
        assert (ARTIFACTS / doc["rapp_weights"]).exists()

    def test_hlo_text_is_parsable_header(self):
        doc = json.loads((ARTIFACTS / "manifest.json").read_text())
        text = (ARTIFACTS / doc["models"][0]["path"]).read_text()
        assert text.startswith("HloModule")
        assert "ENTRY" in text

    def test_rapp_meta_shows_fig5_contrast(self):
        meta = json.loads((ARTIFACTS / "rapp_meta.json").read_text())
        assert meta["rapp"]["test_mape"] < 12.0
        assert meta["rapp"]["unseen_mape"] < 20.0
        assert meta["dippm"]["test_mape"] > 2.0 * meta["rapp"]["test_mape"]

    def test_golden_file_complete(self):
        g = json.loads((ARTIFACTS / "golden" / "perf_golden.json").read_text())
        assert len(g["configs"]) >= 5
        assert len(g["op_times"]) == len(g["graph"]["nodes"])
        assert len(g["graph_features"]) == 22
        assert g["rapp_preds"], "predictor parity pin missing"
