"""RaPP pipeline tests: feature extraction contract, anchor quality, the
Pallas-vs-ref forward parity, weight export round-trip, and a training smoke
run asserting RaPP ≪ DIPPM (the Fig. 5 contrast)."""

import json
import math

import numpy as np
import jax.numpy as jnp
import pytest

from compile import dataset as ds
from compile import features as feat
from compile.model import rapp_forward, rapp_init
from compile.opgraph import golden_graph
from compile.perfsim import PerfModel
from compile.train_rapp import (
    RESIDUAL_COL,
    export_weights,
    mape_latency,
    train_model,
)
from compile.aot import weights_to_params


@pytest.fixture(scope="module")
def perf():
    return PerfModel()


def test_feature_dims(perf):
    g = golden_graph()
    op, gf, edges = feat.extract(g, 4, 0.5, 0.6, perf, "rapp")
    assert op.shape == (len(g.nodes), feat.F_OP_FULL)
    assert gf.shape == (feat.F_G_FULL,)
    op_s, gf_s, _ = feat.extract(g, 4, 0.5, 0.6, perf, "dippm")
    assert op_s.shape == (len(g.nodes), feat.F_OP_STATIC)
    assert gf_s.shape == (feat.F_G_STATIC + feat.F_G_CLASS,)
    # The trailing class column defaults to the reference factor.
    assert gf[-1] == 1.0 and gf_s[-1] == 1.0
    assert len(edges) == len(g.edges)


def test_anchor_tracks_ground_truth(perf):
    """The probe-interpolated window-sim anchor must be a tight estimator
    (it is the reason RaPP reaches paper-grade MAPE)."""
    errs = []
    for g in ds.make_graphs(5, seed=5):
        for b, sm, q in [(1, 0.3, 0.5), (8, 0.15, 0.25), (32, 0.6, 0.9), (4, 1.0, 0.1)]:
            _, gf, _ = feat.extract(g, b, sm, q, perf, "rapp")
            truth = perf.latency(g, b, sm, q)
            est = math.exp(gf[RESIDUAL_COL]) / 1e3
            errs.append(abs(est - truth) / truth)
    assert np.mean(errs) < 0.10, f"anchor MAPE {np.mean(errs):.3f}"


def test_pad_for_hlo_contract(perf):
    g = golden_graph()
    op, _, edges = feat.extract(g, 4, 0.5, 0.6, perf, "rapp")
    x, adj, mask = feat.pad_for_hlo(op, edges, feat.F_OP_FULL)
    assert x.shape == (64, feat.F_OP_FULL)
    assert adj.shape == (64, 64) and mask.shape == (64,)
    assert mask.sum() == len(g.nodes)
    # Self-loops everywhere; symmetry.
    assert np.all(np.diag(adj) == 1.0)
    assert np.array_equal(adj, adj.T)


def test_rapp_forward_pallas_vs_ref_parity(perf):
    g = golden_graph()
    op, gf, edges = feat.extract(g, 4, 0.5, 0.6, perf, "rapp")
    x, adj, mask = feat.pad_for_hlo(op, edges, feat.F_OP_FULL)
    params = rapp_init(feat.F_OP_FULL, feat.F_G_FULL, 16, seed=3)
    # Give the zero-initialised head a nonzero value for a meaningful test.
    params["head2_w"] = jnp.ones((16, 1), jnp.float32) * 0.05
    a = rapp_forward(params, x, adj, mask, jnp.asarray(gf), use_pallas=True, residual_col=RESIDUAL_COL)
    b = rapp_forward(params, x, adj, mask, jnp.asarray(gf), use_pallas=False, residual_col=RESIDUAL_COL)
    assert abs(float(a) - float(b)) < 1e-4


def test_weights_export_roundtrip(tmp_path, perf):
    params = rapp_init(feat.F_OP_FULL, feat.F_G_FULL, 48, seed=9)
    path = tmp_path / "w.json"
    export_weights(params, "rapp", path)
    doc = json.loads(path.read_text())
    assert doc["arch"]["f_op"] == feat.F_OP_FULL
    assert doc["arch"]["residual_col"] == RESIDUAL_COL
    back = weights_to_params(doc)
    for k, v in params.items():
        np.testing.assert_allclose(np.asarray(v), back[k], rtol=1e-6, atol=1e-7)


def test_training_smoke_rapp_beats_dippm(perf):
    graphs = ds.make_graphs(12, seed=21)
    corpus = ds.build_corpus(graphs, 40, perf, seed=22)
    tr, va, te = ds.split_indices(len(corpus), seed=23)
    quiet = lambda *_args, **_kw: None
    rapp = train_model("rapp", corpus, tr, va, 3, 24, quiet)
    dippm = train_model("dippm", corpus, tr, va, 3, 24, quiet)
    m_rapp = mape_latency(rapp, corpus, te, "rapp")
    m_dippm = mape_latency(dippm, corpus, te, "dippm")
    assert m_rapp < 15.0, f"rapp {m_rapp}"
    assert m_rapp < m_dippm / 2.0, f"rapp {m_rapp} vs dippm {m_dippm}"


def test_corpus_determinism(perf):
    graphs = ds.make_graphs(3, seed=31)
    a = ds.build_corpus(graphs, 10, perf, seed=32)
    b = ds.build_corpus(graphs, 10, perf, seed=32)
    assert a.y == b.y
    np.testing.assert_array_equal(np.stack(a.gfeats), np.stack(b.gfeats))
