//! Workload synthesis and open-loop driving.
//!
//! The paper drives its evaluation with "practical application workloads from
//! Microsoft Azure Trace" (Zhang et al., SOSP'21) replayed by Grafana k6. The
//! trace itself is not redistributable at this scale, so [`TraceGen`]
//! synthesises series with the same published structure: a diurnal base, heavy
//! multiplicative noise, Poisson-arriving bursts with Pareto magnitudes, and
//! long low-utilisation valleys. Two presets reproduce the paper's
//! **standard** and **stress** workloads (Fig. 7).
//!
//! A [`Trace`] is a per-function vector of per-second request rates; the
//! driver thins each second into Poisson arrival timestamps (open-loop, like
//! k6's constant-arrival-rate executor).

use crate::cluster::FunctionSpec;
use crate::perf::PerfModel;
use crate::util::json::Json;
use crate::util::prng::Pcg64;
use std::collections::BTreeMap;

/// Workload shape preset. `Standard` and `Stress` reproduce the paper's
/// Fig. 7 workloads; `Diurnal` and `SpikyBurst` extend the scenario matrix
/// with the two Azure-trace regimes the paper presets average away — a
/// clean day/night cycle with rare bursts, and a flat base hammered by
/// frequent heavy-tailed spikes (the worst case for horizontal-only
/// scaling).
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Preset {
    Standard,
    Stress,
    Diurnal,
    SpikyBurst,
    /// Bursts separated by genuine silence on a zero base rate: every burst
    /// head hits a platform that has (or should have) scaled its residency
    /// down, so time-to-first-token is dominated by cold-load/swap latency —
    /// the pod-lifecycle comparison workload.
    ColdStartStorm,
    /// Camera-style steady traffic feeding the `pipeline-vision`
    /// detector→classifier workflow chain (the trace drives only the
    /// workflow's entry stage; downstream stages see hop arrivals).
    PipelineVision,
    /// Burstier mixed traffic feeding the `pipeline-mixed` branching DAG
    /// over mixed model sizes — the workflow co-scaling stress case.
    PipelineMixed,
    /// Sampled Azure-style trace population at grid scale: a few dozen
    /// functions with Zipf-skewed popularity sharing the aggregate rps
    /// budget, most of them idle most of the time. Driven by
    /// [`TraceSource`], not [`TraceGen`].
    TraceAzureSmall,
    /// The trace-scale cell: 100k sampled functions under a bounded
    /// aggregate rps — the workload the O(active) planner loop exists for.
    TraceAzureScale,
}

/// One row of [`PRESET_TABLE`]: the preset, its canonical CLI/export name,
/// and a one-line description for help text.
#[derive(Clone, Copy, Debug)]
pub struct PresetInfo {
    pub preset: Preset,
    pub name: &'static str,
    pub about: &'static str,
}

/// The canonical preset table, in matrix order. `Preset::name`,
/// `Preset::from_name`, [`ALL_PRESETS`], and every CLI help/error surface
/// derive from this single table, so a new preset cannot reach one surface
/// and miss another.
pub const PRESET_TABLE: [PresetInfo; 9] = [
    PresetInfo {
        preset: Preset::Standard,
        name: "standard",
        about: "paper Fig. 7 standard workload: diurnal base, moderate bursts",
    },
    PresetInfo {
        preset: Preset::Stress,
        name: "stress",
        about: "paper Fig. 7 stress workload: faster day, heavier bursts",
    },
    PresetInfo {
        preset: Preset::Diurnal,
        name: "diurnal",
        about: "one clean compressed day: deep valleys, rare bursts",
    },
    PresetInfo {
        preset: Preset::SpikyBurst,
        name: "spiky-burst",
        about: "near-flat base hammered by frequent heavy-tailed spikes",
    },
    PresetInfo {
        preset: Preset::ColdStartStorm,
        name: "cold-start-storm",
        about: "silent base with isolated bursts: TTFT is all cold-load/swap latency",
    },
    PresetInfo {
        preset: Preset::PipelineVision,
        name: "pipeline-vision",
        about: "steady camera traffic into the detector->classifier workflow chain",
    },
    PresetInfo {
        preset: Preset::PipelineMixed,
        name: "pipeline-mixed",
        about: "bursty traffic into the branching mixed-model workflow DAG",
    },
    PresetInfo {
        preset: Preset::TraceAzureSmall,
        name: "trace-azure-small",
        about: "sampled Azure-style population: Zipf popularity, mostly-idle functions",
    },
    PresetInfo {
        preset: Preset::TraceAzureScale,
        name: "trace-azure-scale",
        about: "trace at fleet scale: 100k sampled functions, bounded aggregate rps",
    },
];

/// Every preset, in the canonical matrix order (derived column of
/// [`PRESET_TABLE`]; `preset_table_is_the_single_source` pins agreement).
pub const ALL_PRESETS: [Preset; 9] = [
    Preset::Standard,
    Preset::Stress,
    Preset::Diurnal,
    Preset::SpikyBurst,
    Preset::ColdStartStorm,
    Preset::PipelineVision,
    Preset::PipelineMixed,
    Preset::TraceAzureSmall,
    Preset::TraceAzureScale,
];

impl Preset {
    pub fn name(self) -> &'static str {
        PRESET_TABLE
            .iter()
            .find(|i| i.preset == self)
            .map(|i| i.name)
            .expect("every Preset variant has a PRESET_TABLE row")
    }

    /// One-line description (CLI help and inventory tables).
    pub fn about(self) -> &'static str {
        PRESET_TABLE
            .iter()
            .find(|i| i.preset == self)
            .map(|i| i.about)
            .expect("every Preset variant has a PRESET_TABLE row")
    }

    /// Case-insensitive name lookup (CLI surfaces accept `STANDARD`,
    /// `Spiky-Burst`, …; the canonical lowercase form is what exports use).
    pub fn from_name(s: &str) -> Option<Self> {
        PRESET_TABLE
            .iter()
            .find(|i| i.name.eq_ignore_ascii_case(s.trim()))
            .map(|i| i.preset)
    }

    /// Whether this preset is driven by the sampled-population
    /// [`TraceSource`] backend instead of [`TraceGen`] over the fixed
    /// experiment zoo. Trace presets bring their own function population
    /// and run cold (`warm_start = false`) with a lazy idle sweep.
    pub fn is_trace(self) -> bool {
        matches!(self, Preset::TraceAzureSmall | Preset::TraceAzureScale)
    }

    /// The canonical comma-joined name list for CLI help and unknown-name
    /// errors — every surface quotes the same table.
    pub fn name_menu() -> String {
        PRESET_TABLE
            .iter()
            .map(|i| i.name)
            .collect::<Vec<_>>()
            .join(", ")
    }
}

/// Per-function request-rate series (1-second buckets).
#[derive(Clone, Debug, Default)]
pub struct Trace {
    /// function → RPS per second-bucket.
    pub series: BTreeMap<String, Vec<f64>>,
}

impl Trace {
    pub fn duration(&self) -> usize {
        self.series.values().map(|v| v.len()).max().unwrap_or(0)
    }

    pub fn rps_at(&self, function: &str, t: usize) -> f64 {
        self.series
            .get(function)
            .and_then(|v| v.get(t))
            .copied()
            .unwrap_or(0.0)
    }

    pub fn peak(&self, function: &str) -> f64 {
        self.series
            .get(function)
            .map(|v| v.iter().copied().fold(0.0, f64::max))
            .unwrap_or(0.0)
    }

    pub fn total_requests(&self, function: &str) -> f64 {
        self.series
            .get(function)
            .map(|v| v.iter().sum())
            .unwrap_or(0.0)
    }

    /// Poisson arrival timestamps inside bucket `t` for `function`.
    pub fn arrivals(&self, function: &str, t: usize, rng: &mut Pcg64) -> Vec<f64> {
        let rate = self.rps_at(function, t);
        if rate <= 0.0 {
            return Vec::new();
        }
        let n = rng.poisson(rate);
        let mut out: Vec<f64> = (0..n).map(|_| t as f64 + rng.next_f64()).collect();
        out.sort_unstable_by(|a, b| a.partial_cmp(b).unwrap());
        out
    }

    pub fn to_json(&self) -> Json {
        Json::Obj(
            self.series
                .iter()
                .map(|(k, v)| (k.clone(), Json::num_arr(v)))
                .collect(),
        )
    }

    pub fn from_json(j: &Json) -> anyhow::Result<Self> {
        let mut series = BTreeMap::new();
        for (k, v) in j.as_obj()? {
            series.insert(k.clone(), v.as_f64_vec()?);
        }
        Ok(Trace { series })
    }
}

/// Azure-style trace synthesiser.
#[derive(Clone, Debug)]
pub struct TraceGen {
    pub seed: u64,
    /// Trace length in seconds.
    pub duration: usize,
    /// Mean request rate around which the diurnal base oscillates.
    pub base_rps: f64,
    /// Compressed "day" period in seconds (experiments compress 24 h).
    pub day_period: f64,
    /// Burst events per second (Poisson).
    pub burst_rate: f64,
    /// Pareto shape for burst magnitude (smaller ⇒ heavier tail).
    pub burst_alpha: f64,
    /// Cap on burst magnitude (multiples of the base rate) — the Azure trace
    /// is heavy-tailed but bounded by upstream client limits.
    pub burst_cap: f64,
    /// Burst duration range in seconds.
    pub burst_len: (usize, usize),
    /// Multiplicative noise sigma (lognormal).
    pub noise_sigma: f64,
    /// Fraction of the day a function receives traffic at all (Azure
    /// functions are idle most of the time; scale-to-near-zero is where
    /// fine-grained keep-alive pays off).
    pub duty_cycle: f64,
}

impl TraceGen {
    pub fn preset(preset: Preset, seed: u64, duration: usize, base_rps: f64) -> Self {
        match preset {
            Preset::Standard => TraceGen {
                seed,
                duration,
                base_rps,
                day_period: duration as f64 / 2.0,
                burst_rate: 1.0 / 120.0,
                burst_alpha: 2.5,
                burst_cap: 5.0,
                burst_len: (10, 30),
                noise_sigma: 0.25,
                duty_cycle: 0.45,
            },
            Preset::Stress => TraceGen {
                seed,
                duration,
                base_rps,
                day_period: duration as f64 / 4.0,
                burst_rate: 1.0 / 40.0,
                burst_alpha: 1.6,
                burst_cap: 9.0,
                burst_len: (15, 50),
                noise_sigma: 0.45,
                duty_cycle: 0.7,
            },
            // One clean compressed day across the trace: deep valleys, long
            // active plateaus, almost no bursts — rewards vertical scaling
            // and keep-alive (scale-to-near-zero over the night half).
            Preset::Diurnal => TraceGen {
                seed,
                duration,
                base_rps,
                day_period: duration as f64,
                burst_rate: 1.0 / 300.0,
                burst_alpha: 3.0,
                burst_cap: 3.0,
                burst_len: (20, 40),
                noise_sigma: 0.15,
                duty_cycle: 0.6,
            },
            // Near-flat base with frequent, short, heavy-tailed spikes — the
            // regime where cold starts dominate horizontal-only platforms.
            Preset::SpikyBurst => TraceGen {
                seed,
                duration,
                base_rps,
                day_period: duration as f64 * 4.0,
                burst_rate: 1.0 / 25.0,
                burst_alpha: 1.3,
                burst_cap: 12.0,
                burst_len: (5, 15),
                noise_sigma: 0.35,
                duty_cycle: 0.9,
            },
            // Zero duty cycle kills the base entirely: traffic is *only*
            // bursts, separated by real silence (mean gap 30 s — longer
            // than any swap-tier idle window), so every burst head lands on
            // whatever residency the platform kept. Pure TTFT probe.
            Preset::ColdStartStorm => TraceGen {
                seed,
                duration,
                base_rps,
                day_period: duration as f64,
                burst_rate: 1.0 / 30.0,
                burst_alpha: 1.6,
                burst_cap: 8.0,
                burst_len: (5, 20),
                noise_sigma: 0.3,
                duty_cycle: 0.0,
            },
            // Pipeline entry-stage traffic: near-continuous camera feed with
            // mild bursts — the e2e tail comes from stage contention, not
            // from trace spikes.
            Preset::PipelineVision => TraceGen {
                seed,
                duration,
                base_rps,
                day_period: duration as f64 / 2.0,
                burst_rate: 1.0 / 150.0,
                burst_alpha: 2.8,
                burst_cap: 4.0,
                burst_len: (10, 25),
                noise_sigma: 0.2,
                duty_cycle: 0.8,
            },
            // Branching-DAG entry traffic: burstier and heavier-tailed, so
            // the fan-out stages amplify load imbalance and co-scaling (or
            // its absence) shows up in the e2e percentiles.
            Preset::PipelineMixed => TraceGen {
                seed,
                duration,
                base_rps,
                day_period: duration as f64 / 3.0,
                burst_rate: 1.0 / 60.0,
                burst_alpha: 1.8,
                burst_cap: 7.0,
                burst_len: (10, 30),
                noise_sigma: 0.35,
                duty_cycle: 0.65,
            },
            // The trace presets are normally driven by [`TraceSource`]
            // (sampled population); these TraceGen knobs exist so generic
            // surfaces that iterate ALL_PRESETS through TraceGen (the
            // trace-gen CLI, tests) still produce a sane Azure-flavoured
            // series: short duty windows, heavy tails.
            Preset::TraceAzureSmall => TraceGen {
                seed,
                duration,
                base_rps,
                day_period: duration as f64 / 2.0,
                burst_rate: 1.0 / 90.0,
                burst_alpha: 1.5,
                burst_cap: 8.0,
                burst_len: (5, 25),
                noise_sigma: 0.4,
                duty_cycle: 0.35,
            },
            Preset::TraceAzureScale => TraceGen {
                seed,
                duration,
                base_rps,
                day_period: duration as f64 / 2.0,
                burst_rate: 1.0 / 120.0,
                burst_alpha: 1.4,
                burst_cap: 10.0,
                burst_len: (5, 20),
                noise_sigma: 0.5,
                duty_cycle: 0.25,
            },
        }
    }

    /// Generate series for the named functions. Each function gets its own
    /// RNG stream (adding a function never perturbs the others) and its own
    /// per-function scale drawn from a Gamma (the Azure trace's heavy
    /// cross-function skew).
    pub fn generate(&self, functions: &[&str]) -> Trace {
        let mut trace = Trace::default();
        for (fi, f) in functions.iter().enumerate() {
            let mut rng = Pcg64::new(self.seed, 100 + fi as u64);
            let scale = rng.gamma(2.0, 0.5); // mean 1, heavy-ish
            let phase = rng.next_f64() * std::f64::consts::TAU;
            let mut series = vec![0.0f64; self.duration];
            // Diurnal base + noise.
            for (t, slot) in series.iter_mut().enumerate() {
                // Deep diurnal valleys: serverless functions are near-idle
                // through much of the day (Azure-trace structure).
                let day = (1.0
                    + 0.95
                        * (std::f64::consts::TAU * t as f64 / self.day_period + phase).sin())
                .max(0.0);
                let noise =
                    rng.lognormal(-self.noise_sigma * self.noise_sigma / 2.0, self.noise_sigma);
                // Duty cycling: traffic only while the day-phase is inside
                // the active window.
                let day_pos = (t as f64 / self.day_period + phase / std::f64::consts::TAU).fract();
                let active = day_pos < self.duty_cycle;
                *slot = if active {
                    (self.base_rps * scale * day * noise).max(0.0)
                } else {
                    0.0
                };
            }
            // Bursts.
            let mut t = 0usize;
            loop {
                let gap = rng.exponential(self.burst_rate);
                t += gap.ceil() as usize;
                if t >= self.duration {
                    break;
                }
                let magnitude = rng.pareto(2.0, self.burst_alpha).min(self.burst_cap);
                let len = self.burst_len.0
                    + rng.next_below((self.burst_len.1 - self.burst_len.0).max(1) as u64) as usize;
                for dt in 0..len.min(self.duration - t) {
                    // Ramp up over ~3 s, then decay linearly (client
                    // populations grow fast but not instantaneously).
                    let ramp = ((dt as f64 + 1.0) / 3.0).min(1.0);
                    let env = ramp * (1.0 - dt as f64 / len as f64);
                    series[t + dt] += self.base_rps * scale * magnitude * env;
                }
                t += len;
            }
            trace.series.insert(f.to_string(), series);
        }
        trace
    }
}

/// Sampled Azure-style trace population — the first-class trace workload
/// backend behind the `trace-azure-*` presets.
///
/// Where [`TraceGen`] synthesises one series per *named* function of the
/// fixed experiment zoo, `TraceSource` samples a whole **population**:
/// `functions` serverless functions whose mean rates follow a Zipf
/// popularity law (rank-`r` functions get `∝ 1/(r+1)^zipf_s` of the
/// aggregate `total_rps`), with RNG-shuffled rank assignment, per-function
/// diurnal phase, duty-cycled idle windows, and multiplicative noise.
///
/// Determinism contract: every function's series comes from its **own**
/// seeded RNG stream (`seed`, stream `FN_STREAM_BASE + i`), and the
/// popularity shuffle from its own dedicated stream — so the sampled trace
/// is identical regardless of sampling order, `--jobs` parallelism, or
/// which subset of functions a caller materialises.
#[derive(Clone, Debug)]
pub struct TraceSource {
    pub seed: u64,
    /// Trace length in seconds.
    pub duration: usize,
    /// Aggregate mean request rate across the whole population (rps) — the
    /// rps scaling knob: mean per-function rates are normalised to sum here.
    pub total_rps: f64,
    /// Population size — the function-count scaling knob.
    pub functions: usize,
    /// Zipf exponent for function popularity (larger ⇒ heavier head).
    pub zipf_s: f64,
    /// Compressed "day" period in seconds.
    pub day_period: f64,
    /// Multiplicative lognormal noise sigma.
    pub noise_sigma: f64,
    /// Fraction of the day each function receives traffic (Azure functions
    /// are idle most of the time — this is what the active-set planner and
    /// the lazy idle sweep exploit).
    pub duty_cycle: f64,
}

impl TraceSource {
    /// Per-function series streams live far above [`TraceGen`]'s
    /// `100 + fi` block so the two backends never collide on a seed.
    const FN_STREAM_BASE: u64 = 1_000_000;
    /// Stream for the popularity-rank shuffle.
    const RANK_STREAM: u64 = 999_983;

    /// The `TraceSource` behind a trace preset, or `None` for presets driven
    /// by [`TraceGen`]. `rps` is the aggregate population rps.
    pub fn for_preset(preset: Preset, seed: u64, duration: usize, rps: f64) -> Option<Self> {
        match preset {
            Preset::TraceAzureSmall => Some(TraceSource {
                seed,
                duration,
                total_rps: rps,
                functions: 48,
                zipf_s: 1.1,
                day_period: duration as f64 / 2.0,
                noise_sigma: 0.4,
                duty_cycle: 0.35,
            }),
            Preset::TraceAzureScale => Some(TraceSource {
                seed,
                duration,
                total_rps: rps,
                functions: 100_000,
                zipf_s: 1.2,
                day_period: duration as f64 / 2.0,
                noise_sigma: 0.5,
                duty_cycle: 0.25,
            }),
            _ => None,
        }
    }

    /// Canonical name of sampled function `i`.
    pub fn function_name(i: usize) -> String {
        format!("azfn-{i:06}")
    }

    /// Mean rps per function: Zipf weights over RNG-shuffled ranks,
    /// normalised so they sum to `total_rps`. Deterministic in `seed` alone.
    pub fn mean_rates(&self) -> Vec<f64> {
        let n = self.functions;
        let mut rank: Vec<u32> = (0..n as u32).collect();
        // Fisher–Yates off a dedicated stream: which function is popular is
        // random, but the popularity *distribution* is exactly Zipf.
        let mut rng = Pcg64::new(self.seed, Self::RANK_STREAM);
        for i in (1..n).rev() {
            let j = rng.next_below(i as u64 + 1) as usize;
            rank.swap(i, j);
        }
        let mut w: Vec<f64> = rank
            .iter()
            .map(|&r| 1.0 / (r as f64 + 1.0).powf(self.zipf_s))
            .collect();
        let sum: f64 = w.iter().sum();
        for x in &mut w {
            *x *= self.total_rps / sum;
        }
        w
    }

    /// Rate series for function `i` with mean `mean_rps`: diurnal base with
    /// a random phase, duty-cycled idle windows, lognormal noise. Each
    /// function draws from its own stream, so sampling order is irrelevant.
    pub fn series(&self, i: usize, mean_rps: f64) -> Vec<f64> {
        use std::f64::consts::TAU;
        let mut rng = Pcg64::new(self.seed, Self::FN_STREAM_BASE + i as u64);
        let phase = rng.next_f64() * TAU;
        let mut out = vec![0.0f64; self.duration];
        for (t, slot) in out.iter_mut().enumerate() {
            let day_pos = (t as f64 / self.day_period + phase / TAU).fract();
            if day_pos >= self.duty_cycle {
                continue; // idle window: no draw, rate stays 0
            }
            let day = (1.0 + 0.95 * (TAU * t as f64 / self.day_period + phase).sin()).max(0.0);
            let noise =
                rng.lognormal(-self.noise_sigma * self.noise_sigma / 2.0, self.noise_sigma);
            // Divide by the duty cycle so the mean over the whole day (idle
            // windows included) stays ≈ mean_rps.
            *slot = (mean_rps / self.duty_cycle * day * noise).max(0.0);
        }
        out
    }

    /// The small cycle of model shapes the population serves. Azure-style
    /// functions are tiny models; using a handful of **shared** graphs (same
    /// name ⇒ same predictor cache entry) keeps a 100k-function cell's
    /// specs at hundreds of bytes each and its RaPP caches O(shapes), not
    /// O(functions). Returns `(graph, slo, batch)` per shape.
    fn shape_table(perf: &PerfModel) -> Vec<(crate::model::OpGraph, f64, u32)> {
        use crate::model::builders::GraphBuilder;
        use crate::model::OpKind;
        let mut shapes = Vec::new();
        for (name, hidden) in [
            ("azshape-mlp-s", 256u32),
            ("azshape-mlp-m", 512u32),
            ("azshape-mlp-l", 1024u32),
        ] {
            let mut b = GraphBuilder::new(name, "azure-fn");
            let a = b.dense(&[], hidden, hidden);
            let r = b.elemwise(&[a], OpKind::Relu, hidden as f64, 0.0);
            b.dense(&[r], hidden, 64);
            let graph = b.build();
            let baseline = perf.latency(&graph, 1, 1.0, 1.0);
            // Same SLO discipline as the experiment zoo: a few multiples of
            // the unit-GPU baseline. Small batch — these are light models.
            shapes.push((graph, baseline * 4.0, 4u32));
        }
        shapes
    }

    /// Materialise the sampled population: one [`FunctionSpec`] per function
    /// (cycling the shared shape table) plus the dense [`Trace`].
    pub fn sample(&self, perf: &PerfModel) -> (Vec<FunctionSpec>, Trace) {
        let shapes = Self::shape_table(perf);
        let means = self.mean_rates();
        let mut fns = Vec::with_capacity(self.functions);
        let mut trace = Trace::default();
        for (i, &mean) in means.iter().enumerate() {
            let (graph, slo, batch) = &shapes[i % shapes.len()];
            let name = Self::function_name(i);
            trace.series.insert(name.clone(), self.series(i, mean));
            fns.push(FunctionSpec {
                name,
                graph: graph.clone(),
                slo: *slo,
                batch: *batch,
                artifact: None,
            });
        }
        (fns, trace)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn gen(preset: Preset) -> Trace {
        TraceGen::preset(preset, 7, 600, 20.0).generate(&["resnet50", "bert_tiny"])
    }

    #[test]
    fn deterministic_for_seed() {
        let a = gen(Preset::Standard);
        let b = gen(Preset::Standard);
        assert_eq!(a.series["resnet50"], b.series["resnet50"]);
    }

    #[test]
    fn functions_are_independent_streams() {
        let solo = TraceGen::preset(Preset::Standard, 7, 600, 20.0).generate(&["resnet50"]);
        let duo = gen(Preset::Standard);
        assert_eq!(solo.series["resnet50"], duo.series["resnet50"]);
    }

    #[test]
    fn rates_positive_and_fluctuating() {
        let t = gen(Preset::Standard);
        let s = &t.series["resnet50"];
        assert_eq!(s.len(), 600);
        assert!(s.iter().all(|&x| x >= 0.0));
        let mean = s.iter().sum::<f64>() / s.len() as f64;
        let max = s.iter().copied().fold(0.0, f64::max);
        assert!(mean > 1.0, "mean {mean}");
        // Bursty: peak well above mean.
        assert!(max > 2.0 * mean, "max {max} mean {mean}");
    }

    #[test]
    fn stress_is_heavier_than_standard() {
        // Average peak-to-mean over several seeds (single seeds are noisy).
        let mut std_ratio = 0.0;
        let mut stress_ratio = 0.0;
        for seed in 0..8 {
            for (preset, acc) in [
                (Preset::Standard, &mut std_ratio),
                (Preset::Stress, &mut stress_ratio),
            ] {
                let t = TraceGen::preset(preset, seed, 600, 20.0).generate(&["f"]);
                // Burstiness over ACTIVE seconds (duty cycling idles both
                // presets for different fractions of the day).
                let s: Vec<f64> = t.series["f"].iter().copied().filter(|&x| x > 0.0).collect();
                let mean = s.iter().sum::<f64>() / s.len() as f64;
                *acc += t.peak("f") / mean;
            }
        }
        assert!(
            stress_ratio > std_ratio,
            "stress {stress_ratio} vs standard {std_ratio}"
        );
    }

    #[test]
    fn preset_names_roundtrip() {
        for p in ALL_PRESETS {
            assert_eq!(Preset::from_name(p.name()), Some(p));
        }
        assert_eq!(Preset::from_name("spiky-burst"), Some(Preset::SpikyBurst));
        assert_eq!(Preset::from_name("Spiky-Burst"), Some(Preset::SpikyBurst));
        assert_eq!(Preset::from_name(" STANDARD "), Some(Preset::Standard));
        assert_eq!(
            Preset::from_name("Cold-Start-Storm"),
            Some(Preset::ColdStartStorm)
        );
        assert_eq!(Preset::from_name("bogus"), None);
    }

    #[test]
    fn preset_table_is_the_single_source() {
        // ALL_PRESETS is a derived column of PRESET_TABLE: same order, no
        // duplicates, every row reachable through name()/about()/from_name.
        assert_eq!(PRESET_TABLE.len(), ALL_PRESETS.len());
        for (row, p) in PRESET_TABLE.iter().zip(ALL_PRESETS) {
            assert_eq!(row.preset, p);
            assert_eq!(p.name(), row.name);
            assert_eq!(p.about(), row.about);
            assert!(!row.about.is_empty());
            assert_eq!(row.name, row.name.to_ascii_lowercase(), "canonical names are lowercase");
        }
        let menu = Preset::name_menu();
        for row in PRESET_TABLE {
            assert!(menu.contains(row.name), "menu missing {}: {menu}", row.name);
            assert_eq!(
                PRESET_TABLE.iter().filter(|r| r.name == row.name).count(),
                1,
                "duplicate name {}",
                row.name
            );
        }
    }

    #[test]
    fn cold_start_storm_is_silence_punctuated_by_bursts() {
        for seed in 0..6 {
            let t = TraceGen::preset(Preset::ColdStartStorm, seed, 600, 20.0).generate(&["f"]);
            let s = &t.series["f"];
            let idle = s.iter().filter(|&&x| x == 0.0).count();
            // Mostly silent (no base traffic at all)…
            assert!(idle > 300, "seed {seed}: only {idle} silent seconds");
            // …but the bursts still carry real load.
            assert!(t.total_requests("f") > 100.0, "seed {seed} too quiet");
            // And the silence comes in runs long enough to outlast a
            // swap-tier idle window (10 s), so parking actually happens.
            let mut run = 0usize;
            let mut longest = 0usize;
            for &x in s {
                if x == 0.0 {
                    run += 1;
                    longest = longest.max(run);
                } else {
                    run = 0;
                }
            }
            assert!(longest > 10, "seed {seed}: longest gap {longest}s");
        }
    }

    #[test]
    fn every_preset_generates_traffic() {
        for p in ALL_PRESETS {
            let t = TraceGen::preset(p, 3, 600, 20.0).generate(&["f", "g"]);
            assert!(t.total_requests("f") > 100.0, "{p:?} too quiet");
            assert_eq!(t.duration(), 600);
            assert!(t.series["f"].iter().all(|&x| x >= 0.0));
        }
    }

    #[test]
    fn spiky_burst_is_burstier_than_diurnal() {
        // Peak-to-mean over active seconds, averaged across seeds.
        let ratio = |preset: Preset| {
            let mut acc = 0.0;
            for seed in 0..8 {
                let t = TraceGen::preset(preset, seed, 600, 20.0).generate(&["f"]);
                let s: Vec<f64> = t.series["f"].iter().copied().filter(|&x| x > 0.0).collect();
                let mean = s.iter().sum::<f64>() / s.len() as f64;
                acc += t.peak("f") / mean;
            }
            acc / 8.0
        };
        let spiky = ratio(Preset::SpikyBurst);
        let diurnal = ratio(Preset::Diurnal);
        assert!(spiky > diurnal, "spiky {spiky} vs diurnal {diurnal}");
    }

    #[test]
    fn diurnal_has_idle_valley() {
        // The night half of the compressed day must be (near-)silent.
        let t = TraceGen::preset(Preset::Diurnal, 5, 600, 20.0).generate(&["f"]);
        let idle = t.series["f"].iter().filter(|&&x| x == 0.0).count();
        assert!(idle > 120, "only {idle} idle seconds");
    }

    #[test]
    fn arrivals_match_rate() {
        let t = gen(Preset::Standard);
        let mut rng = Pcg64::seeded(3);
        let mut total = 0usize;
        for sec in 0..600 {
            let a = t.arrivals("resnet50", sec, &mut rng);
            // Sorted within the bucket and inside it.
            for w in a.windows(2) {
                assert!(w[0] <= w[1]);
            }
            for &ts in &a {
                assert!(ts >= sec as f64 && ts < (sec + 1) as f64);
            }
            total += a.len();
        }
        let expected = t.total_requests("resnet50");
        let rel = (total as f64 - expected).abs() / expected;
        assert!(rel < 0.1, "total {total} vs expected {expected}");
    }

    #[test]
    fn json_roundtrip() {
        let t = gen(Preset::Stress);
        let j = t.to_json();
        let back = Trace::from_json(&j).unwrap();
        assert_eq!(back.series.len(), t.series.len());
        let (a, b) = (&t.series["bert_tiny"], &back.series["bert_tiny"]);
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(b) {
            assert!((x - y).abs() < 1e-9);
        }
    }

    fn small_source(seed: u64) -> TraceSource {
        TraceSource::for_preset(Preset::TraceAzureSmall, seed, 300, 120.0).unwrap()
    }

    #[test]
    fn trace_source_is_deterministic_and_order_independent() {
        let perf = PerfModel::default();
        let src = small_source(9);
        let (fns_a, tr_a) = src.sample(&perf);
        let (fns_b, tr_b) = src.sample(&perf);
        assert_eq!(fns_a.len(), 48);
        for (a, b) in fns_a.iter().zip(&fns_b) {
            assert_eq!(a.name, b.name);
            assert_eq!(a.slo.to_bits(), b.slo.to_bits());
        }
        for f in &fns_a {
            let (x, y) = (&tr_a.series[&f.name], &tr_b.series[&f.name]);
            assert_eq!(x.len(), y.len());
            for (p, q) in x.iter().zip(y) {
                assert_eq!(p.to_bits(), q.to_bits());
            }
        }
        // Order independence: function i's series is a pure function of
        // (seed, i) — materialising it alone matches the full sample.
        let means = src.mean_rates();
        for i in [0usize, 7, 47] {
            let solo = src.series(i, means[i]);
            let full = &tr_a.series[&TraceSource::function_name(i)];
            assert_eq!(solo.len(), full.len());
            for (p, q) in solo.iter().zip(full) {
                assert_eq!(p.to_bits(), q.to_bits());
            }
        }
        // Different seed ⇒ different trace.
        let (_, tr_c) = small_source(10).sample(&perf);
        assert!(fns_a
            .iter()
            .any(|f| tr_a.series[&f.name] != tr_c.series[&f.name]));
    }

    #[test]
    fn trace_source_popularity_is_heavy_tailed() {
        let src = small_source(4);
        let mut w = src.mean_rates();
        assert_eq!(w.len(), 48);
        let sum: f64 = w.iter().sum();
        assert!((sum - src.total_rps).abs() < 1e-6, "sum {sum}");
        w.sort_by(|a, b| b.total_cmp(a));
        // Exact Zipf tail: rank-0 over median rank is (25)^s by construction.
        let expect = 25f64.powf(src.zipf_s);
        let got = w[0] / w[24];
        assert!((got - expect).abs() / expect < 1e-9, "got {got} want {expect}");
        // Head-heavy: top 10% of functions carry most of the aggregate rps.
        let head: f64 = w.iter().take(5).sum();
        assert!(head > 0.5 * sum, "head {head} of {sum}");
    }

    #[test]
    fn trace_source_functions_are_mostly_idle() {
        let perf = PerfModel::default();
        let (fns, trace) = small_source(2).sample(&perf);
        let mut idle_seconds = 0usize;
        let mut total_seconds = 0usize;
        let mut total = 0.0;
        for f in &fns {
            let s = &trace.series[&f.name];
            assert_eq!(s.len(), 300);
            idle_seconds += s.iter().filter(|&&x| x == 0.0).count();
            total_seconds += s.len();
            total += trace.total_requests(&f.name);
        }
        // Duty cycle 0.35 ⇒ well over half of all function-seconds silent.
        assert!(
            idle_seconds as f64 > 0.5 * total_seconds as f64,
            "only {idle_seconds}/{total_seconds} idle"
        );
        // …but the aggregate still lands near total_rps × duration.
        let expected = 120.0 * 300.0;
        assert!(
            total > 0.3 * expected && total < 3.0 * expected,
            "total {total} vs expected {expected}"
        );
    }

    #[test]
    fn trace_source_shapes_are_shared_and_tiny() {
        let perf = PerfModel::default();
        let (fns, _) = small_source(1).sample(&perf);
        let mut shape_names: Vec<&str> = fns.iter().map(|f| f.graph.name.as_str()).collect();
        shape_names.sort_unstable();
        shape_names.dedup();
        // A handful of shared shapes, not one graph per function — this is
        // what keeps 100k-function specs and predictor caches small.
        assert!(shape_names.len() <= 4, "shapes {shape_names:?}");
        for f in &fns {
            assert!(f.graph.nodes.len() <= 4, "{} too big", f.graph.name);
            assert!(f.slo > 0.0 && f.batch >= 1);
        }
    }

    #[test]
    fn trace_preset_surfaces_are_wired() {
        assert!(Preset::TraceAzureSmall.is_trace());
        assert!(Preset::TraceAzureScale.is_trace());
        assert!(!Preset::Standard.is_trace());
        assert_eq!(
            Preset::from_name("trace-azure-small"),
            Some(Preset::TraceAzureSmall)
        );
        assert!(TraceSource::for_preset(Preset::Standard, 1, 10, 1.0).is_none());
        let scale = TraceSource::for_preset(Preset::TraceAzureScale, 1, 10, 200.0).unwrap();
        assert_eq!(scale.functions, 100_000);
    }

    #[test]
    fn zero_rate_bucket_no_arrivals() {
        let mut t = Trace::default();
        t.series.insert("f".into(), vec![0.0, 5.0]);
        let mut rng = Pcg64::seeded(1);
        assert!(t.arrivals("f", 0, &mut rng).is_empty());
        assert!(t.arrivals("missing", 0, &mut rng).is_empty());
    }
}
