//! RaPP — the Resource-aware Performance Predictor (paper §3.2) — and the
//! DIPPM static-feature baseline it is evaluated against (Fig. 5).
//!
//! Two interchangeable forwards share one set of trained weights
//! (`artifacts/rapp_weights.json`, produced by `python/compile/train_rapp.py`):
//!
//! * [`RappPredictor`] — the native Rust forward in [`nn`], used on the
//!   autoscaler's decision path (allocation-light, ~µs per query, memoised);
//! * `runtime::PjrtRapp` — the AOT-compiled HLO forward executed through
//!   PJRT, proving the L1/L2/L3 pipeline; parity-tested against this one.
//!
//! [`LatencyPredictor`] is the interface the autoscaler programs against;
//! [`OraclePredictor`] wraps the ground-truth [`PerfModel`] directly (used by
//! tests and as the "perfectly profiled" upper bound in ablations).

pub mod cache;
pub mod dippm;
pub mod features;
pub mod nn;

pub use cache::{min_feasible_quota, CachedPredictor, CountingPredictor};

use crate::model::OpGraph;
use crate::perf::PerfModel;
use crate::util::json::Json;
use features::{FeatureMode, FeaturePlan};
use nn::{Dense, GatLayer, GatScratch, LaneScratch};
use std::cell::RefCell;
use std::collections::HashMap;
use std::sync::{Arc, Mutex};

/// One predictor query: which `graph`, at what `batch` size, on what GPU
/// slice (`sm` fraction, temporal `quota`), on which GPU-class clock
/// (`factor` = [`crate::vgpu::GpuClass::throughput`]; 1.0 = the reference
/// V100). This is the *entire* argument surface of [`LatencyPredictor`] —
/// one value type instead of the 5-arg tuple matrix the `_at` method family
/// used to thread through every impl.
///
/// `Copy` on purpose: queries are built on the stack in the plan hot loop
/// and derived with [`PredictQuery::with_quota`] / `with_factor` without
/// touching the graph reference.
#[derive(Clone, Copy, Debug)]
pub struct PredictQuery<'g> {
    pub graph: &'g OpGraph,
    pub batch: u32,
    pub sm: f64,
    pub quota: f64,
    pub factor: f64,
}

impl<'g> PredictQuery<'g> {
    /// A reference-class query (`factor == 1.0`).
    pub fn new(graph: &'g OpGraph, batch: u32, sm: f64, quota: f64) -> Self {
        PredictQuery {
            graph,
            batch,
            sm,
            quota,
            factor: 1.0,
        }
    }

    /// The same query at a different temporal quota.
    pub fn with_quota(self, quota: f64) -> Self {
        PredictQuery { quota, ..self }
    }

    /// The same query on a different GPU-class clock.
    pub fn with_factor(self, factor: f64) -> Self {
        PredictQuery { factor, ..self }
    }
}

/// Latency prediction interface used by the auto-scalers.
///
/// **Class contract (PR 5):** `factor == 1.0` must take the reference code
/// path verbatim — same bits as a query that never heard of GPU classes —
/// so uniform reference-class fleets stay byte-identical to the pre-catalog
/// pipeline by construction. Implementations own their class surface (the
/// oracle replays the token window on the class clock; RaPP feeds the
/// factor through its trailing class feature column); there is no shared
/// `1/factor` approximation any more.
pub trait LatencyPredictor: Send + Sync {
    /// Predicted end-to-end inference latency (seconds) of one batch.
    fn latency(&self, q: PredictQuery) -> f64;

    /// Throughput capability C = batch · quota / t_raw (items/s), where
    /// t_raw is the predicted latency at full quota (paper: C = Batch/Latency
    /// under saturated time-sharing). The factor clock rides in through
    /// `q.factor` — this default is the *only* place the capacity formula
    /// exists; no impl overrides it.
    fn capacity(&self, q: PredictQuery) -> f64 {
        let t_raw = self.latency(q.with_quota(1.0));
        q.batch as f64 * q.quota / t_raw
    }

    /// Latency at each quota in `quotas` (same graph/batch/sm/factor),
    /// written into `out`; `q.quota` is ignored — row *i* is
    /// `q.with_quota(quotas[i])`. Implementations with a row-batched
    /// forward override this to evaluate a whole lattice level in one
    /// lane-parallel pass; the default loops [`LatencyPredictor::latency`].
    /// Every element must equal the scalar query bit-for-bit — callers (the
    /// autoscaler's candidate sweeps) rely on batched and scalar paths
    /// being interchangeable.
    fn latency_batch(&self, q: PredictQuery, quotas: &[f64], out: &mut Vec<f64>) {
        out.clear();
        out.extend(quotas.iter().map(|&quota| self.latency(q.with_quota(quota))));
    }
}

/// Ground-truth oracle (the perf model itself).
#[derive(Default)]
pub struct OraclePredictor {
    pub perf: PerfModel,
}

impl LatencyPredictor for OraclePredictor {
    /// The oracle knows the class surface exactly: token-window replay on
    /// the class clock. `factor == 1.0` is the reference path verbatim —
    /// [`PerfModel::latency`] *is* `latency_class(.., 1.0)` (the window
    /// replay exists once, in `latency_class`).
    fn latency(&self, q: PredictQuery) -> f64 {
        self.perf
            .latency_class(q.graph, q.batch, q.sm, q.quota, q.factor)
    }
}

/// Trained GAT + MLP weights (schema shared with train_rapp.py).
#[derive(Clone, Debug)]
pub struct RappWeights {
    pub mode: FeatureMode,
    pub hidden: usize,
    /// Residual anchor: raw graph-feature column added to the head output
    /// (ln1p of the full-SM, full-quota profiled latency). None for DIPPM.
    pub residual_col: Option<usize>,
    pub op_mean: Vec<f32>,
    pub op_std: Vec<f32>,
    pub g_mean: Vec<f32>,
    pub g_std: Vec<f32>,
    pub gat1: GatLayer,
    pub gat2: GatLayer,
    pub mlp_g: Dense,
    pub head1: Dense,
    pub head2: Dense,
}

fn dense_from_json(j: &Json, n_in: usize, n_out: usize) -> anyhow::Result<Dense> {
    let w = j.get("w")?.as_f32_vec()?;
    let b = j.get("b")?.as_f32_vec()?;
    anyhow::ensure!(
        w.len() == n_in * n_out && b.len() == n_out,
        "dense shape mismatch: w={} b={} expect [{n_in}x{n_out}]",
        w.len(),
        b.len()
    );
    Ok(Dense { n_in, n_out, w, b })
}

fn gat_from_json(j: &Json, n_in: usize, n_out: usize) -> anyhow::Result<GatLayer> {
    Ok(GatLayer {
        lin: dense_from_json(j, n_in, n_out)?,
        a_src: j.get("a_src")?.as_f32_vec()?,
        a_dst: j.get("a_dst")?.as_f32_vec()?,
    })
}

impl RappWeights {
    /// Load weights JSON (see train_rapp.py for the writer).
    pub fn from_json(j: &Json) -> anyhow::Result<Self> {
        let arch = j.get("arch")?;
        let mode = match arch.get("mode")?.as_str()? {
            "rapp" => FeatureMode::Full,
            "dippm" => FeatureMode::StaticOnly,
            other => anyhow::bail!("unknown feature mode '{other}'"),
        };
        let hidden = arch.get("hidden")?.as_usize()?;
        let f_op = arch.get("f_op")?.as_usize()?;
        let f_g = arch.get("f_g")?.as_usize()?;
        let residual_col = match arch.opt("residual_col").map(|v| v.as_f64()) {
            Some(Ok(c)) if c >= 0.0 => Some(c as usize),
            _ => None,
        };
        anyhow::ensure!(
            f_op == mode.f_op() && f_g == mode.f_g(),
            "feature dims in weights ({f_op},{f_g}) disagree with contract ({},{})",
            mode.f_op(),
            mode.f_g()
        );
        let norm = j.get("norm")?;
        Ok(RappWeights {
            mode,
            hidden,
            residual_col,
            op_mean: norm.get("op_mean")?.as_f32_vec()?,
            op_std: norm.get("op_std")?.as_f32_vec()?,
            g_mean: norm.get("g_mean")?.as_f32_vec()?,
            g_std: norm.get("g_std")?.as_f32_vec()?,
            gat1: gat_from_json(j.get("gat1")?, f_op, hidden)?,
            gat2: gat_from_json(j.get("gat2")?, hidden, hidden)?,
            mlp_g: dense_from_json(j.get("mlp_g")?, f_g, hidden)?,
            head1: dense_from_json(j.get("head1")?, 2 * hidden, hidden)?,
            head2: dense_from_json(j.get("head2")?, hidden, 1)?,
        })
    }

    pub fn load(path: &std::path::Path) -> anyhow::Result<Self> {
        Self::from_json(&crate::util::json::parse_file(path)?)
    }

    /// Random weights for tests/benches (deterministic; NOT trained).
    pub fn random(mode: FeatureMode, hidden: usize, seed: u64) -> Self {
        let mut rng = crate::util::prng::Pcg64::new(seed, 9);
        fn dense(rng: &mut crate::util::prng::Pcg64, n_in: usize, n_out: usize) -> Dense {
            Dense {
                n_in,
                n_out,
                w: (0..n_in * n_out)
                    .map(|_| rng.normal_ms(0.0, (2.0 / n_in as f64).sqrt()) as f32)
                    .collect(),
                b: vec![0.0; n_out],
            }
        }
        fn gat(rng: &mut crate::util::prng::Pcg64, n_in: usize, n_out: usize) -> GatLayer {
            GatLayer {
                lin: dense(rng, n_in, n_out),
                a_src: (0..n_out).map(|_| rng.normal_ms(0.0, 0.3) as f32).collect(),
                a_dst: (0..n_out).map(|_| rng.normal_ms(0.0, 0.3) as f32).collect(),
            }
        }
        let gat1 = gat(&mut rng, mode.f_op(), hidden);
        let gat2 = gat(&mut rng, hidden, hidden);
        RappWeights {
            mode,
            hidden,
            residual_col: None,
            op_mean: vec![0.0; mode.f_op()],
            op_std: vec![1.0; mode.f_op()],
            g_mean: vec![0.0; mode.f_g()],
            g_std: vec![1.0; mode.f_g()],
            gat1,
            gat2,
            mlp_g: dense(&mut rng, mode.f_g(), hidden),
            head1: dense(&mut rng, 2 * hidden, hidden),
            head2: dense(&mut rng, hidden, 1),
        }
    }
}

/// One cached (graph, batch) plan: the raw feature plan plus the pooled GAT
/// embedding — everything upstream of the (sm, quota) columns. With the plan
/// warm, a cache-miss forward is a graph-feature fill + two small dense
/// layers instead of a full re-extraction and two GAT passes.
struct PlanEntry {
    plan: FeaturePlan,
    /// masked-mean of GAT-2 node embeddings over the standardised op
    /// features, length `hidden` — (sm, quota)-independent.
    pooled: Vec<f32>,
}

/// Reusable forward buffers. One arena lives in each planner thread (see
/// [`SCRATCH`]); nothing is shared, so nothing is locked.
#[derive(Default)]
struct ForwardScratch {
    /// Standardised op features / GAT activations (plan build only).
    x: Vec<f32>,
    h1: Vec<f32>,
    h2: Vec<f32>,
    gat: GatScratch,
    /// Per-query buffers.
    gfeats: Vec<f32>,
    gx: Vec<f32>,
    gh: Vec<f32>,
    cat: Vec<f32>,
    hh: Vec<f32>,
    /// Row-batched buffers (`[rows × …]`).
    gfeats_rows: Vec<f32>,
    gx_rows: Vec<f32>,
    gh_rows: Vec<f32>,
    cat_rows: Vec<f32>,
    hh_rows: Vec<f32>,
    out_rows: Vec<f32>,
    /// SoA transpose blocks for the lane kernel.
    lanes: LaneScratch,
}

thread_local! {
    /// Per-thread forward arena. The seed serialised every forward behind a
    /// `Mutex<ForwardScratch>` *per predictor*, so concurrent planners —
    /// the `expt` runner ticks one cell per pool thread — contended on a
    /// lock even though each cell owns its predictor. Each planner thread
    /// now owns an arena outright: plan ticks overlap across cells with
    /// zero lock contention. The buffers are pure scratch (fully
    /// re-initialised per forward), so which thread's arena services a
    /// query can never change a bit of the result.
    static SCRATCH: RefCell<ForwardScratch> = RefCell::new(ForwardScratch::default());
}

/// The native RaPP predictor with a per-(model,config) memo cache and a
/// per-(model,batch) [`FeaturePlan`] + pooled-embedding cache.
pub struct RappPredictor {
    pub weights: RappWeights,
    pub perf: PerfModel,
    /// Memo keyed on (graph, batch, sm‰, quota‰, class-factor‰).
    cache: Mutex<HashMap<(String, u32, u32, u32, u32), f64>>,
    /// Two-level (graph name → batch → entry) so the steady-state probe
    /// costs two hash lookups and **no allocation**; the name `String` is
    /// cloned only when a graph's first plan is inserted.
    #[allow(clippy::type_complexity)]
    plans: Mutex<HashMap<String, HashMap<u32, Arc<PlanEntry>>>>,
}

impl RappPredictor {
    pub fn new(weights: RappWeights, perf: PerfModel) -> Self {
        RappPredictor {
            weights,
            perf,
            cache: Mutex::new(HashMap::new()),
            plans: Mutex::new(HashMap::new()),
        }
    }

    /// Load from `artifacts/rapp_weights.json`.
    pub fn load(path: &std::path::Path, perf: PerfModel) -> anyhow::Result<Self> {
        Ok(Self::new(RappWeights::load(path)?, perf))
    }

    /// Drop every cached plan (benches use this to measure the plan-rebuild
    /// cost — the per-forward price the predictor paid before plans existed).
    pub fn reset_plan_cache(&self) {
        self.plans.lock().unwrap().clear();
    }

    /// Fetch or build the (graph, batch) plan + pooled embedding.
    fn plan_entry(&self, g: &OpGraph, batch: u32) -> Arc<PlanEntry> {
        if let Some(e) = self
            .plans
            .lock()
            .unwrap()
            .get(g.name.as_str())
            .and_then(|m| m.get(&batch))
        {
            return Arc::clone(e);
        }
        let w = &self.weights;
        let plan = FeaturePlan::new(g, batch, &self.perf, w.mode);
        let n = plan.n_nodes();
        let f_op = plan.f_op();
        let mut pooled = Vec::new();
        SCRATCH.with(|cell| {
            let st = &mut *cell.borrow_mut();
            // Standardise the raw op rows.
            st.x.clear();
            st.x.resize(n * f_op, 0.0);
            for i in 0..n {
                let row = plan.op_row(i);
                for (k, &v) in row.iter().enumerate() {
                    st.x[i * f_op + k] = (v - w.op_mean[k]) / w.op_std[k];
                }
            }
            w.gat1.forward_into(&st.x, n, &plan.adj, &mut st.gat, &mut st.h1);
            w.gat2.forward_into(&st.h1, n, &plan.adj, &mut st.gat, &mut st.h2);
            nn::mean_pool_into(&st.h2, n, w.hidden, &mut pooled);
        });
        let entry = Arc::new(PlanEntry { plan, pooled });
        self.plans
            .lock()
            .unwrap()
            .entry(g.name.clone())
            .or_default()
            .entry(batch)
            .or_insert_with(|| Arc::clone(&entry))
            .clone()
    }

    /// The query tail shared by scalar and batched forwards: standardise the
    /// filled graph features, run the graph MLP and the two head layers, add
    /// the residual anchor. `gfeats` is the raw per-query graph vector.
    #[inline]
    fn head_from_gfeats(
        w: &RappWeights,
        pooled: &[f32],
        gfeats: &[f32],
        gx: &mut Vec<f32>,
        gh: &mut Vec<f32>,
        cat: &mut Vec<f32>,
        hh: &mut Vec<f32>,
    ) -> f32 {
        gx.clear();
        gx.resize(w.mode.f_g(), 0.0);
        for (k, &v) in gfeats.iter().enumerate() {
            gx[k] = (v - w.g_mean[k]) / w.g_std[k];
        }
        gh.clear();
        gh.resize(w.hidden, 0.0);
        w.mlp_g.forward(gx, gh);
        for v in gh.iter_mut() {
            *v = nn::relu(*v);
        }
        cat.clear();
        cat.extend_from_slice(pooled);
        cat.extend_from_slice(gh);
        hh.clear();
        hh.resize(w.hidden, 0.0);
        w.head1.forward(cat, hh);
        for v in hh.iter_mut() {
            *v = nn::relu(*v);
        }
        let mut out = [0.0f32];
        w.head2.forward(hh, &mut out);
        if let Some(c) = w.residual_col {
            out[0] += gfeats[c]; // raw (unnormalised) anchor
        }
        out[0]
    }

    /// Raw forward pass at the reference class: returns predicted
    /// ln(latency_ms). Allocation-free once the (graph, batch) plan is warm.
    pub fn forward(&self, g: &OpGraph, batch: u32, sm: f64, quota: f64) -> f32 {
        self.forward_at(g, batch, sm, quota, 1.0)
    }

    /// Forward pass with the GPU-class throughput factor fed through the
    /// trailing class feature column (and the anchor replayed on the class
    /// clock). `factor = 1.0` is [`RappPredictor::forward`] bit-for-bit.
    pub fn forward_at(&self, g: &OpGraph, batch: u32, sm: f64, quota: f64, factor: f64) -> f32 {
        // The plan fetch happens before the arena borrow: a cold plan build
        // borrows the same thread-local arena internally.
        let entry = self.plan_entry(g, batch);
        let w = &self.weights;
        SCRATCH.with(|cell| {
            let st = &mut *cell.borrow_mut();
            entry.plan.fill_graph_feats_at(sm, quota, factor, &mut st.gfeats);
            Self::head_from_gfeats(
                w,
                &entry.pooled,
                &st.gfeats,
                &mut st.gx,
                &mut st.gh,
                &mut st.cat,
                &mut st.hh,
            )
        })
    }

    /// Row-batched forward over a quota sweep at fixed (graph, batch, sm),
    /// reference class: one matmul-shaped pass per layer over all rows.
    /// Each output is bit-identical to the scalar [`RappPredictor::forward`]
    /// at the same point ([`Dense::forward_rows`] preserves per-row
    /// accumulation order).
    pub fn forward_batch(
        &self,
        g: &OpGraph,
        batch: u32,
        sm: f64,
        quotas: &[f64],
        out: &mut Vec<f32>,
    ) {
        self.forward_batch_at(g, batch, sm, quotas, 1.0, out)
    }

    /// [`RappPredictor::forward_batch`] at a GPU-class throughput factor;
    /// row-for-row bit-identical to [`RappPredictor::forward_at`]. The
    /// dense passes run through the SIMD lane kernel
    /// ([`Dense::forward_rows_lanes`]) — per-row bit-identity with the
    /// scalar path is preserved by construction, so the lanes change no
    /// bits, only the wall clock.
    pub fn forward_batch_at(
        &self,
        g: &OpGraph,
        batch: u32,
        sm: f64,
        quotas: &[f64],
        factor: f64,
        out: &mut Vec<f32>,
    ) {
        self.forward_batch_impl(g, batch, sm, quotas, factor, out, true);
    }

    /// The scalar-reference twin of [`RappPredictor::forward_batch_at`]:
    /// identical row assembly, dense passes through the plain
    /// [`Dense::forward_rows`] loop. This is the reference the lane kernel
    /// is bit-compared and benchmarked against (`rapp_forward_simd` vs
    /// `rapp_forward_scalar_ref` in `benches/scheduler_hotpath.rs`).
    pub fn forward_batch_scalar_ref(
        &self,
        g: &OpGraph,
        batch: u32,
        sm: f64,
        quotas: &[f64],
        factor: f64,
        out: &mut Vec<f32>,
    ) {
        self.forward_batch_impl(g, batch, sm, quotas, factor, out, false);
    }

    #[allow(clippy::too_many_arguments)]
    fn forward_batch_impl(
        &self,
        g: &OpGraph,
        batch: u32,
        sm: f64,
        quotas: &[f64],
        factor: f64,
        out: &mut Vec<f32>,
        lanes: bool,
    ) {
        let rows = quotas.len();
        out.clear();
        if rows == 0 {
            return;
        }
        // The plan fetch happens before the arena borrow: a cold plan build
        // borrows the same thread-local arena internally.
        let entry = self.plan_entry(g, batch);
        let w = &self.weights;
        let (f_g, h) = (w.mode.f_g(), w.hidden);
        SCRATCH.with(|cell| {
            let st = &mut *cell.borrow_mut();
            let mut dense_rows = |d: &Dense, x: &[f32], y: &mut [f32], ls: &mut LaneScratch| {
                if lanes {
                    d.forward_rows_lanes(x, rows, y, ls);
                } else {
                    d.forward_rows(x, rows, y);
                }
            };
            // Assemble the raw + standardised graph-feature matrices [rows × f_g].
            st.gfeats_rows.clear();
            st.gx_rows.clear();
            for &q in quotas {
                entry.plan.fill_graph_feats_at(sm, q, factor, &mut st.gfeats);
                st.gfeats_rows.extend_from_slice(&st.gfeats);
                for (k, &v) in st.gfeats.iter().enumerate() {
                    st.gx_rows.push((v - w.g_mean[k]) / w.g_std[k]);
                }
            }
            // Graph MLP over all rows, ReLU.
            st.gh_rows.clear();
            st.gh_rows.resize(rows * h, 0.0);
            dense_rows(&w.mlp_g, &st.gx_rows, &mut st.gh_rows, &mut st.lanes);
            for v in st.gh_rows.iter_mut() {
                *v = nn::relu(*v);
            }
            // Concat [pooled | gh] per row, then the two heads.
            st.cat_rows.clear();
            for r in 0..rows {
                st.cat_rows.extend_from_slice(&entry.pooled);
                st.cat_rows.extend_from_slice(&st.gh_rows[r * h..(r + 1) * h]);
            }
            st.hh_rows.clear();
            st.hh_rows.resize(rows * h, 0.0);
            dense_rows(&w.head1, &st.cat_rows, &mut st.hh_rows, &mut st.lanes);
            for v in st.hh_rows.iter_mut() {
                *v = nn::relu(*v);
            }
            st.out_rows.clear();
            st.out_rows.resize(rows, 0.0);
            dense_rows(&w.head2, &st.hh_rows, &mut st.out_rows, &mut st.lanes);
            for (r, &o) in st.out_rows.iter().enumerate() {
                let mut v = o;
                if let Some(c) = w.residual_col {
                    v += st.gfeats_rows[r * f_g + c];
                }
                out.push(v);
            }
        });
    }

    fn cache_key(q: &PredictQuery) -> (String, u32, u32, u32, u32) {
        (
            q.graph.name.clone(),
            q.batch,
            (q.sm * 1000.0).round() as u32,
            (q.quota * 1000.0).round() as u32,
            (q.factor * 1000.0).round() as u32,
        )
    }

    /// ln(latency_ms) → seconds with the anti-wedge exponent guard.
    #[inline]
    fn ln_ms_to_secs(ln_ms: f64) -> f64 {
        // Guard the exponent: an untrained/corrupt model must not produce
        // Inf/NaN latencies that would wedge the autoscaler.
        ln_ms.clamp(-10.0, 15.0).exp() / 1e3
    }
}

impl LatencyPredictor for RappPredictor {
    /// Class-aware scalar query: the factor flows through the class feature
    /// column (not a post-hoc `1/factor` scale), memoised per lattice point.
    /// `factor == 1.0` is the reference query — same key, same forward.
    fn latency(&self, q: PredictQuery) -> f64 {
        let key = Self::cache_key(&q);
        if let Some(&v) = self.cache.lock().unwrap().get(&key) {
            return v;
        }
        let secs =
            Self::ln_ms_to_secs(self.forward_at(q.graph, q.batch, q.sm, q.quota, q.factor) as f64);
        self.cache.lock().unwrap().insert(key, secs);
        secs
    }

    /// Whole-sweep latency: memo hits are served from the cache; the missing
    /// rows run through one [`RappPredictor::forward_batch_at`] pass. Values
    /// are bit-identical to the equivalent scalar-query sequence: the memo
    /// keys on the per-mille lattice while forwards run at the raw quota
    /// (the scalar contract), so quotas aliasing to one lattice cell within
    /// a sweep are deduped — the first occurrence computes, later aliases
    /// reuse its value, exactly as back-to-back `latency` calls would.
    fn latency_batch(&self, q: PredictQuery, quotas: &[f64], out: &mut Vec<f64>) {
        out.clear();
        out.resize(quotas.len(), f64::NAN);
        let mut miss_keys: Vec<(String, u32, u32, u32, u32)> = Vec::new();
        let mut miss_idx: Vec<usize> = Vec::new();
        let mut miss_q: Vec<f64> = Vec::new();
        // (out position, miss slot) for quotas aliasing an earlier miss.
        let mut aliases: Vec<(usize, usize)> = Vec::new();
        {
            let cache = self.cache.lock().unwrap();
            for (i, &quota) in quotas.iter().enumerate() {
                let key = Self::cache_key(&q.with_quota(quota));
                if let Some(&v) = cache.get(&key) {
                    out[i] = v;
                } else if let Some(slot) = miss_keys.iter().position(|k| *k == key) {
                    aliases.push((i, slot));
                } else {
                    miss_keys.push(key);
                    miss_idx.push(i);
                    miss_q.push(quota);
                }
            }
        }
        if miss_idx.is_empty() {
            return;
        }
        let mut fresh = Vec::new();
        self.forward_batch_at(q.graph, q.batch, q.sm, &miss_q, q.factor, &mut fresh);
        let mut secs_by_slot = Vec::with_capacity(fresh.len());
        {
            let mut cache = self.cache.lock().unwrap();
            for ((&i, key), &ln_ms) in miss_idx.iter().zip(miss_keys).zip(&fresh) {
                let secs = Self::ln_ms_to_secs(ln_ms as f64);
                cache.insert(key, secs);
                out[i] = secs;
                secs_by_slot.push(secs);
            }
        }
        for (i, slot) in aliases {
            out[i] = secs_by_slot[slot];
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::zoo::{zoo_graph, ZooModel};

    /// Shorthand for a reference-class query in these tests.
    fn q(g: &OpGraph, batch: u32, sm: f64, quota: f64) -> PredictQuery<'_> {
        PredictQuery::new(g, batch, sm, quota)
    }

    #[test]
    fn oracle_matches_perf_model() {
        let o = OraclePredictor::default();
        let g = zoo_graph(ZooModel::ResNet50);
        let l = o.latency(q(&g, 8, 0.5, 0.5));
        assert!((l - PerfModel::default().latency(&g, 8, 0.5, 0.5)).abs() < 1e-15);
        let c = o.capacity(q(&g, 8, 0.5, 0.5));
        assert!((c - PerfModel::default().capacity(&g, 8, 0.5, 0.5)).abs() < 1e-12);
    }

    #[test]
    fn random_weights_forward_is_finite_and_deterministic() {
        let p = RappPredictor::new(
            RappWeights::random(FeatureMode::Full, 32, 5),
            PerfModel::default(),
        );
        let g = zoo_graph(ZooModel::ConvNextTiny);
        let a = p.latency(q(&g, 8, 0.5, 0.5));
        let b = p.latency(q(&g, 8, 0.5, 0.5)); // cached path
        assert!(a.is_finite() && a > 0.0);
        assert_eq!(a, b);
        let p2 = RappPredictor::new(
            RappWeights::random(FeatureMode::Full, 32, 5),
            PerfModel::default(),
        );
        assert_eq!(p2.latency(q(&g, 8, 0.5, 0.5)), a);
    }

    #[test]
    fn weights_json_roundtrip() {
        // Serialise random weights to JSON the way train_rapp.py does, then load.
        let w = RappWeights::random(FeatureMode::Full, 8, 3);
        let to_dense = |d: &Dense| {
            Json::obj(vec![
                ("w", Json::num_arr(&d.w.iter().map(|&x| x as f64).collect::<Vec<_>>())),
                ("b", Json::num_arr(&d.b.iter().map(|&x| x as f64).collect::<Vec<_>>())),
            ])
        };
        let to_gat = |g: &GatLayer| {
            let mut obj = to_dense(&g.lin);
            if let Json::Obj(fields) = &mut obj {
                fields.push((
                    "a_src".into(),
                    Json::num_arr(&g.a_src.iter().map(|&x| x as f64).collect::<Vec<_>>()),
                ));
                fields.push((
                    "a_dst".into(),
                    Json::num_arr(&g.a_dst.iter().map(|&x| x as f64).collect::<Vec<_>>()),
                ));
            }
            obj
        };
        let j = Json::obj(vec![
            (
                "arch",
                Json::obj(vec![
                    ("mode", Json::Str("rapp".into())),
                    ("hidden", Json::Num(8.0)),
                    ("f_op", Json::Num(w.mode.f_op() as f64)),
                    ("f_g", Json::Num(w.mode.f_g() as f64)),
                ]),
            ),
            (
                "norm",
                Json::obj(vec![
                    ("op_mean", Json::num_arr(&vec![0.0; w.mode.f_op()])),
                    ("op_std", Json::num_arr(&vec![1.0; w.mode.f_op()])),
                    ("g_mean", Json::num_arr(&vec![0.0; w.mode.f_g()])),
                    ("g_std", Json::num_arr(&vec![1.0; w.mode.f_g()])),
                ]),
            ),
            ("gat1", to_gat(&w.gat1)),
            ("gat2", to_gat(&w.gat2)),
            ("mlp_g", to_dense(&w.mlp_g)),
            ("head1", to_dense(&w.head1)),
            ("head2", to_dense(&w.head2)),
        ]);
        let loaded = RappWeights::from_json(&j).unwrap();
        // Same weights ⇒ same predictions.
        let g = zoo_graph(ZooModel::BertTiny);
        let p1 = RappPredictor::new(w, PerfModel::default());
        let p2 = RappPredictor::new(loaded, PerfModel::default());
        assert!((p1.forward(&g, 4, 0.3, 0.7) - p2.forward(&g, 4, 0.3, 0.7)).abs() < 1e-7);
    }

    #[test]
    fn weights_dim_mismatch_rejected() {
        let j = crate::util::json::parse(
            r#"{"arch": {"mode": "rapp", "hidden": 8, "f_op": 5, "f_g": 15}}"#,
        )
        .unwrap();
        assert!(RappWeights::from_json(&j).is_err());
    }

    #[test]
    fn latency_guard_clamps_extremes() {
        // Random weights can emit large logits; latency must stay finite.
        for seed in 0..5 {
            let p = RappPredictor::new(
                RappWeights::random(FeatureMode::StaticOnly, 16, seed),
                PerfModel::default(),
            );
            let g = zoo_graph(ZooModel::Vgg16);
            let l = p.latency(q(&g, 32, 0.05, 0.05));
            assert!(l.is_finite() && l > 0.0);
        }
    }

    #[test]
    fn plan_cached_forward_bitwise_matches_cold_forward() {
        // A warm (graph, batch) plan must change nothing numerically: the
        // same query through a cold predictor and through one with a warm
        // plan yields identical bits.
        let g = zoo_graph(ZooModel::ResNet50);
        for mode in [FeatureMode::Full, FeatureMode::StaticOnly] {
            let p = RappPredictor::new(RappWeights::random(mode, 32, 7), PerfModel::default());
            let warmup = p.forward(&g, 8, 0.75, 0.25); // builds the plan
            let warm = p.forward(&g, 8, 0.3, 0.9);
            p.reset_plan_cache();
            let cold = p.forward(&g, 8, 0.3, 0.9);
            assert_eq!(warm.to_bits(), cold.to_bits(), "{mode:?}");
            assert_eq!(warmup.to_bits(), p.forward(&g, 8, 0.75, 0.25).to_bits());
        }
    }

    #[test]
    fn batched_forward_bitwise_matches_scalar() {
        let g = zoo_graph(ZooModel::BertTiny);
        let p = RappPredictor::new(
            RappWeights::random(FeatureMode::Full, 32, 11),
            PerfModel::default(),
        );
        let quotas: Vec<f64> = (1..=10).map(|i| i as f64 / 10.0).collect();
        let mut batched = Vec::new();
        p.forward_batch(&g, 4, 0.5, &quotas, &mut batched);
        assert_eq!(batched.len(), quotas.len());
        for (&q, &b) in quotas.iter().zip(&batched) {
            assert_eq!(p.forward(&g, 4, 0.5, q).to_bits(), b.to_bits(), "q={q}");
        }
        // Empty sweep is a no-op.
        p.forward_batch(&g, 4, 0.5, &[], &mut batched);
        assert!(batched.is_empty());
    }

    #[test]
    fn latency_batch_dedupes_lattice_aliases_like_scalar_sequence() {
        // 0.4 and 0.4004 share one per-mille memo cell: the batch must
        // behave exactly like back-to-back scalar calls — first occurrence
        // computes (at its raw quota), the alias reuses that value.
        let g = zoo_graph(ZooModel::ResNet50);
        let p = RappPredictor::new(
            RappWeights::random(FeatureMode::Full, 16, 9),
            PerfModel::default(),
        );
        let mut out = Vec::new();
        p.latency_batch(q(&g, 8, 0.5, 1.0), &[0.4, 0.4004], &mut out);
        assert_eq!(out[0], out[1], "alias must reuse the first occurrence");
        let fresh = RappPredictor::new(
            RappWeights::random(FeatureMode::Full, 16, 9),
            PerfModel::default(),
        );
        assert_eq!(out[0], fresh.latency(q(&g, 8, 0.5, 0.4)));
        assert_eq!(out[1], fresh.latency(q(&g, 8, 0.5, 0.4004)));
    }

    #[test]
    fn class_factor_queries_are_distinct_and_factor_one_is_identity() {
        let g = zoo_graph(ZooModel::ResNet50);
        let p = RappPredictor::new(
            RappWeights::random(FeatureMode::Full, 16, 21),
            PerfModel::default(),
        );
        let reference = p.latency(q(&g, 8, 0.5, 0.5));
        // factor 1.0 is the same memo cell and the same bits.
        assert_eq!(p.latency(q(&g, 8, 0.5, 0.5).with_factor(1.0)), reference);
        // A different class factor is a distinct, deterministic prediction.
        let fast = p.latency(q(&g, 8, 0.5, 0.5).with_factor(2.0));
        assert!(fast.is_finite() && fast > 0.0);
        let p2 = RappPredictor::new(
            RappWeights::random(FeatureMode::Full, 16, 21),
            PerfModel::default(),
        );
        assert_eq!(p2.latency(q(&g, 8, 0.5, 0.5).with_factor(2.0)), fast);
        // Batched class sweep is bit-identical to scalar class queries.
        let quotas = [0.2, 0.5, 0.9];
        let mut out = Vec::new();
        p.latency_batch(q(&g, 8, 0.5, 1.0).with_factor(2.0), &quotas, &mut out);
        for (&quota, &v) in quotas.iter().zip(&out) {
            assert_eq!(v, p.latency(q(&g, 8, 0.5, quota).with_factor(2.0)), "q={quota}");
        }
        // The oracle's class surface is window-exact and orders correctly.
        let o = OraclePredictor::default();
        assert_eq!(
            o.latency(q(&g, 8, 0.5, 0.5).with_factor(1.0)).to_bits(),
            o.latency(q(&g, 8, 0.5, 0.5)).to_bits()
        );
        assert!(o.latency(q(&g, 8, 0.5, 0.5).with_factor(2.0)) < o.latency(q(&g, 8, 0.5, 0.5)));
        assert!(o.capacity(q(&g, 8, 0.5, 0.5).with_factor(2.0)) > o.capacity(q(&g, 8, 0.5, 0.5)));
    }

    #[test]
    fn latency_batch_mixes_hits_and_misses_identically() {
        let g = zoo_graph(ZooModel::MobileNetV2);
        let p = RappPredictor::new(
            RappWeights::random(FeatureMode::Full, 16, 3),
            PerfModel::default(),
        );
        // Prime two points via the scalar path, then sweep across them.
        let a = p.latency(q(&g, 8, 0.5, 0.3));
        let b = p.latency(q(&g, 8, 0.5, 0.7));
        let quotas = [0.1, 0.3, 0.5, 0.7, 0.9];
        let mut out = Vec::new();
        p.latency_batch(q(&g, 8, 0.5, 1.0), &quotas, &mut out);
        assert_eq!(out.len(), 5);
        assert_eq!(out[1], a);
        assert_eq!(out[3], b);
        for (&quota, &v) in quotas.iter().zip(&out) {
            assert_eq!(v, p.latency(q(&g, 8, 0.5, quota)), "q={quota}");
            assert!(v.is_finite() && v > 0.0);
        }
    }

    #[test]
    fn simd_batched_forward_bitwise_matches_scalar_reference_pass() {
        // The lane-kernel batch and the scalar-reference batch are the same
        // numbers to the bit, at the reference class and on a class clock,
        // including sweep lengths that exercise the lane tail.
        let g = zoo_graph(ZooModel::ResNet50);
        let p = RappPredictor::new(
            RappWeights::random(FeatureMode::Full, 32, 13),
            PerfModel::default(),
        );
        for len in [1usize, 7, 8, 10, 19] {
            let quotas: Vec<f64> = (1..=len).map(|i| i as f64 / len as f64).collect();
            for factor in [1.0, 0.4] {
                let (mut simd, mut scalar) = (Vec::new(), Vec::new());
                p.forward_batch_at(&g, 8, 0.5, &quotas, factor, &mut simd);
                p.forward_batch_scalar_ref(&g, 8, 0.5, &quotas, factor, &mut scalar);
                assert_eq!(simd.len(), scalar.len());
                for (r, (a, b)) in simd.iter().zip(&scalar).enumerate() {
                    assert_eq!(
                        a.to_bits(),
                        b.to_bits(),
                        "len={len} factor={factor} row {r}"
                    );
                }
            }
        }
    }
}
