//! RaPP — the Resource-aware Performance Predictor (paper §3.2) — and the
//! DIPPM static-feature baseline it is evaluated against (Fig. 5).
//!
//! Two interchangeable forwards share one set of trained weights
//! (`artifacts/rapp_weights.json`, produced by `python/compile/train_rapp.py`):
//!
//! * [`RappPredictor`] — the native Rust forward in [`nn`], used on the
//!   autoscaler's decision path (allocation-light, ~µs per query, memoised);
//! * `runtime::PjrtRapp` — the AOT-compiled HLO forward executed through
//!   PJRT, proving the L1/L2/L3 pipeline; parity-tested against this one.
//!
//! [`LatencyPredictor`] is the interface the autoscaler programs against;
//! [`OraclePredictor`] wraps the ground-truth [`PerfModel`] directly (used by
//! tests and as the "perfectly profiled" upper bound in ablations).

pub mod cache;
pub mod dippm;
pub mod features;
pub mod nn;

pub use cache::{min_feasible_quota, CachedPredictor, CountingPredictor};

use crate::model::OpGraph;
use crate::perf::PerfModel;
use crate::util::json::Json;
use features::{extract, FeatureMode};
use nn::{Dense, GatLayer};
use std::collections::HashMap;
use std::sync::Mutex;

/// Latency prediction interface used by the auto-scalers.
pub trait LatencyPredictor: Send + Sync {
    /// Predicted end-to-end inference latency (seconds) of one batch.
    fn latency(&self, g: &OpGraph, batch: u32, sm: f64, quota: f64) -> f64;

    /// Throughput capability C = batch · quota / t_raw (items/s), where
    /// t_raw is the predicted latency at full quota (paper: C = Batch/Latency
    /// under saturated time-sharing).
    fn capacity(&self, g: &OpGraph, batch: u32, sm: f64, quota: f64) -> f64 {
        let t_raw = self.latency(g, batch, sm, 1.0);
        batch as f64 * quota / t_raw
    }
}

/// Ground-truth oracle (the perf model itself).
#[derive(Default)]
pub struct OraclePredictor {
    pub perf: PerfModel,
}

impl LatencyPredictor for OraclePredictor {
    fn latency(&self, g: &OpGraph, batch: u32, sm: f64, quota: f64) -> f64 {
        self.perf.latency(g, batch, sm, quota)
    }
}

/// Trained GAT + MLP weights (schema shared with train_rapp.py).
#[derive(Clone, Debug)]
pub struct RappWeights {
    pub mode: FeatureMode,
    pub hidden: usize,
    /// Residual anchor: raw graph-feature column added to the head output
    /// (ln1p of the full-SM, full-quota profiled latency). None for DIPPM.
    pub residual_col: Option<usize>,
    pub op_mean: Vec<f32>,
    pub op_std: Vec<f32>,
    pub g_mean: Vec<f32>,
    pub g_std: Vec<f32>,
    pub gat1: GatLayer,
    pub gat2: GatLayer,
    pub mlp_g: Dense,
    pub head1: Dense,
    pub head2: Dense,
}

fn dense_from_json(j: &Json, n_in: usize, n_out: usize) -> anyhow::Result<Dense> {
    let w = j.get("w")?.as_f32_vec()?;
    let b = j.get("b")?.as_f32_vec()?;
    anyhow::ensure!(
        w.len() == n_in * n_out && b.len() == n_out,
        "dense shape mismatch: w={} b={} expect [{n_in}x{n_out}]",
        w.len(),
        b.len()
    );
    Ok(Dense { n_in, n_out, w, b })
}

fn gat_from_json(j: &Json, n_in: usize, n_out: usize) -> anyhow::Result<GatLayer> {
    Ok(GatLayer {
        lin: dense_from_json(j, n_in, n_out)?,
        a_src: j.get("a_src")?.as_f32_vec()?,
        a_dst: j.get("a_dst")?.as_f32_vec()?,
    })
}

impl RappWeights {
    /// Load weights JSON (see train_rapp.py for the writer).
    pub fn from_json(j: &Json) -> anyhow::Result<Self> {
        let arch = j.get("arch")?;
        let mode = match arch.get("mode")?.as_str()? {
            "rapp" => FeatureMode::Full,
            "dippm" => FeatureMode::StaticOnly,
            other => anyhow::bail!("unknown feature mode '{other}'"),
        };
        let hidden = arch.get("hidden")?.as_usize()?;
        let f_op = arch.get("f_op")?.as_usize()?;
        let f_g = arch.get("f_g")?.as_usize()?;
        let residual_col = match arch.opt("residual_col").map(|v| v.as_f64()) {
            Some(Ok(c)) if c >= 0.0 => Some(c as usize),
            _ => None,
        };
        anyhow::ensure!(
            f_op == mode.f_op() && f_g == mode.f_g(),
            "feature dims in weights ({f_op},{f_g}) disagree with contract ({},{})",
            mode.f_op(),
            mode.f_g()
        );
        let norm = j.get("norm")?;
        Ok(RappWeights {
            mode,
            hidden,
            residual_col,
            op_mean: norm.get("op_mean")?.as_f32_vec()?,
            op_std: norm.get("op_std")?.as_f32_vec()?,
            g_mean: norm.get("g_mean")?.as_f32_vec()?,
            g_std: norm.get("g_std")?.as_f32_vec()?,
            gat1: gat_from_json(j.get("gat1")?, f_op, hidden)?,
            gat2: gat_from_json(j.get("gat2")?, hidden, hidden)?,
            mlp_g: dense_from_json(j.get("mlp_g")?, f_g, hidden)?,
            head1: dense_from_json(j.get("head1")?, 2 * hidden, hidden)?,
            head2: dense_from_json(j.get("head2")?, hidden, 1)?,
        })
    }

    pub fn load(path: &std::path::Path) -> anyhow::Result<Self> {
        Self::from_json(&crate::util::json::parse_file(path)?)
    }

    /// Random weights for tests/benches (deterministic; NOT trained).
    pub fn random(mode: FeatureMode, hidden: usize, seed: u64) -> Self {
        let mut rng = crate::util::prng::Pcg64::new(seed, 9);
        fn dense(rng: &mut crate::util::prng::Pcg64, n_in: usize, n_out: usize) -> Dense {
            Dense {
                n_in,
                n_out,
                w: (0..n_in * n_out)
                    .map(|_| rng.normal_ms(0.0, (2.0 / n_in as f64).sqrt()) as f32)
                    .collect(),
                b: vec![0.0; n_out],
            }
        }
        fn gat(rng: &mut crate::util::prng::Pcg64, n_in: usize, n_out: usize) -> GatLayer {
            GatLayer {
                lin: dense(rng, n_in, n_out),
                a_src: (0..n_out).map(|_| rng.normal_ms(0.0, 0.3) as f32).collect(),
                a_dst: (0..n_out).map(|_| rng.normal_ms(0.0, 0.3) as f32).collect(),
            }
        }
        let gat1 = gat(&mut rng, mode.f_op(), hidden);
        let gat2 = gat(&mut rng, hidden, hidden);
        RappWeights {
            mode,
            hidden,
            residual_col: None,
            op_mean: vec![0.0; mode.f_op()],
            op_std: vec![1.0; mode.f_op()],
            g_mean: vec![0.0; mode.f_g()],
            g_std: vec![1.0; mode.f_g()],
            gat1,
            gat2,
            mlp_g: dense(&mut rng, mode.f_g(), hidden),
            head1: dense(&mut rng, 2 * hidden, hidden),
            head2: dense(&mut rng, hidden, 1),
        }
    }
}

/// The native RaPP predictor with a per-(model,config) memo cache.
pub struct RappPredictor {
    pub weights: RappWeights,
    pub perf: PerfModel,
    cache: Mutex<HashMap<(String, u32, u32, u32), f64>>,
}

impl RappPredictor {
    pub fn new(weights: RappWeights, perf: PerfModel) -> Self {
        RappPredictor {
            weights,
            perf,
            cache: Mutex::new(HashMap::new()),
        }
    }

    /// Load from `artifacts/rapp_weights.json`.
    pub fn load(path: &std::path::Path, perf: PerfModel) -> anyhow::Result<Self> {
        Ok(Self::new(RappWeights::load(path)?, perf))
    }

    /// Raw forward pass: returns predicted ln(latency_ms).
    pub fn forward(&self, g: &OpGraph, batch: u32, sm: f64, quota: f64) -> f32 {
        let w = &self.weights;
        let f = extract(g, batch, sm, quota, &self.perf, w.mode);
        let n = f.op_feats.len();
        let f_op = w.mode.f_op();
        // Standardise + flatten.
        let mut x = vec![0.0f32; n * f_op];
        for (i, row) in f.op_feats.iter().enumerate() {
            for (k, &v) in row.iter().enumerate() {
                x[i * f_op + k] = (v - w.op_mean[k]) / w.op_std[k];
            }
        }
        let nbrs = nn::neighbour_lists(n, &f.edges);
        let h1 = w.gat1.forward(&x, n, &nbrs);
        let h2 = w.gat2.forward(&h1, n, &nbrs);
        let pooled = nn::mean_pool(&h2, n, w.hidden);

        let mut gx = vec![0.0f32; w.mode.f_g()];
        for (k, &v) in f.graph_feats.iter().enumerate() {
            gx[k] = (v - w.g_mean[k]) / w.g_std[k];
        }
        let mut gh = vec![0.0f32; w.hidden];
        w.mlp_g.forward(&gx, &mut gh);
        for v in gh.iter_mut() {
            *v = nn::relu(*v);
        }

        let mut cat = Vec::with_capacity(2 * w.hidden);
        cat.extend_from_slice(&pooled);
        cat.extend_from_slice(&gh);
        let mut hh = vec![0.0f32; w.hidden];
        w.head1.forward(&cat, &mut hh);
        for v in hh.iter_mut() {
            *v = nn::relu(*v);
        }
        let mut out = [0.0f32];
        w.head2.forward(&hh, &mut out);
        if let Some(c) = w.residual_col {
            out[0] += f.graph_feats[c]; // raw (unnormalised) anchor
        }
        out[0]
    }

    fn cache_key(g: &OpGraph, batch: u32, sm: f64, quota: f64) -> (String, u32, u32, u32) {
        (
            g.name.clone(),
            batch,
            (sm * 1000.0).round() as u32,
            (quota * 1000.0).round() as u32,
        )
    }
}

impl LatencyPredictor for RappPredictor {
    fn latency(&self, g: &OpGraph, batch: u32, sm: f64, quota: f64) -> f64 {
        let key = Self::cache_key(g, batch, sm, quota);
        if let Some(&v) = self.cache.lock().unwrap().get(&key) {
            return v;
        }
        let ln_ms = self.forward(g, batch, sm, quota) as f64;
        // Guard the exponent: an untrained/corrupt model must not produce
        // Inf/NaN latencies that would wedge the autoscaler.
        let ms = ln_ms.clamp(-10.0, 15.0).exp();
        let secs = ms / 1e3;
        self.cache.lock().unwrap().insert(key, secs);
        secs
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::zoo::{zoo_graph, ZooModel};

    #[test]
    fn oracle_matches_perf_model() {
        let o = OraclePredictor::default();
        let g = zoo_graph(ZooModel::ResNet50);
        let l = o.latency(&g, 8, 0.5, 0.5);
        assert!((l - PerfModel::default().latency(&g, 8, 0.5, 0.5)).abs() < 1e-15);
        let c = o.capacity(&g, 8, 0.5, 0.5);
        assert!((c - PerfModel::default().capacity(&g, 8, 0.5, 0.5)).abs() < 1e-12);
    }

    #[test]
    fn random_weights_forward_is_finite_and_deterministic() {
        let p = RappPredictor::new(
            RappWeights::random(FeatureMode::Full, 32, 5),
            PerfModel::default(),
        );
        let g = zoo_graph(ZooModel::ConvNextTiny);
        let a = p.latency(&g, 8, 0.5, 0.5);
        let b = p.latency(&g, 8, 0.5, 0.5); // cached path
        assert!(a.is_finite() && a > 0.0);
        assert_eq!(a, b);
        let p2 = RappPredictor::new(
            RappWeights::random(FeatureMode::Full, 32, 5),
            PerfModel::default(),
        );
        assert_eq!(p2.latency(&g, 8, 0.5, 0.5), a);
    }

    #[test]
    fn weights_json_roundtrip() {
        // Serialise random weights to JSON the way train_rapp.py does, then load.
        let w = RappWeights::random(FeatureMode::Full, 8, 3);
        let to_dense = |d: &Dense| {
            Json::obj(vec![
                ("w", Json::num_arr(&d.w.iter().map(|&x| x as f64).collect::<Vec<_>>())),
                ("b", Json::num_arr(&d.b.iter().map(|&x| x as f64).collect::<Vec<_>>())),
            ])
        };
        let to_gat = |g: &GatLayer| {
            let mut obj = to_dense(&g.lin);
            if let Json::Obj(fields) = &mut obj {
                fields.push((
                    "a_src".into(),
                    Json::num_arr(&g.a_src.iter().map(|&x| x as f64).collect::<Vec<_>>()),
                ));
                fields.push((
                    "a_dst".into(),
                    Json::num_arr(&g.a_dst.iter().map(|&x| x as f64).collect::<Vec<_>>()),
                ));
            }
            obj
        };
        let j = Json::obj(vec![
            (
                "arch",
                Json::obj(vec![
                    ("mode", Json::Str("rapp".into())),
                    ("hidden", Json::Num(8.0)),
                    ("f_op", Json::Num(w.mode.f_op() as f64)),
                    ("f_g", Json::Num(w.mode.f_g() as f64)),
                ]),
            ),
            (
                "norm",
                Json::obj(vec![
                    ("op_mean", Json::num_arr(&vec![0.0; w.mode.f_op()])),
                    ("op_std", Json::num_arr(&vec![1.0; w.mode.f_op()])),
                    ("g_mean", Json::num_arr(&vec![0.0; w.mode.f_g()])),
                    ("g_std", Json::num_arr(&vec![1.0; w.mode.f_g()])),
                ]),
            ),
            ("gat1", to_gat(&w.gat1)),
            ("gat2", to_gat(&w.gat2)),
            ("mlp_g", to_dense(&w.mlp_g)),
            ("head1", to_dense(&w.head1)),
            ("head2", to_dense(&w.head2)),
        ]);
        let loaded = RappWeights::from_json(&j).unwrap();
        // Same weights ⇒ same predictions.
        let g = zoo_graph(ZooModel::BertTiny);
        let p1 = RappPredictor::new(w, PerfModel::default());
        let p2 = RappPredictor::new(loaded, PerfModel::default());
        assert!((p1.forward(&g, 4, 0.3, 0.7) - p2.forward(&g, 4, 0.3, 0.7)).abs() < 1e-7);
    }

    #[test]
    fn weights_dim_mismatch_rejected() {
        let j = crate::util::json::parse(
            r#"{"arch": {"mode": "rapp", "hidden": 8, "f_op": 5, "f_g": 15}}"#,
        )
        .unwrap();
        assert!(RappWeights::from_json(&j).is_err());
    }

    #[test]
    fn latency_guard_clamps_extremes() {
        // Random weights can emit large logits; latency must stay finite.
        for seed in 0..5 {
            let p = RappPredictor::new(
                RappWeights::random(FeatureMode::StaticOnly, 16, seed),
                PerfModel::default(),
            );
            let g = zoo_graph(ZooModel::Vgg16);
            let l = p.latency(&g, 32, 0.05, 0.05);
            assert!(l.is_finite() && l > 0.0);
        }
    }
}
