//! DIPPM baseline (Panner Selvam & Brorsson 2023) — the comparator of
//! Fig. 5: a GNN latency predictor over **static** model features only.
//!
//! Per the paper's comparison protocol, the resource configuration (batch,
//! sm, quota) *is* given to DIPPM as extra static inputs and the model is
//! retrained — what it lacks is the operator/graph **runtime priors** (the
//! profiled latencies under the 6 SM / 5 quota probe points). The
//! architecture and training budget are identical to RaPP's, so Fig. 5
//! isolates exactly the contribution of runtime features.

use super::{LatencyPredictor, PredictQuery, RappPredictor, RappWeights};
use crate::perf::PerfModel;
use crate::rapp::features::FeatureMode;

/// DIPPM is RaPP's architecture restricted to `FeatureMode::StaticOnly`.
pub struct DippmPredictor(pub RappPredictor);

impl DippmPredictor {
    pub fn new(weights: RappWeights, perf: PerfModel) -> anyhow::Result<Self> {
        anyhow::ensure!(
            weights.mode == FeatureMode::StaticOnly,
            "DIPPM weights must be trained in static-only mode"
        );
        Ok(DippmPredictor(RappPredictor::new(weights, perf)))
    }

    pub fn load(path: &std::path::Path, perf: PerfModel) -> anyhow::Result<Self> {
        Self::new(RappWeights::load(path)?, perf)
    }
}

impl LatencyPredictor for DippmPredictor {
    /// Class queries flow through the underlying class feature column (the
    /// factor is part of DIPPM's static query configuration, like sm/quota).
    fn latency(&self, q: PredictQuery) -> f64 {
        self.0.latency(q)
    }

    fn latency_batch(&self, q: PredictQuery, quotas: &[f64], out: &mut Vec<f64>) {
        self.0.latency_batch(q, quotas, out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::zoo::{zoo_graph, ZooModel};

    #[test]
    fn rejects_full_mode_weights() {
        let w = RappWeights::random(FeatureMode::Full, 8, 1);
        assert!(DippmPredictor::new(w, PerfModel::default()).is_err());
    }

    #[test]
    fn static_only_forward_runs() {
        let w = RappWeights::random(FeatureMode::StaticOnly, 8, 1);
        let d = DippmPredictor::new(w, PerfModel::default()).unwrap();
        let g = zoo_graph(ZooModel::MobileNetV2);
        assert!(d.latency(PredictQuery::new(&g, 4, 0.5, 0.5)).is_finite());
    }
}
