//! RaPP feature extraction (paper §3.2, Fig. 3).
//!
//! Two feature sets per (model, batch, sm, quota) query:
//!
//! * **operator features** `[n_nodes × F_OP]` — one-hot op kind, static shape
//!   descriptors, and *runtime priors*: the op's profiled kernel time under
//!   [`PerfModel::PROFILE_SMS`] (6 SM configurations at full quota — quota
//!   does not affect individual operators, only the whole graph);
//! * **graph features** `[F_G]` — static totals (FLOPs, bytes, params, op
//!   counts, depth), *runtime priors*: whole-graph latency under
//!   [`PerfModel::PROFILE_QUOTAS`] (5 quota configurations at full SM), and
//!   the query configuration (batch, sm, quota).
//!
//! The numeric layout is a **cross-language contract** with
//! `python/compile/features.py`; `artifacts/golden/perf_golden.json` pins
//! both sides (see `tests/artifact_parity.rs`).
//!
//! The DIPPM baseline ([`FeatureMode::StaticOnly`]) strips every runtime-prior
//! column but keeps the query configuration appended to the static features —
//! the paper's "for comparison, we incorporated this information into its
//! static features same as RaPP and retrained the model".
//!
//! ## FeaturePlan: the cached split
//!
//! Of the whole feature tensor, only **four scalars depend on the query**:
//! the (sm, quota) configuration columns, the trailing GPU-class throughput
//! factor column (heterogeneous fleets; 1.0 = reference V100), and the
//! derived anchor. Everything else — op rows (including all 6 SM
//! runtime-prior probes), graph statics, and the 11 graph-level probe
//! evaluations — is a pure function of (graph, batch). [`FeaturePlan`]
//! computes that expensive part **once** and
//! [`FeaturePlan::fill_graph_feats_at`] produces any (sm, quota, class)
//! query with a memcpy plus the anchor replay: the predictor's cached-miss
//! cost drops from a full re-extraction (11 perf-model probes + GAT input
//! rebuild) to a dynamic fill. [`extract`] is the same computation packaged
//! per query, so plan-based and fresh extraction are bit-identical by
//! construction.

use crate::model::zoo::{zoo_adjacency, ZooModel};
use crate::model::{Adjacency, OpGraph, OpKind, NUM_OP_KINDS};
use crate::perf::PerfModel;
use std::sync::Arc;

/// Full RaPP features vs. the static-only DIPPM ablation.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FeatureMode {
    Full,
    StaticOnly,
}

/// Static operator columns (one-hot + shape descriptors + batch).
pub const F_OP_STATIC: usize = NUM_OP_KINDS + 9; // 21
/// Runtime-prior operator columns.
pub const F_OP_RUNTIME: usize = PerfModel::PROFILE_SMS.len(); // 6
/// Static graph columns (totals + counts + depth + batch + sm + quota).
pub const F_G_STATIC: usize = 10;
/// Runtime-prior graph columns: whole-graph latency at the 5 quota probes
/// (full SM) and raw graph time at the 6 SM probes (full quota) — the
/// paper's two graph-level profiling passes.
/// … plus one derived **anchor** column (separable analytic estimate —
/// see [`anchor`]).
pub const F_G_RUNTIME: usize =
    PerfModel::PROFILE_QUOTAS.len() + PerfModel::PROFILE_SMS.len() + 1; // 12
/// Trailing dynamic column: the GPU-class throughput factor of the query
/// (1.0 = the reference V100). Appended **last** in both modes so every
/// pre-catalog column keeps its historical index (and bits).
pub const F_G_CLASS: usize = 1;

/// Graph-feature column holding the query SM fraction.
pub const G_COL_SM: usize = 8;
/// Graph-feature column holding the query quota fraction.
pub const G_COL_QUOTA: usize = 9;
/// Graph-feature column holding the anchor (Full mode only).
pub const G_COL_ANCHOR: usize =
    F_G_STATIC + PerfModel::PROFILE_QUOTAS.len() + PerfModel::PROFILE_SMS.len(); // 21

impl FeatureMode {
    pub fn f_op(self) -> usize {
        match self {
            FeatureMode::Full => F_OP_STATIC + F_OP_RUNTIME,
            FeatureMode::StaticOnly => F_OP_STATIC,
        }
    }

    pub fn f_g(self) -> usize {
        match self {
            FeatureMode::Full => F_G_STATIC + F_G_RUNTIME + F_G_CLASS,
            FeatureMode::StaticOnly => F_G_STATIC + F_G_CLASS,
        }
    }

    /// Index of the class-factor column: always the last graph column.
    pub fn g_col_class(self) -> usize {
        self.f_g() - 1
    }

    pub fn name(self) -> &'static str {
        match self {
            FeatureMode::Full => "rapp",
            FeatureMode::StaticOnly => "dippm",
        }
    }
}

/// Extracted features for one query.
#[derive(Clone, Debug)]
pub struct Features {
    /// Row-major `[n_nodes][f_op]`.
    pub op_feats: Vec<Vec<f32>>,
    /// `[f_g]`.
    pub graph_feats: Vec<f32>,
    /// Directed edges (src, dst) — the GAT symmetrises + adds self-loops.
    pub edges: Vec<(usize, usize)>,
}

/// The cached, (sm, quota)-independent part of feature extraction for one
/// (graph, batch, mode): raw op rows, the static + probe graph columns, and
/// the GAT adjacency. Build once, then [`FeaturePlan::fill_graph_feats`] per
/// query.
#[derive(Clone, Debug)]
pub struct FeaturePlan {
    pub mode: FeatureMode,
    pub batch: u32,
    n_nodes: usize,
    f_op: usize,
    /// Raw (unstandardised) op features, row-major `[n_nodes × f_op]`.
    op_feats: Vec<f32>,
    /// Full-length graph-feature template; the dynamic columns
    /// ([`G_COL_SM`], [`G_COL_QUOTA`], [`G_COL_ANCHOR`]) hold placeholders.
    graph_template: Vec<f32>,
    /// Kernel-launch counts per node (drives the anchor's window replay).
    kernels: Vec<u32>,
    /// Directed edge list (kept for the [`Features`] contract / HLO path).
    pub edges: Vec<(usize, usize)>,
    /// Symmetrised in-neighbour CSR with self-loops. Zoo graphs share the
    /// per-model [`zoo_adjacency`] memo (adjacency depends only on the
    /// graph, so plans for different batches hold the same `Arc`); unknown
    /// graphs build their own.
    pub adj: Arc<Adjacency>,
    /// Token-window length for the anchor replay.
    window: f64,
}

impl FeaturePlan {
    pub fn new(g: &OpGraph, batch: u32, perf: &PerfModel, mode: FeatureMode) -> Self {
        let b = batch as f64;
        let f_op = mode.f_op();
        let mut op_feats = Vec::with_capacity(g.nodes.len() * f_op);
        for op in &g.nodes {
            // One-hot kind.
            for k in 0..NUM_OP_KINDS {
                op_feats.push(if op.kind.index() == k { 1.0 } else { 0.0 });
            }
            // Static shape descriptors (normalised to O(1) ranges).
            op_feats.push(ln1p(op.flops * b / 1e6) as f32);
            op_feats.push(ln1p((op.bytes * b + 4.0 * op.params) / 1e6) as f32);
            op_feats.push(ln1p(op.params / 1e6) as f32);
            op_feats.push(op.kernel as f32 / 7.0);
            op_feats.push(op.stride as f32 / 4.0);
            op_feats.push(op.cin as f32 / 1024.0);
            op_feats.push(op.cout as f32 / 1024.0);
            op_feats.push(op.spatial as f32 / 256.0);
            op_feats.push((b.log2() / 5.0) as f32);
            // Runtime priors: profiled op time at the 6 SM points, full quota.
            if mode == FeatureMode::Full {
                for &sm_p in PerfModel::PROFILE_SMS.iter() {
                    op_feats.push(ln1p(perf.op_time(op, batch, sm_p) * 1e3) as f32);
                }
            }
        }
        debug_assert_eq!(op_feats.len(), g.nodes.len() * f_op);

        let mut gf = Vec::with_capacity(mode.f_g());
        gf.push(ln1p(g.total_flops(batch) / 1e9) as f32);
        gf.push(ln1p(g.total_bytes(batch) / 1e9) as f32);
        gf.push(ln1p(g.total_params() / 1e6) as f32);
        gf.push(g.nodes.len() as f32 / 64.0);
        gf.push(g.count_kind(OpKind::Conv2d) as f32 / 32.0);
        gf.push(
            (g.count_kind(OpKind::Dense) + g.count_kind(OpKind::MatMul)) as f32 / 32.0,
        );
        gf.push(g.depth() as f32 / 64.0);
        gf.push((b.log2() / 5.0) as f32);
        gf.push(0.0); // G_COL_SM — dynamic
        gf.push(0.0); // G_COL_QUOTA — dynamic
        // Runtime priors: graph latency at the 5 quota points (full SM), then
        // raw graph time at the 6 SM points (full quota).
        if mode == FeatureMode::Full {
            for &q_p in PerfModel::PROFILE_QUOTAS.iter() {
                gf.push(ln1p(perf.latency(g, batch, 1.0, q_p) * 1e3) as f32);
            }
            for &sm_p in PerfModel::PROFILE_SMS.iter() {
                gf.push(ln1p(perf.raw_graph_time(g, batch, sm_p) * 1e3) as f32);
            }
            gf.push(0.0); // G_COL_ANCHOR — dynamic
        }
        gf.push(0.0); // class-factor column (g_col_class) — dynamic
        debug_assert_eq!(gf.len(), mode.f_g());

        FeaturePlan {
            mode,
            batch,
            n_nodes: g.nodes.len(),
            f_op,
            op_feats,
            graph_template: gf,
            kernels: g.nodes.iter().map(|n| n.kernels).collect(),
            edges: g.edges.clone(),
            // Graph names are identity across every cache layer (the
            // predictor memo and plan caches key on `g.name` already), so a
            // zoo-named graph shares the per-model adjacency memo. The
            // node-count filter downgrades a stale/foreign graph that merely
            // borrowed a zoo name from an out-of-bounds GAT walk to a
            // private (correct) build.
            adj: ZooModel::from_name(&g.name)
                .map(zoo_adjacency)
                .filter(|a| a.n() == g.nodes.len())
                .unwrap_or_else(|| Arc::new(g.adjacency())),
            window: perf.dev.window,
        }
    }

    pub fn n_nodes(&self) -> usize {
        self.n_nodes
    }

    pub fn f_op(&self) -> usize {
        self.f_op
    }

    pub fn f_g(&self) -> usize {
        self.graph_template.len()
    }

    /// Raw op-feature row of node `i`.
    pub fn op_row(&self, i: usize) -> &[f32] {
        &self.op_feats[i * self.f_op..(i + 1) * self.f_op]
    }

    /// The flat raw op-feature matrix `[n_nodes × f_op]`.
    pub fn op_feats(&self) -> &[f32] {
        &self.op_feats
    }

    /// Produce the full graph-feature vector for one reference-class
    /// (sm, quota) query — [`FeaturePlan::fill_graph_feats_at`] with class
    /// factor 1.0.
    pub fn fill_graph_feats(&self, sm: f64, quota: f64, out: &mut Vec<f32>) {
        self.fill_graph_feats_at(sm, quota, 1.0, out);
    }

    /// Produce the full graph-feature vector for one (sm, quota, class
    /// factor) query: template memcpy + the dynamic columns. Bit-identical
    /// to what a fresh [`extract`] computes at factor 1.0 (the anchor
    /// replay runs the same code over the same cached op rows; `/ 1.0` is
    /// exact).
    pub fn fill_graph_feats_at(&self, sm: f64, quota: f64, factor: f64, out: &mut Vec<f32>) {
        out.clear();
        out.extend_from_slice(&self.graph_template);
        out[G_COL_SM] = sm as f32;
        out[G_COL_QUOTA] = quota as f32;
        if self.mode == FeatureMode::Full {
            out[G_COL_ANCHOR] = anchor_flat(
                &self.kernels,
                &self.op_feats,
                self.f_op,
                sm,
                quota,
                self.window,
                factor,
            );
        }
        out[self.mode.g_col_class()] = factor as f32;
    }

    /// Materialise the per-query [`Features`] view (compat path for the HLO
    /// forward and the cross-language golden tests). Reference class.
    pub fn to_features(&self, sm: f64, quota: f64) -> Features {
        let mut gf = Vec::new();
        self.fill_graph_feats(sm, quota, &mut gf);
        Features {
            op_feats: (0..self.n_nodes).map(|i| self.op_row(i).to_vec()).collect(),
            graph_feats: gf,
            edges: self.edges.clone(),
        }
    }
}

/// Extract features for (graph, batch, sm, quota) — one-shot convenience
/// over [`FeaturePlan`]; repeated queries against the same (graph, batch)
/// should build the plan once instead.
pub fn extract(
    g: &OpGraph,
    batch: u32,
    sm: f64,
    quota: f64,
    perf: &PerfModel,
    mode: FeatureMode,
) -> Features {
    FeaturePlan::new(g, batch, perf, mode).to_features(sm, quota)
}

#[inline]
fn ln1p(x: f64) -> f64 {
    (1.0 + x).ln()
}

/// Piecewise-linear interpolation with end clamping (mirrors python).
fn interp(xs: &[f64], ys: &[f32], x: f64) -> f64 {
    if x <= xs[0] {
        return ys[0] as f64;
    }
    if x >= xs[xs.len() - 1] {
        return ys[ys.len() - 1] as f64;
    }
    for i in 0..xs.len() - 1 {
        if x <= xs[i + 1] {
            let t = (x - xs[i]) / (xs[i + 1] - xs[i]);
            return ys[i] as f64 * (1.0 - t) + ys[i + 1] as f64 * t;
        }
    }
    ys[ys.len() - 1] as f64
}

/// Probe-based analytic latency estimate: interpolate each op's profiled
/// time (the 6 SM probes, op-feature columns 21..27) to the query SM in
/// ln-ln space, scale kernels by the class throughput `factor` (the probes
/// are reference-class times; the window is a scheduler constant), then
/// replay the scheduler's own token-window mechanics (no-debt, kernel
/// granularity). The GNN head regresses the residual against this anchor.
/// Contract: python features.anchor. `factor = 1.0` reproduces the
/// pre-catalog anchor bit-for-bit (`/ 1.0` is exact).
///
/// `kernels[i]` is node `i`'s launch count; `op_feats` is the flat raw
/// `[n × f_op]` matrix. Allocation-free.
#[allow(clippy::too_many_arguments)]
pub fn anchor_flat(
    kernels: &[u32],
    op_feats: &[f32],
    f_op: usize,
    sm: f64,
    quota: f64,
    window: f64,
    factor: f64,
) -> f32 {
    let ln_sms: [f64; F_OP_RUNTIME] = PerfModel::PROFILE_SMS.map(|s| s.ln());
    let ln_sm = sm.clamp(1e-3, 1.0).ln();
    let mut now = 0.0f64;
    let mut budget = quota * window;
    let mut boundary = window;
    for (i, &n_kernels) in kernels.iter().enumerate() {
        let row = &op_feats[i * f_op + F_OP_STATIC..i * f_op + F_OP_STATIC + 6];
        let ln_t = interp(&ln_sms, row, ln_sm);
        let t_est = ln_t.exp_m1() / 1e3 / factor; // invert ln1p(ms), class clock
        let k = n_kernels.max(1);
        let d = t_est / k as f64;
        for _ in 0..k {
            if boundary <= now {
                let skipped = ((now - boundary) / window).floor() + 1.0;
                boundary += skipped * window;
                budget = quota * window;
            }
            if budget <= 0.0 {
                now = boundary;
                boundary += window;
                budget = quota * window;
            }
            now += d;
            budget -= d;
        }
    }
    // ln(ms), matching the regression target's transform exactly.
    (now * 1e3).max(1e-9).ln() as f32
}

/// [`anchor_flat`] over nested per-node rows (legacy signature; the rows must
/// be Full-mode op features). Reference class.
pub fn anchor(g: &OpGraph, op_feats: &[Vec<f32>], sm: f64, quota: f64, window: f64) -> f32 {
    let f_op = FeatureMode::Full.f_op();
    debug_assert!(op_feats.iter().all(|r| r.len() == f_op));
    let flat: Vec<f32> = op_feats.iter().flatten().copied().collect();
    let kernels: Vec<u32> = g.nodes.iter().map(|n| n.kernels).collect();
    anchor_flat(&kernels, &flat, f_op, sm, quota, window, 1.0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::zoo::{zoo_graph, ZooModel};

    #[test]
    fn dims_match_mode() {
        let g = zoo_graph(ZooModel::ResNet50);
        let pm = PerfModel::default();
        let full = extract(&g, 8, 0.5, 0.5, &pm, FeatureMode::Full);
        assert_eq!(full.op_feats[0].len(), 27);
        assert_eq!(full.graph_feats.len(), 23);
        let stat = extract(&g, 8, 0.5, 0.5, &pm, FeatureMode::StaticOnly);
        assert_eq!(stat.op_feats[0].len(), 21);
        assert_eq!(stat.graph_feats.len(), 11);
        assert_eq!(full.op_feats.len(), g.nodes.len());
        assert_eq!(full.edges.len(), g.edges.len());
        // The class-factor column is always last, in both modes.
        assert_eq!(FeatureMode::Full.g_col_class(), 22);
        assert_eq!(FeatureMode::StaticOnly.g_col_class(), 10);
        assert_eq!(*full.graph_feats.last().unwrap(), 1.0);
        assert_eq!(*stat.graph_feats.last().unwrap(), 1.0);
    }

    #[test]
    fn class_factor_column_is_dynamic_and_factor_one_is_bit_identical() {
        let g = zoo_graph(ZooModel::ResNet50);
        let pm = PerfModel::default();
        for mode in [FeatureMode::Full, FeatureMode::StaticOnly] {
            let plan = FeaturePlan::new(&g, 8, &pm, mode);
            let (mut ref_gf, mut at_gf, mut fast_gf) = (Vec::new(), Vec::new(), Vec::new());
            plan.fill_graph_feats(0.5, 0.6, &mut ref_gf);
            plan.fill_graph_feats_at(0.5, 0.6, 1.0, &mut at_gf);
            for (a, b) in ref_gf.iter().zip(&at_gf) {
                assert_eq!(a.to_bits(), b.to_bits(), "{mode:?}: factor 1.0 must be identity");
            }
            // A non-reference factor only moves the class column — and, in
            // Full mode, the anchor (the replayed kernels run on the class
            // clock); every template column stays put.
            plan.fill_graph_feats_at(0.5, 0.6, 2.0, &mut fast_gf);
            assert_eq!(fast_gf[mode.g_col_class()], 2.0);
            for (c, (a, b)) in ref_gf.iter().zip(&fast_gf).enumerate() {
                if c == mode.g_col_class() || (mode == FeatureMode::Full && c == G_COL_ANCHOR) {
                    continue;
                }
                assert_eq!(a.to_bits(), b.to_bits(), "{mode:?} col {c} must not move");
            }
            if mode == FeatureMode::Full {
                assert!(
                    fast_gf[G_COL_ANCHOR] < ref_gf[G_COL_ANCHOR],
                    "faster class ⇒ smaller ln-latency anchor"
                );
            }
        }
    }

    #[test]
    fn config_columns_present() {
        let g = zoo_graph(ZooModel::BertTiny);
        let pm = PerfModel::default();
        let f = extract(&g, 4, 0.35, 0.7, &pm, FeatureMode::Full);
        assert!((f.graph_feats[8] - 0.35).abs() < 1e-6);
        assert!((f.graph_feats[9] - 0.7).abs() < 1e-6);
        // Runtime priors are monotone: more quota ⇒ lower profiled latency.
        let rt = &f.graph_feats[10..15];
        let rt_sm = &f.graph_feats[15..21];
        for w in rt_sm.windows(2) {
            assert!(w[1] <= w[0] + 1e-6, "{rt_sm:?}");
        }
        for w in rt.windows(2) {
            assert!(w[1] <= w[0] + 1e-6, "{rt:?}");
        }
    }

    #[test]
    fn op_runtime_priors_decrease_with_sm_for_big_ops() {
        let g = zoo_graph(ZooModel::Vgg16);
        let pm = PerfModel::default();
        let f = extract(&g, 16, 1.0, 1.0, &pm, FeatureMode::Full);
        // The heaviest conv node: runtime-prior columns 21..27 decrease.
        let conv_row = f
            .op_feats
            .iter()
            .max_by(|a, b| a[12].partial_cmp(&b[12]).unwrap())
            .unwrap();
        let rt = &conv_row[21..27];
        for w in rt.windows(2) {
            assert!(w[1] <= w[0] + 1e-6, "{rt:?}");
        }
    }

    #[test]
    fn one_hot_is_exclusive() {
        let g = zoo_graph(ZooModel::ConvNextTiny);
        let pm = PerfModel::default();
        let f = extract(&g, 1, 1.0, 1.0, &pm, FeatureMode::Full);
        for row in &f.op_feats {
            let ones = row[..12].iter().filter(|&&x| x == 1.0).count();
            assert_eq!(ones, 1);
        }
    }

    #[test]
    fn features_depend_on_batch() {
        let g = zoo_graph(ZooModel::ResNet50);
        let pm = PerfModel::default();
        let f1 = extract(&g, 1, 0.5, 0.5, &pm, FeatureMode::Full);
        let f8 = extract(&g, 8, 0.5, 0.5, &pm, FeatureMode::Full);
        assert!(f8.graph_feats[0] > f1.graph_feats[0]);
        assert!(f8.op_feats[0][12] >= f1.op_feats[0][12]);
    }

    #[test]
    fn plan_fill_matches_fresh_extract_bitwise() {
        // The cached plan's dynamic fill must reproduce a fresh extraction
        // bit-for-bit at every probe-lattice point (the exhaustive all-model
        // sweep lives in tests/rapp_plan_parity.rs).
        let g = zoo_graph(ZooModel::ConvNextTiny);
        let pm = PerfModel::default();
        for mode in [FeatureMode::Full, FeatureMode::StaticOnly] {
            let plan = FeaturePlan::new(&g, 8, &pm, mode);
            let mut gf = Vec::new();
            for &(sm, quota) in &[(0.1, 0.2), (0.5, 0.5), (0.35, 0.9), (1.0, 1.0)] {
                let fresh = extract(&g, 8, sm, quota, &pm, mode);
                plan.fill_graph_feats(sm, quota, &mut gf);
                assert_eq!(gf.len(), fresh.graph_feats.len());
                for (a, b) in gf.iter().zip(&fresh.graph_feats) {
                    assert_eq!(a.to_bits(), b.to_bits(), "sm={sm} q={quota}");
                }
                for (i, row) in fresh.op_feats.iter().enumerate() {
                    assert_eq!(plan.op_row(i), row.as_slice());
                }
            }
        }
    }

    #[test]
    fn plans_share_adjacency_across_batches() {
        // Adjacency depends only on the graph: zoo-named plans for different
        // batches must hold the same memoised Arc, not per-batch CSR copies.
        let g = zoo_graph(ZooModel::ResNet50);
        let pm = PerfModel::default();
        let p1 = FeaturePlan::new(&g, 1, &pm, FeatureMode::Full);
        let p8 = FeaturePlan::new(&g, 8, &pm, FeatureMode::Full);
        assert!(Arc::ptr_eq(&p1.adj, &p8.adj));
        assert_eq!(*p1.adj, g.adjacency());
        // Non-zoo names fall back to a private build.
        let mut custom = g.clone();
        custom.name = "custom_net".into();
        let pc = FeaturePlan::new(&custom, 1, &pm, FeatureMode::Full);
        assert!(!Arc::ptr_eq(&p1.adj, &pc.adj));
        assert_eq!(*pc.adj, g.adjacency());
    }

    #[test]
    fn anchor_nested_and_flat_agree() {
        let g = zoo_graph(ZooModel::ResNet50);
        let pm = PerfModel::default();
        let f = extract(&g, 8, 0.4, 0.6, &pm, FeatureMode::Full);
        let nested = anchor(&g, &f.op_feats, 0.4, 0.6, pm.dev.window);
        assert_eq!(nested.to_bits(), f.graph_feats[G_COL_ANCHOR].to_bits());
    }
}
