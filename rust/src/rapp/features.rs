//! RaPP feature extraction (paper §3.2, Fig. 3).
//!
//! Two feature sets per (model, batch, sm, quota) query:
//!
//! * **operator features** `[n_nodes × F_OP]` — one-hot op kind, static shape
//!   descriptors, and *runtime priors*: the op's profiled kernel time under
//!   [`PerfModel::PROFILE_SMS`] (6 SM configurations at full quota — quota
//!   does not affect individual operators, only the whole graph);
//! * **graph features** `[F_G]` — static totals (FLOPs, bytes, params, op
//!   counts, depth), *runtime priors*: whole-graph latency under
//!   [`PerfModel::PROFILE_QUOTAS`] (5 quota configurations at full SM), and
//!   the query configuration (batch, sm, quota).
//!
//! The numeric layout is a **cross-language contract** with
//! `python/compile/features.py`; `artifacts/golden/perf_golden.json` pins
//! both sides (see `tests/artifact_parity.rs`).
//!
//! The DIPPM baseline ([`FeatureMode::StaticOnly`]) strips every runtime-prior
//! column but keeps the query configuration appended to the static features —
//! the paper's "for comparison, we incorporated this information into its
//! static features same as RaPP and retrained the model".

use crate::model::{OpGraph, OpKind, NUM_OP_KINDS};
use crate::perf::PerfModel;

/// Full RaPP features vs. the static-only DIPPM ablation.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FeatureMode {
    Full,
    StaticOnly,
}

/// Static operator columns (one-hot + shape descriptors + batch).
pub const F_OP_STATIC: usize = NUM_OP_KINDS + 9; // 21
/// Runtime-prior operator columns.
pub const F_OP_RUNTIME: usize = PerfModel::PROFILE_SMS.len(); // 6
/// Static graph columns (totals + counts + depth + batch + sm + quota).
pub const F_G_STATIC: usize = 10;
/// Runtime-prior graph columns: whole-graph latency at the 5 quota probes
/// (full SM) and raw graph time at the 6 SM probes (full quota) — the
/// paper's two graph-level profiling passes.
/// … plus one derived **anchor** column (separable analytic estimate —
/// see [`anchor`]).
pub const F_G_RUNTIME: usize =
    PerfModel::PROFILE_QUOTAS.len() + PerfModel::PROFILE_SMS.len() + 1; // 12

impl FeatureMode {
    pub fn f_op(self) -> usize {
        match self {
            FeatureMode::Full => F_OP_STATIC + F_OP_RUNTIME,
            FeatureMode::StaticOnly => F_OP_STATIC,
        }
    }

    pub fn f_g(self) -> usize {
        match self {
            FeatureMode::Full => F_G_STATIC + F_G_RUNTIME,
            FeatureMode::StaticOnly => F_G_STATIC,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            FeatureMode::Full => "rapp",
            FeatureMode::StaticOnly => "dippm",
        }
    }
}

/// Extracted features for one query.
#[derive(Clone, Debug)]
pub struct Features {
    /// Row-major `[n_nodes][f_op]`.
    pub op_feats: Vec<Vec<f32>>,
    /// `[f_g]`.
    pub graph_feats: Vec<f32>,
    /// Directed edges (src, dst) — the GAT symmetrises + adds self-loops.
    pub edges: Vec<(usize, usize)>,
}

/// Extract features for (graph, batch, sm, quota).
pub fn extract(
    g: &OpGraph,
    batch: u32,
    sm: f64,
    quota: f64,
    perf: &PerfModel,
    mode: FeatureMode,
) -> Features {
    let b = batch as f64;
    let mut op_feats = Vec::with_capacity(g.nodes.len());
    for op in &g.nodes {
        let mut f = Vec::with_capacity(mode.f_op());
        // One-hot kind.
        for k in 0..NUM_OP_KINDS {
            f.push(if op.kind.index() == k { 1.0 } else { 0.0 });
        }
        // Static shape descriptors (normalised to O(1) ranges).
        f.push(ln1p(op.flops * b / 1e6) as f32);
        f.push(ln1p((op.bytes * b + 4.0 * op.params) / 1e6) as f32);
        f.push(ln1p(op.params / 1e6) as f32);
        f.push(op.kernel as f32 / 7.0);
        f.push(op.stride as f32 / 4.0);
        f.push(op.cin as f32 / 1024.0);
        f.push(op.cout as f32 / 1024.0);
        f.push(op.spatial as f32 / 256.0);
        f.push((b.log2() / 5.0) as f32);
        // Runtime priors: profiled op time at the 6 SM points, full quota.
        if mode == FeatureMode::Full {
            for &sm_p in PerfModel::PROFILE_SMS.iter() {
                f.push(ln1p(perf.op_time(op, batch, sm_p) * 1e3) as f32);
            }
        }
        debug_assert_eq!(f.len(), mode.f_op());
        op_feats.push(f);
    }

    let mut gf = Vec::with_capacity(mode.f_g());
    gf.push(ln1p(g.total_flops(batch) / 1e9) as f32);
    gf.push(ln1p(g.total_bytes(batch) / 1e9) as f32);
    gf.push(ln1p(g.total_params() / 1e6) as f32);
    gf.push(g.nodes.len() as f32 / 64.0);
    gf.push(g.count_kind(OpKind::Conv2d) as f32 / 32.0);
    gf.push(
        (g.count_kind(OpKind::Dense) + g.count_kind(OpKind::MatMul)) as f32 / 32.0,
    );
    gf.push(g.depth() as f32 / 64.0);
    gf.push((b.log2() / 5.0) as f32);
    gf.push(sm as f32);
    gf.push(quota as f32);
    // Runtime priors: graph latency at the 5 quota points (full SM), then
    // raw graph time at the 6 SM points (full quota).
    if mode == FeatureMode::Full {
        for &q_p in PerfModel::PROFILE_QUOTAS.iter() {
            gf.push(ln1p(perf.latency(g, batch, 1.0, q_p) * 1e3) as f32);
        }
        for &sm_p in PerfModel::PROFILE_SMS.iter() {
            gf.push(ln1p(perf.raw_graph_time(g, batch, sm_p) * 1e3) as f32);
        }
        let a = anchor(g, &op_feats, sm, quota, perf.dev.window);
        gf.push(a);
    }
    debug_assert_eq!(gf.len(), mode.f_g());

    Features {
        op_feats,
        graph_feats: gf,
        edges: g.edges.clone(),
    }
}

#[inline]
fn ln1p(x: f64) -> f64 {
    (1.0 + x).ln()
}

/// Piecewise-linear interpolation with end clamping (mirrors python).
fn interp(xs: &[f64], ys: &[f32], x: f64) -> f64 {
    if x <= xs[0] {
        return ys[0] as f64;
    }
    if x >= xs[xs.len() - 1] {
        return ys[ys.len() - 1] as f64;
    }
    for i in 0..xs.len() - 1 {
        if x <= xs[i + 1] {
            let t = (x - xs[i]) / (xs[i + 1] - xs[i]);
            return ys[i] as f64 * (1.0 - t) + ys[i + 1] as f64 * t;
        }
    }
    ys[ys.len() - 1] as f64
}

/// Probe-based analytic latency estimate: interpolate each op's profiled
/// time (the 6 SM probes, op-feature columns 21..27) to the query SM in
/// ln-ln space, then replay the scheduler's own token-window mechanics
/// (no-debt, kernel granularity). The GNN head regresses the residual
/// against this anchor. Contract: python features.anchor.
pub fn anchor(g: &OpGraph, op_feats: &[Vec<f32>], sm: f64, quota: f64, window: f64) -> f32 {
    let ln_sms: Vec<f64> = PerfModel::PROFILE_SMS.iter().map(|s| s.ln()).collect();
    let ln_sm = sm.clamp(1e-3, 1.0).ln();
    let mut now = 0.0f64;
    let mut budget = quota * window;
    let mut boundary = window;
    for (i, node) in g.nodes.iter().enumerate() {
        let ln_t = interp(&ln_sms, &op_feats[i][F_OP_STATIC..F_OP_STATIC + 6], ln_sm);
        let t_est = ln_t.exp_m1() / 1e3; // invert ln1p(ms)
        let k = node.kernels.max(1);
        let d = t_est / k as f64;
        for _ in 0..k {
            if boundary <= now {
                let skipped = ((now - boundary) / window).floor() + 1.0;
                boundary += skipped * window;
                budget = quota * window;
            }
            if budget <= 0.0 {
                now = boundary;
                boundary += window;
                budget = quota * window;
            }
            now += d;
            budget -= d;
        }
    }
    // ln(ms), matching the regression target's transform exactly.
    (now * 1e3).max(1e-9).ln() as f32
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::zoo::{zoo_graph, ZooModel};

    #[test]
    fn dims_match_mode() {
        let g = zoo_graph(ZooModel::ResNet50);
        let pm = PerfModel::default();
        let full = extract(&g, 8, 0.5, 0.5, &pm, FeatureMode::Full);
        assert_eq!(full.op_feats[0].len(), 27);
        assert_eq!(full.graph_feats.len(), 22);
        let stat = extract(&g, 8, 0.5, 0.5, &pm, FeatureMode::StaticOnly);
        assert_eq!(stat.op_feats[0].len(), 21);
        assert_eq!(stat.graph_feats.len(), 10);
        assert_eq!(full.op_feats.len(), g.nodes.len());
        assert_eq!(full.edges.len(), g.edges.len());
    }

    #[test]
    fn config_columns_present() {
        let g = zoo_graph(ZooModel::BertTiny);
        let pm = PerfModel::default();
        let f = extract(&g, 4, 0.35, 0.7, &pm, FeatureMode::Full);
        assert!((f.graph_feats[8] - 0.35).abs() < 1e-6);
        assert!((f.graph_feats[9] - 0.7).abs() < 1e-6);
        // Runtime priors are monotone: more quota ⇒ lower profiled latency.
        let rt = &f.graph_feats[10..15];
        let rt_sm = &f.graph_feats[15..21];
        for w in rt_sm.windows(2) {
            assert!(w[1] <= w[0] + 1e-6, "{rt_sm:?}");
        }
        for w in rt.windows(2) {
            assert!(w[1] <= w[0] + 1e-6, "{rt:?}");
        }
    }

    #[test]
    fn op_runtime_priors_decrease_with_sm_for_big_ops() {
        let g = zoo_graph(ZooModel::Vgg16);
        let pm = PerfModel::default();
        let f = extract(&g, 16, 1.0, 1.0, &pm, FeatureMode::Full);
        // The heaviest conv node: runtime-prior columns 21..27 decrease.
        let conv_row = f
            .op_feats
            .iter()
            .max_by(|a, b| a[12].partial_cmp(&b[12]).unwrap())
            .unwrap();
        let rt = &conv_row[21..27];
        for w in rt.windows(2) {
            assert!(w[1] <= w[0] + 1e-6, "{rt:?}");
        }
    }

    #[test]
    fn one_hot_is_exclusive() {
        let g = zoo_graph(ZooModel::ConvNextTiny);
        let pm = PerfModel::default();
        let f = extract(&g, 1, 1.0, 1.0, &pm, FeatureMode::Full);
        for row in &f.op_feats {
            let ones = row[..12].iter().filter(|&&x| x == 1.0).count();
            assert_eq!(ones, 1);
        }
    }

    #[test]
    fn features_depend_on_batch() {
        let g = zoo_graph(ZooModel::ResNet50);
        let pm = PerfModel::default();
        let f1 = extract(&g, 1, 0.5, 0.5, &pm, FeatureMode::Full);
        let f8 = extract(&g, 8, 0.5, 0.5, &pm, FeatureMode::Full);
        assert!(f8.graph_feats[0] > f1.graph_feats[0]);
        assert!(f8.op_feats[0][12] >= f1.op_feats[0][12]);
    }
}
