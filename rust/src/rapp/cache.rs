//! The quantized capacity cache — memoized predictor lookups on the
//! per-mille (sm, quota) lattice, plus the monotone-quota bisection that
//! turns the autoscaler's O(sm × quota) grid sweeps into O(sm × log quota)
//! table lookups.
//!
//! Every allocation the substrate can express lives on the per-mille lattice
//! ([`crate::vgpu::SmMille`] / [`crate::vgpu::QuotaMille`]), so predictor
//! queries from the scaling hot path only ever hit lattice points.
//! [`CachedPredictor`] keys on `(graph, batch, sm‰, quota‰, factor‰)` — the
//! GPU-class factor is **part of the key type** ([`LatticeKey`]), not a
//! side-table, so two classes can never alias onto one cache line — and
//! evaluates the inner predictor **at the quantized point**, so a cached run
//! is bit-identical to an uncached one for lattice inputs (the `--jobs`
//! byte-identical export guarantee is preserved). The cache is shared by
//! [`crate::autoscaler::HybridAutoscaler`], the [`crate::baselines`]
//! policies, and the simulator's dispatch path — one table per run.

use super::{LatencyPredictor, PredictQuery};
use crate::model::OpGraph;
use crate::vgpu::QuotaMille;
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

/// Quantize a fraction to the per-mille lattice.
fn mille(x: f64) -> u32 {
    (x * 1000.0).round() as u32
}

/// A query quantized to the per-mille lattice — everything that identifies a
/// cache line except the graph (the outer map level keys on the name).
/// `factor` is folded into the key itself: reference-class queries carry
/// `f_m == 1000`, class queries their own cell, and no future factor-varying
/// caller can collide two classes.
#[derive(Clone, Copy, PartialEq, Eq, Hash, Debug)]
struct LatticeKey {
    batch: u32,
    sm_m: u32,
    q_m: u32,
    f_m: u32,
}

impl LatticeKey {
    fn new(q: &PredictQuery) -> Self {
        let key = LatticeKey {
            batch: q.batch,
            sm_m: mille(q.sm),
            q_m: mille(q.quota),
            f_m: mille(q.factor),
        };
        // The quantization must round-trip: evaluating the inner predictor
        // at `key.query(..)` and quantizing *that* query again must land on
        // the same cell, or the cached value would not be a pure function
        // of the key.
        debug_assert!(
            mille(key.sm_m as f64 / 1000.0) == key.sm_m
                && mille(key.q_m as f64 / 1000.0) == key.q_m
                && mille(key.f_m as f64 / 1000.0) == key.f_m,
            "per-mille quantization failed to round-trip: {key:?}"
        );
        key
    }

    /// The exact lattice-point query this cell memoises.
    fn query<'g>(&self, graph: &'g OpGraph) -> PredictQuery<'g> {
        PredictQuery {
            graph,
            batch: self.batch,
            sm: self.sm_m as f64 / 1000.0,
            quota: self.q_m as f64 / 1000.0,
            factor: self.f_m as f64 / 1000.0,
        }
    }
}

/// Memoizing wrapper: latency predictions cached per
/// `(graph, batch, sm‰, quota‰, factor‰)`. Capacity queries go through the
/// default [`LatencyPredictor::capacity`] (one full-quota latency lookup), so
/// a whole quota sweep at fixed sm costs a single underlying invocation.
///
/// The table is two-level (graph name → lattice key → latency) so a cache
/// hit — the steady state of the dispatch and plan hot paths — costs one
/// lock and two hash probes with **no allocation**; the graph-name `String`
/// is cloned only when a graph's first lattice point is inserted.
///
/// `factor == 1.0` queries evaluate the inner predictor at exactly
/// `factor == 1.0` (`1000 / 1000.0` is exact in IEEE 754), so the
/// reference-path-verbatim contract flows straight through the cache.
///
/// Wrapping a predictor that already memoizes internally (e.g.
/// [`super::RappPredictor`]) is harmless but redundant — this wrapper is the
/// designated memo layer for predictors without one (the oracle / perf
/// surface).
pub struct CachedPredictor<'a> {
    inner: &'a dyn LatencyPredictor,
    #[allow(clippy::type_complexity)]
    cache: Mutex<HashMap<String, HashMap<LatticeKey, f64>>>,
}

impl<'a> CachedPredictor<'a> {
    pub fn new(inner: &'a dyn LatencyPredictor) -> Self {
        CachedPredictor {
            inner,
            cache: Mutex::new(HashMap::new()),
        }
    }

    /// Number of distinct lattice points evaluated so far.
    pub fn len(&self) -> usize {
        self.cache.lock().unwrap().values().map(|m| m.len()).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

impl LatencyPredictor for CachedPredictor<'_> {
    fn latency(&self, q: PredictQuery) -> f64 {
        let key = LatticeKey::new(&q);
        {
            let cache = self.cache.lock().unwrap();
            if let Some(&v) = cache.get(q.graph.name.as_str()).and_then(|m| m.get(&key)) {
                return v;
            }
        }
        // Evaluate at the quantized point (lock released during the forward)
        // so the cached value is a pure function of the key — sub-mille
        // inputs alias to their lattice cell.
        let v = self.inner.latency(key.query(q.graph));
        self.cache
            .lock()
            .unwrap()
            .entry(q.graph.name.clone())
            .or_default()
            .insert(key, v);
        v
    }

    /// Sweep-aware lookup: hits come from the lattice table, misses are
    /// forwarded to the inner predictor **as one batch** (at the quantized
    /// points, preserving the pure-function-of-the-key invariant). The steady
    /// state — every point cached — allocates nothing.
    fn latency_batch(&self, q: PredictQuery, quotas: &[f64], out: &mut Vec<f64>) {
        out.clear();
        out.resize(quotas.len(), f64::NAN);
        let mut miss_idx: Vec<usize> = Vec::new();
        let mut miss_q: Vec<f64> = Vec::new();
        {
            let cache = self.cache.lock().unwrap();
            let table = cache.get(q.graph.name.as_str());
            for (i, &quota) in quotas.iter().enumerate() {
                let key = LatticeKey::new(&q.with_quota(quota));
                match table.and_then(|m| m.get(&key)) {
                    Some(&v) => out[i] = v,
                    None => {
                        miss_idx.push(i);
                        miss_q.push(key.q_m as f64 / 1000.0);
                    }
                }
            }
        }
        if miss_idx.is_empty() {
            return;
        }
        let mut fresh = Vec::new();
        let base = LatticeKey::new(&q).query(q.graph);
        self.inner.latency_batch(base, &miss_q, &mut fresh);
        let mut cache = self.cache.lock().unwrap();
        let table = cache.entry(q.graph.name.clone()).or_default();
        for ((&i, &quota), &v) in miss_idx.iter().zip(&miss_q).zip(&fresh) {
            table.insert(LatticeKey::new(&base.with_quota(quota)), v);
            out[i] = v;
        }
    }
}

/// Counting wrapper for benches/tests: how many times does a code path
/// actually invoke the underlying predictor? (Capacity queries and the
/// default batch sweep route through `latency`, so this counts every
/// predictor forward.)
pub struct CountingPredictor<P> {
    pub inner: P,
    count: AtomicU64,
}

impl<P> CountingPredictor<P> {
    pub fn new(inner: P) -> Self {
        CountingPredictor {
            inner,
            count: AtomicU64::new(0),
        }
    }

    pub fn invocations(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }
}

impl<P: LatencyPredictor> LatencyPredictor for CountingPredictor<P> {
    /// Count, then delegate so the inner predictor's exact class surface is
    /// what gets measured.
    fn latency(&self, q: PredictQuery) -> f64 {
        self.count.fetch_add(1, Ordering::Relaxed);
        self.inner.latency(q)
    }
}

/// Smallest quota on the lattice `{step, 2·step, …, ⌊full/step⌋·step}` for
/// which `feasible` holds, assuming the predicate is monotone in quota
/// (false below some threshold, true above — latency is non-increasing and
/// capacity non-decreasing in quota, so both hot-path predicates qualify).
/// Returns `None` when even the largest lattice quota is infeasible. The
/// returned quota is always one the predicate was actually evaluated at, so
/// tiny non-monotonicities in the surface can shift the answer by a step but
/// never yield an infeasible result. O(log(full/step)) predicate calls.
pub fn min_feasible_quota(
    step: QuotaMille,
    full: QuotaMille,
    mut feasible: impl FnMut(QuotaMille) -> bool,
) -> Option<QuotaMille> {
    let n = full / step;
    if n == 0 || !feasible(step * n) {
        return None;
    }
    let (mut lo, mut hi) = (1u32, n);
    while lo < hi {
        let mid = lo + (hi - lo) / 2;
        if feasible(step * mid) {
            hi = mid;
        } else {
            lo = mid + 1;
        }
    }
    Some(step * hi)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::zoo::{zoo_graph, ZooModel};
    use crate::rapp::OraclePredictor;

    fn q(g: &OpGraph, batch: u32, sm: f64, quota: f64) -> PredictQuery<'_> {
        PredictQuery::new(g, batch, sm, quota)
    }

    #[test]
    fn cached_matches_uncached_on_lattice_points() {
        let oracle = OraclePredictor::default();
        let cached = CachedPredictor::new(&oracle);
        let g = zoo_graph(ZooModel::ResNet50);
        for &(sm, quota) in &[(0.05, 0.1), (0.25, 0.3), (0.5, 0.5), (1.0, 1.0)] {
            let a = cached.latency(q(&g, 8, sm, quota));
            let b = oracle.latency(q(&g, 8, sm, quota));
            assert_eq!(a, b, "sm={sm} q={quota}");
            // Second query hits the cache and returns the identical value.
            assert_eq!(cached.latency(q(&g, 8, sm, quota)), a);
        }
        assert_eq!(cached.len(), 4);
        let ca = cached.capacity(q(&g, 8, 0.5, 0.7));
        let cb = oracle.capacity(q(&g, 8, 0.5, 0.7));
        assert_eq!(ca, cb);
    }

    #[test]
    fn counting_predictor_counts_underlying_forwards() {
        let counting = CountingPredictor::new(OraclePredictor::default());
        let cached = CachedPredictor::new(&counting);
        let g = zoo_graph(ZooModel::MobileNetV2);
        for _ in 0..10 {
            cached.latency(q(&g, 4, 0.5, 0.6));
        }
        assert_eq!(counting.invocations(), 1, "9 of 10 queries must hit cache");
        // A capacity sweep over the quota axis costs one underlying forward.
        for step in 1..=10u32 {
            cached.capacity(q(&g, 4, 0.5, step as f64 / 10.0));
        }
        assert_eq!(counting.invocations(), 2);
    }

    #[test]
    fn bisection_finds_smallest_feasible_step() {
        // Threshold predicate: feasible at q >= 380 ⇒ smallest lattice hit
        // with step 100 is 400.
        assert_eq!(min_feasible_quota(100, 1000, |q| q >= 380), Some(400));
        assert_eq!(min_feasible_quota(100, 1000, |q| q >= 100), Some(100));
        assert_eq!(min_feasible_quota(100, 1000, |q| q >= 1000), Some(1000));
        assert_eq!(min_feasible_quota(100, 1000, |q| q > 1000), None);
        assert_eq!(min_feasible_quota(250, 1000, |q| q >= 300), Some(500));
        // Degenerate lattices.
        assert_eq!(min_feasible_quota(1000, 1000, |_| true), Some(1000));
        assert_eq!(min_feasible_quota(2000, 1000, |_| true), None);
    }

    #[test]
    fn latency_batch_agrees_with_scalar_and_batches_misses() {
        let counting = CountingPredictor::new(OraclePredictor::default());
        let cached = CachedPredictor::new(&counting);
        let g = zoo_graph(ZooModel::ResNet50);
        let quotas: Vec<f64> = (1..=10).map(|i| i as f64 / 10.0).collect();
        // Prime one point through the scalar path.
        let primed = cached.latency(q(&g, 8, 0.5, 0.4));
        let mut out = Vec::new();
        cached.latency_batch(q(&g, 8, 0.5, 1.0), &quotas, &mut out);
        assert_eq!(counting.invocations(), 10, "9 misses + 1 primed forward");
        assert_eq!(out[3], primed);
        let oracle = OraclePredictor::default();
        for (&quota, &v) in quotas.iter().zip(&out) {
            assert_eq!(v, oracle.latency(q(&g, 8, 0.5, quota)), "q={quota}");
            assert_eq!(v, cached.latency(q(&g, 8, 0.5, quota)), "q={quota}");
        }
        // A second sweep is all hits: no further underlying forwards.
        cached.latency_batch(q(&g, 8, 0.5, 1.0), &quotas, &mut out);
        assert_eq!(counting.invocations(), 10);
        // Sub-mille inputs alias to their lattice cell, batched or scalar.
        cached.latency_batch(q(&g, 8, 0.5, 1.0), &[0.4004], &mut out);
        assert_eq!(out[0], primed);
        assert_eq!(counting.invocations(), 10);
    }

    #[test]
    fn class_factor_is_part_of_the_lattice_key() {
        let oracle = OraclePredictor::default();
        let cached = CachedPredictor::new(&oracle);
        let g = zoo_graph(ZooModel::ResNet50);
        // factor 1.0 evaluates the inner reference path verbatim.
        let reference = cached.latency(q(&g, 8, 0.5, 0.5));
        assert_eq!(reference, oracle.latency(q(&g, 8, 0.5, 0.5)));
        assert_eq!(cached.len(), 1);
        // A non-reference factor is its own lattice cell with the oracle's
        // window-exact class value (not reference/factor) — no aliasing
        // onto the reference cell.
        let t4 = cached.latency(q(&g, 8, 0.5, 0.5).with_factor(0.4));
        assert_eq!(t4, oracle.perf.latency_class(&g, 8, 0.5, 0.5, 0.4));
        assert_eq!(cached.len(), 2);
        // Cached hit returns the identical value; no growth.
        assert_eq!(cached.latency(q(&g, 8, 0.5, 0.5).with_factor(0.4)), t4);
        assert_eq!(cached.len(), 2);
        // Class sweeps agree with scalar class queries and hit the table.
        let quotas = [0.2, 0.5, 1.0];
        let mut out = Vec::new();
        cached.latency_batch(q(&g, 8, 0.5, 1.0).with_factor(0.4), &quotas, &mut out);
        for (&quota, &v) in quotas.iter().zip(&out) {
            assert_eq!(v, cached.latency(q(&g, 8, 0.5, quota).with_factor(0.4)), "q={quota}");
            assert_eq!(v, oracle.perf.latency_class(&g, 8, 0.5, quota, 0.4), "q={quota}");
        }
        // And a factor-1.0 sweep is the reference sweep.
        cached.latency_batch(q(&g, 8, 0.5, 1.0), &quotas, &mut out);
        assert_eq!(out[1], reference);
    }

    #[test]
    fn bisection_matches_linear_scan_on_latency_surface() {
        // The predicate the autoscaler actually uses: predicted latency under
        // an SLO bound. Bisection must agree with the seed's linear scan.
        let oracle = OraclePredictor::default();
        let g = zoo_graph(ZooModel::ResNet50);
        for &sm in &[0.2, 0.5, 1.0] {
            for &bound_ms in &[20.0, 60.0, 200.0] {
                let bound = bound_ms / 1e3;
                let feasible = |quota: QuotaMille| {
                    oracle.latency(q(&g, 8, sm, quota as f64 / 1000.0)) <= bound
                };
                let linear = (1..=10).map(|n| n * 100).find(|&quota| feasible(quota));
                let bisect = min_feasible_quota(100, 1000, feasible);
                assert_eq!(bisect, linear, "sm={sm} bound={bound_ms}ms");
            }
        }
    }
}
