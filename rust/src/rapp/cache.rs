//! The quantized capacity cache — memoized predictor lookups on the
//! per-mille (sm, quota) lattice, plus the monotone-quota bisection that
//! turns the autoscaler's O(sm × quota) grid sweeps into O(sm × log quota)
//! table lookups.
//!
//! Every allocation the substrate can express lives on the per-mille lattice
//! ([`crate::vgpu::SmMille`] / [`crate::vgpu::QuotaMille`]), so predictor
//! queries from the scaling hot path only ever hit lattice points.
//! [`CachedPredictor`] keys on `(graph, batch, sm‰, quota‰)` and evaluates
//! the inner predictor **at the quantized point**, so a cached run is
//! bit-identical to an uncached one for lattice inputs (the `--jobs`
//! byte-identical export guarantee is preserved). The cache is shared by
//! [`crate::autoscaler::HybridAutoscaler`], the [`crate::baselines`]
//! policies, and the simulator's dispatch path — one table per run.

use super::LatencyPredictor;
use crate::model::OpGraph;
use crate::vgpu::QuotaMille;
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

/// Quantize a fraction to the per-mille lattice.
fn mille(x: f64) -> u32 {
    (x * 1000.0).round() as u32
}

/// Memoizing wrapper: latency predictions cached per
/// `(graph, batch, sm‰, quota‰)`. Capacity queries go through the default
/// [`LatencyPredictor::capacity`] (one full-quota latency lookup), so a whole
/// quota sweep at fixed sm costs a single underlying predictor invocation.
///
/// The table is two-level (graph name → lattice point → latency) so a cache
/// hit — the steady state of the dispatch and plan hot paths — costs one
/// lock and two hash probes with **no allocation**; the graph-name `String`
/// is cloned only when a graph's first lattice point is inserted.
///
/// Wrapping a predictor that already memoizes internally (e.g.
/// [`super::RappPredictor`]) is harmless but redundant — this wrapper is the
/// designated memo layer for predictors without one (the oracle / perf
/// surface).
pub struct CachedPredictor<'a> {
    inner: &'a dyn LatencyPredictor,
    #[allow(clippy::type_complexity)]
    cache: Mutex<HashMap<String, HashMap<(u32, u32, u32), f64>>>,
    /// Class-factor side table: `(batch, sm‰, quota‰, factor‰)` → latency,
    /// for non-reference GPU classes (heterogeneous fleets). Kept separate
    /// so the reference-class table — and every byte it feeds — is
    /// untouched by class-aware callers.
    #[allow(clippy::type_complexity)]
    cache_class: Mutex<HashMap<String, HashMap<(u32, u32, u32, u32), f64>>>,
}

impl<'a> CachedPredictor<'a> {
    pub fn new(inner: &'a dyn LatencyPredictor) -> Self {
        CachedPredictor {
            inner,
            cache: Mutex::new(HashMap::new()),
            cache_class: Mutex::new(HashMap::new()),
        }
    }

    /// Number of distinct lattice points evaluated so far (both tables).
    pub fn len(&self) -> usize {
        self.cache.lock().unwrap().values().map(|m| m.len()).sum::<usize>()
            + self.cache_class.lock().unwrap().values().map(|m| m.len()).sum::<usize>()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

impl LatencyPredictor for CachedPredictor<'_> {
    fn latency(&self, g: &OpGraph, batch: u32, sm: f64, quota: f64) -> f64 {
        let (sm_m, q_m) = (mille(sm), mille(quota));
        let key = (batch, sm_m, q_m);
        {
            let cache = self.cache.lock().unwrap();
            if let Some(&v) = cache.get(g.name.as_str()).and_then(|m| m.get(&key)) {
                return v;
            }
        }
        // Evaluate at the quantized point (lock released during the forward)
        // so the cached value is a pure function of the key — sub-mille
        // inputs alias to their lattice cell.
        let v = self
            .inner
            .latency(g, batch, sm_m as f64 / 1000.0, q_m as f64 / 1000.0);
        self.cache
            .lock()
            .unwrap()
            .entry(g.name.clone())
            .or_default()
            .insert(key, v);
        v
    }

    /// Sweep-aware lookup: hits come from the lattice table, misses are
    /// forwarded to the inner predictor **as one batch** (at the quantized
    /// points, preserving the pure-function-of-the-key invariant). The steady
    /// state — every point cached — allocates nothing.
    fn latency_batch(&self, g: &OpGraph, batch: u32, sm: f64, quotas: &[f64], out: &mut Vec<f64>) {
        let sm_m = mille(sm);
        out.clear();
        out.resize(quotas.len(), f64::NAN);
        let mut miss_idx: Vec<usize> = Vec::new();
        let mut miss_q: Vec<f64> = Vec::new();
        {
            let cache = self.cache.lock().unwrap();
            let table = cache.get(g.name.as_str());
            for (i, &q) in quotas.iter().enumerate() {
                let key = (batch, sm_m, mille(q));
                match table.and_then(|m| m.get(&key)) {
                    Some(&v) => out[i] = v,
                    None => {
                        miss_idx.push(i);
                        miss_q.push(mille(q) as f64 / 1000.0);
                    }
                }
            }
        }
        if miss_idx.is_empty() {
            return;
        }
        let mut fresh = Vec::new();
        self.inner
            .latency_batch(g, batch, sm_m as f64 / 1000.0, &miss_q, &mut fresh);
        let mut cache = self.cache.lock().unwrap();
        let table = cache.entry(g.name.clone()).or_default();
        for ((&i, &q), &v) in miss_idx.iter().zip(&miss_q).zip(&fresh) {
            table.insert((batch, sm_m, mille(q)), v);
            out[i] = v;
        }
    }

    /// Class-aware lookup: factor 1.0 routes through the reference table
    /// verbatim; other factors memoise in the class side table, evaluating
    /// the inner predictor's class surface at the quantized point.
    fn latency_at(&self, g: &OpGraph, batch: u32, sm: f64, quota: f64, factor: f64) -> f64 {
        if factor == 1.0 {
            return self.latency(g, batch, sm, quota);
        }
        let (sm_m, q_m, f_m) = (mille(sm), mille(quota), mille(factor));
        let key = (batch, sm_m, q_m, f_m);
        {
            let cache = self.cache_class.lock().unwrap();
            if let Some(&v) = cache.get(g.name.as_str()).and_then(|m| m.get(&key)) {
                return v;
            }
        }
        let v = self.inner.latency_at(
            g,
            batch,
            sm_m as f64 / 1000.0,
            q_m as f64 / 1000.0,
            f_m as f64 / 1000.0,
        );
        self.cache_class
            .lock()
            .unwrap()
            .entry(g.name.clone())
            .or_default()
            .insert(key, v);
        v
    }

    /// Class-aware sweep: factor 1.0 is the reference sweep verbatim;
    /// otherwise misses batch through the inner class surface at quantized
    /// points, mirroring [`CachedPredictor::latency_batch`].
    fn latency_batch_at(
        &self,
        g: &OpGraph,
        batch: u32,
        sm: f64,
        quotas: &[f64],
        factor: f64,
        out: &mut Vec<f64>,
    ) {
        if factor == 1.0 {
            return self.latency_batch(g, batch, sm, quotas, out);
        }
        let (sm_m, f_m) = (mille(sm), mille(factor));
        out.clear();
        out.resize(quotas.len(), f64::NAN);
        let mut miss_idx: Vec<usize> = Vec::new();
        let mut miss_q: Vec<f64> = Vec::new();
        {
            let cache = self.cache_class.lock().unwrap();
            let table = cache.get(g.name.as_str());
            for (i, &q) in quotas.iter().enumerate() {
                let key = (batch, sm_m, mille(q), f_m);
                match table.and_then(|m| m.get(&key)) {
                    Some(&v) => out[i] = v,
                    None => {
                        miss_idx.push(i);
                        miss_q.push(mille(q) as f64 / 1000.0);
                    }
                }
            }
        }
        if miss_idx.is_empty() {
            return;
        }
        let mut fresh = Vec::new();
        self.inner.latency_batch_at(
            g,
            batch,
            sm_m as f64 / 1000.0,
            &miss_q,
            f_m as f64 / 1000.0,
            &mut fresh,
        );
        let mut cache = self.cache_class.lock().unwrap();
        let table = cache.entry(g.name.clone()).or_default();
        for ((&i, &q), &v) in miss_idx.iter().zip(&miss_q).zip(&fresh) {
            table.insert((batch, sm_m, mille(q), f_m), v);
            out[i] = v;
        }
    }
}

/// Counting wrapper for benches/tests: how many times does a code path
/// actually invoke the underlying predictor? (Capacity queries route through
/// `latency`, so this counts every predictor forward.)
pub struct CountingPredictor<P> {
    pub inner: P,
    count: AtomicU64,
}

impl<P> CountingPredictor<P> {
    pub fn new(inner: P) -> Self {
        CountingPredictor {
            inner,
            count: AtomicU64::new(0),
        }
    }

    pub fn invocations(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }
}

impl<P: LatencyPredictor> LatencyPredictor for CountingPredictor<P> {
    fn latency(&self, g: &OpGraph, batch: u32, sm: f64, quota: f64) -> f64 {
        self.count.fetch_add(1, Ordering::Relaxed);
        self.inner.latency(g, batch, sm, quota)
    }

    /// Count, then delegate so the inner predictor's exact class surface
    /// (not the `1/factor` default) is what gets measured.
    fn latency_at(&self, g: &OpGraph, batch: u32, sm: f64, quota: f64, factor: f64) -> f64 {
        self.count.fetch_add(1, Ordering::Relaxed);
        self.inner.latency_at(g, batch, sm, quota, factor)
    }
}

/// Smallest quota on the lattice `{step, 2·step, …, ⌊full/step⌋·step}` for
/// which `feasible` holds, assuming the predicate is monotone in quota
/// (false below some threshold, true above — latency is non-increasing and
/// capacity non-decreasing in quota, so both hot-path predicates qualify).
/// Returns `None` when even the largest lattice quota is infeasible. The
/// returned quota is always one the predicate was actually evaluated at, so
/// tiny non-monotonicities in the surface can shift the answer by a step but
/// never yield an infeasible result. O(log(full/step)) predicate calls.
pub fn min_feasible_quota(
    step: QuotaMille,
    full: QuotaMille,
    mut feasible: impl FnMut(QuotaMille) -> bool,
) -> Option<QuotaMille> {
    let n = full / step;
    if n == 0 || !feasible(step * n) {
        return None;
    }
    let (mut lo, mut hi) = (1u32, n);
    while lo < hi {
        let mid = lo + (hi - lo) / 2;
        if feasible(step * mid) {
            hi = mid;
        } else {
            lo = mid + 1;
        }
    }
    Some(step * hi)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::zoo::{zoo_graph, ZooModel};
    use crate::rapp::OraclePredictor;

    #[test]
    fn cached_matches_uncached_on_lattice_points() {
        let oracle = OraclePredictor::default();
        let cached = CachedPredictor::new(&oracle);
        let g = zoo_graph(ZooModel::ResNet50);
        for &(sm, q) in &[(0.05, 0.1), (0.25, 0.3), (0.5, 0.5), (1.0, 1.0)] {
            let a = cached.latency(&g, 8, sm, q);
            let b = oracle.latency(&g, 8, sm, q);
            assert_eq!(a, b, "sm={sm} q={q}");
            // Second query hits the cache and returns the identical value.
            assert_eq!(cached.latency(&g, 8, sm, q), a);
        }
        assert_eq!(cached.len(), 4);
        let ca = cached.capacity(&g, 8, 0.5, 0.7);
        let cb = oracle.capacity(&g, 8, 0.5, 0.7);
        assert_eq!(ca, cb);
    }

    #[test]
    fn counting_predictor_counts_underlying_forwards() {
        let counting = CountingPredictor::new(OraclePredictor::default());
        let cached = CachedPredictor::new(&counting);
        let g = zoo_graph(ZooModel::MobileNetV2);
        for _ in 0..10 {
            cached.latency(&g, 4, 0.5, 0.6);
        }
        assert_eq!(counting.invocations(), 1, "9 of 10 queries must hit cache");
        // A capacity sweep over the quota axis costs one underlying forward.
        for q in 1..=10u32 {
            cached.capacity(&g, 4, 0.5, q as f64 / 10.0);
        }
        assert_eq!(counting.invocations(), 2);
    }

    #[test]
    fn bisection_finds_smallest_feasible_step() {
        // Threshold predicate: feasible at q >= 380 ⇒ smallest lattice hit
        // with step 100 is 400.
        assert_eq!(min_feasible_quota(100, 1000, |q| q >= 380), Some(400));
        assert_eq!(min_feasible_quota(100, 1000, |q| q >= 100), Some(100));
        assert_eq!(min_feasible_quota(100, 1000, |q| q >= 1000), Some(1000));
        assert_eq!(min_feasible_quota(100, 1000, |q| q > 1000), None);
        assert_eq!(min_feasible_quota(250, 1000, |q| q >= 300), Some(500));
        // Degenerate lattices.
        assert_eq!(min_feasible_quota(1000, 1000, |_| true), Some(1000));
        assert_eq!(min_feasible_quota(2000, 1000, |_| true), None);
    }

    #[test]
    fn latency_batch_agrees_with_scalar_and_batches_misses() {
        let counting = CountingPredictor::new(OraclePredictor::default());
        let cached = CachedPredictor::new(&counting);
        let g = zoo_graph(ZooModel::ResNet50);
        let quotas: Vec<f64> = (1..=10).map(|i| i as f64 / 10.0).collect();
        // Prime one point through the scalar path.
        let primed = cached.latency(&g, 8, 0.5, 0.4);
        let mut out = Vec::new();
        cached.latency_batch(&g, 8, 0.5, &quotas, &mut out);
        assert_eq!(counting.invocations(), 10, "9 misses + 1 primed forward");
        assert_eq!(out[3], primed);
        let oracle = OraclePredictor::default();
        for (&q, &v) in quotas.iter().zip(&out) {
            assert_eq!(v, oracle.latency(&g, 8, 0.5, q), "q={q}");
            assert_eq!(v, cached.latency(&g, 8, 0.5, q), "q={q}");
        }
        // A second sweep is all hits: no further underlying forwards.
        cached.latency_batch(&g, 8, 0.5, &quotas, &mut out);
        assert_eq!(counting.invocations(), 10);
        // Sub-mille inputs alias to their lattice cell, batched or scalar.
        cached.latency_batch(&g, 8, 0.5, &[0.4004], &mut out);
        assert_eq!(out[0], primed);
        assert_eq!(counting.invocations(), 10);
    }

    #[test]
    fn class_factor_queries_use_a_distinct_table_and_exact_class_surface() {
        let oracle = OraclePredictor::default();
        let cached = CachedPredictor::new(&oracle);
        let g = zoo_graph(ZooModel::ResNet50);
        // factor 1.0 routes through the reference table verbatim.
        let reference = cached.latency_at(&g, 8, 0.5, 0.5, 1.0);
        assert_eq!(reference, oracle.latency(&g, 8, 0.5, 0.5));
        assert_eq!(cached.len(), 1);
        // A non-reference factor is a new lattice point with the oracle's
        // window-exact class value (not reference/factor).
        let t4 = cached.latency_at(&g, 8, 0.5, 0.5, 0.4);
        assert_eq!(t4, oracle.perf.latency_class(&g, 8, 0.5, 0.5, 0.4));
        assert_eq!(cached.len(), 2);
        // Cached hit returns the identical value; no growth.
        assert_eq!(cached.latency_at(&g, 8, 0.5, 0.5, 0.4), t4);
        assert_eq!(cached.len(), 2);
        // Class sweeps agree with scalar class queries and hit the table.
        let quotas = [0.2, 0.5, 1.0];
        let mut out = Vec::new();
        cached.latency_batch_at(&g, 8, 0.5, &quotas, 0.4, &mut out);
        for (&q, &v) in quotas.iter().zip(&out) {
            assert_eq!(v, cached.latency_at(&g, 8, 0.5, q, 0.4), "q={q}");
            assert_eq!(v, oracle.perf.latency_class(&g, 8, 0.5, q, 0.4), "q={q}");
        }
        // And a factor-1.0 sweep is the reference sweep.
        cached.latency_batch_at(&g, 8, 0.5, &quotas, 1.0, &mut out);
        assert_eq!(out[1], reference);
    }

    #[test]
    fn bisection_matches_linear_scan_on_latency_surface() {
        // The predicate the autoscaler actually uses: predicted latency under
        // an SLO bound. Bisection must agree with the seed's linear scan.
        let oracle = OraclePredictor::default();
        let g = zoo_graph(ZooModel::ResNet50);
        for &sm in &[0.2, 0.5, 1.0] {
            for &bound_ms in &[20.0, 60.0, 200.0] {
                let bound = bound_ms / 1e3;
                let feasible =
                    |q: QuotaMille| oracle.latency(&g, 8, sm, q as f64 / 1000.0) <= bound;
                let linear = (1..=10).map(|n| n * 100).find(|&q| feasible(q));
                let bisect = min_feasible_quota(100, 1000, feasible);
                assert_eq!(bisect, linear, "sm={sm} bound={bound_ms}ms");
            }
        }
    }
}
