//! Native neural-network forward pass for the RaPP predictor.
//!
//! The autoscaler evaluates RaPP O(pods × quota-steps) times per tick, so the
//! decision loop uses this dependency-free f32 implementation (same weights as
//! the AOT-compiled HLO forward, parity-tested against it). Architecture —
//! mirrored in `python/compile/train_rapp.py`:
//!
//! ```text
//! op_feats [N,F] ─ GAT(F→H) ─ GAT(H→H) ─ masked-mean ─┐
//!                                                     concat → ReLU dense H
//! graph_feats [G] ─ dense(G→H) + ReLU ────────────────┘        → dense 1
//! ```
//!
//! GAT layer (Veličković et al. 2018, single head): `e_ij =
//! LeakyReLU(a_src·Wh_i + a_dst·Wh_j)`, attention softmax over in-neighbours
//! of the *symmetrised* edge set plus self-loops, ELU output activation.
//!
//! Every forward writes into caller-provided buffers ([`GatScratch`] and
//! plain `&mut Vec<f32>` outputs): the decision hot path performs **zero
//! allocations** once the scratch is warm. Graph structure comes in as
//! [`Adjacency`] (CSR, hoisted to the model/zoo layer) instead of per-call
//! `Vec<Vec<usize>>` neighbour lists.

use crate::model::Adjacency;

/// A dense layer: `y = W^T x + b`, with `w` stored row-major `[n_in][n_out]`.
#[derive(Clone, Debug)]
pub struct Dense {
    pub n_in: usize,
    pub n_out: usize,
    pub w: Vec<f32>,
    pub b: Vec<f32>,
}

impl Dense {
    pub fn forward(&self, x: &[f32], out: &mut [f32]) {
        debug_assert_eq!(x.len(), self.n_in);
        debug_assert_eq!(out.len(), self.n_out);
        out.copy_from_slice(&self.b);
        for (i, &xi) in x.iter().enumerate() {
            if xi == 0.0 {
                continue;
            }
            let row = &self.w[i * self.n_out..(i + 1) * self.n_out];
            for (o, &wv) in out.iter_mut().zip(row) {
                *o += xi * wv;
            }
        }
    }

    /// Row-batched forward: `x` is `[rows][n_in]` row-major, `out` is
    /// `[rows][n_out]`. Each row is computed exactly as [`Dense::forward`]
    /// would (same accumulation order ⇒ bit-identical per row); the batching
    /// is a cache-friendly matmul-shaped sweep over a whole lattice level.
    pub fn forward_rows(&self, x: &[f32], rows: usize, out: &mut [f32]) {
        debug_assert_eq!(x.len(), rows * self.n_in);
        debug_assert_eq!(out.len(), rows * self.n_out);
        for r in 0..rows {
            self.forward(
                &x[r * self.n_in..(r + 1) * self.n_in],
                &mut out[r * self.n_out..(r + 1) * self.n_out],
            );
        }
    }
}

/// One single-head GAT layer.
#[derive(Clone, Debug)]
pub struct GatLayer {
    pub lin: Dense,
    /// Attention vectors over the transformed features, length `n_out`.
    pub a_src: Vec<f32>,
    pub a_dst: Vec<f32>,
}

#[inline]
fn leaky_relu(x: f32) -> f32 {
    if x >= 0.0 {
        x
    } else {
        0.2 * x
    }
}

#[inline]
fn elu(x: f32) -> f32 {
    if x >= 0.0 {
        x
    } else {
        x.exp() - 1.0
    }
}

#[inline]
pub fn relu(x: f32) -> f32 {
    x.max(0.0)
}

/// Reusable buffers for GAT forwards: transformed features, attention
/// pre-products, and the per-node softmax weights. One instance serves any
/// number of forwards; nothing is allocated once capacities are warm.
#[derive(Clone, Debug, Default)]
pub struct GatScratch {
    hx: Vec<f32>,
    s_src: Vec<f32>,
    s_dst: Vec<f32>,
    weights: Vec<f32>,
}

impl GatLayer {
    /// `x`: `[n][n_in]` row-major; `adj`: symmetrised in-neighbour CSR (must
    /// include self-loops). Writes `[n][n_out]` into `out`.
    pub fn forward_into(
        &self,
        x: &[f32],
        n: usize,
        adj: &Adjacency,
        scratch: &mut GatScratch,
        out: &mut Vec<f32>,
    ) {
        let h = self.lin.n_out;
        debug_assert_eq!(adj.n(), n);
        // h_i = W x_i for all nodes.
        scratch.hx.clear();
        scratch.hx.resize(n * h, 0.0);
        let hx = &mut scratch.hx;
        for i in 0..n {
            let (src, dst) = (&x[i * self.lin.n_in..(i + 1) * self.lin.n_in], i * h);
            self.lin.forward(src, &mut hx[dst..dst + h]);
        }
        // Pre-compute a_src·h_i and a_dst·h_j.
        scratch.s_src.clear();
        scratch.s_src.resize(n, 0.0);
        scratch.s_dst.clear();
        scratch.s_dst.resize(n, 0.0);
        for i in 0..n {
            let hi = &hx[i * h..(i + 1) * h];
            scratch.s_src[i] = dot(&self.a_src, hi);
            scratch.s_dst[i] = dot(&self.a_dst, hi);
        }
        out.clear();
        out.resize(n * h, 0.0);
        let (s_src, s_dst) = (&scratch.s_src, &scratch.s_dst);
        let weights = &mut scratch.weights;
        for i in 0..n {
            let ns = adj.neighbours(i);
            debug_assert!(!ns.is_empty(), "node {i} lacks self-loop");
            // Attention logits + stable softmax.
            weights.clear();
            weights.extend(
                ns.iter()
                    .map(|&j| leaky_relu(s_src[i] + s_dst[j as usize])),
            );
            let m = weights.iter().copied().fold(f32::NEG_INFINITY, f32::max);
            let mut z = 0.0f32;
            for w in weights.iter_mut() {
                *w = (*w - m).exp();
                z += *w;
            }
            let oi = &mut out[i * h..(i + 1) * h];
            for (&j, &w) in ns.iter().zip(weights.iter()) {
                let hj = &hx[j as usize * h..(j as usize + 1) * h];
                let a = w / z;
                for (o, &v) in oi.iter_mut().zip(hj) {
                    *o += a * v;
                }
            }
            for o in oi.iter_mut() {
                *o = elu(*o);
            }
        }
    }

    /// Allocating convenience wrapper around [`GatLayer::forward_into`].
    pub fn forward(&self, x: &[f32], n: usize, adj: &Adjacency) -> Vec<f32> {
        let mut scratch = GatScratch::default();
        let mut out = Vec::new();
        self.forward_into(x, n, adj, &mut scratch, &mut out);
        out
    }
}

#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(x, y)| x * y).sum()
}

/// Masked mean-pool over node embeddings `[n][h]`, into a reusable buffer.
pub fn mean_pool_into(x: &[f32], n: usize, h: usize, out: &mut Vec<f32>) {
    out.clear();
    out.resize(h, 0.0);
    if n == 0 {
        return;
    }
    for i in 0..n {
        for (o, &v) in out.iter_mut().zip(&x[i * h..(i + 1) * h]) {
            *o += v;
        }
    }
    for o in out.iter_mut() {
        *o /= n as f32;
    }
}

/// Allocating convenience wrapper around [`mean_pool_into`].
pub fn mean_pool(x: &[f32], n: usize, h: usize) -> Vec<f32> {
    let mut out = Vec::new();
    mean_pool_into(x, n, h, &mut out);
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Pcg64;

    fn rand_dense(rng: &mut Pcg64, n_in: usize, n_out: usize) -> Dense {
        Dense {
            n_in,
            n_out,
            w: (0..n_in * n_out)
                .map(|_| rng.normal_ms(0.0, 0.3) as f32)
                .collect(),
            b: (0..n_out).map(|_| rng.normal_ms(0.0, 0.1) as f32).collect(),
        }
    }

    fn rand_gat(rng: &mut Pcg64, n_in: usize, n_out: usize) -> GatLayer {
        GatLayer {
            lin: rand_dense(rng, n_in, n_out),
            a_src: (0..n_out).map(|_| rng.normal_ms(0.0, 0.3) as f32).collect(),
            a_dst: (0..n_out).map(|_| rng.normal_ms(0.0, 0.3) as f32).collect(),
        }
    }

    #[test]
    fn dense_matches_manual() {
        let d = Dense {
            n_in: 2,
            n_out: 2,
            w: vec![1.0, 2.0, 3.0, 4.0], // rows: x0 -> [1,2], x1 -> [3,4]
            b: vec![0.5, -0.5],
        };
        let mut out = vec![0.0; 2];
        d.forward(&[2.0, 1.0], &mut out);
        assert_eq!(out, vec![2.0 + 3.0 + 0.5, 4.0 + 4.0 - 0.5]);
    }

    #[test]
    fn dense_rows_bitwise_match_scalar() {
        let mut rng = Pcg64::seeded(9);
        let d = rand_dense(&mut rng, 7, 5);
        let x: Vec<f32> = (0..4 * 7).map(|_| rng.normal_ms(0.0, 1.0) as f32).collect();
        let mut batched = vec![0.0f32; 4 * 5];
        d.forward_rows(&x, 4, &mut batched);
        for r in 0..4 {
            let mut one = vec![0.0f32; 5];
            d.forward(&x[r * 7..(r + 1) * 7], &mut one);
            for k in 0..5 {
                assert_eq!(one[k].to_bits(), batched[r * 5 + k].to_bits(), "row {r} col {k}");
            }
        }
    }

    #[test]
    fn gat_attention_sums_to_one() {
        // With identical neighbour features, output = transformed feature
        // (softmax convexity) — checks normalisation.
        let mut rng = Pcg64::seeded(1);
        let gat = rand_gat(&mut rng, 3, 4);
        let x: Vec<f32> = [0.3f32, -0.2, 0.9].repeat(3);
        let adj = Adjacency::from_edges(3, &[(0, 1), (1, 2)]);
        let out = gat.forward(&x, 3, &adj);
        // All nodes have identical inputs ⇒ identical outputs.
        assert_eq!(out[0..4], out[4..8]);
        assert_eq!(out[4..8], out[8..12]);
    }

    #[test]
    fn gat_permutation_equivariance() {
        // Relabelling nodes (and edges) permutes outputs accordingly.
        let mut rng = Pcg64::seeded(2);
        let gat = rand_gat(&mut rng, 3, 4);
        let x = vec![
            0.1f32, 0.2, 0.3, // node 0
            -0.5, 0.4, 0.0, // node 1
            0.9, -0.1, 0.7, // node 2
        ];
        let edges = vec![(0, 1), (1, 2)];
        let out = gat.forward(&x, 3, &Adjacency::from_edges(3, &edges));
        // Permutation: 0->2, 1->0, 2->1 (i.e. new[perm[i]] = old[i]).
        let perm = [2usize, 0, 1];
        let mut px = vec![0.0f32; 9];
        for i in 0..3 {
            px[perm[i] * 3..(perm[i] + 1) * 3].copy_from_slice(&x[i * 3..(i + 1) * 3]);
        }
        let pedges: Vec<(usize, usize)> = edges.iter().map(|&(s, d)| (perm[s], perm[d])).collect();
        let pout = gat.forward(&px, 3, &Adjacency::from_edges(3, &pedges));
        for i in 0..3 {
            for k in 0..4 {
                let a = out[i * 4 + k];
                let b = pout[perm[i] * 4 + k];
                assert!((a - b).abs() < 1e-5, "node {i} dim {k}: {a} vs {b}");
            }
        }
    }

    #[test]
    fn gat_scratch_reuse_is_bit_identical() {
        // The same scratch driven through different graphs must not leak
        // state between forwards.
        let mut rng = Pcg64::seeded(4);
        let gat = rand_gat(&mut rng, 3, 4);
        let xa: Vec<f32> = (0..9).map(|_| rng.normal_ms(0.0, 1.0) as f32).collect();
        let xb: Vec<f32> = (0..15).map(|_| rng.normal_ms(0.0, 1.0) as f32).collect();
        let adj_a = Adjacency::from_edges(3, &[(0, 2)]);
        let adj_b = Adjacency::from_edges(5, &[(0, 1), (1, 4), (2, 3)]);
        let fresh_a = gat.forward(&xa, 3, &adj_a);
        let fresh_b = gat.forward(&xb, 5, &adj_b);
        let mut scratch = GatScratch::default();
        let mut out = Vec::new();
        for _ in 0..3 {
            gat.forward_into(&xa, 3, &adj_a, &mut scratch, &mut out);
            assert_eq!(out, fresh_a);
            gat.forward_into(&xb, 5, &adj_b, &mut scratch, &mut out);
            assert_eq!(out, fresh_b);
        }
    }

    #[test]
    fn mean_pool_averages() {
        let x = vec![1.0f32, 2.0, 3.0, 4.0]; // 2 nodes × 2 dims
        assert_eq!(mean_pool(&x, 2, 2), vec![2.0, 3.0]);
        let mut buf = vec![9.0f32; 7]; // stale content must be overwritten
        mean_pool_into(&x, 2, 2, &mut buf);
        assert_eq!(buf, vec![2.0, 3.0]);
    }

    #[test]
    fn activations() {
        assert_eq!(leaky_relu(1.0), 1.0);
        assert_eq!(leaky_relu(-1.0), -0.2);
        assert_eq!(elu(2.0), 2.0);
        assert!((elu(-1.0) - (f32::exp(-1.0) - 1.0)).abs() < 1e-7);
        assert_eq!(relu(-3.0), 0.0);
    }
}
