//! Native neural-network forward pass for the RaPP predictor.
//!
//! The autoscaler evaluates RaPP O(pods × quota-steps) times per tick, so the
//! decision loop uses this dependency-free f32 implementation (same weights as
//! the AOT-compiled HLO forward, parity-tested against it). Architecture —
//! mirrored in `python/compile/train_rapp.py`:
//!
//! ```text
//! op_feats [N,F] ─ GAT(F→H) ─ GAT(H→H) ─ masked-mean ─┐
//!                                                     concat → ReLU dense H
//! graph_feats [G] ─ dense(G→H) + ReLU ────────────────┘        → dense 1
//! ```
//!
//! GAT layer (Veličković et al. 2018, single head): `e_ij =
//! LeakyReLU(a_src·Wh_i + a_dst·Wh_j)`, attention softmax over in-neighbours
//! of the *symmetrised* edge set plus self-loops, ELU output activation.
//!
//! Every forward writes into caller-provided buffers ([`GatScratch`] and
//! plain `&mut Vec<f32>` outputs): the decision hot path performs **zero
//! allocations** once the scratch is warm. Graph structure comes in as
//! [`Adjacency`] (CSR, hoisted to the model/zoo layer) instead of per-call
//! `Vec<Vec<usize>>` neighbour lists.

use crate::model::Adjacency;

/// SIMD lane width of the row-batched forward: how many query rows advance
/// together through [`Dense::forward_rows_lanes`]. 8 × f32 = one AVX2
/// register (and two NEON registers); the kernel is generic over the width,
/// so retuning is a one-line change.
pub const LANES: usize = 8;

/// Structure-of-arrays transpose buffers for the lane kernel: `xt` holds a
/// `LANES`-row input block as `[n_in][LANES]` (lane *l* = query row *l*),
/// `ot` the matching `[n_out][LANES]` output block. One instance serves any
/// number of forwards; nothing is allocated once capacities are warm.
#[derive(Clone, Debug, Default)]
pub struct LaneScratch {
    xt: Vec<f32>,
    ot: Vec<f32>,
}

/// A dense layer: `y = W^T x + b`, with `w` stored row-major `[n_in][n_out]`.
#[derive(Clone, Debug)]
pub struct Dense {
    pub n_in: usize,
    pub n_out: usize,
    pub w: Vec<f32>,
    pub b: Vec<f32>,
}

impl Dense {
    pub fn forward(&self, x: &[f32], out: &mut [f32]) {
        debug_assert_eq!(x.len(), self.n_in);
        debug_assert_eq!(out.len(), self.n_out);
        out.copy_from_slice(&self.b);
        for (i, &xi) in x.iter().enumerate() {
            if xi == 0.0 {
                continue;
            }
            let row = &self.w[i * self.n_out..(i + 1) * self.n_out];
            for (o, &wv) in out.iter_mut().zip(row) {
                *o += xi * wv;
            }
        }
    }

    /// Row-batched forward: `x` is `[rows][n_in]` row-major, `out` is
    /// `[rows][n_out]`. Each row is computed exactly as [`Dense::forward`]
    /// would (same accumulation order ⇒ bit-identical per row); the batching
    /// is a cache-friendly matmul-shaped sweep over a whole lattice level.
    pub fn forward_rows(&self, x: &[f32], rows: usize, out: &mut [f32]) {
        debug_assert_eq!(x.len(), rows * self.n_in);
        debug_assert_eq!(out.len(), rows * self.n_out);
        for r in 0..rows {
            self.forward(
                &x[r * self.n_in..(r + 1) * self.n_in],
                &mut out[r * self.n_out..(r + 1) * self.n_out],
            );
        }
    }

    /// Lane-parallel row-batched forward: `LANES` query rows advance in
    /// lock-step, one row per SIMD lane (structure-of-arrays: the block is
    /// transposed so lane *l* holds row *l*, weights broadcast across
    /// lanes). For a fixed (row, output) element the accumulation runs over
    /// inputs in ascending order with one add per **non-zero** input —
    /// exactly [`Dense::forward`]'s order and skip rule — so every row is
    /// bit-identical to the scalar reference *by construction*, lanes or
    /// not. Rows beyond the last full block take the scalar path (the
    /// tail); without the `simd` feature the whole call does.
    pub fn forward_rows_lanes(
        &self,
        x: &[f32],
        rows: usize,
        out: &mut [f32],
        scratch: &mut LaneScratch,
    ) {
        debug_assert_eq!(x.len(), rows * self.n_in);
        debug_assert_eq!(out.len(), rows * self.n_out);
        let blocks = if cfg!(feature = "simd") { rows / LANES } else { 0 };
        let (n_in, n_out) = (self.n_in, self.n_out);
        if blocks > 0 {
            scratch.xt.clear();
            scratch.xt.resize(n_in * LANES, 0.0);
            scratch.ot.clear();
            scratch.ot.resize(n_out * LANES, 0.0);
        }
        for blk in 0..blocks {
            let base = blk * LANES;
            // SoA transpose in: lane l = query row base + l. Pure data
            // movement — the f32 bits are untouched.
            for i in 0..n_in {
                for l in 0..LANES {
                    scratch.xt[i * LANES + l] = x[(base + l) * n_in + i];
                }
            }
            // Bias splat: every lane starts from b, like `forward`'s
            // `copy_from_slice(&self.b)`.
            for o in 0..n_out {
                scratch.ot[o * LANES..(o + 1) * LANES].fill(self.b[o]);
            }
            self.lane_block::<LANES>(&scratch.xt, &mut scratch.ot);
            // Transpose back out.
            for o in 0..n_out {
                for l in 0..LANES {
                    out[(base + l) * n_out + o] = scratch.ot[o * LANES + l];
                }
            }
        }
        for r in blocks * LANES..rows {
            self.forward(
                &x[r * n_in..(r + 1) * n_in],
                &mut out[r * n_out..(r + 1) * n_out],
            );
        }
    }

    /// The lane-width-generic inner kernel: `xt`/`ot` are SoA blocks of `L`
    /// rows. Loop order is input-outer, output-middle, lane-innermost, so
    /// per (lane, output) the adds land in ascending input order — the
    /// scalar order. The per-lane `x != 0.0` guard compiles to a
    /// compare+select (no branch), preserving the scalar path's zero-skip
    /// bit behaviour: a zero input leaves the accumulator bits untouched
    /// (an unconditional `acc + 0.0·w` could flip `-0.0` to `+0.0`).
    #[inline]
    fn lane_block<const L: usize>(&self, xt: &[f32], ot: &mut [f32]) {
        debug_assert_eq!(xt.len(), self.n_in * L);
        debug_assert_eq!(ot.len(), self.n_out * L);
        for i in 0..self.n_in {
            let xl: &[f32; L] = xt[i * L..(i + 1) * L].try_into().unwrap();
            let row = &self.w[i * self.n_out..(i + 1) * self.n_out];
            for (o, &wv) in row.iter().enumerate() {
                let acc: &mut [f32; L] = (&mut ot[o * L..(o + 1) * L]).try_into().unwrap();
                for l in 0..L {
                    let xv = xl[l];
                    if xv != 0.0 {
                        acc[l] += xv * wv;
                    }
                }
            }
        }
    }
}

/// One single-head GAT layer.
#[derive(Clone, Debug)]
pub struct GatLayer {
    pub lin: Dense,
    /// Attention vectors over the transformed features, length `n_out`.
    pub a_src: Vec<f32>,
    pub a_dst: Vec<f32>,
}

#[inline]
fn leaky_relu(x: f32) -> f32 {
    if x >= 0.0 {
        x
    } else {
        0.2 * x
    }
}

#[inline]
fn elu(x: f32) -> f32 {
    if x >= 0.0 {
        x
    } else {
        x.exp() - 1.0
    }
}

#[inline]
pub fn relu(x: f32) -> f32 {
    x.max(0.0)
}

/// Reusable buffers for GAT forwards: transformed features, attention
/// pre-products, and the per-node softmax weights. One instance serves any
/// number of forwards; nothing is allocated once capacities are warm.
#[derive(Clone, Debug, Default)]
pub struct GatScratch {
    hx: Vec<f32>,
    s_src: Vec<f32>,
    s_dst: Vec<f32>,
    weights: Vec<f32>,
    lanes: LaneScratch,
}

impl GatLayer {
    /// `x`: `[n][n_in]` row-major; `adj`: symmetrised in-neighbour CSR (must
    /// include self-loops). Writes `[n][n_out]` into `out`.
    pub fn forward_into(
        &self,
        x: &[f32],
        n: usize,
        adj: &Adjacency,
        scratch: &mut GatScratch,
        out: &mut Vec<f32>,
    ) {
        let h = self.lin.n_out;
        debug_assert_eq!(adj.n(), n);
        // h_i = W x_i for all nodes — one lane-parallel pass over the node
        // rows (bit-identical per node to the scalar forward).
        scratch.hx.clear();
        scratch.hx.resize(n * h, 0.0);
        self.lin
            .forward_rows_lanes(&x[..n * self.lin.n_in], n, &mut scratch.hx, &mut scratch.lanes);
        let hx = &mut scratch.hx;
        // Pre-compute a_src·h_i and a_dst·h_j.
        scratch.s_src.clear();
        scratch.s_src.resize(n, 0.0);
        scratch.s_dst.clear();
        scratch.s_dst.resize(n, 0.0);
        for i in 0..n {
            let hi = &hx[i * h..(i + 1) * h];
            scratch.s_src[i] = dot(&self.a_src, hi);
            scratch.s_dst[i] = dot(&self.a_dst, hi);
        }
        out.clear();
        out.resize(n * h, 0.0);
        let (s_src, s_dst) = (&scratch.s_src, &scratch.s_dst);
        let weights = &mut scratch.weights;
        for i in 0..n {
            let ns = adj.neighbours(i);
            debug_assert!(!ns.is_empty(), "node {i} lacks self-loop");
            // Attention logits + stable softmax.
            weights.clear();
            weights.extend(
                ns.iter()
                    .map(|&j| leaky_relu(s_src[i] + s_dst[j as usize])),
            );
            let m = weights.iter().copied().fold(f32::NEG_INFINITY, f32::max);
            let mut z = 0.0f32;
            for w in weights.iter_mut() {
                *w = (*w - m).exp();
                z += *w;
            }
            let oi = &mut out[i * h..(i + 1) * h];
            for (&j, &w) in ns.iter().zip(weights.iter()) {
                let hj = &hx[j as usize * h..(j as usize + 1) * h];
                let a = w / z;
                for (o, &v) in oi.iter_mut().zip(hj) {
                    *o += a * v;
                }
            }
            for o in oi.iter_mut() {
                *o = elu(*o);
            }
        }
    }

    /// Allocating convenience wrapper around [`GatLayer::forward_into`].
    pub fn forward(&self, x: &[f32], n: usize, adj: &Adjacency) -> Vec<f32> {
        let mut scratch = GatScratch::default();
        let mut out = Vec::new();
        self.forward_into(x, n, adj, &mut scratch, &mut out);
        out
    }
}

#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(x, y)| x * y).sum()
}

/// Masked mean-pool over node embeddings `[n][h]`, into a reusable buffer.
///
/// Pooling is a cross-row reduction, so the lanes here run across the `h`
/// embedding **columns** (`LANES` accumulators advance together), never
/// across rows: each output element still sums rows in ascending order —
/// the accumulation order, and therefore every bit, is unchanged from the
/// plain column loop.
pub fn mean_pool_into(x: &[f32], n: usize, h: usize, out: &mut Vec<f32>) {
    out.clear();
    out.resize(h, 0.0);
    if n == 0 {
        return;
    }
    let lanes_end = if cfg!(feature = "simd") { h - h % LANES } else { 0 };
    for i in 0..n {
        let row = &x[i * h..(i + 1) * h];
        let mut c = 0;
        while c < lanes_end {
            let acc: &mut [f32; LANES] = (&mut out[c..c + LANES]).try_into().unwrap();
            let src: &[f32; LANES] = row[c..c + LANES].try_into().unwrap();
            for l in 0..LANES {
                acc[l] += src[l];
            }
            c += LANES;
        }
        while c < h {
            out[c] += row[c];
            c += 1;
        }
    }
    for o in out.iter_mut() {
        *o /= n as f32;
    }
}

/// Allocating convenience wrapper around [`mean_pool_into`].
pub fn mean_pool(x: &[f32], n: usize, h: usize) -> Vec<f32> {
    let mut out = Vec::new();
    mean_pool_into(x, n, h, &mut out);
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Pcg64;

    fn rand_dense(rng: &mut Pcg64, n_in: usize, n_out: usize) -> Dense {
        Dense {
            n_in,
            n_out,
            w: (0..n_in * n_out)
                .map(|_| rng.normal_ms(0.0, 0.3) as f32)
                .collect(),
            b: (0..n_out).map(|_| rng.normal_ms(0.0, 0.1) as f32).collect(),
        }
    }

    fn rand_gat(rng: &mut Pcg64, n_in: usize, n_out: usize) -> GatLayer {
        GatLayer {
            lin: rand_dense(rng, n_in, n_out),
            a_src: (0..n_out).map(|_| rng.normal_ms(0.0, 0.3) as f32).collect(),
            a_dst: (0..n_out).map(|_| rng.normal_ms(0.0, 0.3) as f32).collect(),
        }
    }

    #[test]
    fn dense_matches_manual() {
        let d = Dense {
            n_in: 2,
            n_out: 2,
            w: vec![1.0, 2.0, 3.0, 4.0], // rows: x0 -> [1,2], x1 -> [3,4]
            b: vec![0.5, -0.5],
        };
        let mut out = vec![0.0; 2];
        d.forward(&[2.0, 1.0], &mut out);
        assert_eq!(out, vec![2.0 + 3.0 + 0.5, 4.0 + 4.0 - 0.5]);
    }

    #[test]
    fn dense_rows_bitwise_match_scalar() {
        let mut rng = Pcg64::seeded(9);
        let d = rand_dense(&mut rng, 7, 5);
        let x: Vec<f32> = (0..4 * 7).map(|_| rng.normal_ms(0.0, 1.0) as f32).collect();
        let mut batched = vec![0.0f32; 4 * 5];
        d.forward_rows(&x, 4, &mut batched);
        for r in 0..4 {
            let mut one = vec![0.0f32; 5];
            d.forward(&x[r * 7..(r + 1) * 7], &mut one);
            for k in 0..5 {
                assert_eq!(one[k].to_bits(), batched[r * 5 + k].to_bits(), "row {r} col {k}");
            }
        }
    }

    #[test]
    fn dense_lanes_bitwise_match_scalar_including_tail() {
        // Row counts straddling the lane width: 1 (all tail), LANES-1,
        // LANES, LANES+3, 3*LANES (all blocks). Every row must match the
        // scalar forward to the bit.
        let mut rng = Pcg64::seeded(31);
        let d = rand_dense(&mut rng, 11, 6);
        let mut scratch = LaneScratch::default();
        for rows in [1, LANES - 1, LANES, LANES + 3, 3 * LANES] {
            let x: Vec<f32> = (0..rows * 11).map(|_| rng.normal_ms(0.0, 1.0) as f32).collect();
            let mut lanes = vec![0.0f32; rows * 6];
            d.forward_rows_lanes(&x, rows, &mut lanes, &mut scratch);
            let mut reference = vec![0.0f32; rows * 6];
            d.forward_rows(&x, rows, &mut reference);
            for (k, (a, b)) in lanes.iter().zip(&reference).enumerate() {
                assert_eq!(a.to_bits(), b.to_bits(), "rows={rows} elem {k}");
            }
        }
    }

    #[test]
    fn dense_lanes_honour_the_zero_skip_rule() {
        // Zero-heavy inputs (one-hot features, post-ReLU activations) take
        // the scalar path's skip; the lane kernel's select must leave the
        // accumulator bits untouched for those lanes — including the sign
        // of a -0.0 bias surviving an all-zero input row.
        let mut rng = Pcg64::seeded(32);
        let mut d = rand_dense(&mut rng, 9, 5);
        d.b[2] = -0.0;
        let mut scratch = LaneScratch::default();
        let rows = 2 * LANES + 1;
        let x: Vec<f32> = (0..rows * 9)
            .map(|k| {
                if k % 3 == 0 {
                    0.0
                } else {
                    relu(rng.normal_ms(0.0, 1.0) as f32)
                }
            })
            .collect();
        let mut lanes = vec![0.0f32; rows * 5];
        d.forward_rows_lanes(&x, rows, &mut lanes, &mut scratch);
        let mut reference = vec![0.0f32; rows * 5];
        d.forward_rows(&x, rows, &mut reference);
        for (k, (a, b)) in lanes.iter().zip(&reference).enumerate() {
            assert_eq!(a.to_bits(), b.to_bits(), "elem {k}");
        }
        // An all-zero input row reproduces the bias verbatim, -0.0 and all.
        let zero = vec![0.0f32; LANES * 9];
        let mut out = vec![0.0f32; LANES * 5];
        d.forward_rows_lanes(&zero, LANES, &mut out, &mut scratch);
        for r in 0..LANES {
            for (o, &b) in d.b.iter().enumerate() {
                assert_eq!(out[r * 5 + o].to_bits(), b.to_bits(), "row {r} col {o}");
            }
        }
    }

    #[test]
    fn gat_attention_sums_to_one() {
        // With identical neighbour features, output = transformed feature
        // (softmax convexity) — checks normalisation.
        let mut rng = Pcg64::seeded(1);
        let gat = rand_gat(&mut rng, 3, 4);
        let x: Vec<f32> = [0.3f32, -0.2, 0.9].repeat(3);
        let adj = Adjacency::from_edges(3, &[(0, 1), (1, 2)]);
        let out = gat.forward(&x, 3, &adj);
        // All nodes have identical inputs ⇒ identical outputs.
        assert_eq!(out[0..4], out[4..8]);
        assert_eq!(out[4..8], out[8..12]);
    }

    #[test]
    fn gat_permutation_equivariance() {
        // Relabelling nodes (and edges) permutes outputs accordingly.
        let mut rng = Pcg64::seeded(2);
        let gat = rand_gat(&mut rng, 3, 4);
        let x = vec![
            0.1f32, 0.2, 0.3, // node 0
            -0.5, 0.4, 0.0, // node 1
            0.9, -0.1, 0.7, // node 2
        ];
        let edges = vec![(0, 1), (1, 2)];
        let out = gat.forward(&x, 3, &Adjacency::from_edges(3, &edges));
        // Permutation: 0->2, 1->0, 2->1 (i.e. new[perm[i]] = old[i]).
        let perm = [2usize, 0, 1];
        let mut px = vec![0.0f32; 9];
        for i in 0..3 {
            px[perm[i] * 3..(perm[i] + 1) * 3].copy_from_slice(&x[i * 3..(i + 1) * 3]);
        }
        let pedges: Vec<(usize, usize)> = edges.iter().map(|&(s, d)| (perm[s], perm[d])).collect();
        let pout = gat.forward(&px, 3, &Adjacency::from_edges(3, &pedges));
        for i in 0..3 {
            for k in 0..4 {
                let a = out[i * 4 + k];
                let b = pout[perm[i] * 4 + k];
                assert!((a - b).abs() < 1e-5, "node {i} dim {k}: {a} vs {b}");
            }
        }
    }

    #[test]
    fn gat_scratch_reuse_is_bit_identical() {
        // The same scratch driven through different graphs must not leak
        // state between forwards.
        let mut rng = Pcg64::seeded(4);
        let gat = rand_gat(&mut rng, 3, 4);
        let xa: Vec<f32> = (0..9).map(|_| rng.normal_ms(0.0, 1.0) as f32).collect();
        let xb: Vec<f32> = (0..15).map(|_| rng.normal_ms(0.0, 1.0) as f32).collect();
        let adj_a = Adjacency::from_edges(3, &[(0, 2)]);
        let adj_b = Adjacency::from_edges(5, &[(0, 1), (1, 4), (2, 3)]);
        let fresh_a = gat.forward(&xa, 3, &adj_a);
        let fresh_b = gat.forward(&xb, 5, &adj_b);
        let mut scratch = GatScratch::default();
        let mut out = Vec::new();
        for _ in 0..3 {
            gat.forward_into(&xa, 3, &adj_a, &mut scratch, &mut out);
            assert_eq!(out, fresh_a);
            gat.forward_into(&xb, 5, &adj_b, &mut scratch, &mut out);
            assert_eq!(out, fresh_b);
        }
    }

    #[test]
    fn mean_pool_averages() {
        let x = vec![1.0f32, 2.0, 3.0, 4.0]; // 2 nodes × 2 dims
        assert_eq!(mean_pool(&x, 2, 2), vec![2.0, 3.0]);
        let mut buf = vec![9.0f32; 7]; // stale content must be overwritten
        mean_pool_into(&x, 2, 2, &mut buf);
        assert_eq!(buf, vec![2.0, 3.0]);
    }

    #[test]
    fn activations() {
        assert_eq!(leaky_relu(1.0), 1.0);
        assert_eq!(leaky_relu(-1.0), -0.2);
        assert_eq!(elu(2.0), 2.0);
        assert!((elu(-1.0) - (f32::exp(-1.0) - 1.0)).abs() < 1e-7);
        assert_eq!(relu(-3.0), 0.0);
    }
}
