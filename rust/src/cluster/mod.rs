//! Cluster state: nodes, GPUs, pods, functions, and the GPU Re-configurator.
//!
//! Mirrors the paper's control-plane view (Fig. 1): the Hybrid Auto-Scaler
//! reasons over function pods (`P_f`) and per-GPU occupancy (`{G_i}`, HGO);
//! the **Re-configurator** is the only component that mutates GPU state — it
//! bypasses the k8s device plugin, identifies GPUs by UUID (NVML-style), and
//! writes allocation changes to each vGPU's device files.

pub mod reconfigurator;

pub use reconfigurator::{Applied, ApplyError, Reconfigurator, ScalingAction};

use crate::model::OpGraph;
use crate::vgpu::{ClientId, GpuClass, QuotaMille, SmMille, VGpu};
use std::collections::BTreeMap;

/// Cold-start latencies (seconds) — paper §4.3: KServe's GPU-instance
/// horizontal scaling "incurs high latency from GPU device and system
/// initialization"; shared-GPU platforms pay a container + model-load start;
/// HAS-GPU vertical scaling pays neither.
#[derive(Clone, Copy, Debug)]
pub struct ColdStartSpec {
    /// New GPU instance (device init + driver + system): KServe-style.
    pub gpu_instance: f64,
    /// New container on an already-managed GPU (image + CUDA ctx + model load).
    pub container: f64,
    /// Jitter fraction applied by the simulator (± uniform).
    pub jitter: f64,
}

impl Default for ColdStartSpec {
    fn default() -> Self {
        ColdStartSpec {
            // GPU *instance* provisioning (VM + driver + device init) — the
            // paper singles this out as KServe's tail-latency killer.
            gpu_instance: 20.0,
            container: 3.0,
            jitter: 0.2,
        }
    }
}

/// A deployed serverless inference function (the HASFunc CRD analogue).
#[derive(Clone, Debug)]
pub struct FunctionSpec {
    pub name: String,
    /// Operator graph (drives the perf model, RaPP features, memory checks).
    pub graph: OpGraph,
    /// SLO latency bound in seconds.
    pub slo: f64,
    /// Serving batch size used by this function's pods.
    pub batch: u32,
    /// Real-mode artifact path (HLO text); None in pure-sim experiments.
    pub artifact: Option<std::path::PathBuf>,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct PodId(pub u64);

#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct GpuId(pub usize);

/// Pod lifecycle.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum PodPhase {
    /// Starting up; serves no traffic until `ready_at`.
    ColdStarting { ready_at: f64 },
    Running,
    /// Excluded from routing; removed once in-flight work drains.
    Draining,
}

/// Where a pod's model weights live — the cold-start axis (Torpor/FaaSwap
/// design space). Orthogonal to [`PodPhase`]: phase tracks the container's
/// serving lifecycle, state tracks weight residency. Only `DeviceResident`
/// pods can serve; a `HostCached` pod is parked (weights in host memory,
/// billed at the reduced host-memory rate) and must be promoted — paying
/// the host→device swap — before serving again.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PodState {
    /// No weights staged anywhere yet (freshly scheduled).
    Cold,
    /// Weights parked in host memory; device SM/quota held but idle.
    HostCached,
    /// Weights on the device: the only state that serves traffic.
    DeviceResident,
}

impl PodState {
    /// Legal state-machine edges: `Cold → HostCached → DeviceResident` with
    /// demotion back to `HostCached` (weights are never dropped to `Cold`
    /// while the pod exists — removal is the only way out).
    pub fn can_transition(self, to: PodState) -> bool {
        matches!(
            (self, to),
            (PodState::Cold, PodState::HostCached)
                | (PodState::HostCached, PodState::DeviceResident)
                | (PodState::DeviceResident, PodState::HostCached)
        )
    }

    pub fn name(self) -> &'static str {
        match self {
            PodState::Cold => "cold",
            PodState::HostCached => "host-cached",
            PodState::DeviceResident => "device-resident",
        }
    }
}

/// A function instance bound to an SM partition + quota on one GPU.
#[derive(Clone, Debug)]
pub struct Pod {
    pub id: PodId,
    pub function: String,
    pub gpu: GpuId,
    pub sm: SmMille,
    pub quota: QuotaMille,
    pub batch: u32,
    pub phase: PodPhase,
    /// Weight residency (the cold-start axis). Pods created under the
    /// default zero-latency lifecycle config are born `DeviceResident`.
    pub state: PodState,
    /// When the pod entered its current [`PodState`] (keep-alive clock).
    pub state_since: f64,
    /// Model weight footprint in bytes (what a host↔device swap moves).
    pub weight_bytes: f64,
    pub created_at: f64,
}

impl Pod {
    pub fn client_id(&self) -> ClientId {
        ClientId(self.id.0)
    }

    pub fn is_ready(&self, now: f64) -> bool {
        if self.state != PodState::DeviceResident {
            return false;
        }
        match self.phase {
            PodPhase::ColdStarting { ready_at } => now >= ready_at,
            PodPhase::Running => true,
            PodPhase::Draining => false,
        }
    }
}

/// Whole-cluster state: the auto-scaler's world view.
pub struct ClusterState {
    gpus: Vec<VGpu>,
    pods: BTreeMap<PodId, Pod>,
    functions: BTreeMap<String, FunctionSpec>,
    /// function → pod ids, kept sorted ascending — the same order the old
    /// full-map scan produced — so `pods_of` is O(own pods) instead of
    /// O(all pods). Maintained by the sole mutation points
    /// [`ClusterState::insert_pod`] / [`ClusterState::remove_pod`].
    by_fn: BTreeMap<String, Vec<PodId>>,
    next_pod: u64,
    pub coldstart: ColdStartSpec,
    /// Failed-device mask (fault injection): `down[i]` excludes GPU `i`
    /// from every placement iterator until repaired. All-false by default,
    /// so fault-free runs scan exactly the historical GPU sets.
    down: Vec<bool>,
}

impl ClusterState {
    /// A cluster of `n_gpus` identical reference-class (V100) GPUs with
    /// `mem_cap` bytes each — the homogeneous pre-catalog constructor.
    pub fn new(n_gpus: usize, mem_cap: f64) -> Self {
        ClusterState {
            gpus: (0..n_gpus)
                .map(|i| VGpu::new(&format!("GPU-{i:04x}"), mem_cap))
                .collect(),
            pods: BTreeMap::new(),
            functions: BTreeMap::new(),
            by_fn: BTreeMap::new(),
            next_pod: 1,
            coldstart: ColdStartSpec::default(),
            down: vec![false; n_gpus],
        }
    }

    /// A heterogeneous cluster: one GPU per entry of `classes`, in order
    /// (fleet declaration order — GPU index is a placement tie-break, so
    /// the order is part of a fleet's deterministic identity). UUIDs keep
    /// the homogeneous `GPU-{i:04x}` format; each device's memory capacity
    /// comes from its class descriptor.
    pub fn from_classes(classes: &[GpuClass]) -> Self {
        ClusterState {
            gpus: classes
                .iter()
                .enumerate()
                .map(|(i, c)| VGpu::with_class(&format!("GPU-{i:04x}"), c.clone()))
                .collect(),
            pods: BTreeMap::new(),
            functions: BTreeMap::new(),
            by_fn: BTreeMap::new(),
            next_pod: 1,
            coldstart: ColdStartSpec::default(),
            down: vec![false; classes.len()],
        }
    }

    pub fn register_function(&mut self, spec: FunctionSpec) {
        self.functions.insert(spec.name.clone(), spec);
    }

    pub fn function(&self, name: &str) -> Option<&FunctionSpec> {
        self.functions.get(name)
    }

    pub fn functions(&self) -> impl Iterator<Item = &FunctionSpec> {
        self.functions.values()
    }

    pub fn n_gpus(&self) -> usize {
        self.gpus.len()
    }

    pub fn gpu(&self, id: GpuId) -> &VGpu {
        &self.gpus[id.0]
    }

    pub fn gpu_mut(&mut self, id: GpuId) -> &mut VGpu {
        &mut self.gpus[id.0]
    }

    pub fn pods(&self) -> impl Iterator<Item = &Pod> {
        self.pods.values()
    }

    pub fn pod(&self, id: PodId) -> Option<&Pod> {
        self.pods.get(&id)
    }

    pub fn pod_mut(&mut self, id: PodId) -> Option<&mut Pod> {
        self.pods.get_mut(&id)
    }

    /// Move a pod along the lifecycle state machine, keeping the vGPU's
    /// device/host memory accounting in sync. Rejects illegal edges (see
    /// [`PodState::can_transition`]). Demotion parks the weight footprint in
    /// host memory; promotion requires that much free device memory.
    pub fn set_pod_state(&mut self, id: PodId, to: PodState, now: f64) -> Result<(), String> {
        let (from, gpu, bytes) = {
            let p = self
                .pods
                .get(&id)
                .ok_or_else(|| format!("unknown pod {id:?}"))?;
            (p.state, p.gpu, p.weight_bytes)
        };
        if !from.can_transition(to) {
            return Err(format!(
                "illegal pod state transition {} -> {}",
                from.name(),
                to.name()
            ));
        }
        match (from, to) {
            (PodState::DeviceResident, PodState::HostCached) => {
                self.gpus[gpu.0].swap_out(bytes);
            }
            (PodState::HostCached, PodState::DeviceResident) => {
                self.gpus[gpu.0]
                    .swap_in(bytes)
                    .map_err(|e| e.to_string())?;
            }
            _ => {}
        }
        let p = self.pods.get_mut(&id).expect("pod checked above");
        p.state = to;
        p.state_since = now;
        Ok(())
    }

    /// Pods of one function (any phase), ascending pod id — exactly the
    /// order the historical full-map scan returned.
    pub fn pods_of(&self, function: &str) -> Vec<&Pod> {
        self.by_fn
            .get(function)
            .map(|ids| ids.iter().map(|id| &self.pods[id]).collect())
            .unwrap_or_default()
    }

    /// Whether the function currently owns any pod — O(log functions), no
    /// allocation (the active-set planner's residency probe).
    pub fn has_pods(&self, function: &str) -> bool {
        self.by_fn.get(function).is_some_and(|v| !v.is_empty())
    }

    /// Pod ids resident on one GPU, in id order (fault eviction sweeps).
    pub fn pods_on(&self, gpu: GpuId) -> Vec<PodId> {
        self.pods
            .values()
            .filter(|p| p.gpu == gpu)
            .map(|p| p.id)
            .collect()
    }

    /// Mark a GPU failed (`down = true`) or repaired (`down = false`).
    /// Down GPUs vanish from [`ClusterState::used_gpus`] /
    /// [`ClusterState::idle_gpus`] and thus from every placement rule,
    /// across all platforms, without touching their rules.
    pub fn set_gpu_down(&mut self, gpu: GpuId, down: bool) {
        self.down[gpu.0] = down;
    }

    /// Whether a GPU is currently failed.
    pub fn gpu_is_down(&self, gpu: GpuId) -> bool {
        self.down[gpu.0]
    }

    /// GPUs currently hosting at least one pod, in index order. An
    /// iterator — the plan tick scans this every function every tick, so
    /// no `Vec` is allocated (pinned in `benches/scheduler_hotpath.rs`).
    /// Down (failed) GPUs are excluded; the mask is all-false in fault-free
    /// runs, so the historical scan order is untouched.
    pub fn used_gpus(&self) -> impl Iterator<Item = GpuId> + '_ {
        self.gpus
            .iter()
            .enumerate()
            .filter(|&(i, g)| !g.is_idle() && !self.down[i])
            .map(|(i, _)| GpuId(i))
    }

    /// Idle GPUs in index order (allocation-free scan; down GPUs excluded).
    pub fn idle_gpus(&self) -> impl Iterator<Item = GpuId> + '_ {
        self.gpus
            .iter()
            .enumerate()
            .filter(|&(i, g)| g.is_idle() && !self.down[i])
            .map(|(i, _)| GpuId(i))
    }

    /// An idle GPU, if any (horizontal scale-up to a "new GPU", line 18-19).
    pub fn idle_gpu(&self) -> Option<GpuId> {
        self.idle_gpus().next()
    }

    /// Used GPU with the lowest HGO (Algorithm 1, line 11). First-wins on
    /// HGO ties (index order), as the seed's `min_by` did. `total_cmp`
    /// orders identically on real HGO values and cannot panic on NaN.
    pub fn least_occupied_used_gpu(&self) -> Option<GpuId> {
        self.used_gpus()
            .min_by(|&a, &b| self.gpus[a.0].hgo().total_cmp(&self.gpus[b.0].hgo()))
    }

    /// Used GPU for a new pod under heterogeneous fleets: cheapest feasible
    /// class first (`feasible` judges a *class* — memory + SLO under its
    /// throughput factor), price ascending, tie-broken by lowest HGO then
    /// index. When no used GPU's class is feasible, falls back to the pure
    /// lowest-HGO rule — so on a uniform fleet (one class) the choice is
    /// *exactly* [`ClusterState::least_occupied_used_gpu`], feasible or not
    /// (the byte-identity contract for `uniform-v100`).
    pub fn cheapest_feasible_used_gpu(
        &self,
        mut feasible: impl FnMut(&GpuClass) -> bool,
    ) -> Option<GpuId> {
        let mut best: Option<(f64, f64, GpuId)> = None; // (price, hgo, id)
        for id in self.used_gpus() {
            let g = &self.gpus[id.0];
            if !feasible(g.class()) {
                continue;
            }
            let key = (g.class().price_per_hour, g.hgo());
            if best.map_or(true, |(p, h, _)| key < (p, h)) {
                best = Some((key.0, key.1, id));
            }
        }
        best.map(|(_, _, id)| id)
            .or_else(|| self.least_occupied_used_gpu())
    }

    /// Idle GPU for a new pod under heterogeneous fleets: cheapest feasible
    /// class, price ascending, tie-broken by index. Falls back to the first
    /// idle GPU (index order) when no idle GPU's class is feasible — the
    /// uniform-fleet choice is exactly [`ClusterState::idle_gpu`].
    pub fn cheapest_feasible_idle_gpu(
        &self,
        mut feasible: impl FnMut(&GpuClass) -> bool,
    ) -> Option<GpuId> {
        let mut best: Option<(f64, GpuId)> = None; // (price, id)
        for id in self.idle_gpus() {
            let g = &self.gpus[id.0];
            if !feasible(g.class()) {
                continue;
            }
            let price = g.class().price_per_hour;
            if best.map_or(true, |(p, _)| price < p) {
                best = Some((price, id));
            }
        }
        best.map(|(_, id)| id).or_else(|| self.idle_gpu())
    }

    /// Number of GPUs with at least one pod (cost reporting).
    pub fn gpus_in_use(&self) -> usize {
        self.used_gpus().count()
    }

    /// Allocate a pod id (the Re-configurator performs the actual placement).
    pub(crate) fn alloc_pod_id(&mut self) -> PodId {
        let id = PodId(self.next_pod);
        self.next_pod += 1;
        id
    }

    pub(crate) fn insert_pod(&mut self, pod: Pod) {
        let ids = self.by_fn.entry(pod.function.clone()).or_default();
        let pos = ids.partition_point(|&id| id < pod.id);
        if ids.get(pos) != Some(&pod.id) {
            ids.insert(pos, pod.id);
        }
        self.pods.insert(pod.id, pod);
    }

    pub(crate) fn remove_pod(&mut self, id: PodId) -> Option<Pod> {
        let p = self.pods.remove(&id);
        if let Some(pod) = &p {
            if let Some(ids) = self.by_fn.get_mut(&pod.function) {
                ids.retain(|&x| x != id);
                if ids.is_empty() {
                    self.by_fn.remove(&pod.function);
                }
            }
        }
        p
    }

    /// Global invariant check for property tests: every pod's placement is
    /// consistent with its GPU's vGPU accounting.
    pub fn check_invariants(&self) -> Result<(), String> {
        for g in &self.gpus {
            g.check_invariants()?;
        }
        for pod in self.pods.values() {
            let vg = &self.gpus[pod.gpu.0];
            let placement = vg
                .clients()
                .get(&pod.client_id())
                .ok_or_else(|| format!("pod {:?} missing from vGPU {}", pod.id, vg.uuid))?;
            if placement.sm != pod.sm || placement.quota != pod.quota {
                return Err(format!(
                    "pod {:?} desync: pod(sm={},q={}) vgpu(sm={},q={})",
                    pod.id, pod.sm, pod.quota, placement.sm, placement.quota
                ));
            }
        }
        // No orphan clients.
        let pod_clients: std::collections::BTreeSet<ClientId> =
            self.pods.values().map(|p| p.client_id()).collect();
        for g in &self.gpus {
            for (&c, _) in g.clients() {
                if !pod_clients.contains(&c) {
                    return Err(format!("orphan client {c:?} on {}", g.uuid));
                }
            }
        }
        // The per-function pod index mirrors the pod map exactly.
        let mut indexed = 0usize;
        for (f, ids) in &self.by_fn {
            if ids.is_empty() {
                return Err(format!("empty by_fn bucket for {f}"));
            }
            for w in ids.windows(2) {
                if w[0] >= w[1] {
                    return Err(format!("by_fn bucket for {f} not sorted: {ids:?}"));
                }
            }
            for id in ids {
                let p = self
                    .pods
                    .get(id)
                    .ok_or_else(|| format!("by_fn {f} lists missing pod {id:?}"))?;
                if p.function != *f {
                    return Err(format!("pod {id:?} indexed under {f} but owned by {}", p.function));
                }
                indexed += 1;
            }
        }
        if indexed != self.pods.len() {
            return Err(format!(
                "by_fn indexes {indexed} pods but map holds {}",
                self.pods.len()
            ));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::zoo::{zoo_graph, ZooModel};

    pub(crate) fn test_cluster() -> ClusterState {
        let mut c = ClusterState::new(4, 16e9);
        c.register_function(FunctionSpec {
            name: "resnet50".into(),
            graph: zoo_graph(ZooModel::ResNet50),
            slo: 0.1,
            batch: 8,
            artifact: None,
        });
        c
    }

    #[test]
    fn gpu_inventory() {
        let c = test_cluster();
        assert_eq!(c.n_gpus(), 4);
        assert_eq!(c.used_gpus().count(), 0);
        assert_eq!(c.idle_gpu(), Some(GpuId(0)));
        assert_eq!(c.idle_gpus().count(), 4);
        assert!(c.function("resnet50").is_some());
        assert!(c.function("nope").is_none());
    }

    #[test]
    fn down_gpus_vanish_from_placement_iterators() {
        let mut c = test_cluster();
        c.set_gpu_down(GpuId(0), true);
        assert!(c.gpu_is_down(GpuId(0)));
        assert_eq!(c.idle_gpu(), Some(GpuId(1)));
        assert_eq!(c.idle_gpus().count(), 3);
        // Occupy GPU 1, then fail it: used_gpus must skip it too.
        c.gpu_mut(GpuId(1)).attach(ClientId(9), 500, 500, 1e9).unwrap();
        assert_eq!(c.used_gpus().count(), 1);
        c.set_gpu_down(GpuId(1), true);
        assert_eq!(c.used_gpus().count(), 0);
        assert!(c.least_occupied_used_gpu().is_none());
        // Repair restores the historical view.
        c.set_gpu_down(GpuId(1), false);
        assert_eq!(c.least_occupied_used_gpu(), Some(GpuId(1)));
        c.set_gpu_down(GpuId(0), false);
        assert_eq!(c.idle_gpu(), Some(GpuId(0)));
    }

    #[test]
    fn from_classes_builds_one_gpu_per_entry_in_order() {
        let classes = vec![GpuClass::a100(), GpuClass::v100(), GpuClass::t4()];
        let c = ClusterState::from_classes(&classes);
        assert_eq!(c.n_gpus(), 3);
        assert_eq!(c.gpu(GpuId(0)).class().name, "a100");
        assert_eq!(c.gpu(GpuId(1)).class().name, "v100");
        assert_eq!(c.gpu(GpuId(2)).class().name, "t4");
        assert_eq!(c.gpu(GpuId(0)).uuid, "GPU-0000");
        assert_eq!(c.gpu(GpuId(0)).mem_free(), GpuClass::a100().mem_cap);
        c.check_invariants().unwrap();
    }

    #[test]
    fn cheapest_feasible_idle_gpu_orders_by_price_then_index() {
        let classes = vec![GpuClass::a100(), GpuClass::t4(), GpuClass::v100(), GpuClass::t4()];
        let c = ClusterState::from_classes(&classes);
        // All feasible: the first (lowest-index) T4 wins on price.
        assert_eq!(c.cheapest_feasible_idle_gpu(|_| true), Some(GpuId(1)));
        // T4 infeasible (e.g. SLO too tight for a slow class): next-cheapest.
        assert_eq!(
            c.cheapest_feasible_idle_gpu(|cl| cl.name != "t4"),
            Some(GpuId(2))
        );
        // Nothing feasible: fall back to the first idle GPU — exactly the
        // homogeneous rule, so a uniform fleet is never perturbed.
        assert_eq!(c.cheapest_feasible_idle_gpu(|_| false), c.idle_gpu());
    }

    #[test]
    fn cheapest_feasible_used_gpu_breaks_price_ties_by_hgo() {
        let mut c = ClusterState::from_classes(&[
            GpuClass::v100(),
            GpuClass::v100(),
            GpuClass::t4(),
        ]);
        c.gpu_mut(GpuId(0))
            .attach(crate::vgpu::ClientId(1), 500, 800, 1e9)
            .unwrap();
        c.gpu_mut(GpuId(1))
            .attach(crate::vgpu::ClientId(2), 250, 400, 1e9)
            .unwrap();
        c.gpu_mut(GpuId(2))
            .attach(crate::vgpu::ClientId(3), 500, 1000, 1e9)
            .unwrap();
        // T4 is cheapest and feasible: wins despite the highest HGO.
        assert_eq!(c.cheapest_feasible_used_gpu(|_| true), Some(GpuId(2)));
        // T4 filtered out: among the V100s the lower-HGO one wins.
        assert_eq!(
            c.cheapest_feasible_used_gpu(|cl| cl.name != "t4"),
            Some(GpuId(1))
        );
        // None feasible: the homogeneous lowest-HGO rule decides.
        assert_eq!(
            c.cheapest_feasible_used_gpu(|_| false),
            c.least_occupied_used_gpu()
        );
    }

    #[test]
    fn pod_phase_readiness() {
        let pod = Pod {
            id: PodId(1),
            function: "f".into(),
            gpu: GpuId(0),
            sm: 500,
            quota: 500,
            batch: 4,
            phase: PodPhase::ColdStarting { ready_at: 5.0 },
            state: PodState::DeviceResident,
            state_since: 0.0,
            weight_bytes: 1e8,
            created_at: 0.0,
        };
        assert!(!pod.is_ready(4.9));
        assert!(pod.is_ready(5.0));
        let mut draining = pod.clone();
        draining.phase = PodPhase::Draining;
        assert!(!draining.is_ready(100.0));
        // Non-resident weights gate readiness regardless of phase.
        let mut parked = pod.clone();
        parked.phase = PodPhase::Running;
        parked.state = PodState::HostCached;
        assert!(!parked.is_ready(100.0));
    }

    #[test]
    fn pod_state_machine_edges() {
        use PodState::*;
        assert!(Cold.can_transition(HostCached));
        assert!(HostCached.can_transition(DeviceResident));
        assert!(DeviceResident.can_transition(HostCached));
        for (from, to) in [
            (Cold, DeviceResident),
            (DeviceResident, Cold),
            (HostCached, Cold),
            (Cold, Cold),
            (HostCached, HostCached),
            (DeviceResident, DeviceResident),
        ] {
            assert!(!from.can_transition(to), "{from:?} -> {to:?}");
        }
    }

    #[test]
    fn set_pod_state_swaps_memory_accounting() {
        let mut c = test_cluster();
        let spec = c.function("resnet50").unwrap().clone();
        let id = c.alloc_pod_id();
        let mem = spec.graph.memory_bytes(8);
        let weights = 4.0 * spec.graph.total_params();
        c.gpu_mut(GpuId(0))
            .attach(ClientId(id.0), 500, 500, mem)
            .unwrap();
        c.insert_pod(Pod {
            id,
            function: "resnet50".into(),
            gpu: GpuId(0),
            sm: 500,
            quota: 500,
            batch: 8,
            phase: PodPhase::Running,
            state: PodState::DeviceResident,
            state_since: 0.0,
            weight_bytes: weights,
            created_at: 0.0,
        });
        let free0 = c.gpu(GpuId(0)).mem_free();
        c.set_pod_state(id, PodState::HostCached, 1.0).unwrap();
        assert_eq!(c.pod(id).unwrap().state, PodState::HostCached);
        assert!((c.pod(id).unwrap().state_since - 1.0).abs() < 1e-12);
        assert!((c.gpu(GpuId(0)).mem_free() - (free0 + weights)).abs() < 1.0);
        assert!((c.gpu(GpuId(0)).host_mem_used() - weights).abs() < 1.0);
        // Illegal edge rejected, state untouched.
        assert!(c.set_pod_state(id, PodState::HostCached, 2.0).is_err());
        assert!((c.pod(id).unwrap().state_since - 1.0).abs() < 1e-12);
        c.set_pod_state(id, PodState::DeviceResident, 3.0).unwrap();
        assert!((c.gpu(GpuId(0)).mem_free() - free0).abs() < 1.0);
        assert_eq!(c.gpu(GpuId(0)).host_mem_used(), 0.0);
        c.check_invariants().unwrap();
    }

    #[test]
    fn empty_cluster_invariants_hold() {
        test_cluster().check_invariants().unwrap();
    }
}
