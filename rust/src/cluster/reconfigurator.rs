//! The GPU Re-configurator: the single mutation path for GPU allocations.
//!
//! Paper §3.1: the Re-configurator bypasses the Kubernetes device plugin,
//! manages GPU topology directly via NVML UUIDs, schedules pods to *specific*
//! GPUs, and writes connection + resource reconfiguration information to the
//! vGPU device files. All scaling actions produced by the auto-scaler are
//! applied through [`Reconfigurator::apply`], which keeps the cluster state,
//! vGPU accounting, device files, and (in real mode) token schedulers in sync.

use super::{ClusterState, GpuId, Pod, PodId, PodPhase, PodState};
use crate::perf::PerfModel;
use crate::sim::faults::FaultPlan;
use crate::util::prng::Pcg64;
use crate::vgpu::device_file::DeviceFile;
use crate::vgpu::tokens::TokenScheduler;
use crate::vgpu::{AllocError, QuotaMille, SmMille};

/// A scaling action (the S_i of Algorithm 1).
#[derive(Clone, Debug, PartialEq)]
pub enum ScalingAction {
    /// Vertical scale (→ / ←): re-write a pod's quota.
    SetQuota { pod: PodId, quota: QuotaMille },
    /// Horizontal scale-up (↑): create a pod on a specific GPU.
    CreatePod {
        function: String,
        gpu: GpuId,
        sm: SmMille,
        quota: QuotaMille,
        batch: u32,
        /// True when the GPU was previously unused (pays GPU-instance
        /// cold start instead of container cold start).
        new_gpu: bool,
    },
    /// Horizontal scale-down (↓): drain and remove a pod.
    RemovePod { pod: PodId },
    /// Keep-alive demotion: park the pod's weights in host memory
    /// (`DeviceResident → HostCached`); SM/quota stay reserved, billing
    /// drops to the host-memory rate.
    DemotePod { pod: PodId },
    /// Swap-in promotion: bring parked weights back to the device
    /// (`HostCached → DeviceResident`), paying the host→device transfer.
    PromotePod { pod: PodId },
}

/// Outcome of applying one action.
#[derive(Clone, Debug, PartialEq)]
pub enum Applied {
    QuotaSet { pod: PodId, old: QuotaMille, new: QuotaMille },
    PodCreated { pod: PodId, ready_at: f64 },
    PodRemoved { pod: PodId },
    PodDemoted { pod: PodId },
    /// `ready_at` is when the host→device swap completes and the pod can
    /// serve again.
    PodPromoted { pod: PodId, ready_at: f64 },
}

/// Why [`Reconfigurator::apply_with_faults`] did not apply an action — the
/// hard-rejection vs transient distinction the fault-aware callers need.
#[derive(Clone, Debug, PartialEq)]
pub enum ApplyError {
    /// Hard rejection (allocation race, unknown pod, illegal state):
    /// retrying the identical action cannot help; the policy re-plans on a
    /// fresher snapshot.
    Rejected(AllocError),
    /// Every attempt failed transiently and the retry budget ran out after
    /// `attempts` tries. The action is abandoned; the autoscaler sees the
    /// unchanged cluster next tick and re-plans.
    Transient { attempts: u32 },
}

pub struct Reconfigurator {
    /// One device-file pair per GPU, indexed by GpuId.
    device_files: Vec<DeviceFile>,
    /// Real-mode token schedulers (None in sim mode).
    schedulers: Option<Vec<TokenScheduler>>,
    rng: Pcg64,
}

impl Reconfigurator {
    pub fn new(cluster: &ClusterState, seed: u64) -> Self {
        Reconfigurator {
            device_files: (0..cluster.n_gpus())
                .map(|i| DeviceFile::new(cluster.gpu(GpuId(i)).uuid.clone().as_str()))
                .collect(),
            schedulers: None,
            rng: Pcg64::new(seed, 3),
        }
    }

    /// Attach real token schedulers (real serving mode) with window `w` secs.
    pub fn with_token_schedulers(mut self, n_gpus: usize, window: f64) -> Self {
        self.schedulers = Some((0..n_gpus).map(|_| TokenScheduler::new(window)).collect());
        self
    }

    pub fn device_file(&self, gpu: GpuId) -> &DeviceFile {
        &self.device_files[gpu.0]
    }

    pub fn token_scheduler(&self, gpu: GpuId) -> Option<&TokenScheduler> {
        self.schedulers.as_ref().map(|s| &s[gpu.0])
    }

    /// Apply one scaling action at time `now`, mutating the cluster.
    pub fn apply(
        &mut self,
        cluster: &mut ClusterState,
        perf: &PerfModel,
        action: &ScalingAction,
        now: f64,
    ) -> Result<Applied, AllocError> {
        match action {
            ScalingAction::SetQuota { pod, quota } => {
                let (gpu, client) = {
                    let p = cluster
                        .pod(*pod)
                        .ok_or(AllocError::UnknownClient(crate::vgpu::ClientId(pod.0)))?;
                    (p.gpu, p.client_id())
                };
                let old = cluster.gpu_mut(gpu).set_quota(client, *quota)?;
                cluster.pod_mut(*pod).expect("pod exists").quota = *quota;
                self.device_files[gpu.0].write_quota(client, *quota);
                if let Some(scheds) = &self.schedulers {
                    scheds[gpu.0].set_quota(client, *quota);
                }
                Ok(Applied::QuotaSet {
                    pod: *pod,
                    old,
                    new: *quota,
                })
            }
            ScalingAction::CreatePod {
                function,
                gpu,
                sm,
                quota,
                batch,
                new_gpu,
            } => {
                let spec = cluster
                    .function(function)
                    .unwrap_or_else(|| panic!("unknown function '{function}'"))
                    .clone();
                let mem = spec.graph.memory_bytes(*batch);
                let id = cluster.alloc_pod_id();
                let client = crate::vgpu::ClientId(id.0);
                cluster.gpu_mut(*gpu).attach(client, *sm, *quota, mem)?;
                let cs = &cluster.coldstart;
                let base = if *new_gpu { cs.gpu_instance } else { cs.container };
                let jitter = 1.0 + cs.jitter * (2.0 * self.rng.next_f64() - 1.0);
                // Model-load time scales with weights over PCIe-ish 8 GB/s.
                let load = 4.0 * spec.graph.total_params() / 8e9;
                // Lifecycle traversal Cold → HostCached → DeviceResident:
                // host staging + host→device swap, scaled by the class
                // clock. Both terms are exactly 0.0 under the default
                // (infinite-bandwidth) device spec, so `ready_at` is
                // bit-identical to the historical formula (`x + 0.0` is
                // exact in IEEE 754) — the byte-identity contract.
                let factor = cluster.gpu(*gpu).throughput();
                let stage = perf.cold_load_time(&spec.graph)
                    + perf.swap_time_class(&spec.graph, factor);
                let ready_at = now + base * jitter + load + stage;
                let pod = Pod {
                    id,
                    function: function.clone(),
                    gpu: *gpu,
                    sm: *sm,
                    quota: *quota,
                    batch: *batch,
                    phase: PodPhase::ColdStarting { ready_at },
                    state: PodState::DeviceResident,
                    state_since: now,
                    weight_bytes: 4.0 * spec.graph.total_params(),
                    created_at: now,
                };
                cluster.insert_pod(pod);
                self.device_files[gpu.0].write_client(client, *sm, *quota);
                if let Some(scheds) = &self.schedulers {
                    scheds[gpu.0].register(client, *quota);
                }
                // Memory feasibility double-check against the device spec.
                debug_assert!(perf.fits_memory(&spec.graph, *batch, perf.dev.mem_cap));
                Ok(Applied::PodCreated { pod: id, ready_at })
            }
            ScalingAction::RemovePod { pod } => {
                let p = cluster
                    .remove_pod(*pod)
                    .ok_or(AllocError::UnknownClient(crate::vgpu::ClientId(pod.0)))?;
                let spec = cluster.function(&p.function).expect("function exists");
                let mem = spec.graph.memory_bytes(p.batch);
                // A parked pod's weights live in the host tier, not on the
                // device — free each side exactly what it holds.
                let (dev_mem, host_mem) = if p.state == PodState::HostCached {
                    (mem - p.weight_bytes, p.weight_bytes)
                } else {
                    (mem, 0.0)
                };
                cluster.gpu_mut(p.gpu).detach(p.client_id(), dev_mem)?;
                if host_mem > 0.0 {
                    cluster.gpu_mut(p.gpu).release_host(host_mem);
                }
                self.device_files[p.gpu.0].remove_client(p.client_id());
                if let Some(scheds) = &self.schedulers {
                    scheds[p.gpu.0].deregister(p.client_id());
                }
                Ok(Applied::PodRemoved { pod: *pod })
            }
            ScalingAction::DemotePod { pod } => {
                let p = cluster
                    .pod(*pod)
                    .ok_or(AllocError::UnknownClient(crate::vgpu::ClientId(pod.0)))?;
                if p.state != PodState::DeviceResident
                    || matches!(p.phase, PodPhase::Draining)
                {
                    return Err(AllocError::BadState(p.client_id()));
                }
                cluster
                    .set_pod_state(*pod, PodState::HostCached, now)
                    .expect("edge checked above");
                Ok(Applied::PodDemoted { pod: *pod })
            }
            ScalingAction::PromotePod { pod } => {
                let (state, gpu, function) = {
                    let p = cluster
                        .pod(*pod)
                        .ok_or(AllocError::UnknownClient(crate::vgpu::ClientId(pod.0)))?;
                    (p.state, p.gpu, p.function.clone())
                };
                if state != PodState::HostCached {
                    return Err(AllocError::BadState(crate::vgpu::ClientId(pod.0)));
                }
                let spec = cluster.function(&function).expect("function exists").clone();
                let factor = cluster.gpu(gpu).throughput();
                // swap_in is the fallible step (device memory pressure) —
                // only on success does the pod become resident.
                cluster
                    .set_pod_state(*pod, PodState::DeviceResident, now)
                    .map_err(|_| AllocError::NoMemory {
                        need: 4.0 * spec.graph.total_params(),
                        free: cluster.gpu(gpu).mem_free(),
                    })?;
                let ready_at = now + perf.swap_time_class(&spec.graph, factor);
                let p = cluster.pod_mut(*pod).expect("pod exists");
                p.phase = PodPhase::ColdStarting { ready_at };
                Ok(Applied::PodPromoted { pod: *pod, ready_at })
            }
        }
    }

    /// Apply one action under a fault plan: each attempt first flips the
    /// plan's transient coin; a transient failure costs deterministic
    /// sim-time backoff (`backoff × attempt`, accumulated) and is retried
    /// up to the spec's retry budget. The backoff manifests as delayed
    /// readiness on `PodCreated` / `PodPromoted` — instantaneous actions
    /// (quota writes, removals) simply land late within the same tick.
    ///
    /// Hard allocation errors surface immediately as
    /// [`ApplyError::Rejected`] (retrying an allocation race cannot help);
    /// exhausted budgets surface as [`ApplyError::Transient`]. With an
    /// inactive spec the coin is never drawn and this is byte-identical to
    /// [`Reconfigurator::apply`].
    pub fn apply_with_faults(
        &mut self,
        cluster: &mut ClusterState,
        perf: &PerfModel,
        action: &ScalingAction,
        now: f64,
        faults: &mut FaultPlan,
    ) -> Result<Applied, ApplyError> {
        let (retries, backoff) = {
            let s = faults.spec();
            (s.reconfig_retries, s.reconfig_backoff)
        };
        let mut delay = 0.0;
        let mut attempt = 0u32;
        loop {
            attempt += 1;
            if faults.draw_transient() {
                if attempt > retries {
                    return Err(ApplyError::Transient { attempts: attempt });
                }
                delay += backoff * attempt as f64;
                continue;
            }
            let applied = self
                .apply(cluster, perf, action, now)
                .map_err(ApplyError::Rejected)?;
            return Ok(match applied {
                Applied::PodCreated { pod, ready_at } if delay > 0.0 => {
                    let ready_at = ready_at + delay;
                    if let Some(p) = cluster.pod_mut(pod) {
                        p.phase = PodPhase::ColdStarting { ready_at };
                    }
                    Applied::PodCreated { pod, ready_at }
                }
                Applied::PodPromoted { pod, ready_at } if delay > 0.0 => {
                    let ready_at = ready_at + delay;
                    if let Some(p) = cluster.pod_mut(pod) {
                        p.phase = PodPhase::ColdStarting { ready_at };
                    }
                    Applied::PodPromoted { pod, ready_at }
                }
                other => other,
            });
        }
    }

    /// Forcibly remove a pod whose device died: same bookkeeping as the
    /// `RemovePod` arm of [`Reconfigurator::apply`] (vGPU detach, host-tier
    /// release, device-file + scheduler cleanup), but it returns the evicted
    /// [`Pod`] and deliberately skips the scale-down counters and ledger
    /// boundary — fault eviction is not a scaling decision; the caller
    /// closes the billing account at the failure instant itself.
    pub fn evict_pod(&mut self, cluster: &mut ClusterState, pod: PodId) -> Option<Pod> {
        let p = cluster.remove_pod(pod)?;
        let spec = cluster.function(&p.function).expect("function exists");
        let mem = spec.graph.memory_bytes(p.batch);
        let (dev_mem, host_mem) = if p.state == PodState::HostCached {
            (mem - p.weight_bytes, p.weight_bytes)
        } else {
            (mem, 0.0)
        };
        let detached = cluster.gpu_mut(p.gpu).detach(p.client_id(), dev_mem);
        debug_assert!(detached.is_ok(), "evicted pod must detach cleanly");
        if host_mem > 0.0 {
            cluster.gpu_mut(p.gpu).release_host(host_mem);
        }
        self.device_files[p.gpu.0].remove_client(p.client_id());
        if let Some(scheds) = &self.schedulers {
            scheds[p.gpu.0].deregister(p.client_id());
        }
        Some(p)
    }

    /// NVML-style inventory line per GPU (UUID, classes, HGO, free SM/mem).
    pub fn inventory(&self, cluster: &ClusterState) -> Vec<String> {
        (0..cluster.n_gpus())
            .map(|i| {
                let g = cluster.gpu(GpuId(i));
                format!(
                    "{} classes={:?} hgo={:.3} free_sm={}‰ free_mem={:.1}GB dfv={}",
                    g.uuid,
                    g.sm_classes(),
                    g.hgo(),
                    g.sm_free(),
                    g.mem_free() / 1e9,
                    self.device_files[i].version()
                )
            })
            .collect()
    }
}

/// Convenience builder used by tests, benches, and examples.
pub fn place_pod(
    recon: &mut Reconfigurator,
    cluster: &mut ClusterState,
    perf: &PerfModel,
    function: &str,
    gpu: GpuId,
    sm: SmMille,
    quota: QuotaMille,
    batch: u32,
    now: f64,
) -> Result<PodId, AllocError> {
    let new_gpu = cluster.gpu(gpu).is_idle();
    match recon.apply(
        cluster,
        perf,
        &ScalingAction::CreatePod {
            function: function.to_string(),
            gpu,
            sm,
            quota,
            batch,
            new_gpu,
        },
        now,
    )? {
        Applied::PodCreated { pod, .. } => Ok(pod),
        _ => unreachable!(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::FunctionSpec;
    use crate::model::zoo::{zoo_graph, ZooModel};

    fn setup() -> (ClusterState, Reconfigurator, PerfModel) {
        let mut c = ClusterState::new(3, 16e9);
        c.register_function(FunctionSpec {
            name: "resnet50".into(),
            graph: zoo_graph(ZooModel::ResNet50),
            slo: 0.1,
            batch: 8,
            artifact: None,
        });
        let r = Reconfigurator::new(&c, 42);
        (c, r, PerfModel::default())
    }

    #[test]
    fn create_scale_remove_lifecycle() {
        let (mut c, mut r, pm) = setup();
        let pod = place_pod(&mut r, &mut c, &pm, "resnet50", GpuId(0), 500, 300, 8, 0.0).unwrap();
        c.check_invariants().unwrap();
        assert_eq!(c.pods_of("resnet50").len(), 1);
        assert!(matches!(
            c.pod(pod).unwrap().phase,
            PodPhase::ColdStarting { .. }
        ));
        assert_eq!(r.device_file(GpuId(0)).version(), 1);

        // Vertical scale-up.
        let applied = r
            .apply(&mut c, &pm, &ScalingAction::SetQuota { pod, quota: 800 }, 1.0)
            .unwrap();
        assert_eq!(
            applied,
            Applied::QuotaSet {
                pod,
                old: 300,
                new: 800
            }
        );
        assert_eq!(c.pod(pod).unwrap().quota, 800);
        c.check_invariants().unwrap();

        // Remove.
        r.apply(&mut c, &pm, &ScalingAction::RemovePod { pod }, 2.0)
            .unwrap();
        assert!(c.pod(pod).is_none());
        assert!(c.gpu(GpuId(0)).is_idle());
        c.check_invariants().unwrap();
    }

    #[test]
    fn new_gpu_coldstart_slower_than_container() {
        let (mut c, mut r, pm) = setup();
        // First pod on GPU-0: new_gpu=true.
        let a1 = r
            .apply(
                &mut c,
                &pm,
                &ScalingAction::CreatePod {
                    function: "resnet50".into(),
                    gpu: GpuId(0),
                    sm: 250,
                    quota: 300,
                    batch: 8,
                    new_gpu: true,
                },
                0.0,
            )
            .unwrap();
        // Second pod on same GPU: container start.
        let a2 = r
            .apply(
                &mut c,
                &pm,
                &ScalingAction::CreatePod {
                    function: "resnet50".into(),
                    gpu: GpuId(0),
                    sm: 250,
                    quota: 300,
                    batch: 8,
                    new_gpu: false,
                },
                0.0,
            )
            .unwrap();
        let (Applied::PodCreated { ready_at: r1, .. }, Applied::PodCreated { ready_at: r2, .. }) =
            (a1, a2)
        else {
            panic!()
        };
        assert!(r1 > r2, "gpu-instance start {r1} vs container start {r2}");
    }

    #[test]
    fn quota_rewrite_propagates_to_device_file() {
        let (mut c, mut r, pm) = setup();
        let pod = place_pod(&mut r, &mut c, &pm, "resnet50", GpuId(1), 500, 200, 8, 0.0).unwrap();
        r.apply(&mut c, &pm, &ScalingAction::SetQuota { pod, quota: 700 }, 1.0)
            .unwrap();
        let (_, q, _) = r.device_file(GpuId(1)).read();
        assert_eq!(q.entries[&c.pod(pod).unwrap().client_id()], 700);
    }

    #[test]
    fn alloc_errors_bubble_up() {
        let (mut c, mut r, pm) = setup();
        place_pod(&mut r, &mut c, &pm, "resnet50", GpuId(0), 800, 1000, 8, 0.0).unwrap();
        let err = place_pod(&mut r, &mut c, &pm, "resnet50", GpuId(0), 800, 1000, 8, 0.0);
        assert!(matches!(err, Err(AllocError::NoSm { .. })));
        // Failed placement must not leak state.
        c.check_invariants().unwrap();
        assert_eq!(c.pods_of("resnet50").len(), 1);
    }

    #[test]
    fn pods_born_device_resident_with_weight_footprint() {
        let (mut c, mut r, pm) = setup();
        let pod = place_pod(&mut r, &mut c, &pm, "resnet50", GpuId(0), 500, 300, 8, 0.0).unwrap();
        let p = c.pod(pod).unwrap();
        assert_eq!(p.state, PodState::DeviceResident);
        let spec = c.function("resnet50").unwrap();
        assert!((p.weight_bytes - 4.0 * spec.graph.total_params()).abs() < 1.0);
        // Default (infinite-bandwidth) spec: lifecycle terms add exactly 0.
        assert_eq!(pm.cold_load_time(&spec.graph).to_bits(), 0.0f64.to_bits());
        assert_eq!(
            pm.swap_time_class(&spec.graph, 1.0).to_bits(),
            0.0f64.to_bits()
        );
    }

    #[test]
    fn demote_promote_roundtrip_and_bad_states() {
        let (mut c, mut r, pm) = setup();
        let pod = place_pod(&mut r, &mut c, &pm, "resnet50", GpuId(0), 500, 300, 8, 0.0).unwrap();
        let free0 = c.gpu(GpuId(0)).mem_free();
        let weights = c.pod(pod).unwrap().weight_bytes;

        let a = r
            .apply(&mut c, &pm, &ScalingAction::DemotePod { pod }, 5.0)
            .unwrap();
        assert_eq!(a, Applied::PodDemoted { pod });
        assert_eq!(c.pod(pod).unwrap().state, PodState::HostCached);
        assert!((c.gpu(GpuId(0)).mem_free() - (free0 + weights)).abs() < 1.0);
        assert!(!c.pod(pod).unwrap().is_ready(100.0));
        // Double demote is illegal.
        assert!(matches!(
            r.apply(&mut c, &pm, &ScalingAction::DemotePod { pod }, 6.0),
            Err(AllocError::BadState(_))
        ));

        let a = r
            .apply(&mut c, &pm, &ScalingAction::PromotePod { pod }, 7.0)
            .unwrap();
        let Applied::PodPromoted { ready_at, .. } = a else { panic!() };
        // Default spec: swap completes instantly (exact zero).
        assert_eq!(ready_at.to_bits(), 7.0f64.to_bits());
        assert_eq!(c.pod(pod).unwrap().state, PodState::DeviceResident);
        assert!(c.pod(pod).unwrap().is_ready(7.0));
        assert!((c.gpu(GpuId(0)).mem_free() - free0).abs() < 1.0);
        // Promote a resident pod is illegal.
        assert!(matches!(
            r.apply(&mut c, &pm, &ScalingAction::PromotePod { pod }, 8.0),
            Err(AllocError::BadState(_))
        ));
        c.check_invariants().unwrap();

        // Removing a parked pod frees both tiers.
        r.apply(&mut c, &pm, &ScalingAction::DemotePod { pod }, 9.0)
            .unwrap();
        r.apply(&mut c, &pm, &ScalingAction::RemovePod { pod }, 10.0)
            .unwrap();
        assert!(c.gpu(GpuId(0)).is_idle());
        assert_eq!(c.gpu(GpuId(0)).host_mem_used(), 0.0);
        c.check_invariants().unwrap();
    }

    #[test]
    fn finite_swap_bandwidth_delays_promotion() {
        let (mut c, mut r, _) = setup();
        let pm = PerfModel::new(crate::perf::DeviceSpec {
            host_load_bw: 1e9,
            h2d_bw: 2e8,
            ..Default::default()
        });
        let pod = place_pod(&mut r, &mut c, &pm, "resnet50", GpuId(0), 500, 300, 8, 0.0).unwrap();
        let weights = c.pod(pod).unwrap().weight_bytes;
        r.apply(&mut c, &pm, &ScalingAction::DemotePod { pod }, 5.0)
            .unwrap();
        let Applied::PodPromoted { ready_at, .. } = r
            .apply(&mut c, &pm, &ScalingAction::PromotePod { pod }, 6.0)
            .unwrap()
        else {
            panic!()
        };
        assert!((ready_at - (6.0 + weights / 2e8)).abs() < 1e-9);
        assert!(!c.pod(pod).unwrap().is_ready(6.0));
        assert!(c.pod(pod).unwrap().is_ready(ready_at));
    }

    #[test]
    fn evict_pod_frees_both_tiers_without_scaling_semantics() {
        let (mut c, mut r, pm) = setup();
        let pod = place_pod(&mut r, &mut c, &pm, "resnet50", GpuId(0), 500, 300, 8, 0.0).unwrap();
        let evicted = r.evict_pod(&mut c, pod).expect("pod exists");
        assert_eq!(evicted.id, pod);
        assert!(c.pod(pod).is_none());
        assert!(c.gpu(GpuId(0)).is_idle());
        c.check_invariants().unwrap();
        // Idempotent on missing pods.
        assert!(r.evict_pod(&mut c, pod).is_none());

        // A parked (HostCached) victim frees the host tier too.
        let pod = place_pod(&mut r, &mut c, &pm, "resnet50", GpuId(1), 500, 300, 8, 0.0).unwrap();
        r.apply(&mut c, &pm, &ScalingAction::DemotePod { pod }, 1.0)
            .unwrap();
        assert!(c.gpu(GpuId(1)).host_mem_used() > 0.0);
        r.evict_pod(&mut c, pod).unwrap();
        assert_eq!(c.gpu(GpuId(1)).host_mem_used(), 0.0);
        assert!(c.gpu(GpuId(1)).is_idle());
        c.check_invariants().unwrap();
    }

    #[test]
    fn apply_with_faults_inactive_matches_plain_apply() {
        use crate::sim::faults::FaultSpec;
        let (mut c1, mut r1, pm) = setup();
        let (mut c2, mut r2, _) = setup();
        let mut plan = FaultPlan::compile(&FaultSpec::default(), 42, 3, 100.0);
        let action = ScalingAction::CreatePod {
            function: "resnet50".into(),
            gpu: GpuId(0),
            sm: 500,
            quota: 300,
            batch: 8,
            new_gpu: true,
        };
        let a = r1.apply(&mut c1, &pm, &action, 0.0).unwrap();
        let b = r2
            .apply_with_faults(&mut c2, &pm, &action, 0.0, &mut plan)
            .unwrap();
        assert_eq!(a, b, "inactive fault plan must not perturb apply");
        assert_eq!(plan.transients(), 0);
    }

    #[test]
    fn apply_with_faults_exhausts_retries_and_distinguishes_rejections() {
        use crate::sim::faults::{FaultPlan, FaultSpec};
        let (mut c, mut r, pm) = setup();
        // Certain transient failure: every action aborts after 1 + retries
        // attempts and mutates nothing.
        let spec = FaultSpec {
            reconfig_fail_p: 1.0,
            reconfig_retries: 3,
            ..FaultSpec::default()
        };
        let mut plan = FaultPlan::compile(&spec, 7, 3, 100.0);
        let action = ScalingAction::CreatePod {
            function: "resnet50".into(),
            gpu: GpuId(0),
            sm: 500,
            quota: 300,
            batch: 8,
            new_gpu: true,
        };
        let err = r
            .apply_with_faults(&mut c, &pm, &action, 0.0, &mut plan)
            .unwrap_err();
        assert_eq!(err, ApplyError::Transient { attempts: 4 });
        assert_eq!(plan.transients(), 4);
        assert_eq!(c.pods_of("resnet50").len(), 0);
        c.check_invariants().unwrap();

        // A hard allocation error surfaces as Rejected even under faults —
        // fill the GPU with a clean plan, then ask for more.
        let mut clean = FaultPlan::compile(&FaultSpec::default(), 7, 3, 100.0);
        r.apply_with_faults(
            &mut c,
            &pm,
            &ScalingAction::CreatePod {
                function: "resnet50".into(),
                gpu: GpuId(0),
                sm: 1000,
                quota: 1000,
                batch: 8,
                new_gpu: true,
            },
            0.0,
            &mut clean,
        )
        .unwrap();
        let err = r
            .apply_with_faults(
                &mut c,
                &pm,
                &ScalingAction::CreatePod {
                    function: "resnet50".into(),
                    gpu: GpuId(0),
                    sm: 1000,
                    quota: 1000,
                    batch: 8,
                    new_gpu: false,
                },
                1.0,
                &mut clean,
            )
            .unwrap_err();
        assert!(matches!(err, ApplyError::Rejected(_)));
    }

    #[test]
    fn apply_with_faults_backoff_delays_readiness() {
        use crate::sim::faults::{FaultPlan, FaultSpec};
        // Half the attempts fail: across many creations, at least one must
        // succeed after a retry, and every delayed pod's phase must agree
        // with the returned ready_at.
        let spec = FaultSpec {
            reconfig_fail_p: 0.5,
            reconfig_retries: 5,
            reconfig_backoff: 0.25,
            ..FaultSpec::default()
        };
        let mut c = ClusterState::new(16, 16e9);
        c.register_function(FunctionSpec {
            name: "resnet50".into(),
            graph: zoo_graph(ZooModel::ResNet50),
            slo: 0.1,
            batch: 8,
            artifact: None,
        });
        let mut r = Reconfigurator::new(&c, 42);
        let mut plan = FaultPlan::compile(&spec, 42, 16, 1000.0);
        let mut delayed = 0;
        for gpu in 0..16 {
            let action = ScalingAction::CreatePod {
                function: "resnet50".into(),
                gpu: GpuId(gpu),
                sm: 500,
                quota: 300,
                batch: 8,
                new_gpu: true,
            };
            // Baseline ready_at with the same jitter draw: clone the recon
            // state before applying so the RNG position matches.
            if let Ok(Applied::PodCreated { pod, ready_at }) =
                r.apply_with_faults(&mut c, &pm_default(), &action, 0.0, &mut plan)
            {
                let p = c.pod(pod).unwrap();
                let PodPhase::ColdStarting { ready_at: phase_ready } = p.phase else {
                    panic!("fresh pod must be cold-starting")
                };
                assert_eq!(phase_ready.to_bits(), ready_at.to_bits());
                if plan.transients() > 0 {
                    delayed += 1;
                }
            }
        }
        assert!(plan.transients() > 0, "p=0.5 over 16 creates must draw transients");
        assert!(delayed > 0);
        c.check_invariants().unwrap();
    }

    fn pm_default() -> PerfModel {
        PerfModel::default()
    }

    #[test]
    fn inventory_reports_all_gpus() {
        let (mut c, mut r, pm) = setup();
        place_pod(&mut r, &mut c, &pm, "resnet50", GpuId(2), 500, 500, 8, 0.0).unwrap();
        let inv = r.inventory(&c);
        assert_eq!(inv.len(), 3);
        assert!(inv[2].contains("hgo=0.250"));
    }
}
