//! Real-mode serving plane: gateway, dynamic batcher, pod executors.
//!
//! This is the paper's Fig. 1 data path with *actual* model execution —
//! Python never appears at runtime:
//!
//! ```text
//! client → Gateway::submit → per-function queue
//!             ├── pod executor thread (one per pod)
//!             │     1. pull up to `batch` requests (dynamic batching with a
//!             │        short max-wait, request-batching à la BATCH/MArk)
//!             │     2. acquire time tokens from the pod's vGPU TokenScheduler
//!             │        (cost = modelled GPU time of this batch at the pod's
//!             │        SM partition — the libhas interception point)
//!             │     3. PJRT-execute the AOT HLO artifact (runtime::infer)
//!             │     4. reply + record metrics
//!             └── autoscaler thread: per-second tick → HybridAutoscaler::plan
//!                   → Reconfigurator::apply (quota re-writes reach the token
//!                   scheduler live; new pods spawn executor threads)
//! ```

use crate::autoscaler::ScalingPolicy;
use crate::cluster::{Applied, ClusterState, FunctionSpec, PodId, PodPhase, Reconfigurator};
use crate::metrics::{BillingLedger, BillingMode, Outcome, RunReport};
use crate::perf::PerfModel;
use crate::rapp::LatencyPredictor;
use crate::runtime::{Manifest, PjrtRuntime};
use crate::vgpu::tokens::TokenError;
use std::collections::{HashMap, VecDeque};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, SyncSender};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

/// One inference request.
struct QueuedRequest {
    arrival: Instant,
    input: Vec<f32>,
    reply: SyncSender<InferReply>,
}

/// What the client gets back.
#[derive(Clone, Debug)]
pub struct InferReply {
    pub output: Vec<f32>,
    /// End-to-end latency (queue + batching + tokens + execution).
    pub latency: Duration,
    /// Time waiting for vGPU time tokens (the quota enforcement cost).
    pub token_wait: Duration,
    /// Pure PJRT execution time.
    pub exec_time: Duration,
    pub batch_size: usize,
}

struct FunctionQueue {
    q: Mutex<VecDeque<QueuedRequest>>,
    cv: Condvar,
}

struct Shared {
    cluster: Mutex<ClusterState>,
    recon: Mutex<Reconfigurator>,
    perf: PerfModel,
    runtime: Arc<PjrtRuntime>,
    manifest: Manifest,
    queues: HashMap<String, Arc<FunctionQueue>>,
    arrivals: HashMap<String, AtomicU64>,
    report: Mutex<RunReport>,
    /// The transactional billing engine (shared with sim mode — see
    /// `metrics::ledger`). Real mode always bills the fine-grained slice.
    ledger: Mutex<BillingLedger>,
    shutdown: AtomicBool,
    epoch: Instant,
    /// Dynamic batching max-wait.
    batch_wait: Duration,
}

/// Real-mode serving server.
pub struct Server {
    shared: Arc<Shared>,
    scaler: Mutex<Option<std::thread::JoinHandle<()>>>,
    executors: Mutex<Vec<std::thread::JoinHandle<()>>>,
}

/// Server construction options.
pub struct ServerConfig {
    pub n_gpus: usize,
    pub seed: u64,
    /// Token-window length (seconds).
    pub window: f64,
    /// Autoscaler tick.
    pub tick: Duration,
    /// Dynamic batching max-wait.
    pub batch_wait: Duration,
    /// Cold-start scale factor (1.0 = paper-realistic 10 s GPU starts; demos
    /// use ~0.05 to keep examples snappy).
    pub coldstart_scale: f64,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            n_gpus: 2,
            seed: 7,
            window: 0.005,
            tick: Duration::from_secs(1),
            batch_wait: Duration::from_millis(4),
            coldstart_scale: 0.05,
        }
    }
}

impl Server {
    /// Build a server over AOT artifacts in `artifacts_dir`, serving
    /// `functions` with `policy` as the autoscaler.
    pub fn start(
        artifacts_dir: &std::path::Path,
        functions: Vec<FunctionSpec>,
        mut policy: Box<dyn ScalingPolicy>,
        predictor: Arc<dyn LatencyPredictor>,
        cfg: ServerConfig,
    ) -> anyhow::Result<Arc<Self>> {
        let manifest = Manifest::load(artifacts_dir)?;
        let runtime = Arc::new(PjrtRuntime::new()?);
        let perf = PerfModel::default();
        let mut cluster = ClusterState::new(cfg.n_gpus, perf.dev.mem_cap);
        cluster.coldstart.gpu_instance *= cfg.coldstart_scale;
        cluster.coldstart.container *= cfg.coldstart_scale;
        for f in &functions {
            anyhow::ensure!(
                f.artifact.is_some() || !manifest.variants(&f.name).is_empty(),
                "no artifact for function '{}'",
                f.name
            );
            cluster.register_function(f.clone());
        }
        let recon = Reconfigurator::new(&cluster, cfg.seed)
            .with_token_schedulers(cfg.n_gpus, cfg.window);
        let mut queues = HashMap::new();
        let mut arrivals = HashMap::new();
        for f in &functions {
            queues.insert(
                f.name.clone(),
                Arc::new(FunctionQueue {
                    q: Mutex::new(VecDeque::new()),
                    cv: Condvar::new(),
                }),
            );
            arrivals.insert(f.name.clone(), AtomicU64::new(0));
        }
        let price_per_hour = perf.dev.price_per_hour;
        let shared = Arc::new(Shared {
            cluster: Mutex::new(cluster),
            recon: Mutex::new(recon),
            perf,
            runtime,
            manifest,
            queues,
            arrivals,
            report: Mutex::new(RunReport::new(policy.name())),
            ledger: Mutex::new(BillingLedger::new(BillingMode::FineGrained, price_per_hour)),
            shutdown: AtomicBool::new(false),
            epoch: Instant::now(),
            batch_wait: cfg.batch_wait,
        });
        let server = Arc::new(Server {
            shared: Arc::clone(&shared),
            scaler: Mutex::new(None),
            executors: Mutex::new(Vec::new()),
        });

        // Warm-up: compile every artifact before serving.
        for f in &functions {
            for v in shared.manifest.variants(&f.name) {
                shared.runtime.warmup(&v.path)?;
            }
        }

        // Bootstrap one pod per function and spawn executors.
        {
            let now = shared.now();
            let mut cl = shared.cluster.lock().unwrap();
            let mut rc = shared.recon.lock().unwrap();
            for f in &functions {
                let actions = policy.plan(f, 1.0, &cl, predictor.as_ref(), now);
                for a in &actions {
                    if let Ok(applied) = rc.apply(&mut cl, &shared.perf, a, now) {
                        Self::record_applied(&shared, &cl, &applied, now);
                        if let Applied::PodCreated { pod, .. } = applied {
                            if let Some(p) = cl.pod_mut(pod) {
                                p.phase = PodPhase::Running; // deployment-time warm
                            }
                            server.spawn_executor(pod, f.clone());
                        }
                    }
                }
            }
        }

        // Autoscaler loop.
        {
            let shared2 = Arc::clone(&shared);
            let server2 = Arc::downgrade(&server);
            let functions2 = functions.clone();
            let tick = cfg.tick;
            let handle = std::thread::Builder::new()
                .name("has-autoscaler".into())
                .spawn(move || {
                    while !shared2.shutdown.load(Ordering::Acquire) {
                        std::thread::sleep(tick);
                        let now = shared2.now();
                        for f in &functions2 {
                            let observed = shared2.arrivals[&f.name]
                                .swap(0, Ordering::AcqRel)
                                as f64
                                / tick.as_secs_f64();
                            let actions = {
                                let cl = shared2.cluster.lock().unwrap();
                                policy.plan(f, observed, &cl, predictor.as_ref(), now)
                            };
                            for a in &actions {
                                let applied = {
                                    let mut cl = shared2.cluster.lock().unwrap();
                                    let mut rc = shared2.recon.lock().unwrap();
                                    let applied = rc.apply(&mut cl, &shared2.perf, a, now).ok();
                                    // Ledger + counters only after the
                                    // mutation succeeds: rejected actions
                                    // bill nothing and count nothing.
                                    if let Some(applied) = &applied {
                                        Self::record_applied(&shared2, &cl, applied, now);
                                    }
                                    applied
                                };
                                if let Some(Applied::PodCreated { pod, .. }) = applied {
                                    if let Some(srv) = server2.upgrade() {
                                        srv.spawn_executor(pod, f.clone());
                                    }
                                }
                            }
                        }
                    }
                })
                .expect("spawn autoscaler");
            *server.scaler.lock().unwrap() = Some(handle);
        }
        Ok(server)
    }

    /// Record a successfully applied scaling action (never called for
    /// rejected ones) via the shared `Applied` → accounting mapping in
    /// `metrics::ledger`. Lock order is report → ledger; `report()` takes
    /// them sequentially (never nested), so no ordering cycle exists.
    /// Note: bootstrap pod creations count as `horizontal_ups` too — same
    /// semantics as sim mode's warm bootstrap.
    fn record_applied(shared: &Shared, cl: &ClusterState, applied: &Applied, now: f64) {
        let mut rep = shared.report.lock().unwrap();
        let mut ledger = shared.ledger.lock().unwrap();
        crate::metrics::ledger::record_applied(&mut rep, &mut ledger, cl, applied, now);
    }

    fn now_of(shared: &Shared) -> f64 {
        shared.epoch.elapsed().as_secs_f64()
    }

    /// Submit a request; returns a receiver for the reply.
    ///
    /// An unknown function name is a *client* error, not a server bug: it
    /// comes back as an `Err` listing the deployed menu (the same shape as
    /// the CLI resolvers) instead of panicking the calling thread.
    pub fn submit(&self, function: &str, input: Vec<f32>) -> anyhow::Result<Receiver<InferReply>> {
        let (tx, rx) = sync_channel(1);
        let Some(fq) = self.shared.queues.get(function) else {
            let mut menu: Vec<&str> = self.shared.queues.keys().map(String::as_str).collect();
            menu.sort_unstable();
            anyhow::bail!("unknown function '{function}'; deployed: {}", menu.join(", "));
        };
        self.shared.arrivals[function].fetch_add(1, Ordering::AcqRel);
        fq.q.lock().unwrap().push_back(QueuedRequest {
            arrival: Instant::now(),
            input,
            reply: tx,
        });
        fq.cv.notify_one();
        Ok(rx)
    }

    /// Spawn the executor thread for a pod.
    fn spawn_executor(&self, pod: PodId, spec: FunctionSpec) {
        let shared = Arc::clone(&self.shared);
        let handle = std::thread::Builder::new()
            .name(format!("has-pod-{}", pod.0))
            .spawn(move || pod_executor(shared, pod, spec))
            .expect("spawn pod executor");
        self.executors.lock().unwrap().push(handle);
    }

    /// Snapshot of the metrics report.
    pub fn report(&self) -> RunReport {
        // Settle every open pod account up to `now` (idempotent), then copy
        // the meter into the report snapshot.
        let now = self.shared.now();
        let costs = {
            let mut ledger = self.shared.ledger.lock().unwrap();
            ledger.settle(now);
            ledger.meter().clone()
        };
        let mut r = self.shared.report.lock().unwrap().clone();
        r.costs = costs;
        r.duration = now;
        r
    }

    /// Current pod layout (function, sm‰, quota‰) for observability.
    pub fn pod_layout(&self) -> Vec<(String, u32, u32)> {
        self.shared
            .cluster
            .lock()
            .unwrap()
            .pods()
            .map(|p| (p.function.clone(), p.sm, p.quota))
            .collect()
    }

    /// Stop the server, joining all threads.
    pub fn shutdown(&self) {
        self.shared.shutdown.store(true, Ordering::Release);
        for fq in self.shared.queues.values() {
            fq.cv.notify_all();
        }
        for h in self.executors.lock().unwrap().drain(..) {
            let _ = h.join();
        }
    }
}

impl Shared {
    fn now(&self) -> f64 {
        Server::now_of(self)
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.shutdown();
        if let Some(h) = self.scaler.lock().unwrap().take() {
            let _ = h.join();
        }
    }
}

/// The pod executor loop: batch → tokens → PJRT → reply.
fn pod_executor(shared: Arc<Shared>, pod: PodId, spec: FunctionSpec) {
    let fq = Arc::clone(&shared.queues[&spec.name]);
    loop {
        if shared.shutdown.load(Ordering::Acquire) {
            return;
        }
        // Pod still placed? (Removal deregisters the token client too.)
        let placement = {
            let cl = shared.cluster.lock().unwrap();
            cl.pod(pod).map(|p| (p.gpu, p.sm, p.quota, p.batch))
        };
        let Some((gpu, sm, _quota, max_batch)) = placement else {
            return; // pod removed
        };

        // --- dynamic batching: wait for the first request (bounded, so pod
        // removal and shutdown are noticed), then linger briefly for more.
        let mut batch: Vec<QueuedRequest> = Vec::new();
        {
            let mut q = fq.q.lock().unwrap();
            if q.is_empty() {
                let (guard, _) = fq
                    .cv
                    .wait_timeout(q, Duration::from_millis(50))
                    .unwrap();
                q = guard;
            }
            while batch.len() < max_batch as usize {
                match q.pop_front() {
                    Some(r) => batch.push(r),
                    None => break,
                }
            }
        }
        if batch.is_empty() {
            continue; // re-checks shutdown + placement at loop top
        }
        // Linger for more requests up to batch_wait.
        let linger_deadline = Instant::now() + shared.batch_wait;
        while batch.len() < max_batch as usize && Instant::now() < linger_deadline {
            let mut q = fq.q.lock().unwrap();
            while batch.len() < max_batch as usize {
                match q.pop_front() {
                    Some(r) => batch.push(r),
                    None => break,
                }
            }
            drop(q);
            if batch.len() < max_batch as usize {
                std::thread::sleep(Duration::from_micros(200));
            }
        }

        // --- token acquisition (libhas: gate execution on the time quota).
        // Cost = modelled GPU time of this batch at the pod's SM partition.
        let cost = shared
            .perf
            .raw_graph_time(&spec.graph, batch.len() as u32, crate::vgpu::sm_to_f64(sm));
        let client = crate::vgpu::ClientId(pod.0);
        // libhas gates each *kernel launch*, not each batch: acquire the
        // modelled GPU time in kernel-sized chunks so the quota actually
        // dilates long batches (no-debt windows forgive a single overrun).
        let token_wait = {
            let sched = {
                let rc = shared.recon.lock().unwrap();
                rc.token_scheduler(gpu).cloned()
            };
            match sched {
                Some(s) => {
                    let chunk = (s.window() / 4.0).max(1e-4);
                    let mut remaining = cost;
                    let mut waited = Duration::ZERO;
                    loop {
                        match s.acquire(client, remaining.min(chunk)) {
                            Ok(w) => waited += w,
                            Err(TokenError::Deregistered(_))
                            | Err(TokenError::ZeroQuota(_)) => {
                                requeue(&fq, batch);
                                return;
                            }
                            Err(_) => {}
                        }
                        if remaining <= chunk {
                            break;
                        }
                        remaining -= chunk;
                    }
                    waited
                }
                None => Duration::ZERO,
            }
        };

        // --- PJRT execution of the AOT artifact.
        let artifact = spec.artifact.clone().or_else(|| {
            shared
                .manifest
                .for_batch(&spec.name, batch.len())
                .map(|a| a.path.clone())
        });
        let Some(path) = artifact else {
            requeue(&fq, batch);
            return;
        };
        let meta = shared.manifest.for_batch(&spec.name, batch.len());
        let (abatch, dim, _odim) = match meta {
            Some(m) => (m.batch, m.input_dim, m.output_dim),
            None => (batch.len(), batch[0].input.len(), 0),
        };
        // Pad inputs to the artifact's compiled batch size.
        let mut flat = vec![0.0f32; abatch * dim];
        for (i, r) in batch.iter().enumerate() {
            let n = r.input.len().min(dim);
            flat[i * dim..i * dim + n].copy_from_slice(&r.input[..n]);
        }
        let result = shared
            .runtime
            .infer(&path, &[(&flat, &[abatch as i64, dim as i64])]);
        let now_inst = Instant::now();
        match result {
            Ok(out) => {
                let per_item = out.values.len() / abatch.max(1);
                let mut rep = shared.report.lock().unwrap();
                for (i, r) in batch.iter().enumerate() {
                    let latency = now_inst.duration_since(r.arrival);
                    rep.function(&spec.name).record(
                        shared.epoch.elapsed().as_secs_f64(),
                        latency.as_secs_f64(),
                        Outcome::Ok,
                    );
                    let reply = InferReply {
                        output: out.values[i * per_item..(i + 1) * per_item].to_vec(),
                        latency,
                        token_wait,
                        exec_time: out.exec_time,
                        batch_size: batch.len(),
                    };
                    let _ = r.reply.send(reply);
                }
            }
            Err(e) => {
                let mut rep = shared.report.lock().unwrap();
                for r in &batch {
                    rep.function(&spec.name).record(
                        shared.epoch.elapsed().as_secs_f64(),
                        now_inst.duration_since(r.arrival).as_secs_f64(),
                        Outcome::Dropped,
                    );
                }
                eprintln!("pod {} execution error: {e:#}", pod.0);
            }
        }
    }
}

fn requeue(fq: &FunctionQueue, batch: Vec<QueuedRequest>) {
    let mut q = fq.q.lock().unwrap();
    for r in batch.into_iter().rev() {
        q.push_front(r);
    }
    fq.cv.notify_all();
}

// ---------------------------------------------------------------------------
// Workflow stage-to-stage routing
// ---------------------------------------------------------------------------

/// One scheduled hop along a workflow edge: the completed stage's payload
/// travels to stage `to`, arriving after `latency` seconds on the wire.
#[derive(Clone, Copy, Debug)]
pub struct StageHop {
    pub to: usize,
    pub latency: f64,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum OriginState {
    Open,
    Done,
    Failed,
}

/// Per-origin bookkeeping: one entry per workflow request admitted at the
/// entry stage. Join counts live in the router's flat `counts` arena
/// (stride = stage count) so opening an origin allocates nothing after the
/// arena warms up.
#[derive(Clone, Copy, Debug)]
struct Origin {
    arrival: f64,
    remaining_terminals: u32,
    state: OriginState,
}

/// Stage-to-stage router for one [`crate::workflow::Workflow`].
///
/// The router is serving-plane-agnostic: the sim's discrete-event loop and
/// a real gateway both drive it with the same three calls — [`Self::open`]
/// when a request enters the workflow, [`Self::route_completion`] when a
/// stage execution finishes (yielding either outgoing hops to schedule or
/// the finished end-to-end latency), and [`Self::arrive`] when a hop lands
/// (true = the join is complete, enqueue at that stage *now*).
///
/// **Deadline accounting happens exactly once**: every end-to-end figure is
/// derived from the single origin arrival timestamp (`now − arrival`), so
/// queue time already measured by a stage's own `FunctionMetrics` is never
/// re-added on the next hop — `remaining_deadline` shrinks monotonically
/// through the pipeline and e2e latency equals the sum of per-stage
/// latencies plus hop latencies by construction (pinned by the 3-stage
/// chain regression test below).
#[derive(Clone, Debug)]
pub struct WorkflowRouter {
    /// Outgoing hops per stage, hop latencies precomputed from payloads.
    outgoing: Vec<Vec<StageHop>>,
    in_deg: Vec<u32>,
    n_stages: usize,
    n_terminals: u32,
    origins: Vec<Origin>,
    /// Arrived-copy counts, `origin * n_stages + stage`.
    counts: Vec<u32>,
}

impl WorkflowRouter {
    pub fn new(wf: &crate::workflow::Workflow) -> Self {
        let n = wf.stages.len();
        let mut outgoing: Vec<Vec<StageHop>> = vec![Vec::new(); n];
        let mut in_deg = vec![0u32; n];
        for e in &wf.edges {
            outgoing[e.from].push(StageHop { to: e.to, latency: e.hop_latency() });
            in_deg[e.to] += 1;
        }
        WorkflowRouter {
            outgoing,
            in_deg,
            n_stages: n,
            n_terminals: wf.terminal_count() as u32,
            origins: Vec::new(),
            counts: Vec::new(),
        }
    }

    /// Admit one request at the entry stage; returns its origin id.
    pub fn open(&mut self, arrival: f64) -> u32 {
        let id = self.origins.len() as u32;
        self.origins.push(Origin {
            arrival,
            remaining_terminals: self.n_terminals,
            state: OriginState::Open,
        });
        self.counts.resize(self.counts.len() + self.n_stages, 0);
        id
    }

    /// When the origin entered the workflow.
    pub fn arrival_of(&self, origin: u32) -> f64 {
        self.origins[origin as usize].arrival
    }

    /// Deadline budget left at `now` against the workflow e2e SLO — always
    /// `slo − (now − arrival)`, never re-derived per stage, so queue time is
    /// charged exactly once.
    pub fn remaining_deadline(&self, origin: u32, now: f64, e2e_slo: f64) -> f64 {
        e2e_slo - (now - self.arrival_of(origin))
    }

    /// Outgoing hops of a stage (empty for terminal stages).
    pub fn outgoing(&self, stage: usize) -> &[StageHop] {
        &self.outgoing[stage]
    }

    /// A stage execution for `origin` finished OK at `now`. Terminal stage:
    /// returns `Some(e2e latency)` once the last terminal completes (and
    /// only for still-open origins — a failed origin finishes nothing).
    /// Non-terminal: fills `hops` with the outgoing edges to schedule at
    /// `now + hop.latency` each.
    pub fn route_completion(
        &mut self,
        origin: u32,
        stage: usize,
        now: f64,
        hops: &mut Vec<StageHop>,
    ) -> Option<f64> {
        hops.clear();
        let o = &mut self.origins[origin as usize];
        if o.state != OriginState::Open {
            return None;
        }
        if self.outgoing[stage].is_empty() {
            o.remaining_terminals -= 1;
            if o.remaining_terminals == 0 {
                o.state = OriginState::Done;
                return Some(now - o.arrival);
            }
        } else {
            hops.extend_from_slice(&self.outgoing[stage]);
        }
        None
    }

    /// A hop for `origin` landed at `stage`. Returns true when every
    /// incoming copy has arrived (the join is complete) and the origin is
    /// still open — the caller enqueues one request at the stage *now*.
    pub fn arrive(&mut self, origin: u32, stage: usize) -> bool {
        if self.origins[origin as usize].state != OriginState::Open {
            return false;
        }
        let slot = origin as usize * self.n_stages + stage;
        self.counts[slot] += 1;
        self.counts[slot] == self.in_deg[stage]
    }

    /// Mark the origin failed (a stage copy was dropped or lost). Returns
    /// the elapsed time since entry for the *first* failure only, so the
    /// caller records exactly one e2e outcome per origin.
    pub fn fail(&mut self, origin: u32, now: f64) -> Option<f64> {
        let o = &mut self.origins[origin as usize];
        if o.state != OriginState::Open {
            return None;
        }
        o.state = OriginState::Failed;
        Some(now - o.arrival)
    }

    /// Origins still open (id, arrival) — the End-of-run finalization list.
    pub fn open_origins(&self) -> impl Iterator<Item = (u32, f64)> + '_ {
        self.origins
            .iter()
            .enumerate()
            .filter(|(_, o)| o.state == OriginState::Open)
            .map(|(i, o)| (i as u32, o.arrival))
    }
}

#[cfg(test)]
mod tests {
    // Real-mode serving is integration-tested in `rust/tests/` against the
    // AOT artifacts (requires `make artifacts`). Unit tests here cover the
    // queue helpers only.
    use super::*;

    #[test]
    fn requeue_preserves_order() {
        let fq = FunctionQueue {
            q: Mutex::new(VecDeque::new()),
            cv: Condvar::new(),
        };
        let mk = |_i: usize| {
            let (tx, _rx) = sync_channel(1);
            QueuedRequest {
                arrival: Instant::now(),
                input: vec![],
                reply: tx,
            }
        };
        fq.q.lock().unwrap().push_back(mk(3));
        let batch = vec![mk(1), mk(2)];
        // keep rx alive is unnecessary for this ordering test
        requeue(&fq, batch);
        assert_eq!(fq.q.lock().unwrap().len(), 3);
    }

    use crate::model::zoo::ZooModel;
    use crate::workflow::{Workflow, WorkflowEdge, WorkflowStage};

    fn chain3() -> Workflow {
        let mut w = Workflow::chain(
            "wf",
            "test chain",
            &[
                ("a", ZooModel::MobileNetV2, 4),
                ("b", ZooModel::ResNet50, 4),
                ("c", ZooModel::BertTiny, 4),
            ],
            1e6,
        );
        w.e2e_slo = 1.0;
        w
    }

    /// Satellite regression: through a 3-stage chain the end-to-end latency
    /// equals Σ per-stage latencies + Σ hop latencies *exactly* — the
    /// remaining deadline is carried through hops exactly once and no queue
    /// interval is ever double-counted.
    #[test]
    fn chain_e2e_is_exact_sum_of_stage_and_hop_latencies() {
        let w = chain3();
        let mut r = WorkflowRouter::new(&w);
        let stage_lat = [0.030, 0.050, 0.020]; // queue + service per stage
        let hop: Vec<f64> = w.edges.iter().map(|e| e.hop_latency()).collect();
        let t0 = 5.0;
        let o = r.open(t0);
        let mut now = t0;
        let mut hops = Vec::new();
        for (s, &lat) in stage_lat.iter().enumerate() {
            now += lat; // stage s completes
            let done = r.route_completion(o, s, now, &mut hops);
            if s < 2 {
                assert_eq!(done, None);
                assert_eq!(hops.len(), 1);
                assert_eq!(hops[0].to, s + 1);
                now += hops[0].latency; // hop lands
                assert!(r.arrive(o, s + 1), "chain joins are singletons");
            } else {
                let e2e = done.expect("terminal stage finishes the origin");
                let want: f64 = stage_lat.iter().sum::<f64>() + hop.iter().sum::<f64>();
                assert!((e2e - want).abs() < 1e-12, "e2e {e2e} vs Σ {want}");
            }
        }
        // The deadline shrank monotonically and exactly once per interval.
        assert!((r.remaining_deadline(o, now, w.e2e_slo) - (w.e2e_slo - (now - t0))).abs() < 1e-12);
        // Terminal completion is exactly-once: replays are inert.
        assert_eq!(r.route_completion(o, 2, now + 1.0, &mut hops), None);
        assert_eq!(r.fail(o, now + 1.0), None, "done origins cannot fail");
        assert_eq!(r.open_origins().count(), 0);
    }

    #[test]
    fn diamond_join_fires_on_second_arrival_and_fails_once() {
        let w = Workflow {
            name: "d".into(),
            about: "diamond".into(),
            stages: ["s", "l", "r", "m"]
                .iter()
                .map(|n| WorkflowStage {
                    name: (*n).into(),
                    model: ZooModel::MobileNetV2,
                    batch: 4,
                })
                .collect(),
            edges: vec![
                WorkflowEdge { from: 0, to: 1, payload_bytes: 1e6 },
                WorkflowEdge { from: 0, to: 2, payload_bytes: 1e6 },
                WorkflowEdge { from: 1, to: 3, payload_bytes: 1e4 },
                WorkflowEdge { from: 2, to: 3, payload_bytes: 1e4 },
            ],
            e2e_slo: 1.0,
        };
        w.validate().unwrap();
        let mut r = WorkflowRouter::new(&w);
        let mut hops = Vec::new();
        let o = r.open(0.0);
        assert_eq!(r.route_completion(o, 0, 0.1, &mut hops), None);
        assert_eq!(hops.len(), 2, "split fans out to both branches");
        assert!(r.arrive(o, 1) && r.arrive(o, 2));
        assert_eq!(r.route_completion(o, 1, 0.2, &mut hops), None);
        assert!(!r.arrive(o, 3), "first merge copy must wait for the join");
        assert_eq!(r.route_completion(o, 2, 0.3, &mut hops), None);
        assert!(r.arrive(o, 3), "second copy completes the join");
        let e2e = r.route_completion(o, 3, 0.4, &mut hops);
        assert_eq!(e2e, Some(0.4));

        // Failure path: one branch drop fails the origin exactly once and
        // the surviving branch's copies are inert afterwards.
        let o2 = r.open(1.0);
        r.route_completion(o2, 0, 1.1, &mut hops);
        assert_eq!(r.fail(o2, 1.2), Some(1.2 - 1.0));
        assert_eq!(r.fail(o2, 1.3), None, "second failure is suppressed");
        assert!(!r.arrive(o2, 2), "failed origins route nothing");
        assert_eq!(r.route_completion(o2, 2, 1.4, &mut hops), None);
        assert_eq!(r.open_origins().count(), 0);

        // End finalization sees only still-open origins.
        let o3 = r.open(2.0);
        assert_eq!(r.open_origins().collect::<Vec<_>>(), vec![(o3, 2.0)]);
    }
}
