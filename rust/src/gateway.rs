//! Real-mode serving plane: gateway, dynamic batcher, pod executors.
//!
//! This is the paper's Fig. 1 data path with *actual* model execution —
//! Python never appears at runtime:
//!
//! ```text
//! client → Gateway::submit → per-function queue
//!             ├── pod executor thread (one per pod)
//!             │     1. pull up to `batch` requests (dynamic batching with a
//!             │        short max-wait, request-batching à la BATCH/MArk)
//!             │     2. acquire time tokens from the pod's vGPU TokenScheduler
//!             │        (cost = modelled GPU time of this batch at the pod's
//!             │        SM partition — the libhas interception point)
//!             │     3. PJRT-execute the AOT HLO artifact (runtime::infer)
//!             │     4. reply + record metrics
//!             └── autoscaler thread: per-second tick → HybridAutoscaler::plan
//!                   → Reconfigurator::apply (quota re-writes reach the token
//!                   scheduler live; new pods spawn executor threads)
//! ```

use crate::autoscaler::ScalingPolicy;
use crate::cluster::{Applied, ClusterState, FunctionSpec, PodId, PodPhase, Reconfigurator};
use crate::metrics::{BillingLedger, BillingMode, Outcome, RunReport};
use crate::perf::PerfModel;
use crate::rapp::LatencyPredictor;
use crate::runtime::{Manifest, PjrtRuntime};
use crate::vgpu::tokens::TokenError;
use std::collections::{HashMap, VecDeque};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, SyncSender};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

/// One inference request.
struct QueuedRequest {
    arrival: Instant,
    input: Vec<f32>,
    reply: SyncSender<InferReply>,
}

/// What the client gets back.
#[derive(Clone, Debug)]
pub struct InferReply {
    pub output: Vec<f32>,
    /// End-to-end latency (queue + batching + tokens + execution).
    pub latency: Duration,
    /// Time waiting for vGPU time tokens (the quota enforcement cost).
    pub token_wait: Duration,
    /// Pure PJRT execution time.
    pub exec_time: Duration,
    pub batch_size: usize,
}

struct FunctionQueue {
    q: Mutex<VecDeque<QueuedRequest>>,
    cv: Condvar,
}

struct Shared {
    cluster: Mutex<ClusterState>,
    recon: Mutex<Reconfigurator>,
    perf: PerfModel,
    runtime: Arc<PjrtRuntime>,
    manifest: Manifest,
    queues: HashMap<String, Arc<FunctionQueue>>,
    arrivals: HashMap<String, AtomicU64>,
    report: Mutex<RunReport>,
    /// The transactional billing engine (shared with sim mode — see
    /// `metrics::ledger`). Real mode always bills the fine-grained slice.
    ledger: Mutex<BillingLedger>,
    shutdown: AtomicBool,
    epoch: Instant,
    /// Dynamic batching max-wait.
    batch_wait: Duration,
}

/// Real-mode serving server.
pub struct Server {
    shared: Arc<Shared>,
    scaler: Mutex<Option<std::thread::JoinHandle<()>>>,
    executors: Mutex<Vec<std::thread::JoinHandle<()>>>,
}

/// Server construction options.
pub struct ServerConfig {
    pub n_gpus: usize,
    pub seed: u64,
    /// Token-window length (seconds).
    pub window: f64,
    /// Autoscaler tick.
    pub tick: Duration,
    /// Dynamic batching max-wait.
    pub batch_wait: Duration,
    /// Cold-start scale factor (1.0 = paper-realistic 10 s GPU starts; demos
    /// use ~0.05 to keep examples snappy).
    pub coldstart_scale: f64,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            n_gpus: 2,
            seed: 7,
            window: 0.005,
            tick: Duration::from_secs(1),
            batch_wait: Duration::from_millis(4),
            coldstart_scale: 0.05,
        }
    }
}

impl Server {
    /// Build a server over AOT artifacts in `artifacts_dir`, serving
    /// `functions` with `policy` as the autoscaler.
    pub fn start(
        artifacts_dir: &std::path::Path,
        functions: Vec<FunctionSpec>,
        mut policy: Box<dyn ScalingPolicy>,
        predictor: Arc<dyn LatencyPredictor>,
        cfg: ServerConfig,
    ) -> anyhow::Result<Arc<Self>> {
        let manifest = Manifest::load(artifacts_dir)?;
        let runtime = Arc::new(PjrtRuntime::new()?);
        let perf = PerfModel::default();
        let mut cluster = ClusterState::new(cfg.n_gpus, perf.dev.mem_cap);
        cluster.coldstart.gpu_instance *= cfg.coldstart_scale;
        cluster.coldstart.container *= cfg.coldstart_scale;
        for f in &functions {
            anyhow::ensure!(
                f.artifact.is_some() || !manifest.variants(&f.name).is_empty(),
                "no artifact for function '{}'",
                f.name
            );
            cluster.register_function(f.clone());
        }
        let recon = Reconfigurator::new(&cluster, cfg.seed)
            .with_token_schedulers(cfg.n_gpus, cfg.window);
        let mut queues = HashMap::new();
        let mut arrivals = HashMap::new();
        for f in &functions {
            queues.insert(
                f.name.clone(),
                Arc::new(FunctionQueue {
                    q: Mutex::new(VecDeque::new()),
                    cv: Condvar::new(),
                }),
            );
            arrivals.insert(f.name.clone(), AtomicU64::new(0));
        }
        let price_per_hour = perf.dev.price_per_hour;
        let shared = Arc::new(Shared {
            cluster: Mutex::new(cluster),
            recon: Mutex::new(recon),
            perf,
            runtime,
            manifest,
            queues,
            arrivals,
            report: Mutex::new(RunReport::new(policy.name())),
            ledger: Mutex::new(BillingLedger::new(BillingMode::FineGrained, price_per_hour)),
            shutdown: AtomicBool::new(false),
            epoch: Instant::now(),
            batch_wait: cfg.batch_wait,
        });
        let server = Arc::new(Server {
            shared: Arc::clone(&shared),
            scaler: Mutex::new(None),
            executors: Mutex::new(Vec::new()),
        });

        // Warm-up: compile every artifact before serving.
        for f in &functions {
            for v in shared.manifest.variants(&f.name) {
                shared.runtime.warmup(&v.path)?;
            }
        }

        // Bootstrap one pod per function and spawn executors.
        {
            let now = shared.now();
            let mut cl = shared.cluster.lock().unwrap();
            let mut rc = shared.recon.lock().unwrap();
            for f in &functions {
                let actions = policy.plan(f, 1.0, &cl, predictor.as_ref(), now);
                for a in &actions {
                    if let Ok(applied) = rc.apply(&mut cl, &shared.perf, a, now) {
                        Self::record_applied(&shared, &cl, &applied, now);
                        if let Applied::PodCreated { pod, .. } = applied {
                            if let Some(p) = cl.pod_mut(pod) {
                                p.phase = PodPhase::Running; // deployment-time warm
                            }
                            server.spawn_executor(pod, f.clone());
                        }
                    }
                }
            }
        }

        // Autoscaler loop.
        {
            let shared2 = Arc::clone(&shared);
            let server2 = Arc::downgrade(&server);
            let functions2 = functions.clone();
            let tick = cfg.tick;
            let handle = std::thread::Builder::new()
                .name("has-autoscaler".into())
                .spawn(move || {
                    while !shared2.shutdown.load(Ordering::Acquire) {
                        std::thread::sleep(tick);
                        let now = shared2.now();
                        for f in &functions2 {
                            let observed = shared2.arrivals[&f.name]
                                .swap(0, Ordering::AcqRel)
                                as f64
                                / tick.as_secs_f64();
                            let actions = {
                                let cl = shared2.cluster.lock().unwrap();
                                policy.plan(f, observed, &cl, predictor.as_ref(), now)
                            };
                            for a in &actions {
                                let applied = {
                                    let mut cl = shared2.cluster.lock().unwrap();
                                    let mut rc = shared2.recon.lock().unwrap();
                                    let applied = rc.apply(&mut cl, &shared2.perf, a, now).ok();
                                    // Ledger + counters only after the
                                    // mutation succeeds: rejected actions
                                    // bill nothing and count nothing.
                                    if let Some(applied) = &applied {
                                        Self::record_applied(&shared2, &cl, applied, now);
                                    }
                                    applied
                                };
                                if let Some(Applied::PodCreated { pod, .. }) = applied {
                                    if let Some(srv) = server2.upgrade() {
                                        srv.spawn_executor(pod, f.clone());
                                    }
                                }
                            }
                        }
                    }
                })
                .expect("spawn autoscaler");
            *server.scaler.lock().unwrap() = Some(handle);
        }
        Ok(server)
    }

    /// Record a successfully applied scaling action (never called for
    /// rejected ones) via the shared `Applied` → accounting mapping in
    /// `metrics::ledger`. Lock order is report → ledger; `report()` takes
    /// them sequentially (never nested), so no ordering cycle exists.
    /// Note: bootstrap pod creations count as `horizontal_ups` too — same
    /// semantics as sim mode's warm bootstrap.
    fn record_applied(shared: &Shared, cl: &ClusterState, applied: &Applied, now: f64) {
        let mut rep = shared.report.lock().unwrap();
        let mut ledger = shared.ledger.lock().unwrap();
        crate::metrics::ledger::record_applied(&mut rep, &mut ledger, cl, applied, now);
    }

    fn now_of(shared: &Shared) -> f64 {
        shared.epoch.elapsed().as_secs_f64()
    }

    /// Submit a request; returns a receiver for the reply.
    ///
    /// An unknown function name is a *client* error, not a server bug: it
    /// comes back as an `Err` listing the deployed menu (the same shape as
    /// the CLI resolvers) instead of panicking the calling thread.
    pub fn submit(&self, function: &str, input: Vec<f32>) -> anyhow::Result<Receiver<InferReply>> {
        let (tx, rx) = sync_channel(1);
        let Some(fq) = self.shared.queues.get(function) else {
            let mut menu: Vec<&str> = self.shared.queues.keys().map(String::as_str).collect();
            menu.sort_unstable();
            anyhow::bail!("unknown function '{function}'; deployed: {}", menu.join(", "));
        };
        self.shared.arrivals[function].fetch_add(1, Ordering::AcqRel);
        fq.q.lock().unwrap().push_back(QueuedRequest {
            arrival: Instant::now(),
            input,
            reply: tx,
        });
        fq.cv.notify_one();
        Ok(rx)
    }

    /// Spawn the executor thread for a pod.
    fn spawn_executor(&self, pod: PodId, spec: FunctionSpec) {
        let shared = Arc::clone(&self.shared);
        let handle = std::thread::Builder::new()
            .name(format!("has-pod-{}", pod.0))
            .spawn(move || pod_executor(shared, pod, spec))
            .expect("spawn pod executor");
        self.executors.lock().unwrap().push(handle);
    }

    /// Snapshot of the metrics report.
    pub fn report(&self) -> RunReport {
        // Settle every open pod account up to `now` (idempotent), then copy
        // the meter into the report snapshot.
        let now = self.shared.now();
        let costs = {
            let mut ledger = self.shared.ledger.lock().unwrap();
            ledger.settle(now);
            ledger.meter().clone()
        };
        let mut r = self.shared.report.lock().unwrap().clone();
        r.costs = costs;
        r.duration = now;
        r
    }

    /// Current pod layout (function, sm‰, quota‰) for observability.
    pub fn pod_layout(&self) -> Vec<(String, u32, u32)> {
        self.shared
            .cluster
            .lock()
            .unwrap()
            .pods()
            .map(|p| (p.function.clone(), p.sm, p.quota))
            .collect()
    }

    /// Stop the server, joining all threads.
    pub fn shutdown(&self) {
        self.shared.shutdown.store(true, Ordering::Release);
        for fq in self.shared.queues.values() {
            fq.cv.notify_all();
        }
        for h in self.executors.lock().unwrap().drain(..) {
            let _ = h.join();
        }
    }
}

impl Shared {
    fn now(&self) -> f64 {
        Server::now_of(self)
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.shutdown();
        if let Some(h) = self.scaler.lock().unwrap().take() {
            let _ = h.join();
        }
    }
}

/// The pod executor loop: batch → tokens → PJRT → reply.
fn pod_executor(shared: Arc<Shared>, pod: PodId, spec: FunctionSpec) {
    let fq = Arc::clone(&shared.queues[&spec.name]);
    loop {
        if shared.shutdown.load(Ordering::Acquire) {
            return;
        }
        // Pod still placed? (Removal deregisters the token client too.)
        let placement = {
            let cl = shared.cluster.lock().unwrap();
            cl.pod(pod).map(|p| (p.gpu, p.sm, p.quota, p.batch))
        };
        let Some((gpu, sm, _quota, max_batch)) = placement else {
            return; // pod removed
        };

        // --- dynamic batching: wait for the first request (bounded, so pod
        // removal and shutdown are noticed), then linger briefly for more.
        let mut batch: Vec<QueuedRequest> = Vec::new();
        {
            let mut q = fq.q.lock().unwrap();
            if q.is_empty() {
                let (guard, _) = fq
                    .cv
                    .wait_timeout(q, Duration::from_millis(50))
                    .unwrap();
                q = guard;
            }
            while batch.len() < max_batch as usize {
                match q.pop_front() {
                    Some(r) => batch.push(r),
                    None => break,
                }
            }
        }
        if batch.is_empty() {
            continue; // re-checks shutdown + placement at loop top
        }
        // Linger for more requests up to batch_wait.
        let linger_deadline = Instant::now() + shared.batch_wait;
        while batch.len() < max_batch as usize && Instant::now() < linger_deadline {
            let mut q = fq.q.lock().unwrap();
            while batch.len() < max_batch as usize {
                match q.pop_front() {
                    Some(r) => batch.push(r),
                    None => break,
                }
            }
            drop(q);
            if batch.len() < max_batch as usize {
                std::thread::sleep(Duration::from_micros(200));
            }
        }

        // --- token acquisition (libhas: gate execution on the time quota).
        // Cost = modelled GPU time of this batch at the pod's SM partition.
        let cost = shared
            .perf
            .raw_graph_time(&spec.graph, batch.len() as u32, crate::vgpu::sm_to_f64(sm));
        let client = crate::vgpu::ClientId(pod.0);
        // libhas gates each *kernel launch*, not each batch: acquire the
        // modelled GPU time in kernel-sized chunks so the quota actually
        // dilates long batches (no-debt windows forgive a single overrun).
        let token_wait = {
            let sched = {
                let rc = shared.recon.lock().unwrap();
                rc.token_scheduler(gpu).cloned()
            };
            match sched {
                Some(s) => {
                    let chunk = (s.window() / 4.0).max(1e-4);
                    let mut remaining = cost;
                    let mut waited = Duration::ZERO;
                    loop {
                        match s.acquire(client, remaining.min(chunk)) {
                            Ok(w) => waited += w,
                            Err(TokenError::Deregistered(_))
                            | Err(TokenError::ZeroQuota(_)) => {
                                requeue(&fq, batch);
                                return;
                            }
                            Err(_) => {}
                        }
                        if remaining <= chunk {
                            break;
                        }
                        remaining -= chunk;
                    }
                    waited
                }
                None => Duration::ZERO,
            }
        };

        // --- PJRT execution of the AOT artifact.
        let artifact = spec.artifact.clone().or_else(|| {
            shared
                .manifest
                .for_batch(&spec.name, batch.len())
                .map(|a| a.path.clone())
        });
        let Some(path) = artifact else {
            requeue(&fq, batch);
            return;
        };
        let meta = shared.manifest.for_batch(&spec.name, batch.len());
        let (abatch, dim, _odim) = match meta {
            Some(m) => (m.batch, m.input_dim, m.output_dim),
            None => (batch.len(), batch[0].input.len(), 0),
        };
        // Pad inputs to the artifact's compiled batch size.
        let mut flat = vec![0.0f32; abatch * dim];
        for (i, r) in batch.iter().enumerate() {
            let n = r.input.len().min(dim);
            flat[i * dim..i * dim + n].copy_from_slice(&r.input[..n]);
        }
        let result = shared
            .runtime
            .infer(&path, &[(&flat, &[abatch as i64, dim as i64])]);
        let now_inst = Instant::now();
        match result {
            Ok(out) => {
                let per_item = out.values.len() / abatch.max(1);
                let mut rep = shared.report.lock().unwrap();
                for (i, r) in batch.iter().enumerate() {
                    let latency = now_inst.duration_since(r.arrival);
                    rep.function(&spec.name).record(
                        shared.epoch.elapsed().as_secs_f64(),
                        latency.as_secs_f64(),
                        Outcome::Ok,
                    );
                    let reply = InferReply {
                        output: out.values[i * per_item..(i + 1) * per_item].to_vec(),
                        latency,
                        token_wait,
                        exec_time: out.exec_time,
                        batch_size: batch.len(),
                    };
                    let _ = r.reply.send(reply);
                }
            }
            Err(e) => {
                let mut rep = shared.report.lock().unwrap();
                for r in &batch {
                    rep.function(&spec.name).record(
                        shared.epoch.elapsed().as_secs_f64(),
                        now_inst.duration_since(r.arrival).as_secs_f64(),
                        Outcome::Dropped,
                    );
                }
                eprintln!("pod {} execution error: {e:#}", pod.0);
            }
        }
    }
}

fn requeue(fq: &FunctionQueue, batch: Vec<QueuedRequest>) {
    let mut q = fq.q.lock().unwrap();
    for r in batch.into_iter().rev() {
        q.push_front(r);
    }
    fq.cv.notify_all();
}

#[cfg(test)]
mod tests {
    // Real-mode serving is integration-tested in `rust/tests/` against the
    // AOT artifacts (requires `make artifacts`). Unit tests here cover the
    // queue helpers only.
    use super::*;

    #[test]
    fn requeue_preserves_order() {
        let fq = FunctionQueue {
            q: Mutex::new(VecDeque::new()),
            cv: Condvar::new(),
        };
        let mk = |_i: usize| {
            let (tx, _rx) = sync_channel(1);
            QueuedRequest {
                arrival: Instant::now(),
                input: vec![],
                reply: tx,
            }
        };
        fq.q.lock().unwrap().push_back(mk(3));
        let batch = vec![mk(1), mk(2)];
        // keep rx alive is unnecessary for this ordering test
        requeue(&fq, batch);
        assert_eq!(fq.q.lock().unwrap().len(), 3);
    }
}
