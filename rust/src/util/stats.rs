//! Streaming statistics: percentiles, histograms, MAPE, online mean/variance.
//!
//! The metrics plane records hundreds of thousands of per-request latencies in
//! a simulation run; [`Summary`] keeps exact values (the experiment scale fits
//! in memory) while [`Histogram`] provides a fixed-footprint log-bucketed
//! alternative for the serving hot path.

/// Exact-sample summary with lazily-sorted percentile queries.
#[derive(Clone, Debug, Default)]
pub struct Summary {
    values: Vec<f64>,
    sorted: bool,
}

impl Summary {
    pub fn new() -> Self {
        Self::default()
    }

    #[inline]
    pub fn add(&mut self, v: f64) {
        self.values.push(v);
        self.sorted = false;
    }

    pub fn len(&self) -> usize {
        self.values.len()
    }

    pub fn is_empty(&self) -> bool {
        self.values.is_empty()
    }

    pub fn mean(&self) -> f64 {
        if self.values.is_empty() {
            return f64::NAN;
        }
        self.values.iter().sum::<f64>() / self.values.len() as f64
    }

    pub fn min(&self) -> f64 {
        self.values.iter().copied().fold(f64::INFINITY, f64::min)
    }

    pub fn max(&self) -> f64 {
        self.values.iter().copied().fold(f64::NEG_INFINITY, f64::max)
    }

    pub fn std(&self) -> f64 {
        let m = self.mean();
        if self.values.len() < 2 {
            return 0.0;
        }
        (self.values.iter().map(|v| (v - m) * (v - m)).sum::<f64>()
            / (self.values.len() - 1) as f64)
            .sqrt()
    }

    /// Percentile in [0, 100] with linear interpolation between ranks.
    pub fn percentile(&mut self, p: f64) -> f64 {
        if self.values.is_empty() {
            return f64::NAN;
        }
        if !self.sorted {
            // IEEE total order: NaN sorts to a fixed place (above +inf)
            // instead of panicking the whole report — the same fix the
            // dispatch sort got (`partial_cmp().expect()` aborted on the
            // first NaN sample).
            self.values.sort_unstable_by(f64::total_cmp);
            self.sorted = true;
        }
        let n = self.values.len();
        if n == 1 {
            return self.values[0];
        }
        let rank = p / 100.0 * (n - 1) as f64;
        let lo = rank.floor() as usize;
        let hi = rank.ceil() as usize;
        let frac = rank - lo as f64;
        self.values[lo] * (1.0 - frac) + self.values[hi] * frac
    }

    pub fn p50(&mut self) -> f64 {
        self.percentile(50.0)
    }
    pub fn p90(&mut self) -> f64 {
        self.percentile(90.0)
    }
    pub fn p95(&mut self) -> f64 {
        self.percentile(95.0)
    }
    pub fn p99(&mut self) -> f64 {
        self.percentile(99.0)
    }

    /// Fraction of samples strictly above `threshold` — the SLO-violation rate
    /// for a given latency bound.
    pub fn frac_above(&self, threshold: f64) -> f64 {
        if self.values.is_empty() {
            return 0.0;
        }
        self.values.iter().filter(|&&v| v > threshold).count() as f64 / self.values.len() as f64
    }

    pub fn values(&self) -> &[f64] {
        &self.values
    }
}

/// Log-bucketed histogram: fixed memory, ~2.5% relative error per bucket.
/// Covers [1e-7, ~1e5) seconds with 12 buckets/decade.
#[derive(Clone, Debug)]
pub struct Histogram {
    buckets: Vec<u64>,
    count: u64,
    sum: f64,
    min: f64,
    max: f64,
}

const HIST_BUCKETS_PER_DECADE: f64 = 12.0;
const HIST_LO: f64 = 1e-7;
const HIST_N: usize = 145; // 12 decades * 12 + 1

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

impl Histogram {
    pub fn new() -> Self {
        Histogram {
            buckets: vec![0; HIST_N],
            count: 0,
            sum: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }

    #[inline]
    fn index(v: f64) -> usize {
        if v <= HIST_LO {
            return 0;
        }
        let idx = ((v / HIST_LO).log10() * HIST_BUCKETS_PER_DECADE) as usize;
        idx.min(HIST_N - 1)
    }

    #[inline]
    pub fn add(&mut self, v: f64) {
        self.buckets[Self::index(v)] += 1;
        self.count += 1;
        self.sum += v;
        self.min = self.min.min(v);
        self.max = self.max.max(v);
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            f64::NAN
        } else {
            self.sum / self.count as f64
        }
    }

    /// Percentile estimate from bucket boundaries (upper edge interpolation).
    pub fn percentile(&self, p: f64) -> f64 {
        if self.count == 0 {
            return f64::NAN;
        }
        let target = (p / 100.0 * self.count as f64).ceil() as u64;
        let mut acc = 0u64;
        for (i, &c) in self.buckets.iter().enumerate() {
            acc += c;
            if acc >= target.max(1) {
                let edge = HIST_LO * 10f64.powf((i as f64 + 0.5) / HIST_BUCKETS_PER_DECADE);
                return edge.clamp(self.min, self.max);
            }
        }
        self.max
    }
}

/// Online mean/variance (Welford) — used by the Kalman filter's measurement
/// noise estimator and by streaming throughput meters.
#[derive(Clone, Copy, Debug, Default)]
pub struct Welford {
    n: u64,
    mean: f64,
    m2: f64,
}

impl Welford {
    pub fn new() -> Self {
        Self::default()
    }

    #[inline]
    pub fn add(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        if self.n == 0 {
            f64::NAN
        } else {
            self.mean
        }
    }

    pub fn variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }
}

/// Mean absolute percentage error — the paper's RaPP accuracy metric (Fig. 5).
pub fn mape(truth: &[f64], pred: &[f64]) -> f64 {
    assert_eq!(truth.len(), pred.len());
    assert!(!truth.is_empty());
    let mut acc = 0.0;
    for (&t, &p) in truth.iter().zip(pred) {
        debug_assert!(t > 0.0, "MAPE needs positive ground truth");
        acc += ((t - p) / t).abs();
    }
    acc / truth.len() as f64 * 100.0
}

/// Root-mean-square error.
pub fn rmse(truth: &[f64], pred: &[f64]) -> f64 {
    assert_eq!(truth.len(), pred.len());
    let mse = truth
        .iter()
        .zip(pred)
        .map(|(&t, &p)| (t - p) * (t - p))
        .sum::<f64>()
        / truth.len() as f64;
    mse.sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_percentiles() {
        let mut s = Summary::new();
        for i in 1..=100 {
            s.add(i as f64);
        }
        assert!((s.p50() - 50.5).abs() < 1e-9);
        assert!((s.percentile(0.0) - 1.0).abs() < 1e-9);
        assert!((s.percentile(100.0) - 100.0).abs() < 1e-9);
        assert!((s.p99() - 99.01).abs() < 0.01);
        assert_eq!(s.len(), 100);
        assert!((s.mean() - 50.5).abs() < 1e-9);
    }

    #[test]
    fn summary_single_value() {
        let mut s = Summary::new();
        s.add(3.0);
        assert_eq!(s.p99(), 3.0);
        assert_eq!(s.p50(), 3.0);
    }

    #[test]
    fn summary_frac_above() {
        let mut s = Summary::new();
        for i in 0..10 {
            s.add(i as f64);
        }
        assert!((s.frac_above(6.5) - 0.3).abs() < 1e-9);
        assert_eq!(s.frac_above(100.0), 0.0);
    }

    #[test]
    fn summary_percentile_survives_nan_sample() {
        // Regression: the percentile sort used partial_cmp().expect("NaN
        // latency"), so one NaN sample panicked every consumer of the
        // report. total_cmp ranks +NaN above every number: finite
        // percentiles still read the finite samples, and only the extreme
        // upper tail ever sees the NaN.
        let mut s = Summary::new();
        for i in 1..=99 {
            s.add(i as f64);
        }
        s.add(f64::NAN);
        // 100 samples, NaN ranked last: the median interpolates 50 and 51.
        assert!((s.p50() - 50.5).abs() < 1e-9);
        assert!((s.percentile(0.0) - 1.0).abs() < 1e-9);
        // The NaN occupies the top rank; p100 reports it rather than lying.
        assert!(s.percentile(100.0).is_nan());
        // Interleaved adds after a query still re-sort without panicking.
        s.add(0.5);
        assert!(s.percentile(1.0).is_finite());
    }

    #[test]
    fn summary_interleaved_add_and_query() {
        let mut s = Summary::new();
        s.add(1.0);
        s.add(2.0);
        let _ = s.p50();
        s.add(0.0); // must re-sort
        assert!((s.p50() - 1.0).abs() < 1e-9);
    }

    #[test]
    fn histogram_percentile_accuracy() {
        let mut h = Histogram::new();
        let mut s = Summary::new();
        let mut rng = crate::util::prng::Pcg64::seeded(5);
        for _ in 0..100_000 {
            let v = rng.lognormal(-4.0, 1.0); // latency-like, ~18ms median
            h.add(v);
            s.add(v);
        }
        for p in [50.0, 90.0, 99.0] {
            let exact = s.percentile(p);
            let est = h.percentile(p);
            let rel = (est - exact).abs() / exact;
            assert!(rel < 0.15, "p{p}: exact={exact} est={est}");
        }
        assert!((h.mean() - s.mean()).abs() / s.mean() < 1e-9);
    }

    #[test]
    fn histogram_empty() {
        let h = Histogram::new();
        assert!(h.percentile(50.0).is_nan());
        assert_eq!(h.count(), 0);
    }

    #[test]
    fn welford_matches_naive() {
        let xs = [1.0, 2.0, 4.0, 8.0, 16.0];
        let mut w = Welford::new();
        for &x in &xs {
            w.add(x);
        }
        assert!((w.mean() - 6.2).abs() < 1e-12);
        let naive_var = xs.iter().map(|x| (x - 6.2f64).powi(2)).sum::<f64>() / 4.0;
        assert!((w.variance() - naive_var).abs() < 1e-12);
    }

    #[test]
    fn mape_basic() {
        let t = [10.0, 20.0];
        let p = [11.0, 18.0];
        // (0.1 + 0.1)/2 * 100 = 10%
        assert!((mape(&t, &p) - 10.0).abs() < 1e-9);
        assert!(rmse(&t, &p) > 0.0);
    }
}
