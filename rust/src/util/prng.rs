//! Deterministic pseudo-random number generation and distribution samplers.
//!
//! Every stochastic component of the system (trace synthesis, arrival
//! thinning, cold-start jitter, measurement noise, dataset sampling) draws
//! from a seeded [`Pcg64`] so simulation runs, tests and benches are
//! bit-reproducible. The generator is PCG-XSL-RR 128/64 (O'Neill 2014), which
//! passes PractRand and is fast enough for the event-loop hot path.

/// PCG-XSL-RR 128/64: 128-bit LCG state, 64-bit xorshift-rotate output.
#[derive(Clone, Debug)]
pub struct Pcg64 {
    state: u128,
    inc: u128,
}

const PCG_MULT: u128 = 0x2360ed051fc65da44385df649fccf645;

impl Pcg64 {
    /// Create a generator from a seed and a stream id. Distinct stream ids
    /// give statistically independent sequences for the same seed, which lets
    /// subsystems share one experiment seed without correlating.
    pub fn new(seed: u64, stream: u64) -> Self {
        let mut rng = Pcg64 {
            state: 0,
            inc: ((stream as u128) << 1) | 1,
        };
        rng.next_u64();
        rng.state = rng.state.wrapping_add(seed as u128);
        rng.next_u64();
        rng
    }

    /// Default stream (0) constructor.
    pub fn seeded(seed: u64) -> Self {
        Self::new(seed, 0)
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let rot = (self.state >> 122) as u32;
        let xored = ((self.state >> 64) as u64) ^ (self.state as u64);
        xored.rotate_right(rot)
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        // 53 top bits -> [0,1) with full double precision.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, n). Uses Lemire's multiply-shift rejection.
    #[inline]
    pub fn next_below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        loop {
            let x = self.next_u64();
            let m = (x as u128).wrapping_mul(n as u128);
            let lo = m as u64;
            if lo >= n.wrapping_neg() % n {
                return (m >> 64) as u64;
            }
        }
    }

    /// Uniform f64 in [lo, hi).
    #[inline]
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.next_f64()
    }

    /// Standard normal via Marsaglia polar method.
    pub fn normal(&mut self) -> f64 {
        loop {
            let u = 2.0 * self.next_f64() - 1.0;
            let v = 2.0 * self.next_f64() - 1.0;
            let s = u * u + v * v;
            if s > 0.0 && s < 1.0 {
                return u * (-2.0 * s.ln() / s).sqrt();
            }
        }
    }

    /// Normal with given mean / std deviation.
    #[inline]
    pub fn normal_ms(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    /// Exponential with rate `lambda` (mean 1/lambda). Used for Poisson-process
    /// inter-arrival gaps in the open-loop workload driver.
    #[inline]
    pub fn exponential(&mut self, lambda: f64) -> f64 {
        debug_assert!(lambda > 0.0);
        // 1 - U avoids ln(0).
        -(1.0 - self.next_f64()).ln() / lambda
    }

    /// Poisson-distributed count with mean `lambda`.
    ///
    /// Knuth's product method for small lambda; for lambda > 30 the PTRS
    /// transformed-rejection sampler (Hörmann 1993) keeps it O(1).
    pub fn poisson(&mut self, lambda: f64) -> u64 {
        if lambda <= 0.0 {
            return 0;
        }
        if lambda < 30.0 {
            let l = (-lambda).exp();
            let mut k = 0u64;
            let mut p = 1.0;
            loop {
                p *= self.next_f64();
                if p <= l {
                    return k;
                }
                k += 1;
            }
        }
        // PTRS
        let b = 0.931 + 2.53 * lambda.sqrt();
        let a = -0.059 + 0.02483 * b;
        let inv_alpha = 1.1239 + 1.1328 / (b - 3.4);
        let v_r = 0.9277 - 3.6224 / (b - 2.0);
        loop {
            let u = self.next_f64() - 0.5;
            let v = self.next_f64();
            let us = 0.5 - u.abs();
            let k = ((2.0 * a / us + b) * u + lambda + 0.43).floor();
            if us >= 0.07 && v <= v_r {
                return k as u64;
            }
            if k < 0.0 || (us < 0.013 && v > us) {
                continue;
            }
            let log_v = v.ln();
            let lhs = log_v + (inv_alpha / (a / (us * us) + b)).ln();
            let rhs = -lambda + k * lambda.ln() - ln_factorial(k as u64);
            if lhs <= rhs {
                return k as u64;
            }
        }
    }

    /// Gamma(shape, scale) via Marsaglia–Tsang; used for heavy-tailed
    /// per-function invocation rates in the Azure-style trace synthesiser.
    pub fn gamma(&mut self, shape: f64, scale: f64) -> f64 {
        debug_assert!(shape > 0.0 && scale > 0.0);
        if shape < 1.0 {
            // Boost: Gamma(a) = Gamma(a+1) * U^(1/a)
            let g = self.gamma(shape + 1.0, 1.0);
            return g * self.next_f64().powf(1.0 / shape) * scale;
        }
        let d = shape - 1.0 / 3.0;
        let c = 1.0 / (9.0 * d).sqrt();
        loop {
            let x = self.normal();
            let v = (1.0 + c * x).powi(3);
            if v <= 0.0 {
                continue;
            }
            let u = self.next_f64();
            if u < 1.0 - 0.0331 * x.powi(4) || u.ln() < 0.5 * x * x + d * (1.0 - v + v.ln()) {
                return d * v * scale;
            }
        }
    }

    /// Pareto (heavy tail) with scale `x_m` and shape `alpha`.
    #[inline]
    pub fn pareto(&mut self, x_m: f64, alpha: f64) -> f64 {
        x_m / (1.0 - self.next_f64()).powf(1.0 / alpha)
    }

    /// Log-normal with underlying normal (mu, sigma).
    #[inline]
    pub fn lognormal(&mut self, mu: f64, sigma: f64) -> f64 {
        (mu + sigma * self.normal()).exp()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.next_below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Choose one element uniformly.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.next_below(xs.len() as u64) as usize]
    }
}

/// ln(k!) via Stirling's series for the PTRS sampler.
fn ln_factorial(k: u64) -> f64 {
    // Exact for small k, Stirling beyond.
    const TABLE: [f64; 10] = [
        0.0,
        0.0,
        0.6931471805599453,
        1.791759469228055,
        3.1780538303479458,
        4.787491742782046,
        6.579251212010101,
        8.525161361065415,
        10.60460290274525,
        12.801827480081469,
    ];
    if (k as usize) < TABLE.len() {
        return TABLE[k as usize];
    }
    let x = (k + 1) as f64;
    (x - 0.5) * x.ln() - x + 0.5 * (2.0 * std::f64::consts::PI).ln() + 1.0 / (12.0 * x)
        - 1.0 / (360.0 * x * x * x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Pcg64::seeded(42);
        let mut b = Pcg64::seeded(42);
        for _ in 0..1000 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn distinct_streams_differ() {
        let mut a = Pcg64::new(42, 1);
        let mut b = Pcg64::new(42, 2);
        let same = (0..100).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 5);
    }

    #[test]
    fn uniform_bounds_and_mean() {
        let mut rng = Pcg64::seeded(7);
        let n = 100_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let x = rng.next_f64();
            assert!((0.0..1.0).contains(&x));
            sum += x;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn next_below_is_unbiased_enough() {
        let mut rng = Pcg64::seeded(3);
        let mut counts = [0u32; 7];
        for _ in 0..70_000 {
            counts[rng.next_below(7) as usize] += 1;
        }
        for &c in &counts {
            assert!((9000..11000).contains(&c), "count {c}");
        }
    }

    #[test]
    fn normal_moments() {
        let mut rng = Pcg64::seeded(11);
        let n = 200_000;
        let (mut s1, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let x = rng.normal();
            s1 += x;
            s2 += x * x;
        }
        let mean = s1 / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.01, "mean={mean}");
        assert!((var - 1.0).abs() < 0.02, "var={var}");
    }

    #[test]
    fn poisson_mean_small_and_large_lambda() {
        let mut rng = Pcg64::seeded(13);
        for &lambda in &[0.5, 3.0, 12.0, 45.0, 200.0] {
            let n = 50_000;
            let mut sum = 0u64;
            for _ in 0..n {
                sum += rng.poisson(lambda);
            }
            let mean = sum as f64 / n as f64;
            assert!(
                (mean - lambda).abs() < lambda.max(1.0) * 0.05,
                "lambda={lambda} mean={mean}"
            );
        }
    }

    #[test]
    fn exponential_mean() {
        let mut rng = Pcg64::seeded(17);
        let n = 100_000;
        let mut sum = 0.0;
        for _ in 0..n {
            sum += rng.exponential(4.0);
        }
        assert!((sum / n as f64 - 0.25).abs() < 0.01);
    }

    #[test]
    fn gamma_mean_variance() {
        let mut rng = Pcg64::seeded(19);
        let (shape, scale) = (2.5, 1.5);
        let n = 100_000;
        let (mut s1, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let x = rng.gamma(shape, scale);
            s1 += x;
            s2 += x * x;
        }
        let mean = s1 / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!((mean - shape * scale).abs() < 0.05, "mean={mean}");
        assert!((var - shape * scale * scale).abs() < 0.2, "var={var}");
    }

    #[test]
    fn gamma_shape_below_one() {
        let mut rng = Pcg64::seeded(23);
        let n = 100_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let x = rng.gamma(0.4, 2.0);
            assert!(x >= 0.0);
            sum += x;
        }
        assert!((sum / n as f64 - 0.8).abs() < 0.03);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Pcg64::seeded(29);
        let mut xs: Vec<u32> = (0..100).collect();
        rng.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn ln_factorial_matches_direct() {
        let direct: f64 = (1..=20).map(|i| (i as f64).ln()).sum();
        assert!((ln_factorial(20) - direct).abs() < 1e-9);
    }
}
