//! Self-contained substrate utilities.
//!
//! The reproduction environment is fully offline, so everything that a typical
//! serving stack would pull from crates.io (RNGs and samplers, JSON, a thread
//! pool, a benchmark harness, a property-testing loop) is implemented here.

pub mod bench;
pub mod cli;
pub mod json;
pub mod prng;
pub mod proptest;
pub mod stats;
pub mod threadpool;
