//! Mini-criterion: a statistically honest micro/end-to-end bench harness.
//!
//! Criterion is unavailable offline; this reproduces the parts the project
//! needs — warm-up, adaptive iteration counts targeting a fixed measurement
//! time, outlier-robust statistics (median + MAD), and stable text output
//! consumed by `EXPERIMENTS.md` — with `harness = false` bench binaries.

use crate::util::json::Json;
use std::time::{Duration, Instant};

/// Schema tag of the machine-readable hot-path bench export
/// (`BENCH_hotpath.json`) — the perf trajectory later PRs regress against.
pub const BENCH_HOTPATH_SCHEMA: &str = "has-gpu/bench-hotpath/v1";

/// One parser for the `HAS_BENCH_FAST=1` smoke-mode contract: short
/// measurement windows and shortened bench workloads (CI). Benches and the
/// [`Harness`] must agree on this, so neither parses the env var itself.
pub fn fast_mode() -> bool {
    std::env::var("HAS_BENCH_FAST").map(|v| v == "1").unwrap_or(false)
}

/// Result of one benchmark case.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub iters: u64,
    pub median: Duration,
    pub mean: Duration,
    pub mad: Duration,
    pub min: Duration,
    pub max: Duration,
    /// Optional throughput denominator (elements per iteration).
    pub elements: Option<u64>,
}

impl BenchResult {
    pub fn throughput(&self) -> Option<f64> {
        self.elements
            .map(|e| e as f64 / self.median.as_secs_f64())
    }

    /// Machine-readable form (durations in nanoseconds; `throughput` in
    /// elements/second when an element count was given).
    pub fn to_json(&self) -> Json {
        let ns = |d: Duration| Json::Num(d.as_nanos() as f64);
        Json::obj(vec![
            ("name", Json::Str(self.name.clone())),
            ("iters", Json::Num(self.iters as f64)),
            ("median_ns", ns(self.median)),
            ("mean_ns", ns(self.mean)),
            ("mad_ns", ns(self.mad)),
            ("min_ns", ns(self.min)),
            ("max_ns", ns(self.max)),
            (
                "elements",
                self.elements.map_or(Json::Null, |e| Json::Num(e as f64)),
            ),
            (
                "throughput",
                self.throughput().map_or(Json::Null, Json::Num),
            ),
        ])
    }

    pub fn report(&self) -> String {
        let tp = match self.throughput() {
            Some(t) if t >= 1e6 => format!("  {:>9.2} Melem/s", t / 1e6),
            Some(t) if t >= 1e3 => format!("  {:>9.2} Kelem/s", t / 1e3),
            Some(t) => format!("  {t:>9.2} elem/s"),
            None => String::new(),
        };
        format!(
            "{:<48} {:>12} median  {:>12} mean  ±{:>10} mad  ({} iters){}",
            self.name,
            fmt_dur(self.median),
            fmt_dur(self.mean),
            fmt_dur(self.mad),
            self.iters,
            tp
        )
    }
}

pub fn fmt_dur(d: Duration) -> String {
    let ns = d.as_nanos() as f64;
    if ns < 1e3 {
        format!("{ns:.1} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

/// Bench harness: groups cases, prints a criterion-like report.
pub struct Harness {
    group: String,
    measure_time: Duration,
    warmup_time: Duration,
    results: Vec<BenchResult>,
}

impl Harness {
    pub fn new(group: &str) -> Self {
        // Benches accept HAS_BENCH_FAST=1 to run quickly in CI/tests.
        let fast = fast_mode();
        println!("\n=== bench group: {group} ===");
        Harness {
            group: group.to_string(),
            measure_time: if fast {
                Duration::from_millis(200)
            } else {
                Duration::from_secs(1)
            },
            warmup_time: if fast {
                Duration::from_millis(50)
            } else {
                Duration::from_millis(300)
            },
            results: Vec::new(),
        }
    }

    pub fn with_times(mut self, warmup: Duration, measure: Duration) -> Self {
        self.warmup_time = warmup;
        self.measure_time = measure;
        self
    }

    /// Benchmark `f`, which performs ONE logical iteration per call.
    pub fn bench<F: FnMut()>(&mut self, name: &str, f: F) -> &BenchResult {
        self.bench_elems(name, None, f)
    }

    /// Benchmark with a throughput denominator.
    pub fn bench_elems<F: FnMut()>(
        &mut self,
        name: &str,
        elements: Option<u64>,
        mut f: F,
    ) -> &BenchResult {
        // Warm-up and per-call cost estimate.
        let warm_start = Instant::now();
        let mut warm_iters = 0u64;
        while warm_start.elapsed() < self.warmup_time || warm_iters < 3 {
            f();
            warm_iters += 1;
            if warm_iters > 1_000_000 {
                break;
            }
        }
        let per_call = warm_start.elapsed().as_secs_f64() / warm_iters as f64;

        // Choose batch size so each sample is >= ~50µs (amortise timer cost)
        // and aim for ~60 samples in the measurement window.
        let batch = ((5e-5 / per_call).ceil() as u64).max(1);
        let target_samples = 60u64;
        let est_sample = per_call * batch as f64;
        let samples = ((self.measure_time.as_secs_f64() / est_sample) as u64)
            .clamp(5, target_samples);

        let mut times: Vec<f64> = Vec::with_capacity(samples as usize);
        for _ in 0..samples {
            let t0 = Instant::now();
            for _ in 0..batch {
                f();
            }
            times.push(t0.elapsed().as_secs_f64() / batch as f64);
        }
        times.sort_unstable_by(|a, b| a.partial_cmp(b).unwrap());
        let median = times[times.len() / 2];
        let mean = times.iter().sum::<f64>() / times.len() as f64;
        let mut devs: Vec<f64> = times.iter().map(|t| (t - median).abs()).collect();
        devs.sort_unstable_by(|a, b| a.partial_cmp(b).unwrap());
        let mad = devs[devs.len() / 2];

        let result = BenchResult {
            name: format!("{}/{}", self.group, name),
            iters: samples * batch,
            median: Duration::from_secs_f64(median),
            mean: Duration::from_secs_f64(mean),
            mad: Duration::from_secs_f64(mad),
            min: Duration::from_secs_f64(times[0]),
            max: Duration::from_secs_f64(*times.last().unwrap()),
            elements,
        };
        println!("{}", result.report());
        self.results.push(result);
        self.results.last().unwrap()
    }

    pub fn results(&self) -> &[BenchResult] {
        &self.results
    }

    /// The whole group as JSON under `schema` (e.g.
    /// [`BENCH_HOTPATH_SCHEMA`]): `{schema, group, results: [...]}`.
    pub fn to_json(&self, schema: &str) -> Json {
        Json::obj(vec![
            ("schema", Json::Str(schema.to_string())),
            ("group", Json::Str(self.group.clone())),
            (
                "results",
                Json::Arr(self.results.iter().map(|r| r.to_json()).collect()),
            ),
        ])
    }

    /// Export the group through [`crate::util::json::write_file`].
    pub fn write_json(&self, path: &std::path::Path, schema: &str) -> anyhow::Result<()> {
        crate::util::json::write_file(path, &self.to_json(schema))
    }
}

/// Prevent the optimiser from eliding a computed value (stable-rust black_box).
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

/// Render a fixed-width ASCII table — benches print paper-style tables with it.
pub fn ascii_table(headers: &[&str], rows: &[Vec<String>]) -> String {
    let mut widths: Vec<usize> = headers.iter().map(|h| h.len()).collect();
    for row in rows {
        for (i, cell) in row.iter().enumerate() {
            if i < widths.len() {
                widths[i] = widths[i].max(cell.len());
            }
        }
    }
    let mut out = String::new();
    let sep = |out: &mut String| {
        out.push('+');
        for w in &widths {
            out.push_str(&"-".repeat(w + 2));
            out.push('+');
        }
        out.push('\n');
    };
    sep(&mut out);
    out.push('|');
    for (h, w) in headers.iter().zip(&widths) {
        out.push_str(&format!(" {h:<w$} |"));
    }
    out.push('\n');
    sep(&mut out);
    for row in rows {
        out.push('|');
        for (c, w) in row.iter().zip(&widths) {
            out.push_str(&format!(" {c:>w$} |"));
        }
        out.push('\n');
    }
    sep(&mut out);
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_produces_sane_stats() {
        std::env::set_var("HAS_BENCH_FAST", "1");
        let mut h = Harness::new("test").with_times(
            Duration::from_millis(10),
            Duration::from_millis(50),
        );
        let r = h.bench("spin", || {
            black_box((0..100).sum::<u64>());
        });
        assert!(r.median.as_nanos() > 0);
        assert!(r.min <= r.median && r.median <= r.max);
        assert!(r.iters >= 5);
    }

    #[test]
    fn bench_json_export_roundtrips() {
        std::env::set_var("HAS_BENCH_FAST", "1");
        let mut h = Harness::new("jsontest")
            .with_times(Duration::from_millis(5), Duration::from_millis(20));
        h.bench_elems("spin", Some(100), || {
            black_box((0..100).sum::<u64>());
        });
        let j = h.to_json(BENCH_HOTPATH_SCHEMA);
        assert_eq!(j.get("schema").unwrap().as_str().unwrap(), BENCH_HOTPATH_SCHEMA);
        let results = j.get("results").unwrap().as_arr().unwrap();
        assert_eq!(results.len(), 1);
        let r = &results[0];
        assert_eq!(r.get("name").unwrap().as_str().unwrap(), "jsontest/spin");
        assert!(r.get("median_ns").unwrap().as_f64().unwrap() > 0.0);
        assert!(r.get("throughput").unwrap().as_f64().unwrap() > 0.0);
        // Serialised text parses back with the same result count.
        let text = j.to_string_pretty();
        let back = crate::util::json::parse(&text).unwrap();
        assert_eq!(back.get("results").unwrap().as_arr().unwrap().len(), 1);
        // And the file writer lands it on disk.
        let dir = std::env::temp_dir().join("has_gpu_bench_json_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("BENCH_hotpath.json");
        h.write_json(&path, BENCH_HOTPATH_SCHEMA).unwrap();
        let loaded = crate::util::json::parse_file(&path).unwrap();
        assert_eq!(
            loaded.get("schema").unwrap().as_str().unwrap(),
            BENCH_HOTPATH_SCHEMA
        );
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn table_renders() {
        let t = ascii_table(
            &["model", "lat"],
            &[vec!["resnet50".into(), "12.3".into()]],
        );
        assert!(t.contains("resnet50"));
        assert!(t.lines().count() >= 5);
    }

    #[test]
    fn fmt_dur_ranges() {
        assert!(fmt_dur(Duration::from_nanos(500)).contains("ns"));
        assert!(fmt_dur(Duration::from_micros(500)).contains("µs"));
        assert!(fmt_dur(Duration::from_millis(500)).contains("ms"));
        assert!(fmt_dur(Duration::from_secs(5)).contains(" s"));
    }
}
