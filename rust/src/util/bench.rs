//! Mini-criterion: a statistically honest micro/end-to-end bench harness.
//!
//! Criterion is unavailable offline; this reproduces the parts the project
//! needs — warm-up, adaptive iteration counts targeting a fixed measurement
//! time, outlier-robust statistics (median + MAD), and stable text output
//! consumed by `EXPERIMENTS.md` — with `harness = false` bench binaries.

use std::time::{Duration, Instant};

/// Result of one benchmark case.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub iters: u64,
    pub median: Duration,
    pub mean: Duration,
    pub mad: Duration,
    pub min: Duration,
    pub max: Duration,
    /// Optional throughput denominator (elements per iteration).
    pub elements: Option<u64>,
}

impl BenchResult {
    pub fn throughput(&self) -> Option<f64> {
        self.elements
            .map(|e| e as f64 / self.median.as_secs_f64())
    }

    pub fn report(&self) -> String {
        let tp = match self.throughput() {
            Some(t) if t >= 1e6 => format!("  {:>9.2} Melem/s", t / 1e6),
            Some(t) if t >= 1e3 => format!("  {:>9.2} Kelem/s", t / 1e3),
            Some(t) => format!("  {t:>9.2} elem/s"),
            None => String::new(),
        };
        format!(
            "{:<48} {:>12} median  {:>12} mean  ±{:>10} mad  ({} iters){}",
            self.name,
            fmt_dur(self.median),
            fmt_dur(self.mean),
            fmt_dur(self.mad),
            self.iters,
            tp
        )
    }
}

pub fn fmt_dur(d: Duration) -> String {
    let ns = d.as_nanos() as f64;
    if ns < 1e3 {
        format!("{ns:.1} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

/// Bench harness: groups cases, prints a criterion-like report.
pub struct Harness {
    group: String,
    measure_time: Duration,
    warmup_time: Duration,
    results: Vec<BenchResult>,
}

impl Harness {
    pub fn new(group: &str) -> Self {
        // Benches accept HAS_BENCH_FAST=1 to run quickly in CI/tests.
        let fast = std::env::var("HAS_BENCH_FAST").map(|v| v == "1").unwrap_or(false);
        println!("\n=== bench group: {group} ===");
        Harness {
            group: group.to_string(),
            measure_time: if fast {
                Duration::from_millis(200)
            } else {
                Duration::from_secs(1)
            },
            warmup_time: if fast {
                Duration::from_millis(50)
            } else {
                Duration::from_millis(300)
            },
            results: Vec::new(),
        }
    }

    pub fn with_times(mut self, warmup: Duration, measure: Duration) -> Self {
        self.warmup_time = warmup;
        self.measure_time = measure;
        self
    }

    /// Benchmark `f`, which performs ONE logical iteration per call.
    pub fn bench<F: FnMut()>(&mut self, name: &str, f: F) -> &BenchResult {
        self.bench_elems(name, None, f)
    }

    /// Benchmark with a throughput denominator.
    pub fn bench_elems<F: FnMut()>(
        &mut self,
        name: &str,
        elements: Option<u64>,
        mut f: F,
    ) -> &BenchResult {
        // Warm-up and per-call cost estimate.
        let warm_start = Instant::now();
        let mut warm_iters = 0u64;
        while warm_start.elapsed() < self.warmup_time || warm_iters < 3 {
            f();
            warm_iters += 1;
            if warm_iters > 1_000_000 {
                break;
            }
        }
        let per_call = warm_start.elapsed().as_secs_f64() / warm_iters as f64;

        // Choose batch size so each sample is >= ~50µs (amortise timer cost)
        // and aim for ~60 samples in the measurement window.
        let batch = ((5e-5 / per_call).ceil() as u64).max(1);
        let target_samples = 60u64;
        let est_sample = per_call * batch as f64;
        let samples = ((self.measure_time.as_secs_f64() / est_sample) as u64)
            .clamp(5, target_samples);

        let mut times: Vec<f64> = Vec::with_capacity(samples as usize);
        for _ in 0..samples {
            let t0 = Instant::now();
            for _ in 0..batch {
                f();
            }
            times.push(t0.elapsed().as_secs_f64() / batch as f64);
        }
        times.sort_unstable_by(|a, b| a.partial_cmp(b).unwrap());
        let median = times[times.len() / 2];
        let mean = times.iter().sum::<f64>() / times.len() as f64;
        let mut devs: Vec<f64> = times.iter().map(|t| (t - median).abs()).collect();
        devs.sort_unstable_by(|a, b| a.partial_cmp(b).unwrap());
        let mad = devs[devs.len() / 2];

        let result = BenchResult {
            name: format!("{}/{}", self.group, name),
            iters: samples * batch,
            median: Duration::from_secs_f64(median),
            mean: Duration::from_secs_f64(mean),
            mad: Duration::from_secs_f64(mad),
            min: Duration::from_secs_f64(times[0]),
            max: Duration::from_secs_f64(*times.last().unwrap()),
            elements,
        };
        println!("{}", result.report());
        self.results.push(result);
        self.results.last().unwrap()
    }

    pub fn results(&self) -> &[BenchResult] {
        &self.results
    }
}

/// Prevent the optimiser from eliding a computed value (stable-rust black_box).
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

/// Render a fixed-width ASCII table — benches print paper-style tables with it.
pub fn ascii_table(headers: &[&str], rows: &[Vec<String>]) -> String {
    let mut widths: Vec<usize> = headers.iter().map(|h| h.len()).collect();
    for row in rows {
        for (i, cell) in row.iter().enumerate() {
            if i < widths.len() {
                widths[i] = widths[i].max(cell.len());
            }
        }
    }
    let mut out = String::new();
    let sep = |out: &mut String| {
        out.push('+');
        for w in &widths {
            out.push_str(&"-".repeat(w + 2));
            out.push('+');
        }
        out.push('\n');
    };
    sep(&mut out);
    out.push('|');
    for (h, w) in headers.iter().zip(&widths) {
        out.push_str(&format!(" {h:<w$} |"));
    }
    out.push('\n');
    sep(&mut out);
    for row in rows {
        out.push('|');
        for (c, w) in row.iter().zip(&widths) {
            out.push_str(&format!(" {c:>w$} |"));
        }
        out.push('\n');
    }
    sep(&mut out);
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_produces_sane_stats() {
        std::env::set_var("HAS_BENCH_FAST", "1");
        let mut h = Harness::new("test").with_times(
            Duration::from_millis(10),
            Duration::from_millis(50),
        );
        let r = h.bench("spin", || {
            black_box((0..100).sum::<u64>());
        });
        assert!(r.median.as_nanos() > 0);
        assert!(r.min <= r.median && r.median <= r.max);
        assert!(r.iters >= 5);
    }

    #[test]
    fn table_renders() {
        let t = ascii_table(
            &["model", "lat"],
            &[vec!["resnet50".into(), "12.3".into()]],
        );
        assert!(t.contains("resnet50"));
        assert!(t.lines().count() >= 5);
    }

    #[test]
    fn fmt_dur_ranges() {
        assert!(fmt_dur(Duration::from_nanos(500)).contains("ns"));
        assert!(fmt_dur(Duration::from_micros(500)).contains("µs"));
        assert!(fmt_dur(Duration::from_millis(500)).contains("ms"));
        assert!(fmt_dur(Duration::from_secs(5)).contains(" s"));
    }
}
