//! A small fixed-size thread pool with scoped parallel-for.
//!
//! The serving plane runs one OS thread per pod executor plus the gateway and
//! autoscaler loops; benches use [`ThreadPool::scope_for`] to parallelise
//! parameter sweeps. No async runtime is available offline, so this is plain
//! std::thread + channels — which is also the right tool: the hot path is
//! compute-bound PJRT execution, not I/O.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

type Job = Box<dyn FnOnce() + Send + 'static>;

/// Fixed-size worker pool.
pub struct ThreadPool {
    tx: Option<Sender<Job>>,
    workers: Vec<JoinHandle<()>>,
    queued: Arc<AtomicUsize>,
}

impl ThreadPool {
    /// Spawn `n` workers (clamped to at least 1).
    pub fn new(n: usize) -> Self {
        let n = n.max(1);
        let (tx, rx) = channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let queued = Arc::new(AtomicUsize::new(0));
        let workers = (0..n)
            .map(|i| {
                let rx = Arc::clone(&rx);
                let queued = Arc::clone(&queued);
                std::thread::Builder::new()
                    .name(format!("has-pool-{i}"))
                    .spawn(move || loop {
                        let job = {
                            let guard = rx.lock().unwrap();
                            guard.recv()
                        };
                        match job {
                            Ok(job) => {
                                job();
                                queued.fetch_sub(1, Ordering::Release);
                            }
                            Err(_) => break, // sender dropped: shut down
                        }
                    })
                    .expect("spawn pool worker")
            })
            .collect();
        ThreadPool {
            tx: Some(tx),
            workers,
            queued,
        }
    }

    /// Pool sized to available parallelism.
    pub fn default_size() -> Self {
        let n = std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(4);
        Self::new(n)
    }

    /// Submit a fire-and-forget job.
    pub fn execute<F: FnOnce() + Send + 'static>(&self, f: F) {
        self.queued.fetch_add(1, Ordering::Acquire);
        self.tx
            .as_ref()
            .expect("pool shut down")
            .send(Box::new(f))
            .expect("pool workers gone");
    }

    /// Number of jobs submitted but not yet finished.
    pub fn pending(&self) -> usize {
        self.queued.load(Ordering::Acquire)
    }

    /// Block until every submitted job has finished.
    pub fn wait_idle(&self) {
        while self.pending() > 0 {
            std::thread::yield_now();
        }
    }

    /// Parallel map over `items`, preserving order. Each worker invocation is
    /// independent; results are collected into a Vec.
    pub fn map<T, R, F>(&self, items: Vec<T>, f: F) -> Vec<R>
    where
        T: Send + 'static,
        R: Send + 'static,
        F: Fn(T) -> R + Send + Sync + 'static,
    {
        let n = items.len();
        let f = Arc::new(f);
        let (tx, rx) = channel::<(usize, R)>();
        for (i, item) in items.into_iter().enumerate() {
            let tx = tx.clone();
            let f = Arc::clone(&f);
            self.execute(move || {
                let r = f(item);
                let _ = tx.send((i, r));
            });
        }
        drop(tx);
        let mut out: Vec<Option<R>> = (0..n).map(|_| None).collect();
        for (i, r) in rx {
            out[i] = Some(r);
        }
        out.into_iter().map(|r| r.expect("worker panicked")).collect()
    }

    /// Scoped parallel-for over an index range using std::thread::scope —
    /// allows borrowing from the caller's stack (benches sweep shared
    /// read-only state without Arc plumbing).
    pub fn scope_for<F>(threads: usize, n: usize, f: F)
    where
        F: Fn(usize) + Send + Sync,
    {
        let threads = threads.max(1).min(n.max(1));
        let next = AtomicUsize::new(0);
        std::thread::scope(|s| {
            for _ in 0..threads {
                s.spawn(|| loop {
                    let i = next.fetch_add(1, Ordering::Relaxed);
                    if i >= n {
                        break;
                    }
                    f(i);
                });
            }
        });
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        drop(self.tx.take()); // close channel; workers exit on recv error
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn runs_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicU64::new(0));
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            pool.execute(move || {
                c.fetch_add(1, Ordering::Relaxed);
            });
        }
        pool.wait_idle();
        assert_eq!(counter.load(Ordering::Relaxed), 100);
    }

    #[test]
    fn map_preserves_order() {
        let pool = ThreadPool::new(3);
        let out = pool.map((0..50).collect::<Vec<_>>(), |x| x * 2);
        assert_eq!(out, (0..50).map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn scope_for_covers_range() {
        let hits: Vec<AtomicU64> = (0..64).map(|_| AtomicU64::new(0)).collect();
        ThreadPool::scope_for(8, 64, |i| {
            hits[i].fetch_add(1, Ordering::Relaxed);
        });
        for h in &hits {
            assert_eq!(h.load(Ordering::Relaxed), 1);
        }
    }

    #[test]
    fn drop_joins_cleanly() {
        let pool = ThreadPool::new(2);
        pool.execute(|| std::thread::sleep(std::time::Duration::from_millis(10)));
        drop(pool); // must not hang or panic
    }
}
