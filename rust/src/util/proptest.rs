//! Property-based testing loop (proptest is unavailable offline).
//!
//! [`run_prop`] drives a property over many seeded random cases and, on
//! failure, reports the failing seed so the case can be replayed exactly.
//! Shrinking is by seed replay with reduced size hints rather than structural
//! shrinking — adequate for the invariants tested here (allocator alignment,
//! scheduler conservation, Kalman stability).

use crate::util::prng::Pcg64;

/// Configuration for a property run.
#[derive(Clone, Copy, Debug)]
pub struct PropConfig {
    pub cases: u32,
    pub seed: u64,
    /// Maximum "size" hint passed to the generator (e.g. sequence length).
    pub max_size: usize,
}

impl Default for PropConfig {
    fn default() -> Self {
        PropConfig {
            cases: 256,
            seed: 0xC0FFEE,
            max_size: 64,
        }
    }
}

/// Run `prop(rng, size)` for `config.cases` random cases. The property returns
/// `Err(msg)` to signal a violation. Panics with seed + size on first failure
/// (after trying smaller sizes with the same seed for a more minimal report).
pub fn run_prop<F>(name: &str, config: PropConfig, mut prop: F)
where
    F: FnMut(&mut Pcg64, usize) -> Result<(), String>,
{
    for case in 0..config.cases {
        let case_seed = config.seed ^ (case as u64).wrapping_mul(0x9E3779B97F4A7C15);
        // Grow size with case index so early failures are small.
        let size = 1 + (case as usize * config.max_size) / config.cases.max(1) as usize;
        let mut rng = Pcg64::new(case_seed, 17);
        if let Err(msg) = prop(&mut rng, size) {
            // Attempt to reproduce at smaller sizes for a tighter report.
            let mut min_size = size;
            let mut min_msg = msg;
            for s in 1..size {
                let mut rng = Pcg64::new(case_seed, 17);
                if let Err(m) = prop(&mut rng, s) {
                    min_size = s;
                    min_msg = m;
                    break;
                }
            }
            panic!(
                "property '{name}' failed (replay: seed={case_seed:#x}, size={min_size}): {min_msg}"
            );
        }
    }
}

/// Assert-style helper for use inside properties.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)+) => {
        if !$cond {
            return Err(format!($($fmt)+));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        run_prop("trivial", PropConfig::default(), |rng, size| {
            count += 1;
            let v = rng.next_below(size as u64 + 1);
            if v <= size as u64 {
                Ok(())
            } else {
                Err("impossible".into())
            }
        });
        assert_eq!(count, PropConfig::default().cases);
    }

    #[test]
    #[should_panic(expected = "property 'must_fail' failed")]
    fn failing_property_panics_with_seed() {
        run_prop(
            "must_fail",
            PropConfig {
                cases: 10,
                ..Default::default()
            },
            |_rng, size| {
                if size >= 3 {
                    Err(format!("size {size} too big"))
                } else {
                    Ok(())
                }
            },
        );
    }
}
