//! Minimal JSON: a recursive-descent parser and a writer.
//!
//! Used for all build-time interchange with the Python layer (artifact
//! metadata, RaPP weights, operator graphs, datasets) and for exporting
//! experiment results. Not a general-purpose library: numbers are f64,
//! object key order is preserved for deterministic output.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value. Numbers are f64 (the Python side never emits ints that
/// exceed 2^53 — asserted in the exporters).
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

#[derive(Debug, thiserror::Error)]
pub enum JsonError {
    #[error("json parse error at byte {0}: {1}")]
    Parse(usize, String),
    #[error("json type error: expected {0}")]
    Type(&'static str),
    #[error("json missing key: {0}")]
    MissingKey(String),
}

impl Json {
    // ---- accessors -------------------------------------------------------

    pub fn as_f64(&self) -> Result<f64, JsonError> {
        match self {
            Json::Num(n) => Ok(*n),
            _ => Err(JsonError::Type("number")),
        }
    }

    pub fn as_usize(&self) -> Result<usize, JsonError> {
        let f = self.as_f64()?;
        if f < 0.0 || f.fract() != 0.0 {
            return Err(JsonError::Type("non-negative integer"));
        }
        Ok(f as usize)
    }

    pub fn as_str(&self) -> Result<&str, JsonError> {
        match self {
            Json::Str(s) => Ok(s),
            _ => Err(JsonError::Type("string")),
        }
    }

    pub fn as_bool(&self) -> Result<bool, JsonError> {
        match self {
            Json::Bool(b) => Ok(*b),
            _ => Err(JsonError::Type("bool")),
        }
    }

    pub fn as_arr(&self) -> Result<&[Json], JsonError> {
        match self {
            Json::Arr(a) => Ok(a),
            _ => Err(JsonError::Type("array")),
        }
    }

    pub fn as_obj(&self) -> Result<&[(String, Json)], JsonError> {
        match self {
            Json::Obj(o) => Ok(o),
            _ => Err(JsonError::Type("object")),
        }
    }

    /// Object field lookup.
    pub fn get(&self, key: &str) -> Result<&Json, JsonError> {
        match self {
            Json::Obj(o) => o
                .iter()
                .find(|(k, _)| k == key)
                .map(|(_, v)| v)
                .ok_or_else(|| JsonError::MissingKey(key.to_string())),
            _ => Err(JsonError::Type("object")),
        }
    }

    /// Optional field lookup.
    pub fn opt(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(o) => o.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    /// f64 vector from a numeric array.
    pub fn as_f64_vec(&self) -> Result<Vec<f64>, JsonError> {
        self.as_arr()?.iter().map(|v| v.as_f64()).collect()
    }

    /// f32 vector from a numeric array (weight matrices).
    pub fn as_f32_vec(&self) -> Result<Vec<f32>, JsonError> {
        Ok(self.as_f64_vec()?.into_iter().map(|v| v as f32).collect())
    }

    // ---- construction helpers -------------------------------------------

    pub fn obj(fields: Vec<(&str, Json)>) -> Json {
        Json::Obj(fields.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn num_arr(xs: &[f64]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x)).collect())
    }

    pub fn str_arr<S: AsRef<str>>(xs: &[S]) -> Json {
        Json::Arr(xs.iter().map(|s| Json::Str(s.as_ref().to_string())).collect())
    }

    // ---- serialisation ---------------------------------------------------

    #[allow(clippy::inherent_to_string)] // deliberate: no Display detour for a serialiser
    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, None, 0);
        out
    }

    pub fn to_string_pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, Some(2), 0);
        out.push('\n');
        out
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => write_num(out, *n),
            Json::Str(s) => write_str(out, s),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    v.write(out, indent, depth + 1);
                }
                if !a.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push(']');
            }
            Json::Obj(o) => {
                out.push('{');
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    write_str(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                if !o.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push('}');
            }
        }
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..w * depth {
            out.push(' ');
        }
    }
}

fn write_num(out: &mut String, n: f64) {
    if n.is_nan() || n.is_infinite() {
        // JSON has no NaN/Inf; emit null like Python's json with allow_nan=False off.
        out.push_str("null");
    } else if n == n.trunc() && n.abs() < 1e15 {
        let _ = write!(out, "{}", n as i64);
    } else {
        let _ = write!(out, "{n}");
    }
}

fn write_str(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

// ---- parsing ---------------------------------------------------------------

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

/// Parse a JSON document. Trailing whitespace is allowed, trailing garbage is not.
pub fn parse(input: &str) -> Result<Json, JsonError> {
    let mut p = Parser {
        bytes: input.as_bytes(),
        pos: 0,
    };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        return Err(JsonError::Parse(p.pos, "trailing data".into()));
    }
    Ok(v)
}

/// Parse the JSON file at `path`.
pub fn parse_file(path: &std::path::Path) -> anyhow::Result<Json> {
    let text = std::fs::read_to_string(path)
        .map_err(|e| anyhow::anyhow!("reading {}: {e}", path.display()))?;
    parse(&text).map_err(|e| anyhow::anyhow!("parsing {}: {e}", path.display()))
}

/// Serialise `v` pretty-printed to `path` (created or truncated). The pretty
/// form is deterministic — object key order is preserved — so repeated runs
/// with identical inputs produce byte-identical files.
pub fn write_file(path: &std::path::Path, v: &Json) -> anyhow::Result<()> {
    write_file_fingerprinted(path, v).map(|_| ())
}

/// Like [`write_file`], but also return the [`fnv1a64`] fingerprint of
/// exactly the bytes written — one serialisation feeds both the file and
/// the hash, so the two can never disagree (`has-gpu expt` prints this).
pub fn write_file_fingerprinted(path: &std::path::Path, v: &Json) -> anyhow::Result<u64> {
    let text = v.to_string_pretty();
    std::fs::write(path, &text)
        .map_err(|e| anyhow::anyhow!("writing {}: {e}", path.display()))?;
    Ok(fnv1a64(text.as_bytes()))
}

/// FNV-1a 64-bit hash.
pub fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// Stable fingerprint of a JSON value: FNV-1a over its canonical pretty
/// form (the exact bytes [`write_file`] emits). Because the writer is
/// order-preserving and deterministic, equal fingerprints ⇔ byte-identical
/// exports — `has-gpu expt` prints this so CI and operators can assert grid
/// stability (e.g. `--jobs` independence, stock-cell invariance under
/// ablation extension) without shipping fixture bytes.
pub fn fingerprint(v: &Json) -> u64 {
    fnv1a64(v.to_string_pretty().as_bytes())
}

impl Parser<'_> {
    fn skip_ws(&mut self) {
        while self.pos < self.bytes.len() {
            match self.bytes[self.pos] {
                b' ' | b'\t' | b'\n' | b'\r' => self.pos += 1,
                _ => break,
            }
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn err(&self, msg: &str) -> JsonError {
        JsonError::Parse(self.pos, msg.to_string())
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek().ok_or_else(|| self.err("unexpected eof"))? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            b'-' | b'0'..=b'9' => self.number(),
            c => Err(self.err(&format!("unexpected byte '{}'", c as char))),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{word}'")))
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut fields = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(fields));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            fields.push((key, val));
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(fields));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            let c = self.peek().ok_or_else(|| self.err("eof in string"))?;
            self.pos += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek().ok_or_else(|| self.err("eof in escape"))?;
                    self.pos += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'n' => s.push('\n'),
                        b't' => s.push('\t'),
                        b'r' => s.push('\r'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'u' => {
                            let cp = self.hex4()?;
                            // Surrogate pairs.
                            if (0xD800..0xDC00).contains(&cp) {
                                if self.peek() == Some(b'\\') {
                                    self.pos += 1;
                                    self.expect(b'u')?;
                                    let lo = self.hex4()?;
                                    let c = 0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00);
                                    s.push(
                                        char::from_u32(c)
                                            .ok_or_else(|| self.err("bad surrogate"))?,
                                    );
                                } else {
                                    return Err(self.err("lone surrogate"));
                                }
                            } else {
                                s.push(
                                    char::from_u32(cp).ok_or_else(|| self.err("bad codepoint"))?,
                                );
                            }
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                }
                c if c < 0x80 => s.push(c as char),
                _ => {
                    // Multi-byte UTF-8: copy raw bytes.
                    let start = self.pos - 1;
                    let len = utf8_len(c);
                    self.pos = start + len;
                    if self.pos > self.bytes.len() {
                        return Err(self.err("truncated utf8"));
                    }
                    s.push_str(
                        std::str::from_utf8(&self.bytes[start..self.pos])
                            .map_err(|_| self.err("bad utf8"))?,
                    );
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, JsonError> {
        if self.pos + 4 > self.bytes.len() {
            return Err(self.err("eof in \\u escape"));
        }
        let s = std::str::from_utf8(&self.bytes[self.pos..self.pos + 4])
            .map_err(|_| self.err("bad hex"))?;
        let v = u32::from_str_radix(s, 16).map_err(|_| self.err("bad hex"))?;
        self.pos += 4;
        Ok(v)
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(b'0'..=b'9')) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e') | Some(b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+') | Some(b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.pos += 1;
            }
        }
        let s = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        0xF0..=0xF7 => 4,
        _ => 1,
    }
}

/// Convenience: parse into a string-keyed map (loses order; for lookups only).
pub fn to_map(v: &Json) -> Result<BTreeMap<String, Json>, JsonError> {
    Ok(v.as_obj()?
        .iter()
        .map(|(k, v)| (k.clone(), v.clone()))
        .collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_scalars() {
        for src in ["null", "true", "false", "0", "-1.5", "3e2", "\"hi\""] {
            let v = parse(src).unwrap();
            let back = parse(&v.to_string()).unwrap();
            assert_eq!(v, back, "{src}");
        }
    }

    #[test]
    fn roundtrip_nested() {
        let src = r#"{"a": [1, 2.5, {"b": "x\n\"y\""}], "c": null, "d": [], "e": {}}"#;
        let v = parse(src).unwrap();
        let back = parse(&v.to_string()).unwrap();
        assert_eq!(v, back);
        let pretty = parse(&v.to_string_pretty()).unwrap();
        assert_eq!(v, pretty);
    }

    #[test]
    fn unicode_escapes() {
        let v = parse(r#""é😀""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "é😀");
        let raw = parse("\"é😀\"").unwrap();
        assert_eq!(raw.as_str().unwrap(), "é😀");
    }

    #[test]
    fn accessors_and_errors() {
        let v = parse(r#"{"x": 3, "s": "a", "arr": [1,2]}"#).unwrap();
        assert_eq!(v.get("x").unwrap().as_f64().unwrap(), 3.0);
        assert_eq!(v.get("x").unwrap().as_usize().unwrap(), 3);
        assert_eq!(v.get("arr").unwrap().as_f64_vec().unwrap(), vec![1.0, 2.0]);
        assert!(v.get("nope").is_err());
        assert!(v.get("s").unwrap().as_f64().is_err());
        assert!(parse("[1,2,]").is_err());
        assert!(parse("{\"a\":1} x").is_err());
        assert!(parse("").is_err());
    }

    #[test]
    fn fnv1a64_matches_reference_vectors() {
        // Published FNV-1a 64-bit test vectors.
        assert_eq!(fnv1a64(b""), 0xcbf2_9ce4_8422_2325);
        assert_eq!(fnv1a64(b"a"), 0xaf63_dc4c_8601_ec8c);
        assert_eq!(fnv1a64(b"foobar"), 0x85944171f73967e8);
        // Fingerprint equality tracks byte equality of the pretty form.
        let a = Json::obj(vec![("x", Json::Num(1.0)), ("y", Json::Str("z".into()))]);
        let b = Json::obj(vec![("x", Json::Num(1.0)), ("y", Json::Str("z".into()))]);
        let c = Json::obj(vec![("y", Json::Str("z".into())), ("x", Json::Num(1.0))]);
        assert_eq!(fingerprint(&a), fingerprint(&b));
        assert_ne!(fingerprint(&a), fingerprint(&c), "key order is significant");
    }

    #[test]
    fn write_file_then_parse_file_roundtrip() {
        let path = std::env::temp_dir().join(format!("hasgpu-json-{}.json", std::process::id()));
        let v = parse(r#"{"a": [1, 2.5], "b": "x"}"#).unwrap();
        write_file(&path, &v).unwrap();
        let back = parse_file(&path).unwrap();
        assert_eq!(v, back);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn num_formatting() {
        assert_eq!(Json::Num(3.0).to_string(), "3");
        assert_eq!(Json::Num(3.5).to_string(), "3.5");
        assert_eq!(Json::Num(f64::NAN).to_string(), "null");
    }

    #[test]
    fn deep_nesting() {
        let mut src = String::new();
        for _ in 0..64 {
            src.push('[');
        }
        src.push('1');
        for _ in 0..64 {
            src.push(']');
        }
        assert!(parse(&src).is_ok());
    }

    #[test]
    fn builder_helpers() {
        let j = Json::obj(vec![
            ("name", Json::Str("resnet50".into())),
            ("lat", Json::num_arr(&[1.0, 2.0])),
            ("tags", Json::str_arr(&["a", "b"])),
        ]);
        assert_eq!(j.get("name").unwrap().as_str().unwrap(), "resnet50");
        assert_eq!(j.get("tags").unwrap().as_arr().unwrap().len(), 2);
    }
}
