//! Tiny declarative CLI argument parser (clap is unavailable offline).
//!
//! Supports `--key value`, `--key=value`, boolean `--flag`, positional
//! arguments, and auto-generated `--help` text. Used by `main.rs` and every
//! example binary.

use std::collections::BTreeMap;

/// Parsed arguments.
#[derive(Debug, Default)]
pub struct Args {
    opts: BTreeMap<String, String>,
    flags: Vec<String>,
    positional: Vec<String>,
}

/// Option specification for help text + validation. Help text is owned so
/// subcommands can surface runtime inventories (e.g. the platform registry)
/// in `--help`.
pub struct OptSpec {
    pub name: &'static str,
    pub help: String,
    pub default: Option<&'static str>,
    pub is_flag: bool,
}

/// Declarative CLI definition.
pub struct Cli {
    bin: &'static str,
    about: &'static str,
    opts: Vec<OptSpec>,
}

impl Cli {
    pub fn new(bin: &'static str, about: &'static str) -> Self {
        Cli {
            bin,
            about,
            opts: Vec::new(),
        }
    }

    pub fn opt(self, name: &'static str, default: &'static str, help: &'static str) -> Self {
        self.opt_dyn(name, default, help)
    }

    /// Like [`Cli::opt`] but with a runtime-built help string — used when
    /// the help text enumerates a dynamic inventory (the platform registry,
    /// the preset list) rather than a literal.
    pub fn opt_dyn(
        mut self,
        name: &'static str,
        default: &'static str,
        help: impl Into<String>,
    ) -> Self {
        self.opts.push(OptSpec {
            name,
            help: help.into(),
            default: Some(default),
            is_flag: false,
        });
        self
    }

    pub fn req(mut self, name: &'static str, help: &'static str) -> Self {
        self.opts.push(OptSpec {
            name,
            help: help.to_string(),
            default: None,
            is_flag: false,
        });
        self
    }

    pub fn flag(mut self, name: &'static str, help: &'static str) -> Self {
        self.opts.push(OptSpec {
            name,
            help: help.to_string(),
            default: None,
            is_flag: true,
        });
        self
    }

    pub fn usage(&self) -> String {
        let mut s = format!("{} — {}\n\nOPTIONS:\n", self.bin, self.about);
        for o in &self.opts {
            let kind = if o.is_flag { "" } else { " <value>" };
            let def = match o.default {
                Some(d) => format!(" [default: {d}]"),
                None if !o.is_flag => " [required]".to_string(),
                None => String::new(),
            };
            s.push_str(&format!("  --{}{kind:<10}  {}{def}\n", o.name, o.help));
        }
        s.push_str("  --help        show this message\n");
        s
    }

    /// Parse `std::env::args()`. Prints usage and exits on `--help` or error.
    pub fn parse(self) -> Args {
        self.parse_from_or_exit(std::env::args().skip(1).collect())
    }

    /// Parse an explicit argv (subcommand style: the caller has already
    /// stripped the binary name and the subcommand token). Prints usage and
    /// exits on `--help` or error.
    pub fn parse_from_or_exit(self, argv: Vec<String>) -> Args {
        self.parse_from(argv).unwrap_or_else(|e| {
            eprintln!("error: {e}\n\n{}", self.usage());
            std::process::exit(2);
        })
    }

    /// Parse an explicit vector (testable).
    pub fn parse_from(&self, argv: Vec<String>) -> Result<Args, String> {
        let mut args = Args::default();
        // Seed defaults.
        for o in &self.opts {
            if let Some(d) = o.default {
                args.opts.insert(o.name.to_string(), d.to_string());
            }
        }
        let mut it = argv.into_iter().peekable();
        while let Some(tok) = it.next() {
            if tok == "--help" || tok == "-h" {
                println!("{}", self.usage());
                std::process::exit(0);
            }
            if let Some(body) = tok.strip_prefix("--") {
                let (name, inline_val) = match body.split_once('=') {
                    Some((n, v)) => (n.to_string(), Some(v.to_string())),
                    None => (body.to_string(), None),
                };
                let spec = self
                    .opts
                    .iter()
                    .find(|o| o.name == name)
                    .ok_or_else(|| format!("unknown option --{name}"))?;
                if spec.is_flag {
                    if inline_val.is_some() {
                        return Err(format!("--{name} is a flag, takes no value"));
                    }
                    args.flags.push(name);
                } else {
                    let val = match inline_val {
                        Some(v) => v,
                        None => it
                            .next()
                            .ok_or_else(|| format!("--{name} needs a value"))?,
                    };
                    args.opts.insert(name, val);
                }
            } else {
                args.positional.push(tok);
            }
        }
        // Check required.
        for o in &self.opts {
            if !o.is_flag && o.default.is_none() && !args.opts.contains_key(o.name) {
                return Err(format!("missing required option --{}", o.name));
            }
        }
        Ok(args)
    }
}

impl Args {
    pub fn get(&self, name: &str) -> &str {
        self.opts
            .get(name)
            .unwrap_or_else(|| panic!("option --{name} not declared"))
    }

    pub fn get_f64(&self, name: &str) -> f64 {
        self.get(name)
            .parse()
            .unwrap_or_else(|_| panic!("--{name} must be a number"))
    }

    pub fn get_usize(&self, name: &str) -> usize {
        self.get(name)
            .parse()
            .unwrap_or_else(|_| panic!("--{name} must be an integer"))
    }

    pub fn get_u64(&self, name: &str) -> u64 {
        self.get(name)
            .parse()
            .unwrap_or_else(|_| panic!("--{name} must be an integer"))
    }

    /// Comma-separated list value (`--x a,b,c`); empty segments are dropped
    /// and surrounding whitespace is trimmed.
    pub fn get_list(&self, name: &str) -> Vec<String> {
        self.get(name).split(',').map(|s| s.trim().to_string()).filter(|s| !s.is_empty()).collect()
    }

    pub fn has_flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn positional(&self) -> &[String] {
        &self.positional
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cli() -> Cli {
        Cli::new("t", "test")
            .opt("seed", "42", "rng seed")
            .req("model", "model name")
            .flag("verbose", "chatty")
    }

    #[test]
    fn parses_forms() {
        let a = cli()
            .parse_from(vec![
                "--model".into(),
                "resnet50".into(),
                "--seed=7".into(),
                "--verbose".into(),
                "pos1".into(),
            ])
            .unwrap();
        assert_eq!(a.get("model"), "resnet50");
        assert_eq!(a.get_u64("seed"), 7);
        assert!(a.has_flag("verbose"));
        assert_eq!(a.positional(), &["pos1".to_string()]);
    }

    #[test]
    fn list_values_split_on_commas() {
        let a = Cli::new("t", "test")
            .opt("names", "a,b", "comma list")
            .parse_from(vec!["--names".into(), " x, y ,,z".into()])
            .unwrap();
        assert_eq!(a.get_list("names"), vec!["x", "y", "z"]);
        let d = Cli::new("t", "test")
            .opt("names", "a,b", "comma list")
            .parse_from(vec![])
            .unwrap();
        assert_eq!(d.get_list("names"), vec!["a", "b"]);
    }

    #[test]
    fn dynamic_help_text_lands_in_usage() {
        let inventory = ["alpha", "beta", "gamma"].join(", ");
        let c = Cli::new("t", "test").opt_dyn("which", "alpha", format!("one of: {inventory}"));
        let u = c.usage();
        assert!(u.contains("one of: alpha, beta, gamma"), "{u}");
        let a = c.parse_from(vec!["--which".into(), "beta".into()]).unwrap();
        assert_eq!(a.get("which"), "beta");
    }

    #[test]
    fn defaults_apply() {
        let a = cli()
            .parse_from(vec!["--model".into(), "x".into()])
            .unwrap();
        assert_eq!(a.get("seed"), "42");
        assert!(!a.has_flag("verbose"));
    }

    #[test]
    fn missing_required_rejected() {
        assert!(cli().parse_from(vec![]).is_err());
    }

    #[test]
    fn unknown_option_rejected() {
        assert!(cli()
            .parse_from(vec!["--model".into(), "x".into(), "--nope".into()])
            .is_err());
    }

    #[test]
    fn flag_with_value_rejected() {
        assert!(cli()
            .parse_from(vec!["--model".into(), "x".into(), "--verbose=1".into()])
            .is_err());
    }
}
