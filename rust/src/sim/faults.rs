//! Deterministic fault injection: the chaos engine behind the robustness
//! axis of the scenario matrix.
//!
//! A [`FaultSpec`] describes *what* can go wrong (per-GPU MTBF/MTTR
//! failure+repair processes, per-action transient reconfiguration failures,
//! optional pod-crash events); [`FaultPlan::compile`] turns it into a
//! concrete, time-sorted event schedule drawn from dedicated RNG streams.
//!
//! **Determinism contract.** All schedule draws come from
//! `Pcg64::new(seed, STREAM_SCHEDULE)` in a fixed order (GPU index-major,
//! alternating failure-gap / repair-duration, then pod-crash gaps); all
//! *online* draws (transient reconfiguration coin flips, pod-crash victim
//! selection) come from `Pcg64::new(seed, STREAM_ONLINE)` and are consumed
//! only while a fault spec is active. The arrival stream (77) and cold-start
//! jitter stream (3) are untouched, so a run with [`FaultSpec::default`]
//! (inactive) schedules **zero** fault events, draws **zero** fault random
//! numbers, and is byte-identical to a pre-fault build — and an active spec
//! still yields the same schedule on every run and every `--jobs` value,
//! because the plan is a pure function of `(spec, seed, n_gpus, horizon)`.

use crate::util::prng::Pcg64;

/// RNG stream for compiling the failure/repair/crash schedule.
const STREAM_SCHEDULE: u64 = 91;
/// RNG stream for online draws (transient coin flips, crash victims).
const STREAM_ONLINE: u64 = 92;

/// What can go wrong during a run. The default is fully inactive: no
/// schedules, no coin flips, no RNG draws — the byte-identity baseline.
#[derive(Clone, Debug, PartialEq)]
pub struct FaultSpec {
    /// Mean time between failures per GPU (seconds of sim time). `None`
    /// disables GPU failures entirely.
    pub gpu_mtbf: Option<f64>,
    /// Mean time to repair a failed GPU (seconds). Only read when
    /// `gpu_mtbf` is set.
    pub gpu_mttr: f64,
    /// Probability that one `Reconfigurator` action attempt fails
    /// transiently (retryable). `0.0` disables the coin flip — no RNG draw
    /// happens at all.
    pub reconfig_fail_p: f64,
    /// Retry budget per action after the first attempt.
    pub reconfig_retries: u32,
    /// Base backoff (seconds of sim time) added per retry; attempt `k`
    /// waits `backoff × k`, so an action that succeeds on attempt `k`
    /// accrues `backoff × k(k−1)/2` of extra readiness delay.
    pub reconfig_backoff: f64,
    /// Mean time between individual pod crashes (whole-fleet process);
    /// `None` disables pod crashes.
    pub pod_crash_mtbf: Option<f64>,
    /// Scripted GPU failures `(time, gpu_index)` merged into the schedule —
    /// for deterministic unit tests and targeted what-if runs.
    pub scripted_failures: Vec<(f64, usize)>,
    /// Scripted GPU repairs `(time, gpu_index)`.
    pub scripted_repairs: Vec<(f64, usize)>,
}

impl Default for FaultSpec {
    fn default() -> Self {
        FaultSpec {
            gpu_mtbf: None,
            gpu_mttr: 15.0,
            reconfig_fail_p: 0.0,
            reconfig_retries: 3,
            reconfig_backoff: 0.25,
            pod_crash_mtbf: None,
            scripted_failures: Vec::new(),
            scripted_repairs: Vec::new(),
        }
    }
}

impl FaultSpec {
    /// Whether this spec can produce any fault at all. Inactive specs
    /// compile to an empty plan and consume zero RNG draws.
    pub fn is_active(&self) -> bool {
        self.gpu_mtbf.is_some()
            || self.reconfig_fail_p > 0.0
            || self.pod_crash_mtbf.is_some()
            || !self.scripted_failures.is_empty()
            || !self.scripted_repairs.is_empty()
    }
}

/// One scheduled fault event.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultKind {
    /// The GPU at this index dies: resident pods are evicted, their
    /// accounts closed at the failure instant, in-flight batches fail.
    GpuFails(usize),
    /// The GPU at this index comes back and rejoins placement.
    GpuRepairs(usize),
    /// One pod (chosen deterministically at event time among residents)
    /// crashes; its GPU stays up.
    PodCrash,
}

/// The compiled, time-sorted fault schedule plus the online RNG for
/// transient coin flips and crash-victim selection.
#[derive(Clone, Debug)]
pub struct FaultPlan {
    events: Vec<(f64, FaultKind)>,
    spec: FaultSpec,
    online: Pcg64,
    /// Transient reconfiguration failures drawn so far (monotone counter;
    /// the sim copies it into the report at end of run).
    transients: u64,
}

impl FaultPlan {
    /// Compile `spec` into a concrete schedule over `[0, horizon)`.
    ///
    /// Draw order (the determinism contract): for each GPU in index order,
    /// alternate failure-gap `Exp(1/mtbf)` and repair-duration
    /// `Exp(1/mttr)` until past the horizon; then pod-crash gaps
    /// `Exp(1/crash_mtbf)`. Scripted events are merged afterwards and the
    /// whole schedule is stably sorted by time, so equal-time events keep
    /// their draw order.
    pub fn compile(spec: &FaultSpec, seed: u64, n_gpus: usize, horizon: f64) -> Self {
        let mut events = Vec::new();
        if spec.is_active() {
            let mut rng = Pcg64::new(seed, STREAM_SCHEDULE);
            if let Some(mtbf) = spec.gpu_mtbf {
                for gpu in 0..n_gpus {
                    let mut t = 0.0;
                    loop {
                        t += rng.exponential(1.0 / mtbf);
                        if t >= horizon {
                            break;
                        }
                        events.push((t, FaultKind::GpuFails(gpu)));
                        t += rng.exponential(1.0 / spec.gpu_mttr);
                        if t >= horizon {
                            // Stays down to end of run; the sim closes the
                            // downtime interval at the End event.
                            break;
                        }
                        events.push((t, FaultKind::GpuRepairs(gpu)));
                    }
                }
            }
            if let Some(crash_mtbf) = spec.pod_crash_mtbf {
                let mut t = 0.0;
                loop {
                    t += rng.exponential(1.0 / crash_mtbf);
                    if t >= horizon {
                        break;
                    }
                    events.push((t, FaultKind::PodCrash));
                }
            }
            for &(t, gpu) in &spec.scripted_failures {
                events.push((t, FaultKind::GpuFails(gpu)));
            }
            for &(t, gpu) in &spec.scripted_repairs {
                events.push((t, FaultKind::GpuRepairs(gpu)));
            }
            // Stable: equal-time events keep draw/merge order.
            events.sort_by(|a, b| a.0.total_cmp(&b.0));
        }
        FaultPlan {
            events,
            spec: spec.clone(),
            online: Pcg64::new(seed, STREAM_ONLINE),
            transients: 0,
        }
    }

    /// The compiled schedule, time-sorted. Empty for inactive specs.
    pub fn events(&self) -> &[(f64, FaultKind)] {
        &self.events
    }

    /// The spec this plan was compiled from.
    pub fn spec(&self) -> &FaultSpec {
        &self.spec
    }

    /// Flip the transient-reconfiguration coin. **No RNG is consumed when
    /// the probability is zero** — the inactive path stays draw-free.
    pub fn draw_transient(&mut self) -> bool {
        if self.spec.reconfig_fail_p <= 0.0 {
            return false;
        }
        let fail = self.online.next_f64() < self.spec.reconfig_fail_p;
        if fail {
            self.transients += 1;
        }
        fail
    }

    /// Transient failures drawn so far.
    pub fn transients(&self) -> u64 {
        self.transients
    }

    /// Pick a crash victim index among `n` candidates (deterministic given
    /// the online stream position).
    pub fn pick_victim(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        self.online.next_below(n as u64) as usize
    }
}

/// Canonical name of the inactive fault configuration.
pub const NO_FAULTS: &str = "no-faults";

struct FaultPresetEntry {
    name: &'static str,
    about: &'static str,
    build: fn() -> FaultSpec,
}

/// The fault-preset table: the CLI `--faults` axis, `faults` inventory
/// subcommand, and expt registry all read this one list (same single-source
/// pattern as the workload `PRESET_TABLE`).
const FAULT_PRESET_TABLE: &[FaultPresetEntry] = &[
    FaultPresetEntry {
        name: NO_FAULTS,
        about: "no fault injection (default; byte-identical to pre-fault builds)",
        build: FaultSpec::default,
    },
    FaultPresetEntry {
        name: "chaos-gpu-failures",
        about: "GPU crash/repair churn: per-GPU MTBF 45 s, MTTR 15 s",
        build: || FaultSpec {
            gpu_mtbf: Some(45.0),
            gpu_mttr: 15.0,
            ..FaultSpec::default()
        },
    },
    FaultPresetEntry {
        name: "chaos-flaky-reconfig",
        about: "30% transient reconfiguration failures, 3 retries, 0.25 s backoff",
        build: || FaultSpec {
            reconfig_fail_p: 0.3,
            reconfig_retries: 3,
            reconfig_backoff: 0.25,
            ..FaultSpec::default()
        },
    },
];

/// Resolve a fault-preset name (`no-faults`, `chaos-gpu-failures`,
/// `chaos-flaky-reconfig`).
pub fn fault_spec_from_name(name: &str) -> Option<FaultSpec> {
    FAULT_PRESET_TABLE
        .iter()
        .find(|e| e.name.eq_ignore_ascii_case(name))
        .map(|e| (e.build)())
}

/// Comma-separated menu of valid fault-preset names (error messages, CLI
/// help).
pub fn fault_name_menu() -> String {
    FAULT_PRESET_TABLE
        .iter()
        .map(|e| e.name)
        .collect::<Vec<_>>()
        .join(", ")
}

/// Human-readable inventory table for the `faults` CLI subcommand.
pub fn fault_table() -> String {
    let mut out = String::from("fault presets:\n");
    for e in FAULT_PRESET_TABLE {
        out.push_str(&format!("  {:<22} {}\n", e.name, e.about));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_spec_is_inactive_and_compiles_empty() {
        let spec = FaultSpec::default();
        assert!(!spec.is_active());
        let plan = FaultPlan::compile(&spec, 42, 10, 360.0);
        assert!(plan.events().is_empty());
    }

    #[test]
    fn inactive_plan_draws_no_rng_on_transient_checks() {
        let mut plan = FaultPlan::compile(&FaultSpec::default(), 42, 4, 100.0);
        // The online stream must stay untouched: a fresh generator on the
        // same stream produces the same next value after 1000 checks.
        let mut fresh = Pcg64::new(42, STREAM_ONLINE);
        for _ in 0..1000 {
            assert!(!plan.draw_transient());
        }
        assert_eq!(plan.online.next_u64(), fresh.next_u64());
        assert_eq!(plan.transients(), 0);
    }

    #[test]
    fn compile_is_deterministic() {
        let spec = fault_spec_from_name("chaos-gpu-failures").unwrap();
        let a = FaultPlan::compile(&spec, 7, 6, 120.0);
        let b = FaultPlan::compile(&spec, 7, 6, 120.0);
        assert_eq!(a.events(), b.events());
        assert!(!a.events().is_empty(), "chaos preset must schedule failures");
        // A different seed gives a different schedule.
        let c = FaultPlan::compile(&spec, 8, 6, 120.0);
        assert_ne!(a.events(), c.events());
    }

    #[test]
    fn schedule_is_time_sorted_within_horizon_and_alternates_per_gpu() {
        let spec = FaultSpec {
            gpu_mtbf: Some(30.0),
            gpu_mttr: 10.0,
            ..FaultSpec::default()
        };
        let plan = FaultPlan::compile(&spec, 3, 4, 200.0);
        let evs = plan.events();
        assert!(evs.windows(2).all(|w| w[0].0 <= w[1].0), "must be time-sorted");
        assert!(evs.iter().all(|&(t, _)| (0.0..200.0).contains(&t)));
        // Per GPU, events strictly alternate fail → repair → fail …
        for gpu in 0..4 {
            let mine: Vec<FaultKind> = evs
                .iter()
                .filter(|(_, k)| {
                    matches!(k, FaultKind::GpuFails(g) | FaultKind::GpuRepairs(g) if *g == gpu)
                })
                .map(|&(_, k)| k)
                .collect();
            for (i, k) in mine.iter().enumerate() {
                let expect = if i % 2 == 0 {
                    FaultKind::GpuFails(gpu)
                } else {
                    FaultKind::GpuRepairs(gpu)
                };
                assert_eq!(*k, expect);
            }
        }
    }

    #[test]
    fn scripted_events_merge_into_the_schedule() {
        let spec = FaultSpec {
            scripted_failures: vec![(50.0, 0)],
            scripted_repairs: vec![(70.0, 0)],
            ..FaultSpec::default()
        };
        assert!(spec.is_active());
        let plan = FaultPlan::compile(&spec, 1, 2, 100.0);
        assert_eq!(
            plan.events(),
            &[(50.0, FaultKind::GpuFails(0)), (70.0, FaultKind::GpuRepairs(0))]
        );
    }

    #[test]
    fn transient_coin_respects_probability_and_counts() {
        let spec = FaultSpec {
            reconfig_fail_p: 0.3,
            ..FaultSpec::default()
        };
        let mut plan = FaultPlan::compile(&spec, 9, 2, 60.0);
        let n = 10_000;
        let fails = (0..n).filter(|_| plan.draw_transient()).count();
        let rate = fails as f64 / n as f64;
        assert!((rate - 0.3).abs() < 0.02, "rate={rate}");
        assert_eq!(plan.transients(), fails as u64);
    }

    #[test]
    fn preset_registry_resolves_and_lists() {
        assert!(fault_spec_from_name(NO_FAULTS).is_some());
        assert!(!fault_spec_from_name(NO_FAULTS).unwrap().is_active());
        assert!(fault_spec_from_name("chaos-gpu-failures").unwrap().is_active());
        assert!(fault_spec_from_name("chaos-flaky-reconfig").unwrap().is_active());
        assert!(fault_spec_from_name("nope").is_none());
        let menu = fault_name_menu();
        assert!(menu.contains("no-faults") && menu.contains("chaos-gpu-failures"));
        assert!(fault_table().contains("chaos-flaky-reconfig"));
    }
}
