//! Workflow subsystem: DAG pipelines of zoo models with SLO budget
//! splitting and co-scaled stages.
//!
//! A [`Workflow`] describes a chain or DAG of model stages. Every stage
//! references a zoo model graph; every edge carries a payload size from
//! which its network **hop latency** is derived (`rtt + bytes / bandwidth`).
//! The workflow owns one **end-to-end SLO**, which [`split_budget`]
//! decomposes into per-stage SLO budgets proportionally to RaPP/perf-model
//! predicted full-resource stage latencies:
//!
//! ```text
//! k = max(0, slo_e2e − H) / L          H = longest-path hop latency
//! budget[s] = k · lat[s]               L = longest-path stage latency
//! ```
//!
//! For *every* root-to-leaf path `p` this conserves the SLO:
//! `Σ_p budget + Σ_p hop ≤ k·L + H ≤ slo_e2e` (with equality on the
//! critical path of a chain). Budgets are renormalized by calling
//! [`Workflow::stage_budgets`] again with refreshed latency predictions as
//! stages scale; the split clamps at zero and sanitizes non-finite inputs,
//! so a budget is never negative or NaN (pinned by
//! `rust/tests/workflow_properties.rs`).
//!
//! [`WorkflowRegistry`] mirrors the `PlatformRegistry` / `FleetRegistry`
//! name rules (case-insensitive keys, duplicate and CLI-unreachable names
//! rejected, unknown names error with the full menu) and ships the two
//! built-in pipelines the scenario matrix exposes as presets:
//! `pipeline-vision` (detector → classifier chain) and `pipeline-mixed`
//! (branching diamond over mixed model sizes). Workflow export keys appear
//! *only* in cells run under a workflow preset — stock grids stay
//! byte-identical (pinned by `rust/tests/expt_golden.rs`).

use crate::cluster::FunctionSpec;
use crate::model::zoo::{zoo_graph, ZooModel};
use crate::perf::PerfModel;
use crate::util::bench::ascii_table;

/// Inter-stage link bandwidth (bytes/s) used to derive hop latency from an
/// edge's payload size — a 10 Gbit/s datacenter fabric.
pub const LINK_BANDWIDTH: f64 = 1.25e9;

/// Fixed per-hop round-trip overhead (seconds): serialization + RPC.
pub const LINK_RTT: f64 = 1e-3;

/// A float32 `224×224×3` image tensor — the canonical vision payload.
pub const IMAGE_TENSOR_BYTES: f64 = 602_112.0;

/// One model stage of a workflow.
#[derive(Clone, Debug)]
pub struct WorkflowStage {
    /// Stage name, unique within the workflow (case-insensitive). The
    /// serving function is named `"{workflow}:{stage}"`.
    pub name: String,
    /// Zoo model this stage executes.
    pub model: ZooModel,
    /// Serving batch size of the stage's pods.
    pub batch: u32,
}

/// A directed edge between two stages. Edges must point *forward*
/// (`from < to`), which makes every edge list acyclic by construction.
#[derive(Clone, Copy, Debug)]
pub struct WorkflowEdge {
    pub from: usize,
    pub to: usize,
    /// Payload handed from `from` to `to` (bytes).
    pub payload_bytes: f64,
}

impl WorkflowEdge {
    /// Network hop latency of this edge (seconds).
    pub fn hop_latency(&self) -> f64 {
        LINK_RTT + self.payload_bytes.max(0.0) / LINK_BANDWIDTH
    }
}

/// A DAG pipeline of model stages with one end-to-end SLO.
#[derive(Clone, Debug)]
pub struct Workflow {
    /// Stable registry key (export schema — cells carry this name).
    pub name: String,
    /// One-line description for `--help` and the `workflows` subcommand.
    pub about: String,
    /// Stages in topological order (edges always point forward).
    pub stages: Vec<WorkflowStage>,
    pub edges: Vec<WorkflowEdge>,
    /// End-to-end SLO (seconds): the deadline from entry-stage arrival to
    /// final-stage completion. Violation is an *e2e* deadline miss, never a
    /// per-stage one.
    pub e2e_slo: f64,
}

impl Workflow {
    /// A linear chain: consecutive stages connected by edges carrying
    /// `payload_bytes` each.
    pub fn chain(
        name: impl Into<String>,
        about: impl Into<String>,
        stages: &[(&str, ZooModel, u32)],
        payload_bytes: f64,
    ) -> Self {
        Workflow {
            name: name.into(),
            about: about.into(),
            stages: stages
                .iter()
                .map(|&(n, m, b)| WorkflowStage {
                    name: n.into(),
                    model: m,
                    batch: b,
                })
                .collect(),
            edges: (1..stages.len())
                .map(|i| WorkflowEdge {
                    from: i - 1,
                    to: i,
                    payload_bytes,
                })
                .collect(),
            e2e_slo: 0.0,
        }
    }

    /// Structural validation: non-empty stages with unique reachable names,
    /// forward in-range edges, exactly one entry stage, every stage
    /// reachable from it. (The registry additionally validates the SLO.)
    pub fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(!self.stages.is_empty(), "workflow '{}' has no stages", self.name);
        for (i, s) in self.stages.iter().enumerate() {
            anyhow::ensure!(
                !s.name.is_empty() && s.name.trim() == s.name && !s.name.contains([':', ',']),
                "workflow '{}': stage {i} name '{}' must be non-empty, trimmed, and free of \
                 ':'/',' (it becomes part of the '{{workflow}}:{{stage}}' function name)",
                self.name,
                s.name
            );
            anyhow::ensure!(
                s.batch >= 1,
                "workflow '{}': stage '{}' batch must be ≥ 1",
                self.name,
                s.name
            );
            for other in &self.stages[..i] {
                anyhow::ensure!(
                    !other.name.eq_ignore_ascii_case(&s.name),
                    "workflow '{}': duplicate stage name '{}'",
                    self.name,
                    s.name
                );
            }
        }
        for e in &self.edges {
            anyhow::ensure!(
                e.from < e.to && e.to < self.stages.len(),
                "workflow '{}': edge {}→{} must point forward within {} stages \
                 (forward edges keep the DAG acyclic by construction)",
                self.name,
                e.from,
                e.to,
                self.stages.len()
            );
            anyhow::ensure!(
                e.payload_bytes.is_finite() && e.payload_bytes >= 0.0,
                "workflow '{}': edge {}→{} payload must be finite and ≥ 0",
                self.name,
                e.from,
                e.to
            );
        }
        let entries: Vec<usize> = (0..self.stages.len())
            .filter(|&s| self.in_degree(s) == 0)
            .collect();
        anyhow::ensure!(
            entries.len() == 1,
            "workflow '{}' must have exactly one entry stage (got {})",
            self.name,
            entries.len()
        );
        // Reachability from the single entry. Indices ascend along any
        // forward-edge path, so one ascending sweep settles it.
        let mut reach = vec![false; self.stages.len()];
        reach[entries[0]] = true;
        for s in 0..self.stages.len() {
            if reach[s] {
                for e in self.edges.iter().filter(|e| e.from == s) {
                    reach[e.to] = true;
                }
            }
        }
        if let Some(orphan) = reach.iter().position(|r| !r) {
            anyhow::bail!(
                "workflow '{}': stage '{}' is unreachable from the entry stage",
                self.name,
                self.stages[orphan].name
            );
        }
        Ok(())
    }

    /// Index of the single entry stage (no incoming edges).
    pub fn entry(&self) -> usize {
        (0..self.stages.len()).find(|&s| self.in_degree(s) == 0).unwrap_or(0)
    }

    pub fn in_degree(&self, stage: usize) -> usize {
        self.edges.iter().filter(|e| e.to == stage).count()
    }

    pub fn is_terminal(&self, stage: usize) -> bool {
        !self.edges.iter().any(|e| e.from == stage)
    }

    /// Number of terminal stages (no outgoing edges).
    pub fn terminal_count(&self) -> usize {
        (0..self.stages.len()).filter(|&s| self.is_terminal(s)).count()
    }

    /// Full-resource (`sm = q = 1`) predicted latency per stage under the
    /// calibrated perf model — the weights the budget splitter distributes
    /// the SLO over.
    pub fn full_resource_latencies(&self, perf: &PerfModel) -> Vec<f64> {
        self.stages
            .iter()
            .map(|s| perf.latency(&zoo_graph(s.model), s.batch, 1.0, 1.0))
            .collect()
    }

    /// Longest root-to-leaf path sum of per-stage values (edge-connected;
    /// node weights), i.e. the critical-path latency when `vals` are stage
    /// latencies.
    pub fn critical_path(&self, vals: &[f64]) -> f64 {
        longest_path(self.stages.len(), &self.edges, |s| sane(vals[s]), |_| 0.0)
    }

    /// Longest root-to-leaf hop-latency path sum (edge weights only).
    pub fn critical_path_hops(&self) -> f64 {
        longest_path(self.stages.len(), &self.edges, |_| 0.0, |e| e.hop_latency())
    }

    /// Per-stage SLO budgets for the current predicted stage latencies.
    /// Call again with refreshed predictions to renormalize as stages scale.
    pub fn stage_budgets(&self, lats: &[f64]) -> Vec<f64> {
        split_budget(self.e2e_slo, lats, self.stages.len(), &self.edges)
    }

    /// The serving-function name of a stage: `"{workflow}:{stage}"`.
    pub fn stage_function_name(&self, stage: usize) -> String {
        format!("{}:{}", self.name, self.stages[stage].name)
    }

    /// Build the per-stage [`FunctionSpec`]s: one function per stage, named
    /// `"{workflow}:{stage}"`, whose SLO is the stage's split budget under
    /// `perf`'s full-resource latency predictions.
    pub fn stage_functions(&self, perf: &PerfModel) -> Vec<FunctionSpec> {
        let budgets = self.stage_budgets(&self.full_resource_latencies(perf));
        self.stages
            .iter()
            .enumerate()
            .map(|(i, s)| FunctionSpec {
                name: self.stage_function_name(i),
                graph: zoo_graph(s.model),
                slo: budgets[i],
                batch: s.batch,
                artifact: None,
            })
            .collect()
    }

    /// Derive the end-to-end SLO from the perf model: `mult ×` the
    /// critical-path full-resource latency plus the critical-path hop
    /// latency — the same "× baseline" convention the single-function
    /// experiment grid uses for per-function SLOs.
    pub fn with_auto_slo(mut self, perf: &PerfModel, mult: f64) -> Self {
        let lats = self.full_resource_latencies(perf);
        self.e2e_slo = mult * self.critical_path(&lats) + self.critical_path_hops();
        self
    }
}

/// Replace non-finite or negative values with 0 so one poisoned predictor
/// output can never spread NaN through the budget split.
fn sane(v: f64) -> f64 {
    if v.is_finite() && v > 0.0 {
        v
    } else {
        0.0
    }
}

/// Longest root-to-leaf path over forward edges, summing `node(s)` at every
/// visited stage and `edge(e)` over every traversed edge. Stages with no
/// incoming edge start a path; the maximum over all stages is returned
/// (terminal stages dominate because weights are non-negative).
fn longest_path(
    n: usize,
    edges: &[WorkflowEdge],
    node: impl Fn(usize) -> f64,
    edge: impl Fn(&WorkflowEdge) -> f64,
) -> f64 {
    if n == 0 {
        return 0.0;
    }
    let mut dp = vec![0.0f64; n];
    for (s, d) in dp.iter_mut().enumerate() {
        *d = node(s);
    }
    // Forward edges mean ascending target order is a topological order.
    for s in 0..n {
        for e in edges.iter().filter(|e| e.to == s) {
            let via = dp[e.from] + edge(e) + node(s);
            if via > dp[s] {
                dp[s] = via;
            }
        }
    }
    dp.iter().fold(0.0f64, |a, &b| a.max(b))
}

/// Latency-proportional SLO budget split (module docs): reserve the
/// longest-path hop latency `H` off the top, then distribute the remainder
/// over stages proportionally to their predicted latencies, scaled so the
/// longest latency path `L` exactly spends the remainder. Every budget is
/// clamped non-negative and NaN-sanitized; an all-zero (or non-finite)
/// latency vector yields all-zero budgets rather than a division blow-up.
pub fn split_budget(
    e2e_slo: f64,
    lats: &[f64],
    n_stages: usize,
    edges: &[WorkflowEdge],
) -> Vec<f64> {
    let n = n_stages.min(lats.len());
    let l = longest_path(n, edges, |s| sane(lats[s]), |_| 0.0);
    let h = longest_path(n, edges, |_| 0.0, |e| e.hop_latency());
    let k = if l > 0.0 && e2e_slo.is_finite() {
        ((e2e_slo - h).max(0.0)) / l
    } else {
        0.0
    };
    (0..n).map(|s| sane(k * sane(lats[s]))).collect()
}

/// Ordered collection of [`Workflow`]s; registration order is listing
/// order. Mirrors the `PlatformRegistry` / `FleetRegistry` contract:
/// case-insensitive lookup, duplicate and CLI-unreachable names rejected,
/// unknown names error with the full menu.
#[derive(Clone, Debug)]
pub struct WorkflowRegistry {
    specs: Vec<Workflow>,
}

impl Default for WorkflowRegistry {
    /// The two built-in pipelines the scenario matrix exposes as presets.
    /// End-to-end SLOs follow the grid's `3 × full-resource baseline`
    /// convention, applied to the critical path (plus hop latency), so the
    /// per-stage split lands each stage at ≈ 3 × its own baseline — the
    /// same pressure a single-function grid cell runs under.
    fn default() -> Self {
        let perf = PerfModel::default();
        let mut reg = WorkflowRegistry::empty();
        reg.register(
            Workflow::chain(
                "pipeline-vision",
                "detector → classifier vision chain (resnet50 → mobilenet_v2)",
                &[
                    ("detect", ZooModel::ResNet50, 8),
                    ("classify", ZooModel::MobileNetV2, 8),
                ],
                IMAGE_TENSOR_BYTES,
            )
            .with_auto_slo(&perf, 3.0),
        )
        .unwrap();
        reg.register(
            Workflow {
                name: "pipeline-mixed".into(),
                about: "branching diamond over mixed model sizes \
                        (mobilenet_v2 → {resnet50, convnext_tiny} → bert_tiny)"
                    .into(),
                stages: vec![
                    WorkflowStage {
                        name: "prep".into(),
                        model: ZooModel::MobileNetV2,
                        batch: 8,
                    },
                    WorkflowStage {
                        name: "branch_a".into(),
                        model: ZooModel::ResNet50,
                        batch: 8,
                    },
                    WorkflowStage {
                        name: "branch_b".into(),
                        model: ZooModel::ConvNextTiny,
                        batch: 8,
                    },
                    WorkflowStage {
                        name: "merge".into(),
                        model: ZooModel::BertTiny,
                        batch: 8,
                    },
                ],
                edges: vec![
                    WorkflowEdge {
                        from: 0,
                        to: 1,
                        payload_bytes: IMAGE_TENSOR_BYTES,
                    },
                    WorkflowEdge {
                        from: 0,
                        to: 2,
                        payload_bytes: IMAGE_TENSOR_BYTES,
                    },
                    WorkflowEdge {
                        from: 1,
                        to: 3,
                        payload_bytes: 8_192.0,
                    },
                    WorkflowEdge {
                        from: 2,
                        to: 3,
                        payload_bytes: 8_192.0,
                    },
                ],
                e2e_slo: 0.0,
            }
            .with_auto_slo(&perf, 3.0),
        )
        .unwrap();
        reg
    }
}

impl WorkflowRegistry {
    pub fn empty() -> Self {
        WorkflowRegistry { specs: Vec::new() }
    }

    /// Append a workflow; names are case-insensitive keys with the same
    /// reachability rules as platform/fleet names, and the workflow itself
    /// must pass [`Workflow::validate`] with a positive finite e2e SLO.
    pub fn register(&mut self, wf: Workflow) -> anyhow::Result<()> {
        anyhow::ensure!(!wf.name.is_empty(), "workflow name must be non-empty");
        anyhow::ensure!(
            wf.name.trim() == wf.name,
            "workflow name '{}' must not have surrounding whitespace",
            wf.name
        );
        anyhow::ensure!(
            !wf.name.contains(',') && !wf.name.contains(':'),
            "workflow name '{}' must not contain ',' (CLI separator) or ':' \
             (stage-function separator)",
            wf.name
        );
        wf.validate()?;
        anyhow::ensure!(
            wf.e2e_slo.is_finite() && wf.e2e_slo > 0.0,
            "workflow '{}' needs a positive finite e2e SLO (use with_auto_slo)",
            wf.name
        );
        anyhow::ensure!(
            self.get(&wf.name).is_none(),
            "workflow '{}' is already registered",
            wf.name
        );
        self.specs.push(wf);
        Ok(())
    }

    /// Case-insensitive lookup.
    pub fn get(&self, name: &str) -> Option<&Workflow> {
        self.specs.iter().find(|s| s.name.eq_ignore_ascii_case(name.trim()))
    }

    pub fn specs(&self) -> &[Workflow] {
        &self.specs
    }

    pub fn names(&self) -> Vec<&str> {
        self.specs.iter().map(|s| s.name.as_str()).collect()
    }

    /// Expand a token list into canonical registry names, deduplicated in
    /// first-appearance order.
    pub fn resolve(&self, tokens: &[String]) -> anyhow::Result<Vec<String>> {
        anyhow::ensure!(!tokens.is_empty(), "need at least one workflow");
        let mut out: Vec<String> = Vec::new();
        for tok in tokens {
            let t = tok.trim();
            let Some(spec) = self.get(t) else {
                anyhow::bail!(
                    "unknown workflow '{t}' (expected one of: {})",
                    self.names().join(", ")
                );
            };
            if !out.iter().any(|n| n == &spec.name) {
                out.push(spec.name.clone());
            }
        }
        Ok(out)
    }

    /// One-line inventory for `--help` text.
    pub fn cli_help(&self) -> String {
        format!("comma list of workflow names; names: {}", self.names().join(", "))
    }

    /// The `has-gpu workflows` inventory table (stages, e2e SLO, edge
    /// payloads) — same style as `platforms` / `fleets` / `faults`.
    pub fn table(&self) -> String {
        let rows: Vec<Vec<String>> = self
            .specs
            .iter()
            .map(|w| {
                let stages = w
                    .stages
                    .iter()
                    .map(|s| format!("{}({})", s.name, s.model.name()))
                    .collect::<Vec<_>>()
                    .join(" ");
                let edges = w
                    .edges
                    .iter()
                    .map(|e| {
                        format!(
                            "{}→{} {:.0}KB",
                            w.stages[e.from].name,
                            w.stages[e.to].name,
                            e.payload_bytes / 1024.0
                        )
                    })
                    .collect::<Vec<_>>()
                    .join(" ");
                vec![
                    w.name.clone(),
                    stages,
                    format!("{:.3} s", w.e2e_slo),
                    edges,
                    w.about.clone(),
                ]
            })
            .collect();
        ascii_table(&["workflow", "stages", "e2e SLO", "edges (payload)", "description"], &rows)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_registry_lists_builtin_pipelines() {
        let reg = WorkflowRegistry::default();
        assert_eq!(reg.names(), vec!["pipeline-vision", "pipeline-mixed"]);
        assert!(reg.get("PIPELINE-VISION").is_some(), "lookup is case-insensitive");
        for w in reg.specs() {
            w.validate().unwrap();
            assert!(w.e2e_slo.is_finite() && w.e2e_slo > 0.0, "{}: slo {}", w.name, w.e2e_slo);
        }
        let t = reg.table();
        assert!(t.contains("pipeline-vision") && t.contains("pipeline-mixed"), "{t}");
        assert!(t.contains("resnet50") && t.contains("bert_tiny"), "{t}");
    }

    #[test]
    fn resolve_dedupes_and_errors_with_menu() {
        let reg = WorkflowRegistry::default();
        assert_eq!(
            reg.resolve(&["Pipeline-Mixed".to_string(), "pipeline-vision".to_string()]).unwrap(),
            vec!["pipeline-mixed".to_string(), "pipeline-vision".to_string()]
        );
        assert_eq!(
            reg.resolve(&["pipeline-vision".to_string(), "pipeline-vision".to_string()])
                .unwrap()
                .len(),
            1
        );
        let err = reg.resolve(&["pipeline-zoo".to_string()]).unwrap_err().to_string();
        assert!(err.contains("pipeline-vision") && err.contains("pipeline-mixed"), "{err}");
        assert!(reg.resolve(&[]).is_err());
    }

    #[test]
    fn registration_rejects_unreachable_and_invalid() {
        let mut reg = WorkflowRegistry::default();
        let perf = PerfModel::default();
        let mk = |name: &str| {
            Workflow::chain(name, "t", &[("a", ZooModel::MobileNetV2, 4)], 0.0)
                .with_auto_slo(&perf, 3.0)
        };
        for bad in ["", " padded", "a,b", "a:b", "pipeline-vision", "PIPELINE-VISION"] {
            assert!(reg.register(mk(bad)).is_err(), "'{bad}' must be rejected");
        }
        // Zero SLO rejected.
        let mut no_slo = mk("no-slo");
        no_slo.e2e_slo = 0.0;
        assert!(reg.register(no_slo).is_err());
        // Backward edge rejected.
        let mut back = mk("backward");
        back.stages.push(WorkflowStage {
            name: "b".into(),
            model: ZooModel::MobileNetV2,
            batch: 4,
        });
        back.edges.push(WorkflowEdge { from: 1, to: 0, payload_bytes: 1.0 });
        assert!(back.validate().is_err());
        // Two entry stages rejected.
        let mut twin = mk("twin");
        twin.stages.push(WorkflowStage {
            name: "b".into(),
            model: ZooModel::MobileNetV2,
            batch: 4,
        });
        assert!(twin.validate().is_err());
        // A fresh valid workflow registers, resolves, and lists.
        reg.register(mk("pipeline-tiny")).unwrap();
        assert_eq!(reg.resolve(&["pipeline-tiny".into()]).unwrap(), vec!["pipeline-tiny"]);
        assert!(reg.table().contains("pipeline-tiny"));
        assert!(reg.cli_help().contains("pipeline-tiny"));
    }

    #[test]
    fn chain_budget_split_is_exact_on_the_critical_path() {
        let reg = WorkflowRegistry::default();
        let w = reg.get("pipeline-vision").unwrap();
        let perf = PerfModel::default();
        let lats = w.full_resource_latencies(&perf);
        let budgets = w.stage_budgets(&lats);
        assert_eq!(budgets.len(), 2);
        assert!(budgets.iter().all(|b| b.is_finite() && *b > 0.0), "{budgets:?}");
        // A chain has a single path: budgets + hops spend the SLO exactly.
        let spent: f64 = budgets.iter().sum::<f64>() + w.critical_path_hops();
        assert!((spent - w.e2e_slo).abs() < 1e-9, "spent {spent} vs slo {}", w.e2e_slo);
        // Latency-proportional: budget ratio tracks the latency ratio.
        assert!((budgets[0] / budgets[1] - lats[0] / lats[1]).abs() < 1e-9);
    }

    #[test]
    fn diamond_split_conserves_on_every_path() {
        let reg = WorkflowRegistry::default();
        let w = reg.get("pipeline-mixed").unwrap();
        let perf = PerfModel::default();
        let lats = w.full_resource_latencies(&perf);
        let budgets = w.stage_budgets(&lats);
        let hop = |f: usize, t: usize| {
            w.edges
                .iter()
                .find(|e| e.from == f && e.to == t)
                .unwrap()
                .hop_latency()
        };
        for branch in [1usize, 2] {
            let path = budgets[0] + budgets[branch] + budgets[3] + hop(0, branch) + hop(branch, 3);
            assert!(path <= w.e2e_slo + 1e-9, "path via {branch}: {path} > {}", w.e2e_slo);
        }
        assert_eq!(w.entry(), 0);
        assert_eq!(w.in_degree(3), 2, "merge joins both branches");
        assert!(w.is_terminal(3) && w.terminal_count() == 1);
    }

    #[test]
    fn stage_functions_carry_budgets_and_namespaced_names() {
        let reg = WorkflowRegistry::default();
        let perf = PerfModel::default();
        let w = reg.get("pipeline-mixed").unwrap();
        let fns = w.stage_functions(&perf);
        assert_eq!(fns.len(), 4);
        assert_eq!(fns[0].name, "pipeline-mixed:prep");
        assert_eq!(fns[3].name, "pipeline-mixed:merge");
        let budgets = w.stage_budgets(&w.full_resource_latencies(&perf));
        for (f, b) in fns.iter().zip(&budgets) {
            assert_eq!(f.slo, *b);
            assert!(f.slo > 0.0 && f.artifact.is_none());
        }
    }

    #[test]
    fn split_budget_sanitizes_degenerate_inputs() {
        let edges = [WorkflowEdge { from: 0, to: 1, payload_bytes: 1e6 }];
        // NaN / negative latencies never poison the output.
        let b = split_budget(0.5, &[f64::NAN, -1.0], 2, &edges);
        assert!(b.iter().all(|x| x.is_finite() && *x >= 0.0), "{b:?}");
        // SLO below the hop reserve clamps to zero budgets, not negatives.
        let b = split_budget(1e-9, &[0.1, 0.1], 2, &edges);
        assert!(b.iter().all(|x| *x == 0.0), "{b:?}");
        // Infinite SLO is rejected into zeros rather than Inf budgets.
        let b = split_budget(f64::INFINITY, &[0.1, 0.1], 2, &edges);
        assert!(b.iter().all(|x| x.is_finite()), "{b:?}");
        assert!(split_budget(1.0, &[], 0, &[]).is_empty());
    }

    #[test]
    fn renormalization_tracks_scaled_latencies() {
        let reg = WorkflowRegistry::default();
        let w = reg.get("pipeline-vision").unwrap();
        let perf = PerfModel::default();
        let mut lats = w.full_resource_latencies(&perf);
        let before = w.stage_budgets(&lats);
        // Stage 0 slows 2×: its share must grow, stage 1's must shrink,
        // and the chain still spends exactly the SLO.
        lats[0] *= 2.0;
        let after = w.stage_budgets(&lats);
        assert!(after[0] > before[0] && after[1] < before[1]);
        let spent: f64 = after.iter().sum::<f64>() + w.critical_path_hops();
        assert!((spent - w.e2e_slo).abs() < 1e-9);
    }
}
