//! `has-gpu` — the leader binary: the scenario-matrix experiment runner
//! (`expt`), its single-cell special case (`simulate`), the platform
//! registry inventory (`platforms`), RaPP prediction (`predict`), trace
//! synthesis (`trace-gen`), and the zoo inventory.

use has_gpu::expt::{
    experiment_functions, parse_faults, parse_fleets, parse_platforms, parse_presets,
    parse_seeds, FleetRegistry, PlatformRegistry, ScenarioMatrix,
};
use has_gpu::model::zoo::{zoo_graph, zoo_names, ZooModel};
use has_gpu::perf::PerfModel;
use has_gpu::rapp::{LatencyPredictor, PredictQuery, RappPredictor};
use has_gpu::util::cli::Cli;
use has_gpu::util::json;
use has_gpu::workload::{Preset, TraceGen};
use std::path::PathBuf;

const USAGE: &str = "has-gpu — Hybrid Auto-scaling Serverless GPU inference (reproduction)

USAGE: has-gpu <COMMAND> [options]

COMMANDS:
  expt       run a platform × fleet × fault × preset × seed scenario matrix
             in parallel and export the comparison grid as JSON
             [--platforms all|ablations|csv of names] [--preset all|csv]
             [--fleets csv of fleet names] [--faults csv of fault presets]
             [--seeds N|csv] [--seed-base S]
             [--seconds N] [--gpus N] [--rps R] [--jobs N] [--out PATH]
  simulate   run a single platform-vs-workload cell and print the report
             [--platform NAME] [--preset NAME] [--fleet NAME] [--fault NAME]
             [--seconds N] [--gpus N] [--rps R] [--seed S] [--json]
  platforms  list the platform registry (names, groups, billing, predictor)
  fleets     list the fleet registry (GPU-class compositions)
  faults     list the fault-preset registry (chaos schedules for expt/simulate)
  workflows  list the workflow registry (DAG stages, e2e SLOs, edge payloads)
  predict    RaPP latency prediction (requires artifacts)
             [--model NAME] [--batch B] [--sm F] [--quota F]
  trace-gen  synthesise an Azure-style workload trace as JSON to stdout
             [--preset NAME] [--seconds N] [--rps R] [--seed S]
  zoo        list benchmark models with FLOPs/params/baseline latency
  help       this message

Platform and preset names are case-insensitive; `has-gpu platforms` prints
the full registry. Run `has-gpu <COMMAND> --help` for per-command details.
";

fn main() -> anyhow::Result<()> {
    let mut argv: Vec<String> = std::env::args().skip(1).collect();
    let cmd = if argv.is_empty() { "help".to_string() } else { argv.remove(0) };
    match cmd.as_str() {
        "expt" => expt(argv),
        "simulate" => simulate(argv),
        "platforms" => {
            print!("{}", PlatformRegistry::default().table());
            Ok(())
        }
        "fleets" => {
            print!("{}", FleetRegistry::default().table());
            Ok(())
        }
        "faults" => {
            print!("{}", has_gpu::sim::fault_table());
            Ok(())
        }
        "workflows" => {
            print!("{}", has_gpu::workflow::WorkflowRegistry::default().table());
            Ok(())
        }
        "predict" => predict(argv),
        "trace-gen" => trace_gen(argv),
        "zoo" => {
            let pm = PerfModel::default();
            println!("{:<16} {:>10} {:>10} {:>14}", "model", "GFLOPs", "Mparams", "baseline(ms)");
            for m in has_gpu::model::zoo::ALL_ZOO {
                let g = zoo_graph(m);
                println!(
                    "{:<16} {:>10.2} {:>10.2} {:>14.2}",
                    g.name,
                    g.total_flops(1) / 1e9,
                    g.total_params() / 1e6,
                    pm.latency(&g, 1, 1.0, 1.0) * 1e3
                );
            }
            Ok(())
        }
        _ => {
            println!("{USAGE}");
            Ok(())
        }
    }
}

/// The scenario-matrix runner: shard `platform × preset × seed` cells over a
/// thread pool, print the paper-style comparison table, export the grid.
fn expt(argv: Vec<String>) -> anyhow::Result<()> {
    let registry = PlatformRegistry::default();
    let fleet_registry = FleetRegistry::default();
    let args = Cli::new("has-gpu expt", "scenario-matrix experiment runner")
        .opt_dyn("platforms", "all", registry.cli_help())
        .opt_dyn("fleets", "uniform-v100", fleet_registry.cli_help())
        .opt_dyn(
            "faults",
            "no-faults",
            format!(
                "comma list of fault presets ({}); see `has-gpu faults`",
                has_gpu::sim::fault_name_menu()
            ),
        )
        .opt_dyn(
            "preset",
            "standard",
            format!("comma list of workload presets ({}), or 'all'", Preset::name_menu()),
        )
        .opt("seeds", "2", "seed count (expands from --seed-base) or comma list")
        .opt("seed-base", "11", "first seed when --seeds is a count")
        .opt("seconds", "300", "trace length per cell (virtual seconds)")
        .opt("gpus", "10", "cluster size per cell")
        .opt("rps", "150", "mean request rate per function")
        .opt("jobs", "0", "worker threads (0 = available parallelism)")
        .opt("out", "BENCH_sim.json", "output path for the JSON grid")
        .parse_from_or_exit(argv);
    let platforms = parse_platforms(&args.get_list("platforms"), &registry)?;
    let fleets = parse_fleets(&args.get_list("fleets"), &fleet_registry)?;
    let faults = parse_faults(&args.get_list("faults"))?;
    let matrix = ScenarioMatrix {
        platforms,
        registry,
        presets: parse_presets(&args.get_list("preset"))?,
        seeds: parse_seeds(args.get("seeds"), args.get_u64("seed-base"))?,
        seconds: args.get_usize("seconds"),
        gpus: args.get_usize("gpus"),
        rps: args.get_f64("rps"),
        fleets,
        fleet_registry,
        faults,
    };
    let jobs = args.get_usize("jobs");
    eprintln!(
        "running {} cells ({} platforms × {} fleets × {} faults × {} presets × {} seeds) with jobs={}…",
        matrix.cells().len(),
        matrix.platforms.len(),
        matrix.fleets.len(),
        matrix.faults.len(),
        matrix.presets.len(),
        matrix.seeds.len(),
        if jobs == 0 { "auto".to_string() } else { jobs.to_string() }
    );
    let report = matrix.run(jobs);
    print!("{}", report.table());
    let fmt_ratio = |r: Option<f64>| match r {
        Some(v) => format!("{v:.2}x"),
        None => "n/a (has-gpu baseline is 0)".to_string(),
    };
    for r in report.ratios_vs_has_gpu() {
        // TTFT ratios only exist for lifecycle presets (cold-start-storm);
        // MTTR ratios only for fault-injected cells; e2e ratios only for
        // pipeline presets.
        let ttft = match r.ttft_ratio {
            Some(v) => format!(", ttft-p99 {v:.2}x"),
            None => String::new(),
        };
        let mttr = match r.mttr_ratio {
            Some(v) => format!(", mttr {v:.2}x"),
            None => String::new(),
        };
        let e2e = match r.e2e_ratio {
            Some(v) => format!(", e2e-p99 {v:.2}x"),
            None => String::new(),
        };
        let fault = if r.fault == has_gpu::sim::NO_FAULTS {
            String::new()
        } else {
            format!(" ({})", r.fault)
        };
        println!(
            "{} vs has-gpu @ {} [{}]{}: cost {}, slo-violations {}{}{}{}",
            r.platform,
            r.preset.name(),
            r.fleet,
            fault,
            fmt_ratio(r.cost_ratio),
            fmt_ratio(r.violation_ratio),
            ttft,
            mttr,
            e2e
        );
    }
    let out = PathBuf::from(args.get("out"));
    let hash = json::write_file_fingerprinted(&out, &report.to_json())?;
    println!("wrote {} (fnv1a64 {hash:016x})", out.display());
    Ok(())
}

/// Single-cell special case of the matrix path: one platform, one preset,
/// one seed, full per-function report.
fn simulate(argv: Vec<String>) -> anyhow::Result<()> {
    let registry = PlatformRegistry::default();
    let fleet_registry = FleetRegistry::default();
    let args = Cli::new("has-gpu simulate", "single-cell cluster simulation")
        .opt_dyn(
            "platform",
            "has-gpu",
            format!("one platform name; registered: {}", registry.names().join(", ")),
        )
        .opt_dyn(
            "fleet",
            "uniform-v100",
            format!("one fleet name; registered: {}", fleet_registry.names().join(", ")),
        )
        .opt_dyn(
            "preset",
            "standard",
            format!("one workload preset name ({})", Preset::name_menu()),
        )
        .opt_dyn(
            "fault",
            "no-faults",
            format!("one fault preset name ({})", has_gpu::sim::fault_name_menu()),
        )
        .opt("seconds", "300", "trace length (virtual seconds)")
        .opt("gpus", "10", "cluster size")
        .opt("rps", "150", "mean request rate per function")
        .opt("seed", "11", "workload + simulation seed")
        .flag("json", "emit the full RunReport as JSON")
        .parse_from_or_exit(argv);
    let platforms = parse_platforms(&[args.get("platform").to_string()], &registry)?;
    anyhow::ensure!(
        platforms.len() == 1,
        "simulate runs one platform; '{}' expands to {}",
        args.get("platform"),
        platforms.join(", ")
    );
    let presets = parse_presets(&[args.get("preset").to_string()])?;
    anyhow::ensure!(
        presets.len() == 1,
        "simulate runs one preset; '{}' expands to several",
        args.get("preset")
    );
    let fleets = parse_fleets(&[args.get("fleet").to_string()], &fleet_registry)?;
    let faults = parse_faults(&[args.get("fault").to_string()])?;
    let matrix = ScenarioMatrix {
        platforms,
        registry,
        presets,
        seeds: vec![args.get_u64("seed")],
        seconds: args.get_usize("seconds"),
        gpus: args.get_usize("gpus"),
        rps: args.get_f64("rps"),
        fleets,
        fleet_registry,
        faults,
    };
    let cell = matrix.cells()[0].clone();
    let (report, _cell_result) = matrix.run_cell(&cell);
    if args.has_flag("json") {
        println!("{}", report.to_json().to_string_pretty());
    } else {
        println!(
            "platform={} duration={:.0}s served={} dropped={} cost=${:.4} v-ups={} h-ups={} h-downs={}",
            report.platform,
            report.duration,
            report.total_served(),
            report.total_dropped(),
            report.costs.total_cost(),
            report.vertical_ups,
            report.horizontal_ups,
            report.horizontal_downs
        );
        if report.faults_active {
            let mttr = match report.mttr_mean() {
                Some(v) => format!("{v:.1}s"),
                None => "-".to_string(),
            };
            println!(
                "  faults: gpu-failures={} pods-lost={} failed-reqs={} availability={:.4} mttr={mttr}",
                report.gpu_failures,
                report.pods_lost,
                report.total_failed(),
                report.availability()
            );
        }
        for (f, m) in &report.functions {
            let mut s = m.latency_summary();
            if s.is_empty() {
                continue;
            }
            println!(
                "  {f:<16} served={:>7} p50={:>7.1}ms p99={:>8.1}ms cost/1k=${:.4}",
                m.served(),
                s.p50() * 1e3,
                s.p99() * 1e3,
                report.costs.cost_per_1k(f, m.served())
            );
        }
    }
    Ok(())
}

fn predict(argv: Vec<String>) -> anyhow::Result<()> {
    let args = Cli::new("has-gpu predict", "RaPP latency prediction (requires artifacts)")
        .opt("model", "resnet50", "zoo model name")
        .opt("batch", "8", "batch size")
        .opt("sm", "0.5", "SM partition fraction (0..1]")
        .opt("quota", "0.6", "time quota fraction (0..1]")
        .parse_from_or_exit(argv);
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    let model = args.get("model");
    let batch = args.get_usize("batch") as u32;
    let sm = args.get_f64("sm");
    let quota = args.get_f64("quota");
    let Some(zoo) = ZooModel::from_name(model) else {
        anyhow::bail!("unknown model '{model}'; available: {:?}", zoo_names());
    };
    let g = zoo_graph(zoo);
    let pm = PerfModel::default();
    let truth = pm.latency(&g, batch, sm, quota);
    println!("ground truth: {:.3} ms", truth * 1e3);
    if dir.join("rapp_weights.json").exists() {
        let rapp = RappPredictor::load(&dir.join("rapp_weights.json"), pm.clone())?;
        let q = PredictQuery::new(&g, batch, sm, quota);
        let p = rapp.latency(q);
        println!(
            "RaPP:         {:.3} ms ({:+.1}%)  capacity {:.1} req/s",
            p * 1e3,
            (p / truth - 1.0) * 100.0,
            rapp.capacity(q)
        );
    } else {
        println!("(no artifacts — run `make artifacts` for RaPP predictions)");
    }
    Ok(())
}

fn trace_gen(argv: Vec<String>) -> anyhow::Result<()> {
    let args = Cli::new("has-gpu trace-gen", "synthesise an Azure-style workload trace")
        .opt_dyn(
            "preset",
            "standard",
            format!("one workload preset name ({})", Preset::name_menu()),
        )
        .opt("seconds", "300", "trace length in seconds")
        .opt("rps", "150", "mean request rate per function")
        .opt("seed", "11", "trace seed")
        .parse_from_or_exit(argv);
    let presets = parse_presets(&[args.get("preset").to_string()])?;
    anyhow::ensure!(
        presets.len() == 1,
        "trace-gen takes one preset; '{}' expands to several",
        args.get("preset")
    );
    let fns = experiment_functions();
    let names: Vec<&str> = fns.iter().map(|f| f.name.as_str()).collect();
    let tg = TraceGen::preset(
        presets[0],
        args.get_u64("seed"),
        args.get_usize("seconds"),
        args.get_f64("rps"),
    );
    println!("{}", tg.generate(&names).to_json().to_string_pretty());
    Ok(())
}
