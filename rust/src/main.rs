//! `has-gpu` — the leader binary: simulate (cluster scale), predict (RaPP
//! CLI), trace-gen, and zoo inventory subcommands.

use has_gpu::autoscaler::{HybridAutoscaler, HybridConfig, ScalingPolicy};
use has_gpu::baselines::{FastGSharePolicy, KServePolicy};
use has_gpu::cluster::FunctionSpec;
use has_gpu::model::zoo::{zoo_graph, zoo_names, ZooModel};
use has_gpu::perf::PerfModel;
use has_gpu::rapp::{LatencyPredictor, OraclePredictor, RappPredictor};
use has_gpu::sim::{run_sim, SimConfig};
use has_gpu::workload::{Preset, TraceGen};
use std::path::PathBuf;

const USAGE: &str = "has-gpu — Hybrid Auto-scaling Serverless GPU inference (reproduction)

USAGE: has-gpu <COMMAND> [options]

COMMANDS:
  simulate   run a platform-vs-platform cluster simulation and print the report
             [--platform has-gpu|kserve|fast-gshare] [--preset standard|stress]
             [--seconds N] [--gpus N] [--rps R] [--seed S] [--json]
  predict    RaPP latency prediction (requires artifacts)
             [--model NAME] [--batch B] [--sm F] [--quota F]
  trace-gen  synthesise an Azure-style workload trace as JSON to stdout
             [--preset standard|stress] [--seconds N] [--rps R] [--seed S]
  zoo        list benchmark models with FLOPs/params/baseline latency
  help       this message
";

fn main() -> anyhow::Result<()> {
    let mut argv: Vec<String> = std::env::args().skip(1).collect();
    let cmd = if argv.is_empty() { "help".to_string() } else { argv.remove(0) };
    match cmd.as_str() {
        "simulate" => simulate(argv),
        "predict" => predict(argv),
        "trace-gen" => trace_gen(argv),
        "zoo" => {
            let pm = PerfModel::default();
            println!("{:<16} {:>10} {:>10} {:>14}", "model", "GFLOPs", "Mparams", "baseline(ms)");
            for m in has_gpu::model::zoo::ALL_ZOO {
                let g = zoo_graph(m);
                println!(
                    "{:<16} {:>10.2} {:>10.2} {:>14.2}",
                    g.name,
                    g.total_flops(1) / 1e9,
                    g.total_params() / 1e6,
                    pm.latency(&g, 1, 1.0, 1.0) * 1e3
                );
            }
            Ok(())
        }
        _ => {
            println!("{USAGE}");
            Ok(())
        }
    }
}

fn opt(argv: &[String], name: &str, default: &str) -> String {
    argv.iter()
        .position(|a| a == &format!("--{name}"))
        .and_then(|i| argv.get(i + 1))
        .cloned()
        .unwrap_or_else(|| default.to_string())
}

fn experiment_functions() -> Vec<FunctionSpec> {
    let perf = PerfModel::default();
    has_gpu::model::zoo::ALL_ZOO
        .iter()
        .filter(|m| !matches!(m, ZooModel::ResNet152)) // the Fig.4 subject stays out
        .map(|&m| {
            let graph = zoo_graph(m);
            let baseline = perf.latency(&graph, 1, 1.0, 1.0);
            let slo = baseline * 3.0;
            let batch = [16u32, 8, 4, 2, 1]
                .into_iter()
                .find(|&b| perf.latency(&graph, b, 1.0, 1.0) <= slo * 0.5)
                .unwrap_or(1);
            FunctionSpec { name: graph.name.clone(), slo, batch, graph, artifact: None }
        })
        .collect()
}

fn simulate(argv: Vec<String>) -> anyhow::Result<()> {
    let platform = opt(&argv, "platform", "has-gpu");
    let preset = match opt(&argv, "preset", "standard").as_str() {
        "stress" => Preset::Stress,
        _ => Preset::Standard,
    };
    let seconds: usize = opt(&argv, "seconds", "300").parse()?;
    let gpus: usize = opt(&argv, "gpus", "10").parse()?;
    let rps: f64 = opt(&argv, "rps", "150").parse()?;
    let seed: u64 = opt(&argv, "seed", "11").parse()?;

    let fns = experiment_functions();
    let names: Vec<&str> = fns.iter().map(|f| f.name.as_str()).collect();
    let trace = TraceGen::preset(preset, seed, seconds, rps).generate(&names);
    let perf = PerfModel::default();
    let pred = OraclePredictor::default();

    let (mut policy, whole): (Box<dyn ScalingPolicy>, bool) = match platform.as_str() {
        "kserve" => (Box::new(KServePolicy::default()), true),
        "fast-gshare" => (Box::new(FastGSharePolicy::default()), false),
        _ => (Box::new(HybridAutoscaler::new(HybridConfig::default())), false),
    };
    let report = run_sim(
        policy.as_mut(),
        &fns,
        &trace,
        &pred,
        &perf,
        &SimConfig { n_gpus: gpus, seed, bill_whole_gpu: whole, ..SimConfig::default() },
    );
    if argv.iter().any(|a| a == "--json") {
        println!("{}", report.to_json().to_string_pretty());
    } else {
        println!(
            "platform={} duration={:.0}s served={} dropped={} cost=${:.4} v-ups={} h-ups={} h-downs={}",
            report.platform,
            report.duration,
            report.total_served(),
            report.total_dropped(),
            report.costs.total_cost(),
            report.vertical_ups,
            report.horizontal_ups,
            report.horizontal_downs
        );
        for (f, m) in &report.functions {
            let mut s = m.latency_summary();
            if s.is_empty() {
                continue;
            }
            println!(
                "  {f:<16} served={:>7} p50={:>7.1}ms p99={:>8.1}ms cost/1k=${:.4}",
                m.served(),
                s.p50() * 1e3,
                s.p99() * 1e3,
                report.costs.cost_per_1k(f, m.served())
            );
        }
    }
    Ok(())
}

fn predict(argv: Vec<String>) -> anyhow::Result<()> {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    let model = opt(&argv, "model", "resnet50");
    let batch: u32 = opt(&argv, "batch", "8").parse()?;
    let sm: f64 = opt(&argv, "sm", "0.5").parse()?;
    let quota: f64 = opt(&argv, "quota", "0.6").parse()?;
    let Some(zoo) = ZooModel::from_name(&model) else {
        anyhow::bail!("unknown model '{model}'; available: {:?}", zoo_names());
    };
    let g = zoo_graph(zoo);
    let pm = PerfModel::default();
    let truth = pm.latency(&g, batch, sm, quota);
    println!("ground truth: {:.3} ms", truth * 1e3);
    if dir.join("rapp_weights.json").exists() {
        let rapp = RappPredictor::load(&dir.join("rapp_weights.json"), pm.clone())?;
        let p = rapp.latency(&g, batch, sm, quota);
        println!(
            "RaPP:         {:.3} ms ({:+.1}%)  capacity {:.1} req/s",
            p * 1e3,
            (p / truth - 1.0) * 100.0,
            rapp.capacity(&g, batch, sm, quota)
        );
    } else {
        println!("(no artifacts — run `make artifacts` for RaPP predictions)");
    }
    Ok(())
}

fn trace_gen(argv: Vec<String>) -> anyhow::Result<()> {
    let preset = match opt(&argv, "preset", "standard").as_str() {
        "stress" => Preset::Stress,
        _ => Preset::Standard,
    };
    let seconds: usize = opt(&argv, "seconds", "300").parse()?;
    let rps: f64 = opt(&argv, "rps", "150").parse()?;
    let seed: u64 = opt(&argv, "seed", "11").parse()?;
    let fns = experiment_functions();
    let names: Vec<&str> = fns.iter().map(|f| f.name.as_str()).collect();
    let trace = TraceGen::preset(preset, seed, seconds, rps).generate(&names);
    println!("{}", trace.to_json().to_string_pretty());
    Ok(())
}
