//! Operator-graph IR and the MLPerf-style model zoo.
//!
//! The paper extracts model features from TVM Relay's IRModule; our equivalent
//! is [`OpGraph`] — a DAG of coarse (stage-level) operators with static
//! features (FLOPs, bytes moved, parameters, conv shape descriptors). The
//! graph drives three consumers:
//!
//! 1. the [`crate::perf::PerfModel`] ground-truth latency surface,
//! 2. RaPP feature extraction ([`crate::rapp`]),
//! 3. GPU-memory accounting in the cluster allocator.

pub mod builders;
pub mod zoo;

pub use builders::GraphBuilder;
pub use zoo::{zoo_graph, zoo_names, ZooModel};

/// Operator kind. The discriminant order is the one-hot feature layout shared
/// with the Python training pipeline — do not reorder (contract: FEATURE_SPEC
/// in `python/compile/features.py`).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum OpKind {
    Conv2d,
    Dense,
    MatMul,
    BatchNorm,
    LayerNorm,
    Relu,
    Gelu,
    Softmax,
    Pool,
    Add,
    Embed,
    Attention,
}

pub const NUM_OP_KINDS: usize = 12;

impl OpKind {
    pub fn index(self) -> usize {
        self as usize
    }

    pub fn name(self) -> &'static str {
        match self {
            OpKind::Conv2d => "conv2d",
            OpKind::Dense => "dense",
            OpKind::MatMul => "matmul",
            OpKind::BatchNorm => "batch_norm",
            OpKind::LayerNorm => "layer_norm",
            OpKind::Relu => "relu",
            OpKind::Gelu => "gelu",
            OpKind::Softmax => "softmax",
            OpKind::Pool => "pool",
            OpKind::Add => "add",
            OpKind::Embed => "embed",
            OpKind::Attention => "attention",
        }
    }

    pub fn from_name(s: &str) -> Option<Self> {
        Some(match s {
            "conv2d" => OpKind::Conv2d,
            "dense" => OpKind::Dense,
            "matmul" => OpKind::MatMul,
            "batch_norm" => OpKind::BatchNorm,
            "layer_norm" => OpKind::LayerNorm,
            "relu" => OpKind::Relu,
            "gelu" => OpKind::Gelu,
            "softmax" => OpKind::Softmax,
            "pool" => OpKind::Pool,
            "add" => OpKind::Add,
            "embed" => OpKind::Embed,
            "attention" => OpKind::Attention,
            _ => return None,
        })
    }

    /// Is this op compute-dominated (dense linear algebra) rather than
    /// bandwidth-dominated? Compute ops achieve higher peak-FLOP efficiency.
    pub fn compute_bound(self) -> bool {
        matches!(
            self,
            OpKind::Conv2d | OpKind::Dense | OpKind::MatMul | OpKind::Attention
        )
    }
}

/// One operator node. `flops` / `bytes` are **per input item** (batch = 1);
/// latency models scale them linearly with batch. `params` is the weight
/// count (bytes = 4·params for f32). `kernels` is the number of device
/// kernel launches this (possibly stage-aggregated) node stands for — it
/// drives launch-overhead accounting, the occupancy model, and the
/// granularity of time-quota enforcement (see `perf`).
#[derive(Clone, Debug)]
pub struct OpNode {
    pub kind: OpKind,
    pub flops: f64,
    pub bytes: f64,
    pub params: f64,
    /// underlying kernel launches aggregated into this node (≥ 1)
    pub kernels: u32,
    /// conv kernel size (0 for non-conv)
    pub kernel: u32,
    /// conv/pool stride (0 for non-conv)
    pub stride: u32,
    pub cin: u32,
    pub cout: u32,
    /// output spatial edge (feature-map side, sequence length, …)
    pub spatial: u32,
}

impl OpNode {
    pub fn simple(kind: OpKind, flops: f64, bytes: f64, params: f64) -> Self {
        OpNode {
            kind,
            flops,
            bytes,
            params,
            kernels: 1,
            kernel: 0,
            stride: 0,
            cin: 0,
            cout: 0,
            spatial: 0,
        }
    }
}

/// A model's operator DAG.
#[derive(Clone, Debug)]
pub struct OpGraph {
    pub name: String,
    pub family: String,
    pub nodes: Vec<OpNode>,
    /// Directed edges (src, dst); indices into `nodes`. Always acyclic and
    /// src < dst by construction ([`GraphBuilder`] enforces it).
    pub edges: Vec<(usize, usize)>,
}

impl OpGraph {
    /// Total FLOPs for a given batch size.
    pub fn total_flops(&self, batch: u32) -> f64 {
        self.nodes.iter().map(|n| n.flops).sum::<f64>() * batch as f64
    }

    /// Total bytes moved for a given batch size (weights counted once).
    pub fn total_bytes(&self, batch: u32) -> f64 {
        let act: f64 = self.nodes.iter().map(|n| n.bytes).sum();
        act * batch as f64 + 4.0 * self.total_params()
    }

    pub fn total_params(&self) -> f64 {
        self.nodes.iter().map(|n| n.params).sum()
    }

    /// Device-memory footprint estimate in bytes: weights + working
    /// activations (+20% allocator slack) — used for the 16 GB capacity check.
    pub fn memory_bytes(&self, batch: u32) -> f64 {
        let weights = 4.0 * self.total_params();
        let peak_act = self
            .nodes
            .iter()
            .map(|n| n.bytes)
            .fold(0.0f64, f64::max)
            * batch as f64
            * 2.0; // in + out live simultaneously
        (weights + peak_act) * 1.2 + 256e6 // CUDA context overhead
    }

    pub fn count_kind(&self, kind: OpKind) -> usize {
        self.nodes.iter().filter(|n| n.kind == kind).count()
    }

    /// Length of the longest path (graph depth) — a global RaPP feature.
    pub fn depth(&self) -> usize {
        let n = self.nodes.len();
        let mut depth = vec![1usize; n];
        // Edges satisfy src < dst, so one forward pass suffices.
        for &(s, d) in &self.edges {
            depth[d] = depth[d].max(depth[s] + 1);
        }
        depth.into_iter().max().unwrap_or(0)
    }

    /// Verify DAG invariants (used by tests and the JSON loader).
    pub fn validate(&self) -> anyhow::Result<()> {
        for &(s, d) in &self.edges {
            anyhow::ensure!(
                s < d && d < self.nodes.len(),
                "bad edge ({s},{d}) in '{}' with {} nodes",
                self.name,
                self.nodes.len()
            );
        }
        anyhow::ensure!(!self.nodes.is_empty(), "empty graph '{}'", self.name);
        for (i, node) in self.nodes.iter().enumerate() {
            anyhow::ensure!(
                node.flops >= 0.0 && node.bytes > 0.0,
                "node {i} of '{}' has non-physical flops/bytes",
                self.name
            );
        }
        Ok(())
    }

    // ---- JSON interchange (contract with python/compile/opgraph.py) -------

    pub fn to_json(&self) -> crate::util::json::Json {
        use crate::util::json::Json;
        Json::obj(vec![
            ("name", Json::Str(self.name.clone())),
            ("family", Json::Str(self.family.clone())),
            (
                "nodes",
                Json::Arr(
                    self.nodes
                        .iter()
                        .map(|n| {
                            Json::obj(vec![
                                ("kind", Json::Str(n.kind.name().into())),
                                ("flops", Json::Num(n.flops)),
                                ("bytes", Json::Num(n.bytes)),
                                ("params", Json::Num(n.params)),
                                ("kernels", Json::Num(n.kernels as f64)),
                                ("kernel", Json::Num(n.kernel as f64)),
                                ("stride", Json::Num(n.stride as f64)),
                                ("cin", Json::Num(n.cin as f64)),
                                ("cout", Json::Num(n.cout as f64)),
                                ("spatial", Json::Num(n.spatial as f64)),
                            ])
                        })
                        .collect(),
                ),
            ),
            (
                "edges",
                Json::Arr(
                    self.edges
                        .iter()
                        .map(|&(s, d)| Json::num_arr(&[s as f64, d as f64]))
                        .collect(),
                ),
            ),
        ])
    }

    pub fn from_json(v: &crate::util::json::Json) -> anyhow::Result<Self> {
        let nodes = v
            .get("nodes")?
            .as_arr()?
            .iter()
            .map(|n| -> anyhow::Result<OpNode> {
                let kind_name = n.get("kind")?.as_str()?;
                Ok(OpNode {
                    kind: OpKind::from_name(kind_name)
                        .ok_or_else(|| anyhow::anyhow!("unknown op kind '{kind_name}'"))?,
                    flops: n.get("flops")?.as_f64()?,
                    bytes: n.get("bytes")?.as_f64()?,
                    params: n.get("params")?.as_f64()?,
                    kernels: n.get("kernels")?.as_f64()? as u32,
                    kernel: n.get("kernel")?.as_f64()? as u32,
                    stride: n.get("stride")?.as_f64()? as u32,
                    cin: n.get("cin")?.as_f64()? as u32,
                    cout: n.get("cout")?.as_f64()? as u32,
                    spatial: n.get("spatial")?.as_f64()? as u32,
                })
            })
            .collect::<anyhow::Result<Vec<_>>>()?;
        let edges = v
            .get("edges")?
            .as_arr()?
            .iter()
            .map(|e| -> anyhow::Result<(usize, usize)> {
                let pair = e.as_f64_vec()?;
                anyhow::ensure!(pair.len() == 2, "edge must be a pair");
                Ok((pair[0] as usize, pair[1] as usize))
            })
            .collect::<anyhow::Result<Vec<_>>>()?;
        let g = OpGraph {
            name: v.get("name")?.as_str()?.to_string(),
            family: v.get("family")?.as_str()?.to_string(),
            nodes,
            edges,
        };
        g.validate()?;
        Ok(g)
    }

    /// Symmetrised in-neighbour adjacency (self-loops included) in CSR form —
    /// the structure every GAT pass walks. Built once per model (the zoo
    /// memoises it; [`crate::rapp::features::FeaturePlan`] carries it) instead
    /// of re-allocating nested `Vec<Vec<usize>>` lists per forward.
    pub fn adjacency(&self) -> Adjacency {
        Adjacency::from_edges(self.nodes.len(), &self.edges)
    }
}

/// CSR in-neighbour lists over a symmetrised edge set with self-loops.
///
/// Per-node neighbour **order is part of the numeric contract**: attention
/// weights are accumulated in list order, and f32 summation order must match
/// the historical nested-list construction exactly (self-loop first, then
/// partners appended in edge-declaration order, `dst`-side before `src`-side
/// for each directed edge).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Adjacency {
    n: usize,
    offsets: Vec<u32>,
    nbrs: Vec<u32>,
}

impl Adjacency {
    pub fn from_edges(n: usize, edges: &[(usize, usize)]) -> Self {
        // Pass 1: degree = 1 (self-loop) + symmetrised incidences.
        let mut deg = vec![1u32; n];
        for &(s, d) in edges {
            deg[d] += 1;
            deg[s] += 1;
        }
        let mut offsets = Vec::with_capacity(n + 1);
        let mut acc = 0u32;
        offsets.push(0);
        for &d in &deg {
            acc += d;
            offsets.push(acc);
        }
        // Pass 2: fill preserving the legacy append order.
        let mut nbrs = vec![0u32; acc as usize];
        let mut cursor: Vec<u32> = offsets[..n].to_vec();
        for (i, c) in cursor.iter_mut().enumerate() {
            nbrs[*c as usize] = i as u32;
            *c += 1;
        }
        for &(s, d) in edges {
            nbrs[cursor[d] as usize] = s as u32;
            cursor[d] += 1;
            nbrs[cursor[s] as usize] = d as u32;
            cursor[s] += 1;
        }
        Adjacency { n, offsets, nbrs }
    }

    pub fn n(&self) -> usize {
        self.n
    }

    /// In-neighbours of node `i` (self-loop first).
    pub fn neighbours(&self, i: usize) -> &[u32] {
        &self.nbrs[self.offsets[i] as usize..self.offsets[i + 1] as usize]
    }

    /// Largest in-degree — sizes the attention-weight scratch buffer.
    pub fn max_degree(&self) -> usize {
        (0..self.n)
            .map(|i| (self.offsets[i + 1] - self.offsets[i]) as usize)
            .max()
            .unwrap_or(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kind_name_roundtrip() {
        for i in 0..NUM_OP_KINDS {
            let kind = [
                OpKind::Conv2d,
                OpKind::Dense,
                OpKind::MatMul,
                OpKind::BatchNorm,
                OpKind::LayerNorm,
                OpKind::Relu,
                OpKind::Gelu,
                OpKind::Softmax,
                OpKind::Pool,
                OpKind::Add,
                OpKind::Embed,
                OpKind::Attention,
            ][i];
            assert_eq!(kind.index(), i);
            assert_eq!(OpKind::from_name(kind.name()), Some(kind));
        }
        assert_eq!(OpKind::from_name("bogus"), None);
    }

    #[test]
    fn json_roundtrip() {
        let g = zoo::zoo_graph(zoo::ZooModel::ResNet50);
        let j = g.to_json();
        let back = OpGraph::from_json(&j).unwrap();
        assert_eq!(back.nodes.len(), g.nodes.len());
        assert_eq!(back.edges, g.edges);
        assert!((back.total_flops(4) - g.total_flops(4)).abs() < 1e-6);
        assert_eq!(back.depth(), g.depth());
    }

    #[test]
    fn flops_scale_linearly_with_batch() {
        let g = zoo::zoo_graph(zoo::ZooModel::MobileNetV2);
        assert!((g.total_flops(8) - 8.0 * g.total_flops(1)).abs() < 1.0);
    }

    #[test]
    fn memory_grows_with_batch() {
        let g = zoo::zoo_graph(zoo::ZooModel::ResNet152);
        assert!(g.memory_bytes(32) > g.memory_bytes(1));
        // resnet152 fits a 16GB V100 at batch 32 (it does in practice).
        assert!(g.memory_bytes(32) < 16e9);
    }

    #[test]
    fn adjacency_matches_nested_list_construction() {
        // The CSR fill must reproduce the legacy nested-list neighbour order
        // exactly (self-loop first, then symmetrised appends in edge order) —
        // attention sums in list order, so order is a numeric contract.
        let reference = |n: usize, edges: &[(usize, usize)]| -> Vec<Vec<usize>> {
            let mut nbrs: Vec<Vec<usize>> = (0..n).map(|i| vec![i]).collect();
            for &(s, d) in edges {
                nbrs[d].push(s);
                nbrs[s].push(d);
            }
            nbrs
        };
        for g in [
            zoo::zoo_graph(zoo::ZooModel::ResNet50),
            zoo::zoo_graph(zoo::ZooModel::BertTiny),
            zoo::zoo_graph(zoo::ZooModel::DlrmSmall),
        ] {
            let adj = g.adjacency();
            let want = reference(g.nodes.len(), &g.edges);
            assert_eq!(adj.n(), g.nodes.len());
            for (i, row) in want.iter().enumerate() {
                let got: Vec<usize> = adj.neighbours(i).iter().map(|&x| x as usize).collect();
                assert_eq!(&got, row, "node {i} of {}", g.name);
            }
            assert_eq!(adj.max_degree(), want.iter().map(|r| r.len()).max().unwrap());
        }
    }

    #[test]
    fn adjacency_isolated_nodes_have_self_loops() {
        let adj = Adjacency::from_edges(3, &[(0, 2)]);
        assert_eq!(adj.neighbours(0), &[0, 2]);
        assert_eq!(adj.neighbours(1), &[1]);
        assert_eq!(adj.neighbours(2), &[2, 0]);
    }
}
