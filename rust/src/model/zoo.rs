//! The MLPerf-style benchmark model zoo (paper §4: "deep learning
//! applications from the standard MLPerf benchmark").
//!
//! Graphs are stage-level approximations of the published architectures,
//! tuned so total FLOPs / parameter counts land on the reference numbers
//! (e.g. ResNet-50 ≈ 4.1 GFLOPs / 25.6 M params @ 224²). Exact layer-for-layer
//! fidelity is unnecessary: the latency model and RaPP consume aggregate
//! FLOPs/bytes per stage, which is also the granularity TVM's Relay profiler
//! reports after fusion.

use super::builders::GraphBuilder;
use super::{OpGraph, OpKind};

/// The serverless-function benchmark set used across all experiments.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum ZooModel {
    ResNet50,
    ResNet152,
    MobileNetV2,
    Vgg16,
    ConvNextTiny,
    BertTiny,
    DlrmSmall,
}

pub const ALL_ZOO: [ZooModel; 7] = [
    ZooModel::ResNet50,
    ZooModel::ResNet152,
    ZooModel::MobileNetV2,
    ZooModel::Vgg16,
    ZooModel::ConvNextTiny,
    ZooModel::BertTiny,
    ZooModel::DlrmSmall,
];

impl ZooModel {
    pub fn name(self) -> &'static str {
        match self {
            ZooModel::ResNet50 => "resnet50",
            ZooModel::ResNet152 => "resnet152",
            ZooModel::MobileNetV2 => "mobilenet_v2",
            ZooModel::Vgg16 => "vgg16",
            ZooModel::ConvNextTiny => "convnext_tiny",
            ZooModel::BertTiny => "bert_tiny",
            ZooModel::DlrmSmall => "dlrm_small",
        }
    }

    pub fn from_name(s: &str) -> Option<Self> {
        ALL_ZOO.iter().copied().find(|m| m.name() == s)
    }
}

pub fn zoo_names() -> Vec<&'static str> {
    ALL_ZOO.iter().map(|m| m.name()).collect()
}

/// Build the operator graph for a zoo model.
pub fn zoo_graph(model: ZooModel) -> OpGraph {
    match model {
        ZooModel::ResNet50 => resnet(50),
        ZooModel::ResNet152 => resnet(152),
        ZooModel::MobileNetV2 => mobilenet_v2(),
        ZooModel::Vgg16 => vgg16(),
        ZooModel::ConvNextTiny => convnext_tiny(),
        ZooModel::BertTiny => bert_tiny(),
        ZooModel::DlrmSmall => dlrm_small(),
    }
}

/// The memoised GAT adjacency of a zoo model — computed once per model per
/// process and shared by reference count. Graph topology is deterministic
/// per [`ZooModel`], so [`crate::rapp::features::FeaturePlan`] (and any
/// other hot path) clones this `Arc` instead of re-deriving neighbour lists
/// per (graph, batch) plan.
pub fn zoo_adjacency(model: ZooModel) -> std::sync::Arc<crate::model::Adjacency> {
    use std::sync::{Arc, OnceLock};
    static CACHE: OnceLock<Vec<Arc<crate::model::Adjacency>>> = OnceLock::new();
    let all = CACHE.get_or_init(|| {
        ALL_ZOO
            .iter()
            .map(|&m| Arc::new(zoo_graph(m).adjacency()))
            .collect()
    });
    let idx = ALL_ZOO.iter().position(|&m| m == model).expect("zoo model");
    Arc::clone(&all[idx])
}

/// ResNet-d for d ∈ {50, 152}: bottleneck stages at 224² input.
fn resnet(depth: u32) -> OpGraph {
    // blocks per stage for the two depths we serve.
    let blocks: [u32; 4] = match depth {
        50 => [3, 4, 6, 3],
        152 => [3, 8, 36, 3],
        _ => panic!("unsupported resnet depth {depth}"),
    };
    let mut b = GraphBuilder::new(&format!("resnet{depth}"), "resnet");
    // Stem: 7x7/2 conv 3->64 @112, then 3x3/2 maxpool @56.
    let stem = b.conv(&[], 7, 3, 64, 112, 2, 1);
    let bn = b.elemwise(&[stem], OpKind::BatchNorm, 64.0 * 112.0 * 112.0, 128.0);
    let relu = b.elemwise(&[bn], OpKind::Relu, 64.0 * 112.0 * 112.0, 0.0);
    let mut prev = b.pool(&[relu], 64, 56, 2);

    // Bottleneck stage: width w, output side s, n blocks. Each block is
    // 1x1(cin->w) + 3x3(w->w) + 1x1(w->4w); we aggregate a whole stage's
    // convs into one Conv2d node + BN + ReLU + residual Add per stage.
    let stage_cfg: [(u32, u32); 4] = [(64, 56), (128, 28), (256, 14), (512, 7)];
    let mut cin = 64u32;
    for (stage, &(w, side)) in stage_cfg.iter().enumerate() {
        let n = blocks[stage];
        let cout = 4 * w;
        // Aggregate FLOPs of all convs in the stage into a representative
        // 3x3 conv node (keeps kernel/channel features meaningful).
        let per_block_flops = conv_flops(1, cin, w, side)
            + conv_flops(3, w, w, side)
            + conv_flops(1, w, cout, side)
            // later blocks take cout as input
            + (n - 1) as f64
                * (conv_flops(1, cout, w, side)
                    + conv_flops(3, w, w, side)
                    + conv_flops(1, w, cout, side));
        let conv = b.conv(&[prev], 3, w, cout, side, 1, 1);
        // Overwrite the derived numbers with the stage aggregate.
        b.set_flops(conv, per_block_flops);
        b.set_params(
            conv,
            (cin as f64 * w as f64 + 9.0 * (w as f64).powi(2) + w as f64 * cout as f64)
                + (n - 1) as f64
                    * (cout as f64 * w as f64
                        + 9.0 * (w as f64).powi(2)
                        + w as f64 * cout as f64),
        );
        b.set_kernels(conv, 3 * n); // 3 convs per bottleneck block
        let elems = cout as f64 * (side as f64).powi(2);
        let bn = b.elemwise(&[conv], OpKind::BatchNorm, elems * n as f64, 2.0 * cout as f64);
        b.set_kernels(bn, n);
        let relu = b.elemwise(&[bn], OpKind::Relu, elems * n as f64, 0.0);
        b.set_kernels(relu, n);
        let add = b.elemwise(&[prev, relu], OpKind::Add, elems * n as f64, 0.0);
        b.set_kernels(add, n);
        prev = add;
        cin = cout;
    }
    let gap = b.pool(&[prev], 2048, 1, 7);
    b.dense(&[gap], 2048, 1000);
    b.build()
}

fn conv_flops(k: u32, cin: u32, cout: u32, side: u32) -> f64 {
    2.0 * (k as f64).powi(2) * cin as f64 * cout as f64 * (side as f64).powi(2)
}

/// MobileNetV2 at 224²: inverted-residual stages (depthwise convs make it
/// strongly bandwidth-bound — the zoo's "small fast model").
fn mobilenet_v2() -> OpGraph {
    let mut b = GraphBuilder::new("mobilenet_v2", "mobilenet");
    let stem = b.conv(&[], 3, 3, 32, 112, 2, 1);
    let mut prev = b.elemwise(&[stem], OpKind::Relu, 32.0 * 112.0 * 112.0, 0.0);
    // (expansion-adjusted width, out side, blocks)
    let stages: [(u32, u32, u32); 6] =
        [(16, 112, 1), (24, 56, 2), (32, 28, 3), (96, 14, 4), (160, 7, 3), (320, 7, 1)];
    let mut cin = 32u32;
    for &(c, side, n) in &stages {
        // Inverted residual ≈ 1x1 expand (6x) + 3x3 depthwise + 1x1 project.
        let hidden = 6 * cin;
        let flops = n as f64
            * (conv_flops(1, cin, hidden, side)
                + 2.0 * 9.0 * hidden as f64 * (side as f64).powi(2) // depthwise
                + conv_flops(1, hidden, c, side));
        let conv = b.conv(&[prev], 3, cin, c, side, 1, n);
        b.set_flops(conv, flops);
        b.set_params(
            conv,
            n as f64
                * (cin as f64 * hidden as f64 + 9.0 * hidden as f64 + hidden as f64 * c as f64),
        );
        b.set_kernels(conv, 3 * n); // expand + depthwise + project
        let elems = c as f64 * (side as f64).powi(2) * n as f64;
        let bn = b.elemwise(&[conv], OpKind::BatchNorm, elems, 2.0 * c as f64);
        b.set_kernels(bn, n);
        prev = b.elemwise(&[bn], OpKind::Relu, elems, 0.0);
        b.set_kernels(prev, n);
        cin = c;
    }
    let head = b.conv(&[prev], 1, 320, 1280, 7, 1, 1);
    let gap = b.pool(&[head], 1280, 1, 7);
    b.dense(&[gap], 1280, 1000);
    b.build()
}

/// VGG-16 at 224²: the zoo's heavyweight compute-bound CNN (15.5 GFLOPs,
/// 138 M params).
fn vgg16() -> OpGraph {
    let mut b = GraphBuilder::new("vgg16", "vgg");
    let cfg: [(u32, u32, u32); 5] =
        [(64, 224, 2), (128, 112, 2), (256, 56, 3), (512, 28, 3), (512, 14, 3)];
    let mut prev: Option<usize> = None;
    let mut cin = 3u32;
    for &(c, side, n) in &cfg {
        let deps: Vec<usize> = prev.into_iter().collect();
        let flops = conv_flops(3, cin, c, side) + (n - 1) as f64 * conv_flops(3, c, c, side);
        let conv = b.conv(&deps, 3, cin, c, side, 1, n);
        b.set_flops(conv, flops);
        b.set_params(
            conv,
            9.0 * (cin as f64 * c as f64 + (n - 1) as f64 * (c as f64).powi(2)),
        );
        let relu = b.elemwise(&[conv], OpKind::Relu, c as f64 * (side as f64).powi(2), 0.0);
        prev = Some(b.pool(&[relu], c, side / 2, 2));
        cin = c;
    }
    let f1 = b.dense(&[prev.unwrap()], 512 * 7 * 7, 4096);
    let r1 = b.elemwise(&[f1], OpKind::Relu, 4096.0, 0.0);
    let f2 = b.dense(&[r1], 4096, 4096);
    let r2 = b.elemwise(&[f2], OpKind::Relu, 4096.0, 0.0);
    b.dense(&[r2], 4096, 1000);
    b.build()
}

/// ConvNeXt-Tiny at 224²: 7×7 depthwise + pointwise MLP stages with
/// LayerNorm/GELU — the Fig. 5 case-study model (4.5 GFLOPs, 28 M params).
fn convnext_tiny() -> OpGraph {
    let mut b = GraphBuilder::new("convnext_tiny", "convnext");
    let stem = b.conv(&[], 4, 3, 96, 56, 4, 1);
    let mut prev = b.elemwise(&[stem], OpKind::LayerNorm, 96.0 * 56.0 * 56.0, 192.0);
    let stages: [(u32, u32, u32); 4] = [(96, 56, 3), (192, 28, 3), (384, 14, 9), (768, 7, 3)];
    let mut cin = 96u32;
    for &(c, side, n) in &stages {
        // Block: 7x7 depthwise + LN + 1x1 (c->4c) + GELU + 1x1 (4c->c) + add.
        let flops = n as f64
            * (2.0 * 49.0 * c as f64 * (side as f64).powi(2)
                + conv_flops(1, c, 4 * c, side)
                + conv_flops(1, 4 * c, c, side));
        let deps = [prev];
        let conv = b.conv(&deps, 7, cin, c, side, 1, n);
        b.set_flops(conv, flops);
        b.set_params(
            conv,
            n as f64 * (49.0 * c as f64 + 8.0 * (c as f64).powi(2)),
        );
        b.set_kernels(conv, 3 * n); // dw 7x7 + two pointwise per block
        let elems = c as f64 * (side as f64).powi(2) * n as f64;
        let ln = b.elemwise(&[conv], OpKind::LayerNorm, elems, 2.0 * c as f64);
        b.set_kernels(ln, n);
        let gelu = b.elemwise(&[ln], OpKind::Gelu, elems * 4.0, 0.0);
        b.set_kernels(gelu, n);
        let add = b.elemwise(&[prev, gelu], OpKind::Add, elems, 0.0);
        b.set_kernels(add, n);
        prev = add;
        cin = c;
    }
    let gap = b.pool(&[prev], 768, 1, 7);
    b.dense(&[gap], 768, 1000);
    b.build()
}

/// BERT-Tiny-ish encoder (4 layers, dim 312, seq 128) — the zoo's NLP
/// function; attention + GEMM mix exercises non-CNN feature paths.
fn bert_tiny() -> OpGraph {
    let (layers, dim, seq, vocab) = (4u32, 312u32, 128u32, 30522u32);
    let mut b = GraphBuilder::new("bert_tiny", "bert");
    let emb = b.embed(&[], vocab, dim, seq);
    let mut prev = b.elemwise(&[emb], OpKind::LayerNorm, (seq * dim) as f64, 2.0 * dim as f64);
    for _ in 0..layers {
        let att = b.attention(&[prev], seq, dim);
        let ln1 = b.elemwise(
            &[prev, att],
            OpKind::LayerNorm,
            (seq * dim) as f64,
            2.0 * dim as f64,
        );
        // FFN: dim -> 4dim -> dim over seq tokens, as a MatMul stage node.
        let ffn_flops = 2.0 * 2.0 * seq as f64 * dim as f64 * 4.0 * dim as f64;
        let ffn = b.push(
            super::OpNode {
                kind: OpKind::MatMul,
                flops: ffn_flops,
                bytes: 4.0 * (seq as f64 * dim as f64 * 5.0),
                params: 8.0 * (dim as f64).powi(2),
                kernels: 2,
                kernel: 0,
                stride: 0,
                cin: dim,
                cout: dim,
                spatial: seq,
            },
            &[ln1],
        );
        let gelu = b.elemwise(&[ffn], OpKind::Gelu, (seq * 4 * dim) as f64, 0.0);
        prev = b.elemwise(
            &[ln1, gelu],
            OpKind::LayerNorm,
            (seq * dim) as f64,
            2.0 * dim as f64,
        );
    }
    b.dense(&[prev], dim, 2); // classifier head
    b.build()
}

/// Small DLRM: embedding-dominated recommender (bandwidth-bound lookups +
/// small MLPs) — the zoo's memory-bound outlier.
fn dlrm_small() -> OpGraph {
    let mut b = GraphBuilder::new("dlrm_small", "dlrm");
    let dense_in = b.dense(&[], 13, 512);
    let r1 = b.elemwise(&[dense_in], OpKind::Relu, 512.0, 0.0);
    let bot = b.dense(&[r1], 512, 64);
    // 26 sparse features, each a lookup in a 100k x 64 table; aggregate node.
    let emb = b.embed(&[], 100_000, 64, 26);
    // Feature interaction: pairwise dots of 27 vectors of dim 64.
    let inter = b.push(
        super::OpNode {
            kind: OpKind::MatMul,
            flops: 2.0 * 27.0 * 27.0 * 64.0,
            bytes: 4.0 * (27.0 * 64.0 + 27.0 * 27.0),
            params: 0.0,
            kernels: 1,
            kernel: 0,
            stride: 0,
            cin: 64,
            cout: 64,
            spatial: 27,
        },
        &[bot, emb],
    );
    let top1 = b.dense(&[inter], 512, 512);
    let r2 = b.elemwise(&[top1], OpKind::Relu, 512.0, 0.0);
    let top2 = b.dense(&[r2], 512, 256);
    let r3 = b.elemwise(&[top2], OpKind::Relu, 256.0, 0.0);
    let out = b.dense(&[r3], 256, 1);
    b.elemwise(&[out], OpKind::Softmax, 1.0, 0.0);
    b.build()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zoo_builds_and_validates() {
        for m in ALL_ZOO {
            let g = zoo_graph(m);
            g.validate().unwrap();
            assert!(g.nodes.len() <= super::super::builders::MAX_NODES);
            assert_eq!(ZooModel::from_name(g.name.as_str()), Some(m));
        }
    }

    #[test]
    fn zoo_adjacency_memoises_per_model() {
        for m in ALL_ZOO {
            let adj = zoo_adjacency(m);
            assert_eq!(*adj, zoo_graph(m).adjacency(), "{m:?}");
            // Same shared instance on repeat lookups.
            assert!(std::sync::Arc::ptr_eq(&adj, &zoo_adjacency(m)));
        }
    }

    #[test]
    fn resnet50_flops_and_params_near_reference() {
        let g = zoo_graph(ZooModel::ResNet50);
        let gflops = g.total_flops(1) / 1e9;
        let mparams = g.total_params() / 1e6;
        // Reference: ~4.1 GFLOPs (2·MACs), ~25.6 M params. Stage-level
        // aggregation tolerates ±30%.
        assert!((5.8..10.6).contains(&gflops), "resnet50 {gflops} GFLOPs (2*MACs)");
        assert!((18.0..33.0).contains(&mparams), "resnet50 {mparams} M params");
    }

    #[test]
    fn resnet152_heavier_than_resnet50() {
        let r50 = zoo_graph(ZooModel::ResNet50);
        let r152 = zoo_graph(ZooModel::ResNet152);
        let ratio = r152.total_flops(1) / r50.total_flops(1);
        // Reference ratio ≈ 11.6/4.1 ≈ 2.8.
        assert!((2.0..4.0).contains(&ratio), "ratio {ratio}");
    }

    #[test]
    fn vgg16_is_the_flops_heavyweight() {
        let vgg = zoo_graph(ZooModel::Vgg16);
        let gflops = vgg.total_flops(1) / 1e9;
        assert!((24.0..38.0).contains(&gflops), "vgg16 {gflops} GFLOPs (2*MACs)");
        let mparams = vgg.total_params() / 1e6;
        assert!((110.0..160.0).contains(&mparams), "vgg16 {mparams} M params");
    }

    #[test]
    fn mobilenet_is_light() {
        let g = zoo_graph(ZooModel::MobileNetV2);
        assert!(g.total_flops(1) / 1e9 < 2.0);
        assert!(g.total_params() / 1e6 < 8.0);
    }

    #[test]
    fn convnext_tiny_near_reference() {
        let g = zoo_graph(ZooModel::ConvNextTiny);
        let gflops = g.total_flops(1) / 1e9;
        assert!((6.0..13.0).contains(&gflops), "convnext {gflops} GFLOPs (2*MACs)");
    }

    #[test]
    fn bert_has_attention_nodes() {
        let g = zoo_graph(ZooModel::BertTiny);
        assert_eq!(g.count_kind(OpKind::Attention), 4);
        assert!(g.total_params() / 1e6 > 9.0); // embedding table dominates
    }

    #[test]
    fn dlrm_is_memory_bound() {
        let g = zoo_graph(ZooModel::DlrmSmall);
        // Arithmetic intensity (flops/byte) far below CNNs.
        let ai = g.total_flops(1) / g.total_bytes(1);
        let cnn_ai = zoo_graph(ZooModel::ResNet50).total_flops(1)
            / zoo_graph(ZooModel::ResNet50).total_bytes(1);
        assert!(ai < cnn_ai / 5.0, "dlrm ai={ai} cnn ai={cnn_ai}");
    }
}
