//! [`GraphBuilder`] — ergonomic construction of operator DAGs with
//! automatically derived FLOPs/bytes/params from layer hyper-parameters.
//!
//! Builders emit *stage-level* nodes (a residual block's convs are one node)
//! so zoo graphs stay under the 64-node padding bound shared with the
//! RaPP HLO artifact (`MAX_NODES` contract).

use super::{OpGraph, OpKind, OpNode};

/// Hard cap shared with `python/compile/features.py::MAX_NODES`.
pub const MAX_NODES: usize = 64;

pub struct GraphBuilder {
    name: String,
    family: String,
    nodes: Vec<OpNode>,
    edges: Vec<(usize, usize)>,
}

impl GraphBuilder {
    pub fn new(name: &str, family: &str) -> Self {
        GraphBuilder {
            name: name.to_string(),
            family: family.to_string(),
            nodes: Vec::new(),
            edges: Vec::new(),
        }
    }

    /// Append a node depending on `deps`; returns its index.
    pub fn push(&mut self, node: OpNode, deps: &[usize]) -> usize {
        let id = self.nodes.len();
        self.nodes.push(node);
        for &d in deps {
            assert!(d < id, "forward edges only");
            self.edges.push((d, id));
        }
        id
    }

    /// Conv2d node: `k`×`k` kernel, `cin`→`cout` channels, output side
    /// `out_side`, stride `stride`. FLOPs = 2·k²·cin·cout·out². `repeat`
    /// aggregates N identical convs into one stage node (stage-level IR).
    pub fn conv(
        &mut self,
        deps: &[usize],
        k: u32,
        cin: u32,
        cout: u32,
        out_side: u32,
        stride: u32,
        repeat: u32,
    ) -> usize {
        let out_elems = (cout as f64) * (out_side as f64).powi(2);
        let flops = 2.0 * (k as f64).powi(2) * cin as f64 * out_elems * repeat as f64;
        let bytes = 4.0
            * (cin as f64 * (out_side as f64 * stride as f64).powi(2)
                + out_elems)
            * repeat as f64;
        let params = (k as f64).powi(2) * cin as f64 * cout as f64 * repeat as f64;
        self.push(
            OpNode {
                kind: OpKind::Conv2d,
                flops,
                bytes,
                params,
                kernels: repeat.max(1),
                kernel: k,
                stride,
                cin,
                cout,
                spatial: out_side,
            },
            deps,
        )
    }

    /// Dense (fully-connected) layer: FLOPs = 2·nin·nout.
    pub fn dense(&mut self, deps: &[usize], nin: u32, nout: u32) -> usize {
        self.push(
            OpNode {
                kind: OpKind::Dense,
                flops: 2.0 * nin as f64 * nout as f64,
                bytes: 4.0 * (nin as f64 + nout as f64),
                params: nin as f64 * nout as f64 + nout as f64,
                kernels: 1,
                kernel: 0,
                stride: 0,
                cin: nin,
                cout: nout,
                spatial: 1,
            },
            deps,
        )
    }

    /// Elementwise / normalisation node over `elems` activations.
    pub fn elemwise(&mut self, deps: &[usize], kind: OpKind, elems: f64, params: f64) -> usize {
        let flops_per_elem = match kind {
            OpKind::Gelu => 8.0,
            OpKind::Softmax => 5.0,
            OpKind::LayerNorm | OpKind::BatchNorm => 4.0,
            _ => 1.0,
        };
        self.push(
            OpNode::simple(kind, flops_per_elem * elems, 8.0 * elems, params),
            deps,
        )
    }

    /// Pooling over a `c`×`side`×`side` output.
    pub fn pool(&mut self, deps: &[usize], c: u32, side: u32, window: u32) -> usize {
        let elems = c as f64 * (side as f64).powi(2);
        self.push(
            OpNode {
                kind: OpKind::Pool,
                flops: elems * (window as f64).powi(2),
                bytes: 4.0 * elems * ((window as f64).powi(2) + 1.0),
                params: 0.0,
                kernels: 1,
                kernel: window,
                stride: window,
                cin: c,
                cout: c,
                spatial: side,
            },
            deps,
        )
    }

    /// Multi-head self-attention stage over `seq` tokens of width `dim`
    /// (QKV projections + attention matmuls + output projection).
    pub fn attention(&mut self, deps: &[usize], seq: u32, dim: u32) -> usize {
        let s = seq as f64;
        let d = dim as f64;
        let proj = 4.0 * 2.0 * s * d * d; // q,k,v,o projections
        let attn = 2.0 * 2.0 * s * s * d; // qk^T and att·v
        self.push(
            OpNode {
                kind: OpKind::Attention,
                flops: proj + attn,
                bytes: 4.0 * (3.0 * s * d + s * s),
                params: 4.0 * d * d,
                kernels: 6,
                kernel: 0,
                stride: 0,
                cin: dim,
                cout: dim,
                spatial: seq,
            },
            deps,
        )
    }

    /// Embedding lookup: `vocab`×`dim` table, `seq` gathers.
    pub fn embed(&mut self, deps: &[usize], vocab: u32, dim: u32, seq: u32) -> usize {
        self.push(
            OpNode {
                kind: OpKind::Embed,
                flops: seq as f64,
                bytes: 4.0 * seq as f64 * dim as f64,
                params: vocab as f64 * dim as f64,
                kernels: 1,
                kernel: 0,
                stride: 0,
                cin: vocab,
                cout: dim,
                spatial: seq,
            },
            deps,
        )
    }

    /// Override a node's FLOPs (stage aggregation in the zoo builders).
    pub fn set_flops(&mut self, id: usize, flops: f64) {
        self.nodes[id].flops = flops;
    }

    /// Override a node's parameter count (stage aggregation).
    pub fn set_params(&mut self, id: usize, params: f64) {
        self.nodes[id].params = params;
    }

    /// Override a node's kernel-launch count (stage aggregation).
    pub fn set_kernels(&mut self, id: usize, kernels: u32) {
        self.nodes[id].kernels = kernels.max(1);
    }

    pub fn len(&self) -> usize {
        self.nodes.len()
    }

    pub fn is_empty(&self) -> bool {
        self.nodes.is_empty()
    }

    pub fn last(&self) -> usize {
        self.nodes.len() - 1
    }

    pub fn build(self) -> OpGraph {
        assert!(
            self.nodes.len() <= MAX_NODES,
            "graph '{}' has {} nodes > MAX_NODES={MAX_NODES}",
            self.name,
            self.nodes.len()
        );
        let g = OpGraph {
            name: self.name,
            family: self.family,
            nodes: self.nodes,
            edges: self.edges,
        };
        g.validate().expect("builder produced invalid graph");
        g
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn conv_flops_formula() {
        let mut b = GraphBuilder::new("t", "test");
        // 3x3, 64->64, 56x56 out, stride 1: 2*9*64*64*3136 = 231.2 MFLOPs
        b.conv(&[], 3, 64, 64, 56, 1, 1);
        let g = b.build();
        assert!((g.nodes[0].flops - 2.0 * 9.0 * 64.0 * 64.0 * 3136.0).abs() < 1.0);
        assert!((g.nodes[0].params - 9.0 * 64.0 * 64.0).abs() < 1.0);
    }

    #[test]
    fn dense_params_include_bias() {
        let mut b = GraphBuilder::new("t", "test");
        b.dense(&[], 512, 10);
        let g = b.build();
        assert_eq!(g.nodes[0].params, 512.0 * 10.0 + 10.0);
    }

    #[test]
    #[should_panic(expected = "forward edges only")]
    fn backward_edge_panics() {
        let mut b = GraphBuilder::new("t", "test");
        b.push(OpNode::simple(OpKind::Relu, 1.0, 8.0, 0.0), &[0]);
    }

    #[test]
    fn depth_tracks_chain() {
        let mut b = GraphBuilder::new("t", "test");
        let a = b.elemwise(&[], OpKind::Relu, 10.0, 0.0);
        let c = b.elemwise(&[a], OpKind::Relu, 10.0, 0.0);
        let d = b.elemwise(&[a], OpKind::Relu, 10.0, 0.0); // parallel branch
        b.elemwise(&[c, d], OpKind::Add, 10.0, 0.0);
        let g = b.build();
        assert_eq!(g.depth(), 3);
    }
}
