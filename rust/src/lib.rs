//! # HAS-GPU — Hybrid Auto-scaling Serverless inference with fine-grained GPU allocation
//!
//! Reproduction of *HAS-GPU: Efficient Hybrid Auto-scaling with Fine-grained GPU
//! Allocation for SLO-aware Serverless Inferences* (Gu et al., 2025).
//!
//! The crate is the **Layer-3 coordinator** of a three-layer Rust + JAX + Pallas
//! stack: Python/JAX (L2) and Pallas kernels (L1) are used *only at build time*
//! to AOT-compile model artifacts to HLO text; this crate loads and executes
//! them through the PJRT CPU client ([`runtime`]) and owns every request-path
//! component:
//!
//! * [`vgpu`] — the fine-grained spatio-temporal GPU allocation substrate
//!   (SM partitions + time-window token quotas, runtime quota re-writes);
//! * [`cluster`] — nodes, GPUs, pods, occupancy (HGO), the re-configurator;
//! * [`rapp`] — the Resource-aware Performance Predictor (GAT + MLP) and the
//!   DIPPM static-feature baseline;
//! * [`autoscaler`] — Kalman-filter workload prediction + the hybrid
//!   vertical/horizontal scaling algorithm (paper Algorithm 1);
//! * [`baselines`] — KServe-like and FaST-GShare-like comparator autoscalers;
//! * [`gateway`] — ingress, capacity-weighted load balancing, dynamic batching;
//! * [`workload`] — Azure-trace-style workload synthesis and open-loop driving;
//! * [`sim`] — a discrete-event simulation harness reproducing the paper's
//!   cluster-scale experiments (Figs. 6 and 7);
//! * [`expt`] — the scenario-matrix experiment runner: platform × preset ×
//!   seed grids sharded over a thread pool, aggregated into paper-style
//!   comparison tables and exported as `BENCH_sim.json`;
//! * [`perf`] — the calibrated roofline performance model (ground truth);
//! * [`metrics`] — SLO-violation curves, tail latency, and cost accounting;
//! * [`workflow`] — DAG pipelines of zoo models: end-to-end SLO budget
//!   splitting over stages and co-scaled stage planning.
//!
//! See `DESIGN.md` for the module inventory and experiment index and
//! `EXPERIMENTS.md` for measured-vs-paper results.

pub mod autoscaler;
pub mod baselines;
pub mod cluster;
pub mod expt;
pub mod gateway;
pub mod metrics;
pub mod model;
pub mod perf;
pub mod rapp;
pub mod runtime;
pub mod sim;
pub mod simclock;
pub mod util;
pub mod vgpu;
pub mod workflow;
pub mod workload;


pub use perf::PerfModel;

