//! Comparator platforms (paper §4.3): KServe-like and FaST-GShare-like
//! scaling policies, run on the *same* substrate, workload, and metrics as
//! HAS-GPU — isolating exactly the allocation/scaling policy, which is the
//! paper's A/B design.
//!
//! * [`KServePolicy`] — mainstream GPU serverless: every pod exclusively owns
//!   a whole GPU (sm = quota = 100%), scaling is horizontal-only driven by a
//!   concurrency/RPS target, and each scale-up pays a **GPU-instance** cold
//!   start (device + system init — the source of its P95/P99 tail blowup).
//! * [`FastGSharePolicy`] — state-of-the-art spatio-temporal GPU sharing:
//!   each function gets a **fixed** most-efficient (sm, quota) slice chosen
//!   once via the predictor, then scales horizontally only, paying container
//!   cold starts. No vertical scaling: bursts must wait for new replicas.
//! * [`TorporPolicy`] — Torpor/FaaSwap-like swap tier: the same fixed
//!   fine-grained slices, but idle replicas are **demoted** to host memory
//!   after a short idle window and **promoted** (host→device swap) on
//!   demand. GPU-frugal — parked replicas bill at the reduced host-cached
//!   rate — at the price of a swap-latency TTFT tail at every burst head.

use crate::autoscaler::ScalingPolicy;
use crate::cluster::{ClusterState, FunctionSpec, GpuId, Pod, PodPhase, PodState, ScalingAction};
use crate::rapp::{min_feasible_quota, LatencyPredictor, PredictQuery};
use crate::vgpu::{GpuClass, QuotaMille, SmMille, QUOTA_FULL, SM_FULL};
use std::collections::BTreeMap;

/// Class feasibility for a new pod of `f` holding `(sm, quota)`: the model
/// fits the device and the slice meets the SLO under the class clock. Both
/// baselines gate heterogeneous placement on this — the same *shape* of
/// rule the hybrid scaler uses (memory + SLO under the class factor), so
/// no baseline is handicapped by blindly landing on an SLO-infeasible
/// class. Each platform keeps its own SLO discipline, though: the
/// baselines judge at their bare SLO (neither has a planning margin —
/// FaST-GShare's offline slice search already runs flush against the
/// bound), while HAS-GPU judges at `slo × slo_margin`, consistent with
/// its own placement maths.
fn class_feasible(
    f: &FunctionSpec,
    sm: SmMille,
    quota: QuotaMille,
    predictor: &dyn LatencyPredictor,
    class: &GpuClass,
) -> bool {
    f.graph.memory_bytes(f.batch) <= class.mem_cap
        && predictor.latency(
            PredictQuery::new(
                &f.graph,
                f.batch,
                crate::vgpu::sm_to_f64(sm),
                crate::vgpu::quota_to_f64(quota),
            )
            .with_factor(class.throughput),
        ) <= f.slo
}

/// Per-plan-tick memo over [`class_feasible`]: feasibility depends only on
/// the class (catalog-sized set), never the individual GPU, so the per-GPU
/// ordering scans probe a tiny Vec instead of re-querying the predictor
/// per device.
fn class_feasible_memo<'a>(
    f: &'a FunctionSpec,
    sm: SmMille,
    quota: QuotaMille,
    predictor: &'a dyn LatencyPredictor,
) -> impl FnMut(&GpuClass) -> bool + 'a {
    let mut cache: Vec<(String, bool)> = Vec::new();
    move |c: &GpuClass| {
        if let Some((_, ok)) = cache.iter().find(|(n, _)| n == &c.name) {
            return *ok;
        }
        let ok = class_feasible(f, sm, quota, predictor, c);
        cache.push((c.name.clone(), ok));
        ok
    }
}

/// The offline "most efficient configuration" search shared by the
/// fine-grained baselines: the slice maximising throughput-per-GPU-share
/// subject to the SLO.
///
/// Efficiency `cap/(sm×quota)` is quota-invariant (capacity is linear in
/// quota), so per SM class the winner is the *smallest* SLO-feasible
/// quota — found by bisection over the monotone quota axis instead of a
/// full grid sweep. Callers memoise per function; lookups go through the
/// run's shared capacity cache.
fn efficient_slice(f: &FunctionSpec, predictor: &dyn LatencyPredictor) -> (SmMille, QuotaMille) {
    let mut best: Option<(f64, SmMille, QuotaMille)> = None;
    let mut fallback = (0.0f64, SM_FULL, QUOTA_FULL);
    for sm in (100..=SM_FULL).step_by(100) {
        let smf = crate::vgpu::sm_to_f64(sm);
        let cap_full = predictor.capacity(PredictQuery::new(
            &f.graph,
            f.batch,
            smf,
            crate::vgpu::quota_to_f64(QUOTA_FULL),
        ));
        if cap_full > fallback.0 {
            fallback = (cap_full, sm, QUOTA_FULL);
        }
        // FaST-GShare maximises throughput-per-GPU-share subject to the
        // SLO — it runs with latency close to the bound and no headroom
        // (the source of its persistent violations under fluctuation,
        // paper §4.3).
        let Some(q) = min_feasible_quota(100, QUOTA_FULL, |q| {
            predictor.latency(PredictQuery::new(
                &f.graph,
                f.batch,
                smf,
                crate::vgpu::quota_to_f64(q),
            )) <= f.slo
        }) else {
            continue;
        };
        let qf = crate::vgpu::quota_to_f64(q);
        let cap = predictor.capacity(PredictQuery::new(&f.graph, f.batch, smf, qf));
        let eff = cap / (smf * qf);
        if best.map_or(true, |(e, _, _)| eff > e) {
            best = Some((eff, sm, q));
        }
    }
    best.map(|(_, s, q)| (s, q))
        .unwrap_or((fallback.1, fallback.2))
}

/// KServe-like: whole-GPU pods, horizontal-only.
pub struct KServePolicy {
    /// Target utilisation of a pod before adding another (KServe's
    /// `autoscaling.knative.dev/target` analogue).
    pub target_util: f64,
    /// Scale-down cooldown (stable window).
    pub cooldown: f64,
    last_scale_down: BTreeMap<String, f64>,
    /// Smoothed RPS per function (KServe uses a sliding-window average,
    /// not a Kalman filter).
    ewma: BTreeMap<String, f64>,
    pub ewma_alpha: f64,
}

impl Default for KServePolicy {
    fn default() -> Self {
        KServePolicy {
            target_util: 0.7,
            cooldown: 60.0,
            last_scale_down: BTreeMap::new(),
            ewma: BTreeMap::new(),
            ewma_alpha: 0.3,
        }
    }
}

impl ScalingPolicy for KServePolicy {
    fn name(&self) -> &str {
        "kserve"
    }

    fn plan(
        &mut self,
        f: &FunctionSpec,
        observed_rps: f64,
        cluster: &ClusterState,
        predictor: &dyn LatencyPredictor,
        now: f64,
    ) -> Vec<ScalingAction> {
        let rate = {
            let e = self.ewma.entry(f.name.clone()).or_insert(observed_rps);
            *e = (1.0 - self.ewma_alpha) * *e + self.ewma_alpha * observed_rps;
            *e
        };
        let pods: Vec<&Pod> = cluster
            .pods_of(&f.name)
            .into_iter()
            .filter(|p| p.phase != PodPhase::Draining)
            .collect();
        // Heterogeneous fleets: order idle GPUs so `pop()` takes the
        // cheapest *feasible* class first (memory + SLO under the class
        // clock), LIFO-by-index inside a class — which on a uniform fleet
        // is exactly the seed's highest-index-first pop, feasible or not.
        // `idle_gpus()` (not a hand-rolled scan) so failed devices are
        // excluded under fault injection.
        let mut idle: Vec<GpuId> = cluster.idle_gpus().collect();
        let mut feas = class_feasible_memo(f, SM_FULL, QUOTA_FULL, predictor);
        idle.sort_by_key(|&g| {
            let c = cluster.gpu(g).class();
            let feasible = feas(c);
            // Ascending sort; pop() takes the maximum: feasible beats
            // infeasible, then cheaper price (reversed into the ordering),
            // then higher index.
            (feasible, std::cmp::Reverse((c.price_per_hour * 1e6) as u64), g.0)
        });
        // Full-GPU pod capacity, judged at the class the next pod would
        // land on (reference class when the fleet is exhausted).
        let next_factor = idle
            .last()
            .map(|&g| cluster.gpu(g).throughput())
            .unwrap_or(1.0);
        let cap =
            predictor.capacity(PredictQuery::new(&f.graph, f.batch, 1.0, 1.0).with_factor(next_factor));
        let desired = ((rate / (cap * self.target_util)).ceil() as usize).max(1);
        let current = pods.len();
        let mut actions = Vec::new();
        if desired > current {
            // Each new pod needs its own idle GPU (exclusive allocation).
            for _ in current..desired {
                let Some(gpu) = idle.pop() else { break };
                actions.push(ScalingAction::CreatePod {
                    function: f.name.clone(),
                    gpu,
                    sm: SM_FULL,
                    quota: QUOTA_FULL,
                    batch: f.batch,
                    new_gpu: true, // exclusive GPU ⇒ instance cold start
                });
            }
        } else if desired < current {
            let last = self.last_scale_down.get(&f.name).copied().unwrap_or(-1e18);
            if now - last >= self.cooldown {
                // Remove the newest pods first (LIFO, like knative).
                let mut victims: Vec<&&Pod> = pods.iter().collect();
                victims.sort_by(|a, b| b.created_at.total_cmp(&a.created_at));
                for v in victims.into_iter().take(current - desired) {
                    actions.push(ScalingAction::RemovePod { pod: v.id });
                }
                if !actions.is_empty() {
                    self.last_scale_down.insert(f.name.clone(), now);
                }
            }
        }
        actions
    }
}

/// FaST-GShare-like: fixed fine-grained slice per function, horizontal-only.
pub struct FastGSharePolicy {
    /// Chosen once per function: the most efficient (sm, quota) meeting the
    /// SLO (FaST-GShare's offline profiling step).
    slices: BTreeMap<String, (SmMille, QuotaMille)>,
    pub target_util: f64,
    pub cooldown: f64,
    last_scale_down: BTreeMap<String, f64>,
    ewma: BTreeMap<String, f64>,
    pub ewma_alpha: f64,
}

impl Default for FastGSharePolicy {
    fn default() -> Self {
        FastGSharePolicy {
            slices: BTreeMap::new(),
            target_util: 0.7,
            cooldown: 60.0,
            last_scale_down: BTreeMap::new(),
            ewma: BTreeMap::new(),
            ewma_alpha: 0.3,
        }
    }
}

impl FastGSharePolicy {
    /// Memoised [`efficient_slice`] — FaST-GShare's offline profiling step,
    /// run once per function.
    fn slice_for(
        &mut self,
        f: &FunctionSpec,
        predictor: &dyn LatencyPredictor,
    ) -> (SmMille, QuotaMille) {
        if let Some(&s) = self.slices.get(&f.name) {
            return s;
        }
        let slice = efficient_slice(f, predictor);
        self.slices.insert(f.name.clone(), slice);
        slice
    }

    /// First-fit GPU for a slice, respecting SM alignment; used GPUs first
    /// (FaST-GShare packs functions to raise utilisation). Heterogeneous
    /// fleets: within each tier (used, then idle) candidates are visited
    /// feasible-classes-first (slice meets the SLO under the class clock,
    /// model fits), price ascending, index ascending — infeasible classes
    /// stay at the back as a last resort, so a uniform fleet (one class)
    /// keeps the seed's plain index-order first-fit exactly.
    fn find_gpu(
        cluster: &ClusterState,
        f: &FunctionSpec,
        predictor: &dyn LatencyPredictor,
        sm: SmMille,
        quota: QuotaMille,
    ) -> Option<(GpuId, bool)> {
        let mut feas = class_feasible_memo(f, sm, quota, predictor);
        let mut rank = |g: GpuId| {
            let c = cluster.gpu(g).class();
            let feasible = feas(c);
            (!feasible, (c.price_per_hour * 1e6) as u64, g.0)
        };
        let mut used: Vec<GpuId> = cluster.used_gpus().collect();
        used.sort_by_key(|&g| rank(g));
        for g in used {
            if cluster.gpu(g).admissible(sm, quota).is_ok() {
                return Some((g, false));
            }
        }
        let mut idle: Vec<GpuId> = cluster.idle_gpus().collect();
        idle.sort_by_key(|&g| rank(g));
        idle.first().map(|&g| (g, true))
    }
}

impl ScalingPolicy for FastGSharePolicy {
    fn name(&self) -> &str {
        "fast-gshare"
    }

    fn plan(
        &mut self,
        f: &FunctionSpec,
        observed_rps: f64,
        cluster: &ClusterState,
        predictor: &dyn LatencyPredictor,
        now: f64,
    ) -> Vec<ScalingAction> {
        let rate = {
            let e = self.ewma.entry(f.name.clone()).or_insert(observed_rps);
            *e = (1.0 - self.ewma_alpha) * *e + self.ewma_alpha * observed_rps;
            *e
        };
        // The slice (and its capacity, which sizes the replica count) stays
        // profiled on the reference class — FaST-GShare's offline step knows
        // one device; mixed fleets only reorder *where* replicas land.
        let (sm, quota) = self.slice_for(f, predictor);
        let slice_cap = predictor.capacity(PredictQuery::new(
            &f.graph,
            f.batch,
            crate::vgpu::sm_to_f64(sm),
            crate::vgpu::quota_to_f64(quota),
        ));
        let pods: Vec<&Pod> = cluster
            .pods_of(&f.name)
            .into_iter()
            .filter(|p| p.phase != PodPhase::Draining)
            .collect();
        let desired = ((rate / (slice_cap * self.target_util)).ceil() as usize).max(1);
        let current = pods.len();
        let mut actions = Vec::new();
        if desired > current {
            for _ in current..desired {
                let Some((gpu, new_gpu)) = Self::find_gpu(cluster, f, predictor, sm, quota)
                else {
                    break;
                };
                actions.push(ScalingAction::CreatePod {
                    function: f.name.clone(),
                    gpu,
                    sm,
                    quota,
                    batch: f.batch,
                    new_gpu,
                });
                // NOTE: subsequent iterations see stale cluster state; the
                // harness applies actions one tick at a time, so at most one
                // over-placement per tick is possible and is rejected by the
                // Re-configurator (alignment/quota checks) — acceptable and
                // faithful to a reconcile-loop controller.
                break;
            }
        } else if desired < current {
            let last = self.last_scale_down.get(&f.name).copied().unwrap_or(-1e18);
            if now - last >= self.cooldown {
                let mut victims: Vec<&&Pod> = pods.iter().collect();
                victims.sort_by(|a, b| b.created_at.total_cmp(&a.created_at));
                for v in victims.into_iter().take(current - desired) {
                    actions.push(ScalingAction::RemovePod { pod: v.id });
                }
                if !actions.is_empty() {
                    self.last_scale_down.insert(f.name.clone(), now);
                }
            }
        }
        actions
    }
}

/// Torpor/FaaSwap-like: fine-grained slices with a host-memory swap tier.
///
/// Replicas are sized like FaST-GShare (fixed most-efficient slice), but a
/// function idle past [`Self::idle_timeout`] has *all* its resident
/// replicas demoted to host memory — weights parked, device memory freed,
/// billing dropped to the host-cached rate. Demand revives parked replicas
/// via promotion (one host→device swap) before any cold CreatePod; parked
/// replicas idle past [`Self::keep_alive`] are deleted for real. This is
/// the GPU-frugal design point the paper's keep-alive floor is compared
/// against: cheaper than always-on, but every burst head pays the swap
/// latency in TTFT.
pub struct TorporPolicy {
    slices: BTreeMap<String, (SmMille, QuotaMille)>,
    pub target_util: f64,
    /// Seconds without a single arrival before resident replicas are parked.
    pub idle_timeout: f64,
    /// Seconds a parked replica survives before actual deletion.
    pub keep_alive: f64,
    last_active: BTreeMap<String, f64>,
    ewma: BTreeMap<String, f64>,
    pub ewma_alpha: f64,
}

impl Default for TorporPolicy {
    fn default() -> Self {
        TorporPolicy {
            slices: BTreeMap::new(),
            target_util: 0.7,
            // Torpor reclaims device memory aggressively — swaps are assumed
            // cheap, so the idle window is an order of magnitude shorter
            // than the baselines' scale-down cooldowns.
            idle_timeout: 10.0,
            keep_alive: 300.0,
            last_active: BTreeMap::new(),
            ewma: BTreeMap::new(),
            ewma_alpha: 0.3,
        }
    }
}

impl TorporPolicy {
    fn slice_for(
        &mut self,
        f: &FunctionSpec,
        predictor: &dyn LatencyPredictor,
    ) -> (SmMille, QuotaMille) {
        if let Some(&s) = self.slices.get(&f.name) {
            return s;
        }
        let slice = efficient_slice(f, predictor);
        self.slices.insert(f.name.clone(), slice);
        slice
    }
}

impl ScalingPolicy for TorporPolicy {
    fn name(&self) -> &str {
        "torpor-like"
    }

    fn plan(
        &mut self,
        f: &FunctionSpec,
        observed_rps: f64,
        cluster: &ClusterState,
        predictor: &dyn LatencyPredictor,
        now: f64,
    ) -> Vec<ScalingAction> {
        let rate = {
            let e = self.ewma.entry(f.name.clone()).or_insert(observed_rps);
            *e = (1.0 - self.ewma_alpha) * *e + self.ewma_alpha * observed_rps;
            *e
        };
        // The idle clock starts at the first plan tick and resets on any
        // arrival — parking keys off real silence, not the EWMA's slow
        // decay tail.
        let last_active = self.last_active.entry(f.name.clone()).or_insert(now);
        if observed_rps > 0.0 {
            *last_active = now;
        }
        let idle = now - *last_active > self.idle_timeout;

        let (sm, quota) = self.slice_for(f, predictor);
        let all = cluster.pods_of(&f.name);
        let mut parked: Vec<&Pod> = all
            .iter()
            .copied()
            .filter(|p| p.phase != PodPhase::Draining && p.state == PodState::HostCached)
            .collect();
        let resident: Vec<&Pod> = all
            .into_iter()
            .filter(|p| p.phase != PodPhase::Draining && p.state != PodState::HostCached)
            .collect();
        let mut actions = Vec::new();

        if idle {
            // Park everything; reap parked replicas past the keep-alive.
            for p in &resident {
                actions.push(ScalingAction::DemotePod { pod: p.id });
            }
            for p in &parked {
                if now - p.state_since > self.keep_alive {
                    actions.push(ScalingAction::RemovePod { pod: p.id });
                }
            }
            return actions;
        }

        let slice_cap = predictor.capacity(PredictQuery::new(
            &f.graph,
            f.batch,
            crate::vgpu::sm_to_f64(sm),
            crate::vgpu::quota_to_f64(quota),
        ));
        let desired = ((rate / (slice_cap * self.target_util)).ceil() as usize).max(1);
        let current = resident.len();
        if desired > current {
            let mut need = desired - current;
            // Most recently parked first: their host copies are warmest and
            // ties break deterministically on pod id.
            parked.sort_by(|a, b| {
                b.state_since.total_cmp(&a.state_since).then(a.id.0.cmp(&b.id.0))
            });
            for p in &parked {
                if need == 0 {
                    break;
                }
                actions.push(ScalingAction::PromotePod { pod: p.id });
                need -= 1;
            }
            if need > 0 {
                // Cold create only once the swap tier is exhausted — one per
                // tick, reconcile-loop style (see FastGShare's note).
                if let Some((gpu, new_gpu)) =
                    FastGSharePolicy::find_gpu(cluster, f, predictor, sm, quota)
                {
                    actions.push(ScalingAction::CreatePod {
                        function: f.name.clone(),
                        gpu,
                        sm,
                        quota,
                        batch: f.batch,
                        new_gpu,
                    });
                }
            }
        } else if desired < current {
            // Surplus goes to the swap tier immediately (no cooldown:
            // demotion is reversible at one swap, unlike deletion).
            let mut victims: Vec<&&Pod> = resident.iter().collect();
            victims.sort_by(|a, b| b.created_at.total_cmp(&a.created_at));
            for v in victims.into_iter().take(current - desired) {
                actions.push(ScalingAction::DemotePod { pod: v.id });
            }
        }
        actions
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::reconfigurator::{place_pod, Reconfigurator};
    use crate::model::zoo::{zoo_graph, ZooModel};
    use crate::perf::PerfModel;
    use crate::rapp::OraclePredictor;

    fn setup() -> (ClusterState, Reconfigurator, PerfModel, FunctionSpec) {
        let mut c = ClusterState::new(4, 16e9);
        let spec = FunctionSpec {
            name: "resnet50".into(),
            graph: zoo_graph(ZooModel::ResNet50),
            slo: 0.25,
            batch: 8,
            artifact: None,
        };
        c.register_function(spec.clone());
        let r = Reconfigurator::new(&c, 1);
        (c, r, PerfModel::default(), spec)
    }

    #[test]
    fn kserve_allocates_whole_gpus() {
        let (c, _r, _pm, spec) = setup();
        let pred = OraclePredictor::default();
        let mut ks = KServePolicy::default();
        let actions = ks.plan(&spec, 10.0, &c, &pred, 0.0);
        assert_eq!(actions.len(), 1);
        match &actions[0] {
            ScalingAction::CreatePod { sm, quota, new_gpu, .. } => {
                assert_eq!((*sm, *quota), (SM_FULL, QUOTA_FULL));
                assert!(new_gpu);
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn kserve_scales_horizontally_with_load() {
        let (mut c, mut recon, pm, spec) = setup();
        place_pod(&mut recon, &mut c, &pm, "resnet50", GpuId(0), SM_FULL, QUOTA_FULL, 8, 0.0)
            .unwrap();
        let pred = OraclePredictor::default();
        let mut ks = KServePolicy::default();
        let cap = pred.capacity(PredictQuery::new(&spec.graph, 8, 1.0, 1.0));
        // Push the EWMA up with repeated high observations.
        let mut actions = Vec::new();
        for t in 0..20 {
            actions = ks.plan(&spec, cap * 2.5, &c, &pred, t as f64);
            if !actions.is_empty() {
                break;
            }
        }
        assert!(
            actions.iter().filter(|a| matches!(a, ScalingAction::CreatePod { .. })).count() >= 1,
            "{actions:?}"
        );
    }

    #[test]
    fn kserve_respects_gpu_exhaustion() {
        let (mut c, mut recon, pm, spec) = setup();
        for g in 0..4 {
            place_pod(&mut recon, &mut c, &pm, "resnet50", GpuId(g), SM_FULL, QUOTA_FULL, 8, 0.0)
                .unwrap();
        }
        let pred = OraclePredictor::default();
        let mut ks = KServePolicy::default();
        let cap = pred.capacity(PredictQuery::new(&spec.graph, 8, 1.0, 1.0));
        let actions = ks.plan(&spec, cap * 100.0, &c, &pred, 0.0);
        assert!(actions.is_empty(), "no idle GPUs left: {actions:?}");
    }

    #[test]
    fn fastgshare_slice_is_fixed_and_slo_feasible() {
        let (c, _r, _pm, spec) = setup();
        let pred = OraclePredictor::default();
        let mut fg = FastGSharePolicy::default();
        let _ = fg.plan(&spec, 1.0, &c, &pred, 0.0);
        let slice = fg.slices[&spec.name];
        // Fixed across calls.
        let _ = fg.plan(&spec, 50.0, &c, &pred, 1.0);
        assert_eq!(fg.slices[&spec.name], slice);
        // SLO-feasible.
        let lat = pred.latency(PredictQuery::new(
            &spec.graph,
            spec.batch,
            crate::vgpu::sm_to_f64(slice.0),
            crate::vgpu::quota_to_f64(slice.1),
        ));
        assert!(lat <= spec.slo, "slice {slice:?} lat {lat}");
        // Fine-grained (not a whole GPU).
        assert!(slice.0 < SM_FULL || slice.1 < QUOTA_FULL);
    }

    #[test]
    fn fastgshare_packs_used_gpus_first() {
        let (mut c, mut recon, pm, spec) = setup();
        let pred = OraclePredictor::default();
        let mut fg = FastGSharePolicy::default();
        // First pod.
        let a1 = fg.plan(&spec, 5.0, &c, &pred, 0.0);
        for a in &a1 {
            recon.apply(&mut c, &pm, a, 0.0).unwrap();
        }
        // Demand forcing a second replica.
        let slice = fg.slices[&spec.name];
        let cap = pred.capacity(PredictQuery::new(
            &spec.graph,
            spec.batch,
            crate::vgpu::sm_to_f64(slice.0),
            crate::vgpu::quota_to_f64(slice.1),
        ));
        let mut a2 = Vec::new();
        for t in 1..30 {
            a2 = fg.plan(&spec, cap * 1.9, &c, &pred, t as f64);
            if !a2.is_empty() {
                break;
            }
        }
        match a2.first() {
            Some(ScalingAction::CreatePod { gpu, new_gpu, .. }) => {
                // Same GPU as the first pod if alignment admits it.
                if c.gpu(*gpu).is_idle() {
                    assert!(*new_gpu);
                } else {
                    assert!(!*new_gpu);
                }
            }
            other => panic!("expected CreatePod, got {other:?}"),
        }
    }

    #[test]
    fn kserve_pops_cheapest_feasible_class_first() {
        use crate::cluster::ClusterState;
        use crate::vgpu::GpuClass;
        let mut c = ClusterState::from_classes(&[
            GpuClass::a100(),
            GpuClass::t4(),
            GpuClass::v100(),
        ]);
        let mut spec = setup().3;
        spec.slo = 10.0; // loose: all classes feasible
        c.register_function(spec.clone());
        let pred = OraclePredictor::default();
        let mut ks = KServePolicy::default();
        let actions = ks.plan(&spec, 10.0, &c, &pred, 0.0);
        match actions.as_slice() {
            [ScalingAction::CreatePod { gpu, .. }] => {
                assert_eq!(*gpu, GpuId(1), "t4 is the cheapest feasible whole GPU");
            }
            other => panic!("{other:?}"),
        }
        // SLO the T4 cannot meet even as a whole GPU: next-cheapest class.
        let lat_t4 =
            pred.latency(PredictQuery::new(&spec.graph, spec.batch, 1.0, 1.0).with_factor(0.4));
        let lat_v100 = pred.latency(PredictQuery::new(&spec.graph, spec.batch, 1.0, 1.0));
        spec.slo = (lat_v100 + lat_t4) / 2.0;
        let mut ks2 = KServePolicy::default();
        let actions = ks2.plan(&spec, 10.0, &c, &pred, 0.0);
        match actions.as_slice() {
            [ScalingAction::CreatePod { gpu, .. }] => {
                assert_eq!(*gpu, GpuId(2), "v100 beats a100 on price once t4 is out");
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn fastgshare_places_slice_on_cheapest_feasible_class() {
        use crate::cluster::ClusterState;
        use crate::vgpu::GpuClass;
        let mut c = ClusterState::from_classes(&[GpuClass::v100(), GpuClass::t4()]);
        let spec = setup().3; // slo 0.25: reference slice is comfortably feasible
        c.register_function(spec.clone());
        let pred = OraclePredictor::default();
        let mut fg = FastGSharePolicy::default();
        let actions = fg.plan(&spec, 5.0, &c, &pred, 0.0);
        let (sm, quota) = fg.slices[&spec.name];
        match actions.as_slice() {
            [ScalingAction::CreatePod { gpu, .. }] => {
                let class = c.gpu(*gpu).class().clone();
                // Wherever it landed, the slice must meet the SLO under that
                // class's clock (the shared feasibility rule).
                assert!(
                    class_feasible(&spec, sm, quota, &pred, &class),
                    "placed on an SLO-infeasible class {}",
                    class.name
                );
                // And if the cheap class is feasible, it must have won.
                if class_feasible(&spec, sm, quota, &pred, &GpuClass::t4()) {
                    assert_eq!(*gpu, GpuId(1));
                }
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn torpor_parks_idle_replicas_then_revives_them_on_demand() {
        let (mut c, mut recon, pm, spec) = setup();
        let pred = OraclePredictor::default();
        let mut tp = TorporPolicy::default();
        // Bootstrap a replica under live traffic.
        let boot = tp.plan(&spec, 5.0, &c, &pred, 0.0);
        assert!(
            matches!(boot.as_slice(), [ScalingAction::CreatePod { .. }]),
            "{boot:?}"
        );
        for a in &boot {
            recon.apply(&mut c, &pm, a, 0.0).unwrap();
        }
        let pod = c.pods_of(&spec.name)[0].id;
        // Silence inside the idle window: nothing happens.
        let quiet = tp.plan(&spec, 0.0, &c, &pred, 5.0);
        assert!(quiet.is_empty(), "{quiet:?}");
        // Silence past the window: the replica is parked, not deleted.
        let parked_at = 20.0;
        let park = tp.plan(&spec, 0.0, &c, &pred, parked_at);
        assert!(
            matches!(park.as_slice(), [ScalingAction::DemotePod { pod: p }] if *p == pod),
            "{park:?}"
        );
        for a in &park {
            recon.apply(&mut c, &pm, a, parked_at).unwrap();
        }
        // Demand returns: the parked replica is promoted — never a cold
        // CreatePod while the swap tier can cover the gap.
        let revive = tp.plan(&spec, 5.0, &c, &pred, 30.0);
        assert!(
            matches!(revive.as_slice(), [ScalingAction::PromotePod { pod: p }] if *p == pod),
            "{revive:?}"
        );
    }

    #[test]
    fn torpor_reaps_parked_replicas_past_keep_alive() {
        let (mut c, mut recon, pm, spec) = setup();
        let pred = OraclePredictor::default();
        let mut tp = TorporPolicy::default();
        assert_eq!(tp.name(), "torpor-like");
        for a in tp.plan(&spec, 5.0, &c, &pred, 0.0) {
            recon.apply(&mut c, &pm, &a, 0.0).unwrap();
        }
        let pod = c.pods_of(&spec.name)[0].id;
        for a in tp.plan(&spec, 0.0, &c, &pred, 20.0) {
            recon.apply(&mut c, &pm, &a, 20.0).unwrap();
        }
        assert_eq!(c.pod(pod).unwrap().state, crate::cluster::PodState::HostCached);
        // Still parked inside the keep-alive horizon.
        let mid = tp.plan(&spec, 0.0, &c, &pred, 100.0);
        assert!(mid.is_empty(), "{mid:?}");
        // Past it: deleted for real.
        let late = tp.plan(&spec, 0.0, &c, &pred, 400.0);
        assert!(
            matches!(late.as_slice(), [ScalingAction::RemovePod { pod: p }] if *p == pod),
            "{late:?}"
        );
    }

    #[test]
    fn scale_down_keeps_min_one_pod() {
        let (mut c, mut recon, pm, spec) = setup();
        place_pod(&mut recon, &mut c, &pm, "resnet50", GpuId(0), SM_FULL, QUOTA_FULL, 8, 0.0)
            .unwrap();
        let pred = OraclePredictor::default();
        let mut ks = KServePolicy::default();
        for t in 0..50 {
            let actions = ks.plan(&spec, 0.0, &c, &pred, t as f64 * 100.0);
            assert!(
                !actions.iter().any(|a| matches!(a, ScalingAction::RemovePod { .. })),
                "single pod must be retained: {actions:?}"
            );
        }
    }
}
