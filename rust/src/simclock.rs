//! Simulation time and the discrete-event engine.
//!
//! Experiments at paper scale (10 GPUs, hours of Azure trace, three platforms)
//! run in **sim mode**: a discrete-event loop over virtual seconds driven by a
//! binary-heap event queue. Small-scale end-to-end runs use **real mode**
//! (wall clock + actual PJRT execution); both share the same component code by
//! programming against [`Clock`].

use std::cmp::Ordering;
use std::collections::BinaryHeap;
use std::time::Instant;

/// Time source abstraction: virtual (simulation) or wall (serving).
#[derive(Clone, Debug)]
pub enum Clock {
    /// Wall clock anchored at creation.
    Wall(Instant),
    /// Virtual time in seconds, advanced explicitly by the event loop.
    Virtual(f64),
}

impl Clock {
    pub fn wall() -> Self {
        Clock::Wall(Instant::now())
    }

    pub fn virtual_at(t: f64) -> Self {
        Clock::Virtual(t)
    }

    /// Seconds since the epoch of this clock.
    pub fn now(&self) -> f64 {
        match self {
            Clock::Wall(t0) => t0.elapsed().as_secs_f64(),
            Clock::Virtual(t) => *t,
        }
    }

    /// Advance a virtual clock (no-op error on wall clocks).
    pub fn advance_to(&mut self, t: f64) {
        if let Clock::Virtual(cur) = self {
            debug_assert!(t >= *cur, "time moved backwards: {t} < {cur}");
            *cur = t;
        }
    }
}

/// An event scheduled at virtual time `at` with an opaque payload.
struct Scheduled<E> {
    at: f64,
    seq: u64, // FIFO tie-break for simultaneous events (determinism)
    event: E,
}

impl<E> PartialEq for Scheduled<E> {
    fn eq(&self, other: &Self) -> bool {
        self.at == other.at && self.seq == other.seq
    }
}
impl<E> Eq for Scheduled<E> {}
impl<E> PartialOrd for Scheduled<E> {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl<E> Ord for Scheduled<E> {
    fn cmp(&self, other: &Self) -> Ordering {
        // BinaryHeap is a max-heap: invert for earliest-first.
        other
            .at
            .partial_cmp(&self.at)
            .unwrap_or(Ordering::Equal)
            .then(other.seq.cmp(&self.seq))
    }
}

/// Deterministic discrete-event queue.
///
/// Events with equal timestamps pop in insertion order, which makes whole
/// simulation runs bit-reproducible for a given seed.
pub struct EventQueue<E> {
    heap: BinaryHeap<Scheduled<E>>,
    seq: u64,
    now: f64,
    high_water: usize,
}

impl<E> Default for EventQueue<E> {
    fn default() -> Self {
        Self::new()
    }
}

impl<E> EventQueue<E> {
    pub fn new() -> Self {
        Self::with_capacity(0)
    }

    /// Pre-size the heap: the streaming simulator keeps the queue at
    /// O(in-flight), so one up-front reservation eliminates re-allocation
    /// churn on the event hot path.
    pub fn with_capacity(cap: usize) -> Self {
        EventQueue {
            heap: BinaryHeap::with_capacity(cap),
            seq: 0,
            now: 0.0,
            high_water: 0,
        }
    }

    /// Current virtual time (time of the last popped event).
    pub fn now(&self) -> f64 {
        self.now
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    /// Deepest the queue has ever been — the observable footprint of the
    /// streaming-arrival rework (O(in-flight), not O(total requests)).
    pub fn high_water(&self) -> usize {
        self.high_water
    }

    /// Schedule `event` at absolute time `at` (clamped to now).
    pub fn push_at(&mut self, at: f64, event: E) {
        let at = if at < self.now { self.now } else { at };
        self.heap.push(Scheduled {
            at,
            seq: self.seq,
            event,
        });
        self.seq += 1;
        if self.heap.len() > self.high_water {
            self.high_water = self.heap.len();
        }
    }

    /// Schedule `event` after `delay` seconds.
    pub fn push_after(&mut self, delay: f64, event: E) {
        debug_assert!(delay >= 0.0);
        self.push_at(self.now + delay, event);
    }

    /// Pop the earliest event, advancing virtual time to its timestamp.
    pub fn pop(&mut self) -> Option<(f64, E)> {
        self.heap.pop().map(|s| {
            debug_assert!(s.at >= self.now);
            self.now = s.at;
            (s.at, s.event)
        })
    }

    /// Peek at the next event time without popping.
    pub fn peek_time(&self) -> Option<f64> {
        self.heap.peek().map(|s| s.at)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pops_in_time_order() {
        let mut q = EventQueue::new();
        q.push_at(3.0, "c");
        q.push_at(1.0, "a");
        q.push_at(2.0, "b");
        assert_eq!(q.pop().unwrap(), (1.0, "a"));
        assert_eq!(q.pop().unwrap(), (2.0, "b"));
        assert_eq!(q.pop().unwrap(), (3.0, "c"));
        assert!(q.pop().is_none());
    }

    #[test]
    fn simultaneous_events_fifo() {
        let mut q = EventQueue::new();
        for i in 0..100 {
            q.push_at(5.0, i);
        }
        for i in 0..100 {
            assert_eq!(q.pop().unwrap().1, i);
        }
    }

    #[test]
    fn time_advances_with_pop() {
        let mut q = EventQueue::new();
        q.push_at(1.5, ());
        assert_eq!(q.now(), 0.0);
        q.pop();
        assert_eq!(q.now(), 1.5);
        q.push_after(0.5, ());
        assert_eq!(q.peek_time(), Some(2.0));
    }

    #[test]
    fn past_events_clamped_to_now() {
        let mut q = EventQueue::new();
        q.push_at(2.0, "late");
        q.pop();
        q.push_at(1.0, "early"); // in the past: clamp to now=2.0
        let (t, _) = q.pop().unwrap();
        assert_eq!(t, 2.0);
    }

    #[test]
    fn high_water_tracks_peak_depth() {
        let mut q = EventQueue::with_capacity(8);
        assert_eq!(q.high_water(), 0);
        for i in 0..5 {
            q.push_at(i as f64, i);
        }
        assert_eq!(q.high_water(), 5);
        q.pop();
        q.pop();
        assert_eq!(q.len(), 3);
        // Draining never lowers the peak; pushing past it raises it.
        assert_eq!(q.high_water(), 5);
        for i in 0..4 {
            q.push_at(10.0 + i as f64, i);
        }
        assert_eq!(q.high_water(), 7);
    }

    #[test]
    fn virtual_clock_advances() {
        let mut c = Clock::virtual_at(0.0);
        assert_eq!(c.now(), 0.0);
        c.advance_to(10.0);
        assert_eq!(c.now(), 10.0);
    }

    #[test]
    fn wall_clock_monotonic() {
        let c = Clock::wall();
        let a = c.now();
        let b = c.now();
        assert!(b >= a);
    }
}
