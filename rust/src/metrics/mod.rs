//! Metrics plane: per-request records, SLO-violation curves, tail latency,
//! and fine-grained cost accounting.
//!
//! Reproduces the paper's evaluation methodology (§4.3):
//!
//! * **SLO violations** are measured against *baseline multipliers*: the
//!   baseline is "the theoretical shortest inference time of a DL model
//!   running in a pure container" (= full GPU, full quota latency), swept
//!   from 1× to 10× in steps of 0.25 (Fig. 6).
//! * **Cost** is billed at the Google Cloud V100 price ($2.48/h); for
//!   fine-grained allocation, a pod is billed for `sm × quota × wall-time`;
//!   whole-GPU platforms are billed for the full GPU (Fig. 7, $/1K requests).

pub mod ledger;

pub use ledger::{BillingLedger, BillingMode, HOST_CACHED_RATE};

use crate::util::stats::Summary;
use std::collections::BTreeMap;

/// Outcome of one request.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Outcome {
    Ok,
    /// Dropped: queue overflowed or no capacity before timeout.
    Dropped,
    /// Failed: the request was in flight on a pod whose device died (fault
    /// injection). Recorded with its real time-in-queue up to the failure
    /// instant — never produced on the fault-free default path.
    Failed,
}

/// One served (or dropped) request.
#[derive(Clone, Copy, Debug)]
pub struct RequestRecord {
    pub arrival: f64,
    /// End-to-end latency (queueing + batching + execution), seconds.
    pub latency: f64,
    pub outcome: Outcome,
}

/// Per-function request log.
#[derive(Clone, Debug, Default)]
pub struct FunctionMetrics {
    pub records: Vec<RequestRecord>,
    /// Time-to-first-token per served request: arrival → dispatch wait
    /// (queueing behind cold/non-resident pods is exactly what this
    /// measures — the cold-start axis).
    pub ttft: Vec<f64>,
}

impl FunctionMetrics {
    pub fn record(&mut self, arrival: f64, latency: f64, outcome: Outcome) {
        self.records.push(RequestRecord {
            arrival,
            latency,
            outcome,
        });
    }

    pub fn record_ttft(&mut self, wait: f64) {
        self.ttft.push(wait);
    }

    /// True when nothing was ever recorded. The simulator's sharded
    /// per-function logs use this to merge only touched functions into
    /// [`RunReport::functions`], matching the lazy-entry shape that
    /// [`RunReport::function`] always produced.
    pub fn is_empty(&self) -> bool {
        self.records.is_empty() && self.ttft.is_empty()
    }

    /// Summary over the TTFT samples.
    pub fn ttft_summary(&self) -> Summary {
        let mut s = Summary::new();
        for &w in &self.ttft {
            s.add(w);
        }
        s
    }

    pub fn served(&self) -> usize {
        self.records
            .iter()
            .filter(|r| r.outcome == Outcome::Ok)
            .count()
    }

    pub fn dropped(&self) -> usize {
        self.records
            .iter()
            .filter(|r| r.outcome == Outcome::Dropped)
            .count()
    }

    /// Requests failed by device death (fault runs only).
    pub fn failed(&self) -> usize {
        self.records
            .iter()
            .filter(|r| r.outcome == Outcome::Failed)
            .count()
    }

    /// Violation rate at an absolute latency bound. Dropped and failed
    /// requests always count as violations.
    pub fn violation_rate(&self, slo: f64) -> f64 {
        if self.records.is_empty() {
            return 0.0;
        }
        let viol = self
            .records
            .iter()
            .filter(|r| r.outcome != Outcome::Ok || r.latency > slo)
            .count();
        viol as f64 / self.records.len() as f64
    }

    /// The Fig. 6 curve: violation rate at `baseline × m` for each multiplier
    /// m in 1.0..=10.0 step 0.25.
    pub fn violation_curve(&self, baseline: f64) -> Vec<(f64, f64)> {
        let mut out = Vec::with_capacity(37);
        let mut m = 1.0;
        while m <= 10.0 + 1e-9 {
            out.push((m, self.violation_rate(baseline * m)));
            m += 0.25;
        }
        out
    }

    /// Latency summary over served requests.
    pub fn latency_summary(&self) -> Summary {
        let mut s = Summary::new();
        for r in &self.records {
            if r.outcome == Outcome::Ok {
                s.add(r.latency);
            }
        }
        s
    }
}

/// Billing meter: accumulates $-cost per function from GPU-slice usage,
/// with a per-GPU-class breakdown riding along (heterogeneous fleets; on a
/// uniform fleet everything lands under the reference class and the
/// breakdown is simply never exported).
#[derive(Clone, Debug, Default)]
pub struct CostMeter {
    /// function → accumulated cost in $.
    cost: BTreeMap<String, f64>,
    /// function → accumulated GPU-seconds (sm×quota-weighted).
    gpu_seconds: BTreeMap<String, f64>,
    /// GPU class → accumulated cost in $.
    class_cost: BTreeMap<String, f64>,
    /// GPU class → accumulated GPU-seconds (sm×quota-weighted).
    class_gpu_seconds: BTreeMap<String, f64>,
}

impl CostMeter {
    pub fn new() -> Self {
        Self::default()
    }

    /// Bill `function` for holding an (sm, quota) slice over `dur` seconds.
    /// Whole-GPU platforms pass sm = quota = 1. The reference-class shorthand
    /// for [`CostMeter::bill_slice_class`].
    pub fn bill_slice(
        &mut self,
        function: &str,
        sm: f64,
        quota: f64,
        dur: f64,
        price_per_hour: f64,
    ) {
        self.bill_slice_class(
            function,
            crate::vgpu::REFERENCE_CLASS,
            sm,
            quota,
            dur,
            price_per_hour,
        );
    }

    /// Bill a slice held on a GPU of `class` at that class's effective
    /// hourly price. Function totals and the per-class breakdown accrue
    /// together, so Σ class cost == Σ function cost by construction.
    pub fn bill_slice_class(
        &mut self,
        function: &str,
        class: &str,
        sm: f64,
        quota: f64,
        dur: f64,
        price_per_hour: f64,
    ) {
        debug_assert!(dur >= 0.0);
        let gpu_sec = sm * quota * dur;
        let cost = price_per_hour / 3600.0 * gpu_sec;
        *self.cost.entry(function.to_string()).or_insert(0.0) += cost;
        *self.gpu_seconds.entry(function.to_string()).or_insert(0.0) += gpu_sec;
        *self.class_cost.entry(class.to_string()).or_insert(0.0) += cost;
        *self.class_gpu_seconds.entry(class.to_string()).or_insert(0.0) += gpu_sec;
    }

    pub fn class_cost_of(&self, class: &str) -> f64 {
        self.class_cost.get(class).copied().unwrap_or(0.0)
    }

    pub fn class_gpu_seconds_of(&self, class: &str) -> f64 {
        self.class_gpu_seconds.get(class).copied().unwrap_or(0.0)
    }

    /// GPU classes that accrued any billing, in name order.
    pub fn billed_classes(&self) -> impl Iterator<Item = &str> {
        self.class_cost.keys().map(String::as_str)
    }

    pub fn cost_of(&self, function: &str) -> f64 {
        self.cost.get(function).copied().unwrap_or(0.0)
    }

    pub fn gpu_seconds_of(&self, function: &str) -> f64 {
        self.gpu_seconds.get(function).copied().unwrap_or(0.0)
    }

    pub fn total_cost(&self) -> f64 {
        self.cost.values().sum()
    }

    /// Total sm×quota-weighted GPU-seconds across every function — the
    /// scenario-matrix cost axis (cheaper than $ for cross-device compares).
    pub fn total_gpu_seconds(&self) -> f64 {
        self.gpu_seconds.values().sum()
    }

    /// The Fig. 7 metric: $ per 1000 served requests. A function that served
    /// nothing reports `0.0` — kept finite so the JSON export round-trips
    /// losslessly (`Json::Num(INFINITY)` serialises as `null`, which breaks
    /// `as_f64`) and so the `expt` grid and this meter agree.
    pub fn cost_per_1k(&self, function: &str, served: usize) -> f64 {
        if served == 0 {
            return 0.0;
        }
        self.cost_of(function) * 1000.0 / served as f64
    }
}

/// Aggregated result of one platform × workload experiment run.
#[derive(Clone, Debug, Default)]
pub struct RunReport {
    pub platform: String,
    pub functions: BTreeMap<String, FunctionMetrics>,
    pub costs: CostMeter,
    /// Wall-clock (or virtual) duration of the run.
    pub duration: f64,
    /// Scaling-action counts for diagnostics.
    pub vertical_ups: usize,
    pub vertical_downs: usize,
    pub horizontal_ups: usize,
    pub horizontal_downs: usize,
    /// Deepest the simulator's event queue ever got (sim-mode runs only).
    /// With streaming arrival cursors this is O(duration/tick + in-flight)
    /// — pre-pushed ticks dominate — instead of the seed's O(total
    /// requests); `0` for real-mode runs, which have no event queue.
    pub event_queue_peak: usize,
    /// Fleet composition of the run: GPU class → device count. Empty for
    /// runs that never declared a fleet (homogeneous constructors).
    pub fleet_gpus: BTreeMap<String, usize>,
    /// Lifecycle transition counts (keep-alive demotions to `HostCached`
    /// and swap-in promotions back). Zero on the default path.
    pub demotions: usize,
    pub promotions: usize,
    /// True when the run exercised the lifecycle axis (finite swap
    /// bandwidths / keep-alive): gates the TTFT + transition-count JSON
    /// export so default-path exports stay byte-identical.
    pub lifecycle: bool,
    /// True when the run injected faults: gates the availability / MTTR /
    /// failed-request JSON export (same key-omission contract as
    /// `lifecycle`).
    pub faults_active: bool,
    /// GPU failure events that fired.
    pub gpu_failures: usize,
    /// Total GPU-down seconds summed over devices (intervals still open at
    /// end of run are truncated there).
    pub gpu_downtime: f64,
    /// Pods killed by device death or pod-crash events.
    pub pods_lost: usize,
    /// Transient reconfiguration failures drawn (including ones later
    /// retried to success).
    pub reconfig_transients: u64,
    /// Actions abandoned after exhausting their transient-retry budget.
    pub reconfig_aborts: usize,
    /// Per-function time-to-restore-capacity samples: seconds from a
    /// replica's loss to the next replacement replica turning ready.
    pub mttr_samples: BTreeMap<String, Vec<f64>>,
    /// Per-workflow end-to-end request log: one record per pipeline
    /// *origin* (entry-stage arrival), latency = entry arrival → last
    /// terminal completion, hop latencies included and every interval
    /// charged exactly once. Empty on the default (no-workflow) path.
    pub workflow_e2e: BTreeMap<String, FunctionMetrics>,
    /// Per-workflow end-to-end SLO. Non-empty exactly when the run was
    /// configured with workflows — gates the `workflows` JSON export the
    /// same way `lifecycle` / `faults_active` gate theirs.
    pub workflow_slos: BTreeMap<String, f64>,
}

impl RunReport {
    pub fn new(platform: &str) -> Self {
        RunReport {
            platform: platform.to_string(),
            ..Default::default()
        }
    }

    pub fn function(&mut self, name: &str) -> &mut FunctionMetrics {
        self.functions.entry(name.to_string()).or_default()
    }

    /// End-to-end metrics of one workflow (see [`RunReport::workflow_e2e`]).
    pub fn workflow(&mut self, name: &str) -> &mut FunctionMetrics {
        self.workflow_e2e.entry(name.to_string()).or_default()
    }

    pub fn total_served(&self) -> usize {
        self.functions.values().map(|f| f.served()).sum()
    }

    pub fn total_dropped(&self) -> usize {
        self.functions.values().map(|f| f.dropped()).sum()
    }

    pub fn total_failed(&self) -> usize {
        self.functions.values().map(|f| f.failed()).sum()
    }

    /// Fleet availability: 1 − (GPU-down seconds / GPU-fleet seconds).
    /// Exactly 1.0 when no device ever failed.
    pub fn availability(&self) -> f64 {
        let n: usize = self.fleet_gpus.values().sum();
        if n == 0 || self.duration <= 0.0 {
            return 1.0;
        }
        1.0 - self.gpu_downtime / (n as f64 * self.duration)
    }

    /// Mean time-to-restore-capacity over every function's samples, if any
    /// replica was ever lost and replaced.
    pub fn mttr_mean(&self) -> Option<f64> {
        let (mut sum, mut n) = (0.0, 0usize);
        for v in self.mttr_samples.values() {
            sum += v.iter().sum::<f64>();
            n += v.len();
        }
        if n == 0 {
            None
        } else {
            Some(sum / n as f64)
        }
    }

    /// Latency summary merged over every function's served requests — the
    /// grid aggregation behind the scenario matrix's per-cell P99 column.
    pub fn merged_latency_summary(&self) -> Summary {
        let mut s = Summary::new();
        for m in self.functions.values() {
            for r in &m.records {
                if r.outcome == Outcome::Ok {
                    s.add(r.latency);
                }
            }
        }
        s
    }

    /// TTFT summary merged over every function — the grid's cold-start
    /// columns (P50/P99).
    pub fn merged_ttft_summary(&self) -> Summary {
        let mut s = Summary::new();
        for m in self.functions.values() {
            for &w in &m.ttft {
                s.add(w);
            }
        }
        s
    }

    /// Request-weighted SLO-violation rate across functions, each request
    /// judged against its own function's SLO bound. Dropped and failed
    /// requests always count as violations; functions absent from `slos`
    /// are skipped.
    pub fn slo_violation_rate<'a, I>(&self, slos: I) -> f64
    where
        I: IntoIterator<Item = (&'a str, f64)>,
    {
        let mut viol = 0usize;
        let mut total = 0usize;
        for (name, slo) in slos {
            if let Some(m) = self.functions.get(name) {
                total += m.records.len();
                viol += m
                    .records
                    .iter()
                    .filter(|r| r.outcome != Outcome::Ok || r.latency > slo)
                    .count();
            }
        }
        if total == 0 {
            0.0
        } else {
            viol as f64 / total as f64
        }
    }

    /// Export as JSON for EXPERIMENTS.md tooling.
    pub fn to_json(&self) -> crate::util::json::Json {
        use crate::util::json::Json;
        let fns = self
            .functions
            .iter()
            .map(|(name, m)| {
                let mut lat = m.latency_summary();
                let mut f = vec![
                    ("served", Json::Num(m.served() as f64)),
                    ("dropped", Json::Num(m.dropped() as f64)),
                ];
                // Fault runs add the failed count right after dropped; the
                // default path keeps the historical per-function shape.
                if self.faults_active {
                    f.push(("failed", Json::Num(m.failed() as f64)));
                }
                f.extend(vec![
                    ("p50", Json::Num(if lat.is_empty() { 0.0 } else { lat.p50() })),
                    ("p90", Json::Num(if lat.is_empty() { 0.0 } else { lat.p90() })),
                    ("p95", Json::Num(if lat.is_empty() { 0.0 } else { lat.p95() })),
                    ("p99", Json::Num(if lat.is_empty() { 0.0 } else { lat.p99() })),
                    ("cost", Json::Num(self.costs.cost_of(name))),
                    ("gpu_seconds", Json::Num(self.costs.gpu_seconds_of(name))),
                    (
                        "cost_per_1k",
                        Json::Num(self.costs.cost_per_1k(name, m.served())),
                    ),
                ]);
                (name.clone(), Json::obj(f))
            })
            .collect();
        let mut fields = vec![
            ("platform", Json::Str(self.platform.clone())),
            ("duration", Json::Num(self.duration)),
            ("functions", Json::Obj(fns)),
            ("vertical_ups", Json::Num(self.vertical_ups as f64)),
            ("vertical_downs", Json::Num(self.vertical_downs as f64)),
            ("horizontal_ups", Json::Num(self.horizontal_ups as f64)),
            ("horizontal_downs", Json::Num(self.horizontal_downs as f64)),
            ("event_queue_peak", Json::Num(self.event_queue_peak as f64)),
        ];
        // Heterogeneous runs export the fleet composition and the per-class
        // billing breakdown; uniform reference-class runs stay byte-stable.
        let heterogeneous = self
            .fleet_gpus
            .keys()
            .any(|c| c != crate::vgpu::REFERENCE_CLASS)
            || self.fleet_gpus.len() > 1;
        // Lifecycle runs export transition counts + TTFT; the default path
        // omits the keys entirely (byte-identity contract).
        if self.lifecycle {
            fields.push(("demotions", Json::Num(self.demotions as f64)));
            fields.push(("promotions", Json::Num(self.promotions as f64)));
            let mut t = self.merged_ttft_summary();
            fields.push((
                "ttft_p50",
                Json::Num(if t.is_empty() { 0.0 } else { t.p50() }),
            ));
            fields.push((
                "ttft_p99",
                Json::Num(if t.is_empty() { 0.0 } else { t.p99() }),
            ));
        }
        // Fault runs export availability / failure / MTTR accounting; the
        // no-fault path omits every key (the standing identity contract).
        if self.faults_active {
            fields.push(("availability", Json::Num(self.availability())));
            fields.push(("gpu_failures", Json::Num(self.gpu_failures as f64)));
            fields.push(("gpu_downtime", Json::Num(self.gpu_downtime)));
            fields.push(("pods_lost", Json::Num(self.pods_lost as f64)));
            fields.push(("failed", Json::Num(self.total_failed() as f64)));
            fields.push((
                "reconfig_transients",
                Json::Num(self.reconfig_transients as f64),
            ));
            fields.push(("reconfig_aborts", Json::Num(self.reconfig_aborts as f64)));
            fields.push((
                "mttr",
                Json::Obj(
                    self.mttr_samples
                        .iter()
                        .filter(|(_, v)| !v.is_empty())
                        .map(|(f, v)| {
                            (
                                f.clone(),
                                Json::Num(v.iter().sum::<f64>() / v.len() as f64),
                            )
                        })
                        .collect(),
                ),
            ));
            fields.push((
                "mttr_mean",
                Json::Num(self.mttr_mean().unwrap_or(0.0)),
            ));
        }
        // Workflow runs export per-pipeline end-to-end percentiles and the
        // e2e violation rate; runs without workflows omit the key entirely
        // (the standing byte-identity contract).
        if !self.workflow_slos.is_empty() {
            let empty = FunctionMetrics::default();
            fields.push((
                "workflows",
                Json::Obj(
                    self.workflow_slos
                        .iter()
                        .map(|(name, &slo)| {
                            let m = self.workflow_e2e.get(name).unwrap_or(&empty);
                            let mut lat = m.latency_summary();
                            let (p50, p99) = if lat.is_empty() {
                                (0.0, 0.0)
                            } else {
                                (lat.p50(), lat.p99())
                            };
                            (
                                name.clone(),
                                Json::obj(vec![
                                    ("e2e_slo", Json::Num(slo)),
                                    ("served", Json::Num(m.served() as f64)),
                                    ("dropped", Json::Num(m.dropped() as f64)),
                                    ("e2e_p50", Json::Num(p50)),
                                    ("e2e_p99", Json::Num(p99)),
                                    ("e2e_violation_rate", Json::Num(m.violation_rate(slo))),
                                ]),
                            )
                        })
                        .collect(),
                ),
            ));
        }
        if heterogeneous {
            fields.push((
                "fleet_gpus",
                Json::Obj(
                    self.fleet_gpus
                        .iter()
                        .map(|(c, &n)| (c.clone(), Json::Num(n as f64)))
                        .collect(),
                ),
            ));
            fields.push((
                "class_costs",
                Json::Obj(
                    self.costs
                        .billed_classes()
                        .map(|c| {
                            (
                                c.to_string(),
                                Json::obj(vec![
                                    ("cost", Json::Num(self.costs.class_cost_of(c))),
                                    (
                                        "gpu_seconds",
                                        Json::Num(self.costs.class_gpu_seconds_of(c)),
                                    ),
                                ]),
                            )
                        })
                        .collect(),
                ),
            ));
        }
        Json::obj(fields)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn violation_rate_counts_drops() {
        let mut m = FunctionMetrics::default();
        m.record(0.0, 0.05, Outcome::Ok);
        m.record(1.0, 0.20, Outcome::Ok);
        m.record(2.0, 0.01, Outcome::Dropped);
        // SLO 0.1: one slow + one dropped = 2/3.
        assert!((m.violation_rate(0.1) - 2.0 / 3.0).abs() < 1e-9);
        // Very loose SLO: only the drop violates.
        assert!((m.violation_rate(10.0) - 1.0 / 3.0).abs() < 1e-9);
    }

    #[test]
    fn violation_curve_shape() {
        let mut m = FunctionMetrics::default();
        for i in 0..100 {
            m.record(i as f64, 0.01 * (1.0 + i as f64 / 25.0), Outcome::Ok);
        }
        let curve = m.violation_curve(0.01);
        assert_eq!(curve.len(), 37);
        assert_eq!(curve[0].0, 1.0);
        assert_eq!(curve[36].0, 10.0);
        // Monotone non-increasing in the multiplier.
        for w in curve.windows(2) {
            assert!(w[1].1 <= w[0].1 + 1e-12);
        }
    }

    #[test]
    fn cost_meter_fine_vs_whole_gpu() {
        let mut cm = CostMeter::new();
        cm.bill_slice("f", 0.25, 0.5, 3600.0, 2.48);
        cm.bill_slice("g", 1.0, 1.0, 3600.0, 2.48);
        assert!((cm.cost_of("f") - 2.48 * 0.125).abs() < 1e-9);
        assert!((cm.cost_of("g") - 2.48).abs() < 1e-9);
        assert!((cm.total_cost() - 2.48 * 1.125).abs() < 1e-9);
        assert!((cm.cost_per_1k("g", 500) - 4.96).abs() < 1e-9);
        // Zero-served is defined as 0.0 (finite), matching the expt grid.
        assert_eq!(cm.cost_per_1k("g", 0), 0.0);
        assert!(cm.gpu_seconds_of("f") > 0.0);
    }

    #[test]
    fn merged_summary_and_grid_violation_rate() {
        let mut r = RunReport::new("has-gpu");
        r.function("a").record(0.0, 0.010, Outcome::Ok);
        r.function("a").record(1.0, 0.100, Outcome::Ok);
        r.function("b").record(2.0, 0.050, Outcome::Ok);
        r.function("b").record(3.0, 0.0, Outcome::Dropped);
        let mut s = r.merged_latency_summary();
        assert_eq!(s.len(), 3);
        assert!((s.percentile(100.0) - 0.100).abs() < 1e-12);
        // a's SLO 0.05 (one slow), b's SLO 1.0 (one drop): 2 of 4 violate.
        let v = r.slo_violation_rate([("a", 0.05), ("b", 1.0)]);
        assert!((v - 0.5).abs() < 1e-12);
        // No matching functions ⇒ defined as zero.
        assert_eq!(r.slo_violation_rate([("missing", 0.1)]), 0.0);
    }

    #[test]
    fn total_gpu_seconds_sums_functions() {
        let mut cm = CostMeter::new();
        cm.bill_slice("f", 0.5, 0.5, 100.0, 2.48);
        cm.bill_slice("g", 1.0, 1.0, 10.0, 2.48);
        assert!((cm.total_gpu_seconds() - (0.25 * 100.0 + 10.0)).abs() < 1e-9);
    }

    #[test]
    fn class_breakdown_accrues_alongside_function_totals() {
        let mut cm = CostMeter::new();
        cm.bill_slice_class("f", "a100", 0.5, 1.0, 10.0, 10.0);
        cm.bill_slice_class("f", "t4", 1.0, 1.0, 10.0, 1.0);
        cm.bill_slice("g", 1.0, 1.0, 5.0, 2.48); // reference shorthand
        // Σ class cost == Σ function cost, always.
        let class_total: f64 = cm.billed_classes().map(|c| cm.class_cost_of(c)).sum();
        assert!((class_total - cm.total_cost()).abs() < 1e-12);
        assert!((cm.class_cost_of("a100") - 10.0 / 3600.0 * 5.0).abs() < 1e-12);
        assert!((cm.class_gpu_seconds_of("t4") - 10.0).abs() < 1e-12);
        assert!((cm.class_gpu_seconds_of("v100") - 5.0).abs() < 1e-12);
        assert_eq!(cm.class_cost_of("h100"), 0.0);
        let names: Vec<&str> = cm.billed_classes().collect();
        assert_eq!(names, vec!["a100", "t4", "v100"]);
    }

    #[test]
    fn zero_served_cost_per_1k_roundtrips_through_json() {
        // Regression: INFINITY serialised as JSON `null`, breaking `as_f64`
        // round-trips; zero-served must export a readable finite number.
        let mut r = RunReport::new("has-gpu");
        r.function("idle").record(0.0, 0.0, Outcome::Dropped); // 0 served
        r.costs.bill_slice("idle", 0.5, 0.5, 10.0, 2.48);
        let j = r.to_json();
        let f = j.get("functions").unwrap().get("idle").unwrap();
        let v = f.get("cost_per_1k").unwrap().as_f64().unwrap();
        assert_eq!(v, 0.0);
        // And the textual form parses back to the same number.
        let text = j.to_string_pretty();
        let back = crate::util::json::parse(&text).unwrap();
        let v2 = back
            .get("functions")
            .unwrap()
            .get("idle")
            .unwrap()
            .get("cost_per_1k")
            .unwrap()
            .as_f64()
            .unwrap();
        assert_eq!(v2, 0.0);
    }

    #[test]
    fn lifecycle_keys_exported_only_for_lifecycle_runs() {
        let mut r = RunReport::new("has-gpu");
        r.function("f").record(0.0, 0.03, Outcome::Ok);
        r.function("f").record_ttft(0.5);
        r.function("f").record_ttft(1.5);
        // Default path: keys absent even though TTFT samples exist.
        let j = r.to_json();
        assert!(j.get("ttft_p50").is_err());
        assert!(j.get("demotions").is_err());
        // Lifecycle run: keys present with the merged summary.
        r.lifecycle = true;
        r.demotions = 3;
        let j = r.to_json();
        assert_eq!(j.get("demotions").unwrap().as_f64().unwrap(), 3.0);
        assert_eq!(j.get("promotions").unwrap().as_f64().unwrap(), 0.0);
        assert!(j.get("ttft_p99").unwrap().as_f64().unwrap() >= 0.5);
        let mut s = r.merged_ttft_summary();
        assert_eq!(s.len(), 2);
        assert!(s.percentile(100.0) >= 1.5 - 1e-12);
    }

    #[test]
    fn failed_outcome_counts_and_fault_keys_gate_on_faults_active() {
        let mut r = RunReport::new("has-gpu");
        r.function("f").record(0.0, 0.01, Outcome::Ok);
        r.function("f").record(1.0, 2.0, Outcome::Failed);
        r.function("f").record(2.0, 0.5, Outcome::Dropped);
        let m = &r.functions["f"];
        assert_eq!((m.served(), m.dropped(), m.failed()), (1, 1, 1));
        // Failed always violates, at any SLO.
        assert!((m.violation_rate(100.0) - 2.0 / 3.0).abs() < 1e-12);
        assert!((r.slo_violation_rate([("f", 100.0)]) - 2.0 / 3.0).abs() < 1e-12);
        // Default path: no fault keys, per-function shape unchanged.
        let j = r.to_json();
        assert!(j.get("availability").is_err());
        assert!(j.get("mttr_mean").is_err());
        assert!(j.get("functions").unwrap().get("f").unwrap().get("failed").is_err());
        // Fault run: availability reflects downtime, keys appear.
        r.faults_active = true;
        r.duration = 100.0;
        r.fleet_gpus.insert("v100".into(), 4);
        r.gpu_downtime = 40.0; // 40 of 400 gpu-seconds down
        r.gpu_failures = 2;
        r.pods_lost = 3;
        r.mttr_samples.insert("f".into(), vec![2.0, 4.0]);
        assert!((r.availability() - 0.9).abs() < 1e-12);
        assert_eq!(r.mttr_mean(), Some(3.0));
        let j = r.to_json();
        assert!((j.get("availability").unwrap().as_f64().unwrap() - 0.9).abs() < 1e-12);
        assert_eq!(j.get("failed").unwrap().as_f64().unwrap(), 1.0);
        assert_eq!(j.get("pods_lost").unwrap().as_f64().unwrap(), 3.0);
        assert_eq!(j.get("mttr_mean").unwrap().as_f64().unwrap(), 3.0);
        assert_eq!(j.get("mttr").unwrap().get("f").unwrap().as_f64().unwrap(), 3.0);
        let f = j.get("functions").unwrap().get("f").unwrap();
        assert_eq!(f.get("failed").unwrap().as_f64().unwrap(), 1.0);
    }

    #[test]
    fn workflow_keys_exported_only_for_workflow_runs() {
        let mut r = RunReport::new("has-gpu");
        r.function("wf:a").record(0.0, 0.03, Outcome::Ok);
        // Default path: no `workflows` key even with stage-like functions.
        assert!(r.to_json().get("workflows").is_err());
        // Workflow run: the gate is the SLO map, so a zero-traffic pipeline
        // still exports (with zeroed percentiles).
        r.workflow_slos.insert("wf".into(), 0.5);
        let j = r.to_json();
        let w = j.get("workflows").unwrap().get("wf").unwrap();
        assert_eq!(w.get("served").unwrap().as_f64().unwrap(), 0.0);
        assert_eq!(w.get("e2e_p99").unwrap().as_f64().unwrap(), 0.0);
        // With traffic: percentiles and the e2e violation rate (one of the
        // three records is over the 0.5 s budget, one is a drop).
        r.workflow("wf").record(0.0, 0.2, Outcome::Ok);
        r.workflow("wf").record(1.0, 0.9, Outcome::Ok);
        r.workflow("wf").record(2.0, 0.1, Outcome::Dropped);
        let j = r.to_json();
        let w = j.get("workflows").unwrap().get("wf").unwrap();
        assert_eq!(w.get("served").unwrap().as_f64().unwrap(), 2.0);
        assert_eq!(w.get("dropped").unwrap().as_f64().unwrap(), 1.0);
        assert_eq!(w.get("e2e_slo").unwrap().as_f64().unwrap(), 0.5);
        let viol = w.get("e2e_violation_rate").unwrap().as_f64().unwrap();
        assert!((viol - 2.0 / 3.0).abs() < 1e-12);
        assert!(w.get("e2e_p99").unwrap().as_f64().unwrap() >= 0.9 - 1e-12);
    }

    #[test]
    fn run_report_json_exports() {
        let mut r = RunReport::new("has-gpu");
        r.function("resnet50").record(0.0, 0.03, Outcome::Ok);
        r.costs.bill_slice("resnet50", 0.5, 0.5, 100.0, 2.48);
        let j = r.to_json();
        assert_eq!(j.get("platform").unwrap().as_str().unwrap(), "has-gpu");
        let f = j.get("functions").unwrap().get("resnet50").unwrap();
        assert_eq!(f.get("served").unwrap().as_f64().unwrap(), 1.0);
        assert!(f.get("cost").unwrap().as_f64().unwrap() > 0.0);
        assert_eq!(r.total_served(), 1);
        assert_eq!(r.total_dropped(), 0);
    }
}
