//! The transactional billing ledger — the single cost-accounting engine.
//!
//! **Invariant: every pod-second is billed exactly once, at the slice the pod
//! held during that second** (see DESIGN.md §Billing ledger). The ledger owns
//! `billed_until` for every open pod; callers report lifecycle boundaries
//! (`open` / `resize` / `close` / `settle`) and the ledger integrates
//! `sm × quota × wall-time` between them under the run's [`BillingMode`].
//!
//! This replaces the seed's scattered billing call sites, which re-billed at
//! resize/remove boundaries with a hard-coded fine-grained mode — silently
//! under-billing whole-GPU platforms at every boundary event and biasing the
//! baseline÷HAS cost ratios the scenario matrix exports (Fig. 7). Both the
//! simulator ([`crate::sim`]) and the real-mode gateway
//! ([`crate::gateway`]) drive this one engine.

use super::{CostMeter, RunReport};
use crate::cluster::{Applied, ClusterState, PodId};
use crate::vgpu::{quota_to_f64, sm_to_f64, QuotaMille, SmMille};
use std::collections::BTreeMap;

/// How a pod-second is priced.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BillingMode {
    /// Bill the `sm × quota` slice actually held (shared-GPU platforms).
    FineGrained,
    /// Bill the full GPU regardless of slice (KServe-style exclusive
    /// allocation: the whole device is reserved even if the pod is smaller).
    WholeGpu,
}

/// Billing-rate multiplier for a pod parked in the `HostCached` lifecycle
/// state: host memory is ~20× cheaper than a held GPU slice, so keep-alive
/// has a real — but small — cost (the Torpor trade-off). Resident pods bill
/// at the full rate (multiplier exactly 1.0, which [`BillingLedger::accrue`]
/// never even applies, preserving bit-identical default-path costs).
pub const HOST_CACHED_RATE: f64 = 0.05;

impl BillingMode {
    pub fn from_whole_gpu(bill_whole_gpu: bool) -> Self {
        if bill_whole_gpu {
            BillingMode::WholeGpu
        } else {
            BillingMode::FineGrained
        }
    }

    /// The (sm, quota) fractions billed for a pod holding `(sm, quota)`.
    fn billed_fractions(self, sm: SmMille, quota: QuotaMille) -> (f64, f64) {
        match self {
            BillingMode::FineGrained => (sm_to_f64(sm), quota_to_f64(quota)),
            BillingMode::WholeGpu => (1.0, 1.0),
        }
    }
}

/// One open pod account: the slice currently held, the hosting GPU class
/// and its effective price, and the time up to which it has been billed.
#[derive(Clone, Debug)]
struct Account {
    function: String,
    sm: SmMille,
    quota: QuotaMille,
    billed_until: f64,
    /// GPU class hosting the pod (per-class cost breakdown).
    class: String,
    /// Effective $/hr for this pod: the run's configured reference price
    /// scaled by the class's catalog price ratio. Exactly the configured
    /// price on the reference class (`× 1.0` is exact).
    price_per_hour: f64,
    /// Weight residency: `false` while parked `HostCached`, billing the
    /// reduced [`HOST_CACHED_RATE`] instead of the full slice rate.
    resident: bool,
}

/// The transactional billing engine. See the module docs for the invariant.
#[derive(Clone, Debug)]
pub struct BillingLedger {
    mode: BillingMode,
    price_per_hour: f64,
    accounts: BTreeMap<PodId, Account>,
    meter: CostMeter,
}

impl BillingLedger {
    pub fn new(mode: BillingMode, price_per_hour: f64) -> Self {
        BillingLedger {
            mode,
            price_per_hour,
            accounts: BTreeMap::new(),
            meter: CostMeter::new(),
        }
    }

    pub fn mode(&self) -> BillingMode {
        self.mode
    }

    /// Number of pods with open accounts.
    pub fn open_accounts(&self) -> usize {
        self.accounts.len()
    }

    /// Bill one account forward to `now` at its current slice, class, and
    /// effective class price.
    fn accrue(meter: &mut CostMeter, mode: BillingMode, acct: &mut Account, now: f64) {
        let dur = now - acct.billed_until;
        if dur <= 0.0 {
            return;
        }
        let (sm, quota) = mode.billed_fractions(acct.sm, acct.quota);
        if acct.resident {
            // Resident path is the historical one, bit for bit — no
            // multiplier is applied at all.
            meter.bill_slice_class(&acct.function, &acct.class, sm, quota, dur, acct.price_per_hour);
        } else {
            // Parked weights: host-memory rate on the same slice integral.
            meter.bill_slice_class(
                &acct.function,
                &acct.class,
                sm * HOST_CACHED_RATE,
                quota,
                dur,
                acct.price_per_hour,
            );
        }
        acct.billed_until = now;
    }

    /// A pod started holding its slice at `now` (billing begins immediately:
    /// cold-starting pods hold — and pay for — their slice before readiness).
    /// The reference-class shorthand for [`BillingLedger::open_on`] at the
    /// ledger's configured price.
    pub fn open(&mut self, pod: PodId, function: &str, sm: SmMille, quota: QuotaMille, now: f64) {
        let price = self.price_per_hour;
        self.open_on(pod, function, sm, quota, crate::vgpu::REFERENCE_CLASS, price, now);
    }

    /// Open a pod account on a specific GPU class at an explicit effective
    /// price (heterogeneous fleets — see [`record_applied`] for the one
    /// class-price derivation both drivers share).
    #[allow(clippy::too_many_arguments)]
    pub fn open_on(
        &mut self,
        pod: PodId,
        function: &str,
        sm: SmMille,
        quota: QuotaMille,
        class: &str,
        price_per_hour: f64,
        now: f64,
    ) {
        let prev = self.accounts.insert(
            pod,
            Account {
                function: function.to_string(),
                sm,
                quota,
                billed_until: now,
                class: class.to_string(),
                price_per_hour,
                resident: true,
            },
        );
        debug_assert!(prev.is_none(), "double-open of {pod:?}");
    }

    /// The pod's weight residency changed at `now` (demotion to
    /// `HostCached` or promotion back): bill the elapsed interval at the
    /// **old** rate, then flip. Same boundary discipline as
    /// [`BillingLedger::resize`].
    pub fn set_resident(&mut self, pod: PodId, resident: bool, now: f64) {
        let Some(acct) = self.accounts.get_mut(&pod) else {
            debug_assert!(false, "set_resident of unopened {pod:?}");
            return;
        };
        Self::accrue(&mut self.meter, self.mode, acct, now);
        acct.resident = resident;
    }

    /// The pod's quota changed at `now`: bill the elapsed interval at the
    /// **old** slice, then switch the account to the new one. This is the
    /// boundary the seed got wrong — it re-billed here with a hard-coded
    /// fine-grained mode regardless of the run's billing mode.
    pub fn resize(&mut self, pod: PodId, quota: QuotaMille, now: f64) {
        let Some(acct) = self.accounts.get_mut(&pod) else {
            debug_assert!(false, "resize of unopened {pod:?}");
            return;
        };
        Self::accrue(&mut self.meter, self.mode, acct, now);
        acct.quota = quota;
    }

    /// The pod released its slice at `now`: bill the final interval and
    /// retire the account.
    pub fn close(&mut self, pod: PodId, now: f64) {
        let Some(mut acct) = self.accounts.remove(&pod) else {
            debug_assert!(false, "close of unopened {pod:?}");
            return;
        };
        Self::accrue(&mut self.meter, self.mode, &mut acct, now);
    }

    /// Bill every open account forward to `now` (end-of-run / report
    /// snapshots). Idempotent: a second settle at the same time bills zero.
    pub fn settle(&mut self, now: f64) {
        for acct in self.accounts.values_mut() {
            Self::accrue(&mut self.meter, self.mode, acct, now);
        }
    }

    /// The accumulated meter (costs are current as of the last boundary
    /// event; call [`Self::settle`] first for up-to-`now` totals).
    pub fn meter(&self) -> &CostMeter {
        &self.meter
    }

    /// Settle at `now` and hand the meter to the caller (end of run).
    pub fn into_meter(mut self, now: f64) -> CostMeter {
        self.settle(now);
        self.meter
    }
}

/// Record a **successfully applied** scaling action: the matching
/// action-counter increment plus the ledger boundary event. This is the one
/// `Applied` → accounting mapping, shared by sim mode
/// (`sim::apply_action`) and real mode (`gateway`) so the two reports
/// cannot drift. Never call this for rejected actions — rejections must
/// neither bill nor count.
pub fn record_applied(
    report: &mut RunReport,
    ledger: &mut BillingLedger,
    cluster: &ClusterState,
    applied: &Applied,
    now: f64,
) {
    match applied {
        Applied::QuotaSet { pod, old, new } => {
            if new > old {
                report.vertical_ups += 1;
            } else {
                report.vertical_downs += 1;
            }
            // Bills the elapsed interval at the *old* slice, then switches.
            ledger.resize(*pod, *new, now);
        }
        Applied::PodCreated { pod, .. } => {
            report.horizontal_ups += 1;
            if let Some(p) = cluster.pod(*pod) {
                // The one class-price derivation: the run's configured price
                // is the *reference-class* rate; other classes scale by the
                // catalog ratio. On the reference class the multiplier is
                // exactly 1.0, so uniform fleets bill the configured price
                // to the bit.
                let class = cluster.gpu(p.gpu).class();
                let price = ledger.price_per_hour * class.price_relative();
                ledger.open_on(*pod, &p.function, p.sm, p.quota, &class.name, price, now);
            } else {
                debug_assert!(false, "created pod {pod:?} missing from cluster");
            }
        }
        Applied::PodRemoved { pod } => {
            report.horizontal_downs += 1;
            ledger.close(*pod, now);
        }
        Applied::PodDemoted { pod } => {
            report.demotions += 1;
            ledger.set_resident(*pod, false, now);
        }
        Applied::PodPromoted { pod, .. } => {
            report.promotions += 1;
            ledger.set_resident(*pod, true, now);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const PRICE: f64 = 3600.0; // $1 per slice-second: costs read as gpu-seconds

    #[test]
    fn record_applied_maps_counters_and_boundary_events() {
        let cluster = ClusterState::new(1, 16e9);
        let mut report = RunReport::new("t");
        let mut l = BillingLedger::new(BillingMode::FineGrained, PRICE);
        l.open(PodId(1), "f", 500, 200, 0.0);
        let up = Applied::QuotaSet { pod: PodId(1), old: 200, new: 400 };
        record_applied(&mut report, &mut l, &cluster, &up, 5.0);
        assert_eq!((report.vertical_ups, report.vertical_downs), (1, 0));
        let down = Applied::QuotaSet { pod: PodId(1), old: 400, new: 300 };
        record_applied(&mut report, &mut l, &cluster, &down, 8.0);
        assert_eq!((report.vertical_ups, report.vertical_downs), (1, 1));
        record_applied(&mut report, &mut l, &cluster, &Applied::PodRemoved { pod: PodId(1) }, 10.0);
        assert_eq!(report.horizontal_downs, 1);
        // 5 s at 0.5×0.2, 3 s at 0.5×0.4, 2 s at 0.5×0.3.
        let expect = 0.5 * (0.2 * 5.0 + 0.4 * 3.0 + 0.3 * 2.0);
        assert!((l.meter().cost_of("f") - expect).abs() < 1e-9);
    }

    #[test]
    fn fine_grained_bills_slice_time_integral() {
        let mut l = BillingLedger::new(BillingMode::FineGrained, PRICE);
        l.open(PodId(1), "f", 500, 400, 0.0);
        l.resize(PodId(1), 800, 10.0); // 10 s at 0.5×0.4
        l.close(PodId(1), 25.0); // 15 s at 0.5×0.8
        let expect = 0.5 * 0.4 * 10.0 + 0.5 * 0.8 * 15.0;
        assert!((l.meter().cost_of("f") - expect).abs() < 1e-9);
        assert!((l.meter().gpu_seconds_of("f") - expect).abs() < 1e-9);
        assert_eq!(l.open_accounts(), 0);
    }

    #[test]
    fn whole_gpu_mode_respected_at_every_boundary() {
        // The seed bug: resize/remove boundaries billed fine-grained even for
        // whole-GPU runs. Each boundary must bill 1×1×dur.
        let mut l = BillingLedger::new(BillingMode::WholeGpu, PRICE);
        l.open(PodId(1), "f", 250, 300, 0.0);
        l.resize(PodId(1), 900, 7.0);
        l.settle(10.0);
        l.close(PodId(1), 12.0);
        assert!((l.meter().cost_of("f") - 12.0).abs() < 1e-9);
    }

    #[test]
    fn settle_is_idempotent_and_time_monotone() {
        let mut l = BillingLedger::new(BillingMode::FineGrained, PRICE);
        l.open(PodId(3), "g", 1000, 1000, 0.0);
        l.settle(5.0);
        l.settle(5.0); // same instant: no double billing
        let at5 = l.meter().cost_of("g");
        assert!((at5 - 5.0).abs() < 1e-9);
        l.close(PodId(3), 5.0); // close at the settled time bills zero more
        assert!((l.meter().cost_of("g") - at5).abs() < 1e-12);
    }

    #[test]
    fn class_accounts_bill_at_their_effective_price_and_tag_the_class() {
        let mut l = BillingLedger::new(BillingMode::FineGrained, PRICE);
        // Reference shorthand and explicit reference open are equivalent.
        l.open(PodId(1), "f", 500, 1000, 0.0);
        l.open_on(PodId(2), "f", 500, 1000, "t4", PRICE * 0.5, 0.0);
        let meter = l.into_meter(10.0);
        // Pod 1: 0.5 slice × 10 s × $1/slice-s; pod 2 at half the rate.
        assert!((meter.class_cost_of("v100") - 5.0).abs() < 1e-9);
        assert!((meter.class_cost_of("t4") - 2.5).abs() < 1e-9);
        assert!((meter.cost_of("f") - 7.5).abs() < 1e-9);
        // GPU-seconds are price-independent.
        assert!((meter.class_gpu_seconds_of("t4") - 5.0).abs() < 1e-9);
        assert!((meter.total_gpu_seconds() - 10.0).abs() < 1e-9);
    }

    #[test]
    fn record_applied_prices_pods_by_their_gpu_class() {
        use crate::cluster::{GpuId, Reconfigurator, ScalingAction};
        use crate::cluster::FunctionSpec;
        use crate::model::zoo::{zoo_graph, ZooModel};
        use crate::perf::PerfModel;
        use crate::vgpu::GpuClass;
        let perf = PerfModel::default();
        let mut cluster = ClusterState::from_classes(&[GpuClass::v100(), GpuClass::t4()]);
        cluster.register_function(FunctionSpec {
            name: "mobilenetv2".into(),
            graph: zoo_graph(ZooModel::MobileNetV2),
            slo: 0.1,
            batch: 1,
            artifact: None,
        });
        let mut recon = Reconfigurator::new(&cluster, 5);
        let mut report = RunReport::new("t");
        let mut l = BillingLedger::new(BillingMode::FineGrained, PRICE);
        for gpu in [GpuId(0), GpuId(1)] {
            let applied = recon
                .apply(
                    &mut cluster,
                    &perf,
                    &ScalingAction::CreatePod {
                        function: "mobilenetv2".into(),
                        gpu,
                        sm: 500,
                        quota: 1000,
                        batch: 1,
                        new_gpu: true,
                    },
                    0.0,
                )
                .unwrap();
            record_applied(&mut report, &mut l, &cluster, &applied, 0.0);
        }
        let meter = l.into_meter(10.0);
        // v100 bills the configured reference rate; t4 scales by catalog
        // ratio (0.95 / 2.48).
        let t4_ratio = GpuClass::t4().price_relative();
        assert!((meter.class_cost_of("v100") - 0.5 * 10.0).abs() < 1e-9);
        assert!((meter.class_cost_of("t4") - 0.5 * 10.0 * t4_ratio).abs() < 1e-9);
        assert_eq!(report.horizontal_ups, 2);
    }

    #[test]
    fn host_cached_state_bills_reduced_rate_at_boundaries() {
        let mut l = BillingLedger::new(BillingMode::FineGrained, PRICE);
        l.open(PodId(1), "f", 500, 400, 0.0);
        l.set_resident(PodId(1), false, 10.0); // 10 s resident
        l.set_resident(PodId(1), true, 30.0); // 20 s parked
        l.close(PodId(1), 35.0); // 5 s resident again
        let expect = 0.5 * 0.4 * (10.0 + 5.0) + 0.5 * HOST_CACHED_RATE * 0.4 * 20.0;
        assert!((l.meter().cost_of("f") - expect).abs() < 1e-9);

        // Whole-GPU mode: the parked multiplier applies to the full device.
        let mut l = BillingLedger::new(BillingMode::WholeGpu, PRICE);
        l.open(PodId(2), "g", 250, 300, 0.0);
        l.set_resident(PodId(2), false, 4.0);
        l.close(PodId(2), 10.0);
        let expect = 4.0 + HOST_CACHED_RATE * 6.0;
        assert!((l.meter().cost_of("g") - expect).abs() < 1e-9);
    }

    #[test]
    fn record_applied_maps_lifecycle_transitions() {
        let cluster = ClusterState::new(1, 16e9);
        let mut report = RunReport::new("t");
        let mut l = BillingLedger::new(BillingMode::FineGrained, PRICE);
        l.open(PodId(1), "f", 500, 1000, 0.0);
        record_applied(&mut report, &mut l, &cluster, &Applied::PodDemoted { pod: PodId(1) }, 10.0);
        assert_eq!((report.demotions, report.promotions), (1, 0));
        record_applied(
            &mut report,
            &mut l,
            &cluster,
            &Applied::PodPromoted { pod: PodId(1), ready_at: 12.0 },
            12.0,
        );
        assert_eq!((report.demotions, report.promotions), (1, 1));
        l.close(PodId(1), 20.0);
        let expect = 0.5 * 10.0 + 0.5 * HOST_CACHED_RATE * 2.0 + 0.5 * 8.0;
        assert!((l.meter().cost_of("f") - expect).abs() < 1e-9);
    }

    #[test]
    fn concurrent_pods_bill_independently() {
        let mut l = BillingLedger::new(BillingMode::FineGrained, PRICE);
        l.open(PodId(1), "a", 500, 1000, 0.0);
        l.open(PodId(2), "b", 250, 400, 2.0);
        let meter = l.into_meter(10.0);
        assert!((meter.cost_of("a") - 0.5 * 10.0).abs() < 1e-9);
        assert!((meter.cost_of("b") - 0.25 * 0.4 * 8.0).abs() < 1e-9);
    }
}
