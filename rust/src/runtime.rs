//! PJRT runtime: loads AOT-compiled HLO-text artifacts (produced once by
//! `python/compile/aot.py` from the JAX/Pallas layers) and executes them from
//! the Rust request path. Python is never on this path.
//!
//! Interchange is HLO **text**, not serialized `HloModuleProto` — jax ≥ 0.5
//! emits protos with 64-bit instruction ids which xla_extension 0.5.1
//! rejects; the text parser reassigns ids (see `/opt/xla-example/README.md`).
//!
//! The runtime keeps one PJRT CPU client and a compiled-executable cache
//! keyed by artifact path; `infer` is thread-safe (PJRT CPU execution is
//! internally synchronized; we additionally serialise calls per executable to
//! model one physical accelerator per node).
//!
//! ## Feature gate
//!
//! The actual PJRT backend needs the `xla` bindings crate, which is not
//! available in the offline reproduction environment. It is therefore gated
//! behind the `pjrt` cargo feature: with the feature off (the default),
//! [`PjrtRuntime`] is an API-compatible stub whose `infer`/`warmup` return a
//! clear "built without the `pjrt` feature" error, so the control plane, the
//! simulator, and every non-execution test build and pass unchanged.

use crate::util::json;
use anyhow::Result;
use std::path::{Path, PathBuf};
use std::sync::Arc;

/// Metadata for one servable model artifact (from `artifacts/manifest.json`).
#[derive(Clone, Debug)]
pub struct ModelArtifact {
    pub name: String,
    pub path: PathBuf,
    pub batch: usize,
    /// Flat input length per item (the L2 models take one `[batch, dim]` input).
    pub input_dim: usize,
    /// Flat output length per item.
    pub output_dim: usize,
}

/// The artifact manifest written by `python/compile/aot.py`.
#[derive(Clone, Debug, Default)]
pub struct Manifest {
    pub models: Vec<ModelArtifact>,
    /// RaPP artifact paths, if present.
    pub rapp_hlo: Option<PathBuf>,
    pub rapp_weights: Option<PathBuf>,
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Self> {
        let j = json::parse_file(&dir.join("manifest.json"))?;
        let mut models = Vec::new();
        for m in j.get("models")?.as_arr()? {
            models.push(ModelArtifact {
                name: m.get("name")?.as_str()?.to_string(),
                path: dir.join(m.get("path")?.as_str()?),
                batch: m.get("batch")?.as_usize()?,
                input_dim: m.get("input_dim")?.as_usize()?,
                output_dim: m.get("output_dim")?.as_usize()?,
            });
        }
        let opt_path = |key: &str| -> Option<PathBuf> {
            j.opt(key)
                .and_then(|v| v.as_str().ok())
                .map(|s| dir.join(s))
        };
        Ok(Manifest {
            models,
            rapp_hlo: opt_path("rapp_hlo"),
            rapp_weights: opt_path("rapp_weights"),
        })
    }

    /// Artifacts for `model` at any batch, smallest batch first.
    pub fn variants(&self, model: &str) -> Vec<&ModelArtifact> {
        let mut v: Vec<&ModelArtifact> =
            self.models.iter().filter(|m| m.name == model).collect();
        v.sort_by_key(|m| m.batch);
        v
    }

    /// The artifact for `model` with batch ≥ `batch` (or the largest).
    pub fn for_batch(&self, model: &str, batch: usize) -> Option<&ModelArtifact> {
        let vs = self.variants(model);
        vs.iter()
            .find(|m| m.batch >= batch)
            .copied()
            .or_else(|| vs.last().copied())
    }
}

/// Result of one inference execution.
#[derive(Clone, Debug)]
pub struct InferOutput {
    pub values: Vec<f32>,
    /// Pure execution time (excludes queueing/token waits).
    pub exec_time: std::time::Duration,
}

pub use backend::PjrtRuntime;

/// The real PJRT backend (requires the `xla` bindings crate).
#[cfg(feature = "pjrt")]
mod backend {
    use super::InferOutput;
    use anyhow::{Context, Result};
    use std::collections::HashMap;
    use std::path::{Path, PathBuf};
    use std::sync::{Arc, Mutex};
    use std::time::Instant;

    struct Compiled {
        exe: xla::PjRtLoadedExecutable,
        /// PJRT CPU executables are not re-entrant across our pods; one lock
        /// per executable models one accelerator per node anyway.
        lock: Mutex<()>,
    }

    /// The PJRT runtime with an executable cache.
    pub struct PjrtRuntime {
        client: xla::PjRtClient,
        cache: Mutex<HashMap<PathBuf, Arc<Compiled>>>,
    }

    // SAFETY: the xla crate wraps C++ PJRT objects behind pointers without
    // Send/Sync markers; the PJRT CPU client is thread-safe for compilation,
    // and we serialise execution through `Compiled::lock`.
    unsafe impl Send for PjrtRuntime {}
    unsafe impl Sync for PjrtRuntime {}

    impl PjrtRuntime {
        pub fn new() -> Result<Self> {
            Ok(PjrtRuntime {
                client: xla::PjRtClient::cpu().context("creating PJRT CPU client")?,
                cache: Mutex::new(HashMap::new()),
            })
        }

        pub fn platform(&self) -> String {
            self.client.platform_name()
        }

        /// Load + compile an HLO-text artifact (cached).
        fn compiled(&self, path: &Path) -> Result<Arc<Compiled>> {
            if let Some(c) = self.cache.lock().unwrap().get(path) {
                return Ok(Arc::clone(c));
            }
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().context("non-utf8 artifact path")?,
            )
            .with_context(|| format!("parsing HLO text {}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .with_context(|| format!("compiling {}", path.display()))?;
            let c = Arc::new(Compiled {
                exe,
                lock: Mutex::new(()),
            });
            self.cache
                .lock()
                .unwrap()
                .insert(path.to_path_buf(), Arc::clone(&c));
            Ok(c)
        }

        /// Pre-compile an artifact (warm-up; keeps first-request latency flat).
        pub fn warmup(&self, path: &Path) -> Result<()> {
            self.compiled(path).map(|_| ())
        }

        pub fn cache_len(&self) -> usize {
            self.cache.lock().unwrap().len()
        }

        /// Execute an artifact on f32 inputs. Each input is (flat values,
        /// dims). The computation must return a 1-tuple (jax lowered with
        /// `return_tuple=True`); returns the flattened f32 output.
        pub fn infer(&self, path: &Path, inputs: &[(&[f32], &[i64])]) -> Result<InferOutput> {
            let compiled = self.compiled(path)?;
            let mut lits = Vec::with_capacity(inputs.len());
            for (vals, dims) in inputs {
                let lit = xla::Literal::vec1(vals)
                    .reshape(dims)
                    .with_context(|| format!("reshaping input to {dims:?}"))?;
                lits.push(lit);
            }
            let _guard = compiled.lock.lock().unwrap();
            let t0 = Instant::now();
            let result = compiled.exe.execute::<xla::Literal>(&lits)?[0][0].to_literal_sync()?;
            let exec_time = t0.elapsed();
            let out = result.to_tuple1().context("expected 1-tuple output")?;
            Ok(InferOutput {
                values: out.to_vec::<f32>()?,
                exec_time,
            })
        }
    }
}

/// Offline stub backend: same API surface, no execution capability.
#[cfg(not(feature = "pjrt"))]
mod backend {
    use super::InferOutput;
    use anyhow::Result;
    use std::path::Path;

    /// API-compatible stand-in for the PJRT runtime. Construction succeeds
    /// (callers hold it behind `Arc` long before first execution); any
    /// attempt to compile or execute an artifact reports the missing
    /// feature instead of aborting the process.
    pub struct PjrtRuntime {
        _priv: (),
    }

    fn unavailable(path: &Path) -> anyhow::Error {
        anyhow::anyhow!(
            "PJRT execution unavailable: has_gpu was built without the `pjrt` feature \
             (artifact: {})",
            path.display()
        )
    }

    impl PjrtRuntime {
        pub fn new() -> Result<Self> {
            Ok(PjrtRuntime { _priv: () })
        }

        pub fn platform(&self) -> String {
            "pjrt-stub (feature disabled)".to_string()
        }

        /// Stub: always fails — surfacing the configuration problem at server
        /// start-up instead of silently dropping every request later.
        pub fn warmup(&self, path: &Path) -> Result<()> {
            Err(unavailable(path))
        }

        pub fn cache_len(&self) -> usize {
            0
        }

        pub fn infer(&self, path: &Path, _inputs: &[(&[f32], &[i64])]) -> Result<InferOutput> {
            Err(unavailable(path))
        }
    }
}

/// RaPP's AOT-compiled forward (the L1+L2 artifact executed via PJRT).
///
/// Inputs (padded to `MAX_NODES` = 64, matching
/// `python/compile/features.py`): op features `[64, F_OP]`, symmetrised
/// adjacency-with-self-loops mask `[64, 64]`, node mask `[64]`, graph
/// features `[F_G]`. Output: `[1]` predicted ln(latency_ms).
pub struct PjrtRapp {
    runtime: Arc<PjrtRuntime>,
    path: PathBuf,
    pub f_op: usize,
    pub f_g: usize,
}

pub const RAPP_MAX_NODES: usize = 64;

impl PjrtRapp {
    pub fn new(runtime: Arc<PjrtRuntime>, path: PathBuf, f_op: usize, f_g: usize) -> Self {
        PjrtRapp {
            runtime,
            path,
            f_op,
            f_g,
        }
    }

    /// Predict ln(latency_ms) from extracted features (normalisation is baked
    /// into the python-side graph, so raw features go in).
    pub fn forward(&self, feats: &crate::rapp::features::Features) -> Result<f32> {
        let n = feats.op_feats.len();
        anyhow::ensure!(
            n <= RAPP_MAX_NODES,
            "graph has {n} nodes > RAPP_MAX_NODES"
        );
        let mut x = vec![0.0f32; RAPP_MAX_NODES * self.f_op];
        for (i, row) in feats.op_feats.iter().enumerate() {
            anyhow::ensure!(row.len() == self.f_op, "op feature dim mismatch");
            x[i * self.f_op..(i + 1) * self.f_op].copy_from_slice(row);
        }
        let mut adj = vec![0.0f32; RAPP_MAX_NODES * RAPP_MAX_NODES];
        // Self-loops on every row, including padding (contract with
        // python/compile/features.py::pad_for_hlo).
        for i in 0..RAPP_MAX_NODES {
            adj[i * RAPP_MAX_NODES + i] = 1.0;
        }
        for &(s, d) in &feats.edges {
            adj[d * RAPP_MAX_NODES + s] = 1.0;
            adj[s * RAPP_MAX_NODES + d] = 1.0;
        }
        let mut mask = vec![0.0f32; RAPP_MAX_NODES];
        for m in mask.iter_mut().take(n) {
            *m = 1.0;
        }
        anyhow::ensure!(feats.graph_feats.len() == self.f_g, "graph feature dim mismatch");
        let out = self.runtime.infer(
            &self.path,
            &[
                (&x, &[RAPP_MAX_NODES as i64, self.f_op as i64]),
                (&adj, &[RAPP_MAX_NODES as i64, RAPP_MAX_NODES as i64]),
                (&mask, &[RAPP_MAX_NODES as i64]),
                (feats.graph_feats.as_slice(), &[self.f_g as i64]),
            ],
        )?;
        anyhow::ensure!(!out.values.is_empty(), "empty RaPP output");
        Ok(out.values[0])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Write a tiny HLO-text module equivalent to what aot.py emits and run
    /// it through the full load-compile-execute path (real backend only).
    #[cfg(feature = "pjrt")]
    fn write_test_hlo(dir: &Path) -> PathBuf {
        // f(x, y) = (x @ y + 2.0,) over f32[2,2] — matches the reference
        // round-trip from /opt/xla-example.
        let hlo = r#"HloModule jit_fn, entry_computation_layout={(f32[2,2]{1,0}, f32[2,2]{1,0})->(f32[2,2]{1,0})}

ENTRY main.7 {
  Arg_0.1 = f32[2,2]{1,0} parameter(0)
  Arg_1.2 = f32[2,2]{1,0} parameter(1)
  dot.3 = f32[2,2]{1,0} dot(Arg_0.1, Arg_1.2), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  constant.4 = f32[] constant(2)
  broadcast.5 = f32[2,2]{1,0} broadcast(constant.4), dimensions={}
  add.6 = f32[2,2]{1,0} add(dot.3, broadcast.5)
  ROOT tuple.7 = (f32[2,2]{1,0}) tuple(add.6)
}
"#;
        let path = dir.join("test_fn.hlo.txt");
        std::fs::write(&path, hlo).unwrap();
        path
    }

    #[cfg(feature = "pjrt")]
    #[test]
    fn load_execute_roundtrip() {
        let dir = std::env::temp_dir().join(format!("hasgpu-rt-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = write_test_hlo(&dir);
        let rt = PjrtRuntime::new().unwrap();
        let x = [1f32, 2.0, 3.0, 4.0];
        let y = [1f32, 1.0, 1.0, 1.0];
        let out = rt
            .infer(&path, &[(&x, &[2, 2]), (&y, &[2, 2])])
            .unwrap();
        assert_eq!(out.values, vec![5.0, 5.0, 9.0, 9.0]);
        assert!(out.exec_time.as_nanos() > 0);
        // Second call hits the cache.
        assert_eq!(rt.cache_len(), 1);
        let out2 = rt.infer(&path, &[(&x, &[2, 2]), (&y, &[2, 2])]).unwrap();
        assert_eq!(out2.values, out.values);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn missing_artifact_errors_cleanly() {
        let rt = PjrtRuntime::new().unwrap();
        let err = rt.infer(Path::new("/nonexistent/model.hlo.txt"), &[]);
        assert!(err.is_err());
    }

    #[cfg(not(feature = "pjrt"))]
    #[test]
    fn stub_reports_missing_feature() {
        let rt = PjrtRuntime::new().unwrap();
        assert_eq!(rt.cache_len(), 0);
        assert!(rt.platform().contains("stub"));
        let err = rt.warmup(Path::new("whatever.hlo.txt")).unwrap_err();
        assert!(err.to_string().contains("pjrt"), "{err}");
    }

    #[test]
    fn manifest_parses() {
        let dir = std::env::temp_dir().join(format!("hasgpu-man-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("manifest.json"),
            r#"{"models": [
                {"name": "cnn_s", "path": "models/cnn_s_b4.hlo.txt", "batch": 4, "input_dim": 3072, "output_dim": 10},
                {"name": "cnn_s", "path": "models/cnn_s_b1.hlo.txt", "batch": 1, "input_dim": 3072, "output_dim": 10}
            ], "rapp_hlo": "rapp.hlo.txt", "rapp_weights": "rapp_weights.json"}"#,
        )
        .unwrap();
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.models.len(), 2);
        assert_eq!(m.variants("cnn_s")[0].batch, 1);
        assert_eq!(m.for_batch("cnn_s", 3).unwrap().batch, 4);
        assert_eq!(m.for_batch("cnn_s", 100).unwrap().batch, 4);
        assert!(m.rapp_hlo.is_some());
        std::fs::remove_dir_all(&dir).ok();
    }
}
