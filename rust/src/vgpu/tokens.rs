//! Real-mode temporal enforcement: the time-window token scheduler.
//!
//! In the paper, a pod's `cuLaunchKernel` calls are intercepted by `libhas`
//! and each launch must obtain a **time token** from the pod's GPU client in
//! the HAS-GPU-Scheduler; a pod holding quota `q` receives `q·W` seconds of
//! execution budget per scheduling window `W` (Fig. 2). Vertical scaling
//! re-writes the quota; the change takes effect at the next window boundary.
//!
//! Here the interception point is the pod executor's call to PJRT `execute`
//! (on TPU-style hardware there is no per-kernel launch to gate — see
//! DESIGN.md §Hardware-Adaptation), which requests a token for its estimated
//! kernel time before running. Kernels are non-preemptible, so a grant may
//! overdraw the current window; the debt is charged against future windows —
//! exactly the behaviour that makes long kernels insensitive to extra quota
//! (Fig. 4's SM-starved regime).

use super::{ClientId, QuotaMille, QUOTA_FULL};
use std::collections::BTreeMap;
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

#[derive(Debug)]
struct ClientState {
    quota: QuotaMille,
    /// Quota re-write staged by vertical scaling; applied at window rollover.
    pending_quota: Option<QuotaMille>,
    /// Remaining execution budget in this window, seconds. May be negative
    /// (non-preemptible overdraw).
    budget: f64,
    /// Lifetime token-seconds granted (metrics / cost accounting).
    granted_total: f64,
}

struct State {
    window: f64,
    window_start: Instant,
    epoch: u64,
    clients: BTreeMap<ClientId, ClientState>,
}

impl State {
    /// Roll windows forward if wall time passed one or more boundaries.
    /// Budgets refill by quota per elapsed window (capped at one window's
    /// worth above zero so idle pods don't hoard unbounded credit).
    fn refill(&mut self, now: Instant) {
        let elapsed = now.duration_since(self.window_start).as_secs_f64();
        if elapsed < self.window {
            return;
        }
        let windows = (elapsed / self.window) as u64;
        self.window_start += Duration::from_secs_f64(windows as f64 * self.window);
        self.epoch += windows;
        let _ = windows;
        for c in self.clients.values_mut() {
            if let Some(q) = c.pending_quota.take() {
                c.quota = q;
            }
            // No-debt, no-banking semantics (cgroups-CFS style, and the same
            // rule as PerfModel::latency): the budget RESETS to one window's
            // grant at each boundary. Overruns by non-preemptible kernels are
            // forgiven; idle windows don't accumulate credit.
            c.budget = c.quota as f64 / QUOTA_FULL as f64 * self.window;
        }
    }
}

/// Per-vGPU token scheduler shared by that GPU's clients.
#[derive(Clone)]
pub struct TokenScheduler {
    inner: Arc<(Mutex<State>, Condvar)>,
}

impl TokenScheduler {
    pub fn new(window_secs: f64) -> Self {
        TokenScheduler {
            inner: Arc::new((
                Mutex::new(State {
                    window: window_secs,
                    window_start: Instant::now(),
                    epoch: 0,
                    clients: BTreeMap::new(),
                }),
                Condvar::new(),
            )),
        }
    }

    pub fn window(&self) -> f64 {
        self.inner.0.lock().unwrap().window
    }

    /// Register a client with an initial quota. Its first window's budget is
    /// granted immediately (a cold-started pod can run right away).
    pub fn register(&self, id: ClientId, quota: QuotaMille) {
        let (m, _) = &*self.inner;
        let mut st = m.lock().unwrap();
        let per_window = quota as f64 / QUOTA_FULL as f64 * st.window;
        st.clients.insert(
            id,
            ClientState {
                quota,
                pending_quota: None,
                budget: per_window,
                granted_total: 0.0,
            },
        );
    }

    pub fn deregister(&self, id: ClientId) {
        let (m, cv) = &*self.inner;
        m.lock().unwrap().clients.remove(&id);
        cv.notify_all();
    }

    /// Stage a quota re-write (vertical scaling). Takes effect at the next
    /// window boundary, per Fig. 2. Returns the previous (target) quota.
    pub fn set_quota(&self, id: ClientId, quota: QuotaMille) -> Option<QuotaMille> {
        let (m, cv) = &*self.inner;
        let mut st = m.lock().unwrap();
        let c = st.clients.get_mut(&id)?;
        let old = c.pending_quota.unwrap_or(c.quota);
        c.pending_quota = Some(quota);
        cv.notify_all();
        Some(old)
    }

    /// Current effective quota.
    pub fn quota(&self, id: ClientId) -> Option<QuotaMille> {
        self.inner.0.lock().unwrap().clients.get(&id).map(|c| c.quota)
    }

    /// Total token-seconds granted to a client so far.
    pub fn granted_total(&self, id: ClientId) -> Option<f64> {
        self.inner
            .0
            .lock()
            .unwrap()
            .clients
            .get(&id)
            .map(|c| c.granted_total)
    }

    /// Block until `cost` seconds of execution budget are available, then
    /// debit them. Non-preemptible semantics: the grant succeeds as soon as
    /// the budget is **positive**; `cost` may push it negative (overdraw
    /// repaid by future refills).
    ///
    /// Returns the time spent waiting for tokens.
    pub fn acquire(&self, id: ClientId, cost: f64) -> Result<Duration, TokenError> {
        let t0 = Instant::now();
        let (m, cv) = &*self.inner;
        let mut st = m.lock().unwrap();
        loop {
            let now = Instant::now();
            st.refill(now);
            let window = st.window;
            let window_start = st.window_start;
            match st.clients.get_mut(&id) {
                None => return Err(TokenError::Deregistered(id)),
                Some(c) => {
                    if c.quota == 0 && c.pending_quota.is_none() {
                        return Err(TokenError::ZeroQuota(id));
                    }
                    if c.budget > 0.0 {
                        c.budget -= cost;
                        c.granted_total += cost;
                        return Ok(t0.elapsed());
                    }
                }
            }
            // Sleep until the next window boundary (plus a hair) or a notify.
            let until_next = window - now.duration_since(window_start).as_secs_f64();
            let wait = Duration::from_secs_f64(until_next.max(1e-4));
            let (guard, _timeout) = cv.wait_timeout(st, wait).unwrap();
            st = guard;
        }
    }

    /// Non-blocking variant: try to debit; Err(wait hint) if no budget.
    pub fn try_acquire(&self, id: ClientId, cost: f64) -> Result<(), TokenError> {
        let (m, _) = &*self.inner;
        let mut st = m.lock().unwrap();
        st.refill(Instant::now());
        match st.clients.get_mut(&id) {
            None => Err(TokenError::Deregistered(id)),
            Some(c) if c.budget > 0.0 => {
                c.budget -= cost;
                c.granted_total += cost;
                Ok(())
            }
            Some(_) => Err(TokenError::WouldBlock),
        }
    }
}

#[derive(Debug, thiserror::Error, PartialEq)]
pub enum TokenError {
    #[error("client {0:?} deregistered")]
    Deregistered(ClientId),
    #[error("client {0:?} has zero quota")]
    ZeroQuota(ClientId),
    #[error("no budget available")]
    WouldBlock,
}

#[cfg(test)]
mod tests {
    use super::*;

    const W: f64 = 0.005; // 5 ms windows keep tests fast

    #[test]
    fn full_quota_never_blocks_much() {
        let ts = TokenScheduler::new(W);
        ts.register(ClientId(1), QUOTA_FULL);
        let mut total_wait = 0.0;
        for _ in 0..50 {
            let waited = ts.acquire(ClientId(1), W * 0.5).unwrap();
            total_wait += waited.as_secs_f64();
        }
        // Full quota admits ~2 grants per window; the average wait stays
        // well under a window (averaged to tolerate scheduler jitter).
        assert!(
            total_wait / 50.0 < W * 2.0,
            "avg wait {}",
            total_wait / 50.0
        );
    }

    #[test]
    fn half_quota_dilates_execution() {
        let ts = TokenScheduler::new(W);
        ts.register(ClientId(1), 500);
        let t0 = Instant::now();
        // Consume 10 windows' worth of full-GPU time at 50% quota: should
        // take ≈ 2× the raw time.
        let raw = 10.0 * W;
        let mut consumed = 0.0;
        while consumed < raw {
            let step = W * 0.25;
            ts.acquire(ClientId(1), step).unwrap();
            consumed += step;
        }
        let elapsed = t0.elapsed().as_secs_f64();
        assert!(
            elapsed > raw * 1.5 && elapsed < raw * 3.5,
            "expected ~2x dilation, elapsed {elapsed} vs raw {raw}"
        );
    }

    #[test]
    fn quota_rewrite_takes_effect_next_window() {
        let ts = TokenScheduler::new(W);
        ts.register(ClientId(1), 100);
        // Drain the initial budget.
        ts.acquire(ClientId(1), W).unwrap();
        ts.set_quota(ClientId(1), QUOTA_FULL);
        assert_eq!(ts.quota(ClientId(1)), Some(100)); // not yet applied
        std::thread::sleep(Duration::from_secs_f64(W * 1.5));
        ts.acquire(ClientId(1), W * 0.1).unwrap();
        assert_eq!(ts.quota(ClientId(1)), Some(QUOTA_FULL));
    }

    #[test]
    fn quota_rewrite_boundary_semantics_independent_of_kernel_time_scale() {
        // GPU classes scale *kernel durations* (a faster class issues
        // cheaper acquires), never the scheduler: the token window is a
        // scheduler constant and a staged quota re-write must land at the
        // next window boundary regardless of how the class clock scales the
        // per-acquire cost. Two clients whose costs differ by a 2x "class
        // factor" must observe the identical rewrite protocol.
        for class_factor in [1.0f64, 2.0, 0.4] {
            let ts = TokenScheduler::new(W);
            ts.register(ClientId(1), 200);
            // Drain the current window's budget (overdraw is allowed; the
            // absolute cost magnitude is irrelevant to the protocol).
            ts.acquire(ClientId(1), W).unwrap();
            ts.set_quota(ClientId(1), 800);
            // Staged, not applied: reads must still see the old quota…
            assert_eq!(
                ts.quota(ClientId(1)),
                Some(200),
                "factor {class_factor}: rewrite must wait for the boundary"
            );
            // …and a second stage before the boundary replaces the pending
            // value (returns the previously staged target).
            assert_eq!(ts.set_quota(ClientId(1), 600), Some(800));
            std::thread::sleep(Duration::from_secs_f64(W * 1.5));
            ts.acquire(ClientId(1), W * 0.01 / class_factor).unwrap();
            assert_eq!(
                ts.quota(ClientId(1)),
                Some(600),
                "factor {class_factor}: rewrite must land at the boundary"
            );
        }
    }

    #[test]
    fn zero_quota_rejected() {
        let ts = TokenScheduler::new(W);
        ts.register(ClientId(1), 0);
        assert_eq!(
            ts.acquire(ClientId(1), 0.001),
            Err(TokenError::ZeroQuota(ClientId(1)))
        );
    }

    #[test]
    fn deregistered_client_unblocks() {
        // Long window so the blocked acquire cannot be released by a
        // boundary before the deregister lands.
        let wl = 0.5;
        let ts = TokenScheduler::new(wl);
        ts.register(ClientId(1), 10);
        // Drain this window's budget (no-debt: resets only at the boundary).
        ts.acquire(ClientId(1), wl).unwrap();
        let ts2 = ts.clone();
        let h = std::thread::spawn(move || ts2.acquire(ClientId(1), wl * 0.1));
        std::thread::sleep(Duration::from_millis(50));
        ts.deregister(ClientId(1));
        let r = h.join().unwrap();
        assert_eq!(r, Err(TokenError::Deregistered(ClientId(1))));
    }

    #[test]
    fn two_clients_share_proportionally() {
        let ts = TokenScheduler::new(W);
        ts.register(ClientId(1), 750);
        ts.register(ClientId(2), 250);
        let run = |id: ClientId, ts: TokenScheduler| {
            std::thread::spawn(move || {
                let mut consumed = 0.0;
                let t0 = Instant::now();
                while consumed < 5.0 * W {
                    ts.acquire(id, W * 0.25).unwrap();
                    consumed += W * 0.25;
                }
                t0.elapsed().as_secs_f64()
            })
        };
        let h1 = run(ClientId(1), ts.clone());
        let h2 = run(ClientId(2), ts.clone());
        let t1 = h1.join().unwrap();
        let t2 = h2.join().unwrap();
        // 750‰ client finishes distinctly faster than the 250‰ client.
        assert!(t1 < t2, "t1={t1} t2={t2}");
    }

    #[test]
    fn try_acquire_would_block_when_drained() {
        let ts = TokenScheduler::new(W);
        ts.register(ClientId(1), 500);
        ts.try_acquire(ClientId(1), W * 10.0).unwrap(); // overdraw deeply
        assert_eq!(
            ts.try_acquire(ClientId(1), 0.0001),
            Err(TokenError::WouldBlock)
        );
    }

    #[test]
    fn granted_total_accumulates() {
        let ts = TokenScheduler::new(W);
        ts.register(ClientId(1), QUOTA_FULL);
        ts.acquire(ClientId(1), 0.001).unwrap();
        ts.acquire(ClientId(1), 0.002).unwrap();
        assert!((ts.granted_total(ClientId(1)).unwrap() - 0.003).abs() < 1e-12);
    }
}
