//! The per-vGPU resource-configuration "device files".
//!
//! In the paper each vGPU is associated with two configuration device files in
//! the host filesystem: the GPU Re-configurator writes fine-grained resource
//! allocation instructions into them, and the HAS-GPU-Scheduler picks the
//! changes up at runtime (§3, Fig. 1). We reproduce the same decoupling with
//! an in-process versioned store that can optionally be mirrored to real
//! files (useful for debugging and for the `has-gpu serve --state-dir` CLI).

use super::{ClientId, QuotaMille, SmMille};
use crate::util::json::Json;
use std::collections::BTreeMap;
use std::path::PathBuf;
use std::sync::{Arc, Mutex};

/// Contents of the **partition file**: SM partition per client.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct PartitionConfig {
    pub entries: BTreeMap<ClientId, SmMille>,
}

/// Contents of the **quota file**: time-window length + quota per client.
#[derive(Clone, Debug, PartialEq)]
pub struct QuotaConfig {
    pub window_secs: f64,
    pub entries: BTreeMap<ClientId, QuotaMille>,
}

impl Default for QuotaConfig {
    fn default() -> Self {
        QuotaConfig {
            window_secs: 0.025,
            entries: BTreeMap::new(),
        }
    }
}

struct Inner {
    partition: PartitionConfig,
    quota: QuotaConfig,
    version: u64,
    mirror_dir: Option<PathBuf>,
}

/// The pair of device files for one vGPU.
#[derive(Clone)]
pub struct DeviceFile {
    gpu_uuid: String,
    inner: Arc<Mutex<Inner>>,
}

impl DeviceFile {
    pub fn new(gpu_uuid: &str) -> Self {
        DeviceFile {
            gpu_uuid: gpu_uuid.to_string(),
            inner: Arc::new(Mutex::new(Inner {
                partition: PartitionConfig::default(),
                quota: QuotaConfig::default(),
                version: 0,
                mirror_dir: None,
            })),
        }
    }

    /// Mirror every write to `<dir>/<uuid>.partition.json` and
    /// `<dir>/<uuid>.quota.json`.
    pub fn with_mirror(self, dir: &std::path::Path) -> std::io::Result<Self> {
        std::fs::create_dir_all(dir)?;
        self.inner.lock().unwrap().mirror_dir = Some(dir.to_path_buf());
        self.flush()?;
        Ok(self)
    }

    pub fn gpu_uuid(&self) -> &str {
        &self.gpu_uuid
    }

    /// Monotone version counter; bumps on every write. The scheduler polls it
    /// to detect reconfiguration.
    pub fn version(&self) -> u64 {
        self.inner.lock().unwrap().version
    }

    /// Write a client's full configuration (re-configurator side).
    pub fn write_client(&self, id: ClientId, sm: SmMille, quota: QuotaMille) {
        let mut inner = self.inner.lock().unwrap();
        inner.partition.entries.insert(id, sm);
        inner.quota.entries.insert(id, quota);
        inner.version += 1;
        Self::mirror(&inner, &self.gpu_uuid);
    }

    /// Update only the quota entry (vertical scaling re-write).
    pub fn write_quota(&self, id: ClientId, quota: QuotaMille) {
        let mut inner = self.inner.lock().unwrap();
        inner.quota.entries.insert(id, quota);
        inner.version += 1;
        Self::mirror(&inner, &self.gpu_uuid);
    }

    /// Remove a client from both files.
    pub fn remove_client(&self, id: ClientId) {
        let mut inner = self.inner.lock().unwrap();
        inner.partition.entries.remove(&id);
        inner.quota.entries.remove(&id);
        inner.version += 1;
        Self::mirror(&inner, &self.gpu_uuid);
    }

    pub fn set_window(&self, window_secs: f64) {
        let mut inner = self.inner.lock().unwrap();
        inner.quota.window_secs = window_secs;
        inner.version += 1;
        Self::mirror(&inner, &self.gpu_uuid);
    }

    /// Read both files (scheduler side).
    pub fn read(&self) -> (PartitionConfig, QuotaConfig, u64) {
        let inner = self.inner.lock().unwrap();
        (inner.partition.clone(), inner.quota.clone(), inner.version)
    }

    fn mirror(inner: &Inner, uuid: &str) {
        if let Some(dir) = &inner.mirror_dir {
            let part = Json::obj(vec![(
                "clients",
                Json::Arr(
                    inner
                        .partition
                        .entries
                        .iter()
                        .map(|(c, &sm)| {
                            Json::obj(vec![
                                ("client", Json::Num(c.0 as f64)),
                                ("sm_mille", Json::Num(sm as f64)),
                            ])
                        })
                        .collect(),
                ),
            )]);
            let quota = Json::obj(vec![
                ("window_secs", Json::Num(inner.quota.window_secs)),
                (
                    "clients",
                    Json::Arr(
                        inner
                            .quota
                            .entries
                            .iter()
                            .map(|(c, &q)| {
                                Json::obj(vec![
                                    ("client", Json::Num(c.0 as f64)),
                                    ("quota_mille", Json::Num(q as f64)),
                                ])
                            })
                            .collect(),
                    ),
                ),
            ]);
            let _ = std::fs::write(
                dir.join(format!("{uuid}.partition.json")),
                part.to_string_pretty(),
            );
            let _ = std::fs::write(
                dir.join(format!("{uuid}.quota.json")),
                quota.to_string_pretty(),
            );
        }
    }

    fn flush(&self) -> std::io::Result<()> {
        let inner = self.inner.lock().unwrap();
        Self::mirror(&inner, &self.gpu_uuid);
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn versions_bump_on_writes() {
        let df = DeviceFile::new("GPU-0");
        assert_eq!(df.version(), 0);
        df.write_client(ClientId(1), 500, 300);
        assert_eq!(df.version(), 1);
        df.write_quota(ClientId(1), 600);
        assert_eq!(df.version(), 2);
        let (p, q, v) = df.read();
        assert_eq!(p.entries[&ClientId(1)], 500);
        assert_eq!(q.entries[&ClientId(1)], 600);
        assert_eq!(v, 2);
        df.remove_client(ClientId(1));
        assert!(df.read().0.entries.is_empty());
    }

    #[test]
    fn mirror_writes_real_files() {
        let dir = std::env::temp_dir().join(format!("hasgpu-df-{}", std::process::id()));
        let df = DeviceFile::new("GPU-7").with_mirror(&dir).unwrap();
        df.write_client(ClientId(3), 250, 750);
        let text = std::fs::read_to_string(dir.join("GPU-7.quota.json")).unwrap();
        let parsed = crate::util::json::parse(&text).unwrap();
        let clients = parsed.get("clients").unwrap().as_arr().unwrap();
        assert_eq!(clients.len(), 1);
        assert_eq!(
            clients[0].get("quota_mille").unwrap().as_f64().unwrap(),
            750.0
        );
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn shared_handle_sees_writes() {
        let df = DeviceFile::new("GPU-1");
        let df2 = df.clone();
        df.write_client(ClientId(9), 100, 100);
        assert_eq!(df2.read().0.entries[&ClientId(9)], 100);
    }
}
