//! The GPU class catalog — device-class descriptors for heterogeneous
//! fleets.
//!
//! The paper's testbed is a uniform rack of V100s, but real serverless
//! fleets mix device generations: pricing and SM throughput differ per
//! class, and placement quality across non-uniform GPUs dominates cost
//! (Torpor, ESG). A [`GpuClass`] captures the four facts the control plane
//! needs about a device class:
//!
//! * `sm_count` — physical streaming multiprocessors (informational; the
//!   allocation substrate keeps working in per-mille *fractions* of
//!   whatever device hosts the slot, so SM alignment is class-agnostic);
//! * `mem_cap` — device memory in bytes (placement feasibility);
//! * `throughput` — relative execution speed versus the reference V100:
//!   a kernel that takes `t` seconds on the reference class takes
//!   `t / throughput` on this class (single-factor model: compute and
//!   bandwidth scale together; launch overhead rides along). The token
//!   **window length is a scheduler constant** and does not scale — quota
//!   semantics are identical on every class;
//! * `price_per_hour` — $/hr for the whole device (Google-Cloud-style
//!   on-demand pricing). Billing scales a run's configured reference price
//!   by [`GpuClass::price_relative`], so the reference class always bills
//!   at exactly the configured rate.
//!
//! **Name stability:** like platform names, class names are export keys
//! (per-class grid columns in `BENCH_sim.json`). Never reuse a name for a
//! different device configuration; renaming one is a schema change.

/// Registry name of the reference class every throughput/price factor is
/// expressed against (the paper's testbed device).
pub const REFERENCE_CLASS: &str = "v100";

/// One GPU device class: the unit of fleet heterogeneity.
#[derive(Clone, Debug, PartialEq)]
pub struct GpuClass {
    /// Stable class key (export schema; see module docs).
    pub name: String,
    /// Physical SM count (informational — allocation is fractional).
    pub sm_count: u32,
    /// Device memory capacity in bytes.
    pub mem_cap: f64,
    /// Relative execution speed vs. the reference class (V100 = 1.0):
    /// kernel time on this class = reference time / `throughput`.
    pub throughput: f64,
    /// On-demand $/hr for the whole device.
    pub price_per_hour: f64,
}

impl GpuClass {
    /// The reference class: V100-16GB, the paper's testbed GPU. Its
    /// `mem_cap` and `price_per_hour` equal
    /// [`crate::perf::DeviceSpec::default`]'s (pinned by test), so a
    /// uniform-V100 fleet is indistinguishable from the pre-catalog
    /// homogeneous cluster.
    pub fn v100() -> Self {
        GpuClass {
            name: REFERENCE_CLASS.to_string(),
            sm_count: 80,
            mem_cap: 16.0e9,
            throughput: 1.0,
            price_per_hour: 2.48,
        }
    }

    /// A100-40GB: ~2x the V100's effective throughput on inference-shaped
    /// work, 2.5x the memory, at a premium hourly rate.
    pub fn a100() -> Self {
        GpuClass {
            name: "a100".to_string(),
            sm_count: 108,
            mem_cap: 40.0e9,
            throughput: 2.0,
            price_per_hour: 3.67,
        }
    }

    /// T4-16GB: the budget inference card — ~0.4x V100 throughput at a
    /// fraction of the price. The cost-optimal home for latency-slack
    /// functions.
    pub fn t4() -> Self {
        GpuClass {
            name: "t4".to_string(),
            sm_count: 40,
            mem_cap: 16.0e9,
            throughput: 0.4,
            price_per_hour: 0.95,
        }
    }

    /// The built-in catalog, reference class first.
    pub fn catalog() -> Vec<GpuClass> {
        vec![GpuClass::v100(), GpuClass::a100(), GpuClass::t4()]
    }

    /// Case-insensitive catalog lookup.
    pub fn from_name(name: &str) -> Option<GpuClass> {
        GpuClass::catalog()
            .into_iter()
            .find(|c| c.name.eq_ignore_ascii_case(name.trim()))
    }

    /// Price of this class relative to the reference class. Billing
    /// multiplies a run's configured reference-class price by this factor,
    /// so the reference class bills at **exactly** the configured rate
    /// (`x * 1.0` is exact in IEEE 754 — the uniform fleet's costs are
    /// bit-identical to the pre-catalog ledger).
    pub fn price_relative(&self) -> f64 {
        self.price_per_hour / GpuClass::v100().price_per_hour
    }

    pub fn is_reference(&self) -> bool {
        self.name == REFERENCE_CLASS
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::perf::DeviceSpec;

    #[test]
    fn reference_class_matches_device_spec_exactly() {
        // The uniform-fleet byte-identity contract hinges on these being the
        // *same* f64 values the pre-catalog code used.
        let v = GpuClass::v100();
        let dev = DeviceSpec::default();
        assert_eq!(v.mem_cap.to_bits(), dev.mem_cap.to_bits());
        assert_eq!(v.price_per_hour.to_bits(), dev.price_per_hour.to_bits());
        assert_eq!(v.throughput.to_bits(), 1.0f64.to_bits());
        assert_eq!(v.price_relative().to_bits(), 1.0f64.to_bits());
        assert!(v.is_reference());
    }

    #[test]
    fn catalog_names_are_distinct_and_resolvable() {
        let cat = GpuClass::catalog();
        for c in &cat {
            assert_eq!(GpuClass::from_name(&c.name).as_ref(), Some(c));
            assert_eq!(GpuClass::from_name(&c.name.to_uppercase()).as_ref(), Some(c));
            assert!(c.throughput > 0.0 && c.price_per_hour > 0.0 && c.mem_cap > 0.0);
        }
        let mut names: Vec<&str> = cat.iter().map(|c| c.name.as_str()).collect();
        names.sort_unstable();
        names.dedup();
        assert_eq!(names.len(), cat.len());
        assert!(GpuClass::from_name("h100").is_none());
    }

    #[test]
    fn price_and_throughput_order_the_catalog_sensibly() {
        let (v, a, t) = (GpuClass::v100(), GpuClass::a100(), GpuClass::t4());
        assert!(a.throughput > v.throughput && v.throughput > t.throughput);
        assert!(a.price_per_hour > v.price_per_hour && v.price_per_hour > t.price_per_hour);
        // T4 is the cheapest per hour; A100 the cheapest per unit throughput.
        assert!(t.price_relative() < 1.0 && a.price_relative() > 1.0);
        assert!(
            a.price_per_hour / a.throughput < v.price_per_hour / v.throughput,
            "a100 should win on $/throughput"
        );
    }
}
