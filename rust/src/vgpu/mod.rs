//! The fine-grained spatio-temporal GPU allocation substrate.
//!
//! This is the reproduction of the paper's node-level machinery
//! (HAS-GPU-Scheduler + `libhas` + device files, §3.1):
//!
//! * [`VGpu`] — spatial accounting: a physical GPU abstracted into SM
//!   partition **slots**; pods inside one slot time-share it via quotas
//!   (Σ quota ≤ 1 per slot, Σ slot SM ≤ 1 per GPU). Slot sizes obey the
//!   **SM-alignment** rule of Fig. 2 (bounded number of distinct partition
//!   classes, 5%-granular) so fine-grained allocation cannot fragment the GPU.
//! * [`tokens::TokenScheduler`] — temporal enforcement: the real-mode analogue
//!   of gating `cuLaunchKernel` on time tokens inside a scheduling window,
//!   with runtime quota re-writes taking effect at the next window boundary
//!   (the vertical-scaling mechanism).
//! * [`device_file::DeviceFile`] — the two per-vGPU resource-configuration
//!   "device files" the GPU Re-configurator writes and the scheduler reads.

pub mod class;
pub mod device_file;
pub mod tokens;

pub use class::{GpuClass, REFERENCE_CLASS};

use std::collections::BTreeMap;

/// SM fractions are tracked in integer **per-mille** to keep alignment
/// arithmetic exact (no f64 drift in Σ checks).
pub type SmMille = u32;

pub const SM_FULL: SmMille = 1000;
/// Allocation granularity: 5% of the GPU (paper: "arbitrary granularity";
/// we quantise at the V100's finest MPS step — 1/20 ≈ one SM pair of 80).
pub const SM_STEP: SmMille = 50;
/// Maximum distinct partition classes per GPU (SM alignment, Fig. 2).
pub const MAX_SM_CLASSES: usize = 3;

/// Quota is also per-mille of the time window.
pub type QuotaMille = u32;
pub const QUOTA_FULL: QuotaMille = 1000;
/// Default vertical-scaling step ΔI_q (10% of the window).
pub const QUOTA_STEP: QuotaMille = 100;

pub fn sm_to_f64(sm: SmMille) -> f64 {
    sm as f64 / SM_FULL as f64
}

pub fn quota_to_f64(q: QuotaMille) -> f64 {
    q as f64 / QUOTA_FULL as f64
}

/// Unique id of a GPU client (one per pod attached to a vGPU).
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct ClientId(pub u64);

/// A pod's placement on a vGPU: which slot, and how much of its time.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Placement {
    pub slot: usize,
    pub sm: SmMille,
    pub quota: QuotaMille,
}

/// One SM partition slot: a fixed spatial share hosting time-sharing clients.
#[derive(Clone, Debug)]
pub struct Slot {
    pub sm: SmMille,
    /// client → quota (per-mille of this slot's time window).
    pub clients: BTreeMap<ClientId, QuotaMille>,
}

impl Slot {
    pub fn quota_used(&self) -> QuotaMille {
        self.clients.values().sum()
    }

    /// Remaining quota headroom. Saturating: a slot that a buggy caller
    /// over-committed reports zero headroom instead of underflow-panicking
    /// the whole plan tick in debug builds (the invariant itself is still
    /// asserted in debug, and [`VGpu::check_invariants`] reports it).
    pub fn quota_free(&self) -> QuotaMille {
        let used = self.quota_used();
        debug_assert!(
            used <= QUOTA_FULL,
            "slot over-committed: {used}‰ > {QUOTA_FULL}‰"
        );
        QUOTA_FULL.saturating_sub(used)
    }
}

#[derive(Debug, thiserror::Error, PartialEq)]
pub enum AllocError {
    #[error("SM request {0}‰ not a multiple of {SM_STEP}‰")]
    Misaligned(SmMille),
    #[error("not enough free SM: need {need}‰, free {free}‰")]
    NoSm { need: SmMille, free: SmMille },
    #[error("alignment classes exhausted ({MAX_SM_CLASSES} in use, {0}‰ is a new size)")]
    ClassLimit(SmMille),
    #[error("no quota headroom in slot: need {need}‰, free {free}‰")]
    NoQuota { need: QuotaMille, free: QuotaMille },
    #[error("unknown client {0:?}")]
    UnknownClient(ClientId),
    #[error("client {0:?} not in a legal lifecycle state for this action")]
    BadState(ClientId),
    #[error("not enough device memory: need {need:.2e} B, free {free:.2e} B")]
    NoMemory { need: f64, free: f64 },
}

/// Spatial + temporal accounting for one physical GPU.
#[derive(Clone, Debug)]
pub struct VGpu {
    pub uuid: String,
    slots: Vec<Slot>,
    /// Device memory accounting (bytes).
    mem_cap: f64,
    mem_used: f64,
    /// Host (pinned) memory holding parked model weights, in bytes — the
    /// Torpor-style swap tier. Not capacity-bounded: host RAM dwarfs HBM.
    host_mem_used: f64,
    clients: BTreeMap<ClientId, Placement>,
    /// Device class (throughput factor, pricing, catalog identity). The
    /// allocation substrate itself is class-agnostic — fractions of
    /// whatever device hosts the slot — so the class only informs the
    /// control plane (placement, billing, service-time scaling).
    class: GpuClass,
}

impl VGpu {
    /// A reference-class (V100) GPU with an explicit memory capacity — the
    /// pre-catalog constructor, unchanged for every homogeneous caller.
    pub fn new(uuid: &str, mem_cap: f64) -> Self {
        VGpu {
            uuid: uuid.to_string(),
            slots: Vec::new(),
            mem_cap,
            mem_used: 0.0,
            host_mem_used: 0.0,
            clients: BTreeMap::new(),
            class: GpuClass::v100(),
        }
    }

    /// A GPU of an explicit device class; memory capacity comes from the
    /// class descriptor.
    pub fn with_class(uuid: &str, class: GpuClass) -> Self {
        VGpu {
            uuid: uuid.to_string(),
            slots: Vec::new(),
            mem_cap: class.mem_cap,
            mem_used: 0.0,
            host_mem_used: 0.0,
            clients: BTreeMap::new(),
            class,
        }
    }

    pub fn class(&self) -> &GpuClass {
        &self.class
    }

    /// The class throughput factor (1.0 for the reference V100).
    pub fn throughput(&self) -> f64 {
        self.class.throughput
    }

    pub fn slots(&self) -> &[Slot] {
        &self.slots
    }

    pub fn clients(&self) -> &BTreeMap<ClientId, Placement> {
        &self.clients
    }

    pub fn mem_free(&self) -> f64 {
        self.mem_cap - self.mem_used
    }

    /// Bytes of parked model weights in the host-memory swap tier.
    pub fn host_mem_used(&self) -> f64 {
        self.host_mem_used
    }

    /// Park `bytes` of resident weights in host memory (pod demotion).
    /// Infallible: host RAM is modelled as unbounded.
    pub fn swap_out(&mut self, bytes: f64) {
        self.mem_used = (self.mem_used - bytes).max(0.0);
        self.host_mem_used += bytes;
    }

    /// Bring `bytes` of parked weights back to the device (pod promotion).
    /// Fails if the device lacks free memory; host accounting is untouched
    /// on failure.
    pub fn swap_in(&mut self, bytes: f64) -> Result<(), AllocError> {
        if bytes > self.mem_free() {
            return Err(AllocError::NoMemory {
                need: bytes,
                free: self.mem_free(),
            });
        }
        self.host_mem_used = (self.host_mem_used - bytes).max(0.0);
        self.mem_used += bytes;
        Ok(())
    }

    /// Drop `bytes` from the host tier without touching device memory
    /// (removing a pod that was parked when it died).
    pub fn release_host(&mut self, bytes: f64) {
        self.host_mem_used = (self.host_mem_used - bytes).max(0.0);
    }

    /// Total SM allocated to slots (whether or not their quota is full).
    pub fn sm_allocated(&self) -> SmMille {
        self.slots.iter().map(|s| s.sm).sum()
    }

    pub fn sm_free(&self) -> SmMille {
        SM_FULL - self.sm_allocated()
    }

    /// Distinct partition sizes currently in use.
    pub fn sm_classes(&self) -> Vec<SmMille> {
        let mut v: Vec<SmMille> = self.slots.iter().map(|s| s.sm).collect();
        v.sort_unstable();
        v.dedup();
        v
    }

    /// HAS GPU Occupancy: H_G = Σ_pods sm_i × q_i (paper Algorithm 1 line 11),
    /// in [0,1].
    pub fn hgo(&self) -> f64 {
        self.slots
            .iter()
            .map(|s| sm_to_f64(s.sm) * quota_to_f64(s.quota_used()))
            .sum()
    }

    /// Is the GPU completely empty (scale-down reclaims it, line 25-26)?
    pub fn is_idle(&self) -> bool {
        self.clients.is_empty()
    }

    /// Can a new client with `sm` be admitted under the alignment rule —
    /// either an existing slot of this exact class has quota headroom, or a
    /// new slot fits in free SM without exceeding the class limit?
    pub fn admissible(&self, sm: SmMille, quota: QuotaMille) -> Result<(), AllocError> {
        if sm == 0 || sm % SM_STEP != 0 || sm > SM_FULL {
            return Err(AllocError::Misaligned(sm));
        }
        // Existing slot of the same class with room?
        if self
            .slots
            .iter()
            .any(|s| s.sm == sm && s.quota_free() >= quota)
        {
            return Ok(());
        }
        // New slot.
        if self.sm_free() < sm {
            return Err(AllocError::NoSm {
                need: sm,
                free: self.sm_free(),
            });
        }
        let mut classes = self.sm_classes();
        if !classes.contains(&sm) {
            classes.push(sm);
            if classes.len() > MAX_SM_CLASSES {
                return Err(AllocError::ClassLimit(sm));
            }
        }
        Ok(())
    }

    /// Attach a client: reuse an aligned slot with quota headroom, else open a
    /// new slot. `mem` bytes are reserved on the device.
    pub fn attach(
        &mut self,
        id: ClientId,
        sm: SmMille,
        quota: QuotaMille,
        mem: f64,
    ) -> Result<Placement, AllocError> {
        self.admissible(sm, quota)?;
        if mem > self.mem_free() {
            return Err(AllocError::NoMemory {
                need: mem,
                free: self.mem_free(),
            });
        }
        assert!(
            !self.clients.contains_key(&id),
            "client {id:?} already attached to {}",
            self.uuid
        );
        // Prefer the existing aligned slot with the MOST free quota (leaves
        // the tightest slots free for vertical scaling of their tenants).
        let slot_idx = self
            .slots
            .iter()
            .enumerate()
            .filter(|(_, s)| s.sm == sm && s.quota_free() >= quota)
            .max_by_key(|(_, s)| s.quota_free())
            .map(|(i, _)| i);
        let slot = match slot_idx {
            Some(i) => i,
            None => {
                self.slots.push(Slot {
                    sm,
                    clients: BTreeMap::new(),
                });
                self.slots.len() - 1
            }
        };
        self.slots[slot].clients.insert(id, quota);
        self.mem_used += mem;
        let placement = Placement { slot, sm, quota };
        self.clients.insert(id, placement);
        Ok(placement)
    }

    /// Detach a client, freeing its quota, memory, and — if the slot empties —
    /// the slot's SM partition.
    pub fn detach(&mut self, id: ClientId, mem: f64) -> Result<(), AllocError> {
        let placement = self
            .clients
            .remove(&id)
            .ok_or(AllocError::UnknownClient(id))?;
        self.slots[placement.slot].clients.remove(&id);
        self.mem_used = (self.mem_used - mem).max(0.0);
        // Reclaim empty slots (keep indices stable: mark by zero SM and sweep).
        if self.slots[placement.slot].clients.is_empty() {
            self.slots[placement.slot].sm = 0;
            // Compact trailing empty slots; interior ones are reused by size-0
            // filtering in sm_allocated / sm_classes.
            while matches!(self.slots.last(), Some(s) if s.sm == 0 && s.clients.is_empty()) {
                self.slots.pop();
            }
            self.remap_placements();
        }
        Ok(())
    }

    fn remap_placements(&mut self) {
        // Drop zero-SM interior slots and rebuild placements.
        let mut new_slots: Vec<Slot> = Vec::with_capacity(self.slots.len());
        for s in self.slots.drain(..) {
            if s.sm > 0 || !s.clients.is_empty() {
                new_slots.push(s);
            }
        }
        self.slots = new_slots;
        let mut placements = BTreeMap::new();
        for (i, s) in self.slots.iter().enumerate() {
            for (&c, &q) in &s.clients {
                placements.insert(
                    c,
                    Placement {
                        slot: i,
                        sm: s.sm,
                        quota: q,
                    },
                );
            }
        }
        self.clients = placements;
    }

    /// Maximum quota this client could scale up to in-place
    /// (`RetriveMaxAvailQuotaForPod`, Algorithm 1 line 5): its current quota
    /// plus the slot's free headroom.
    pub fn max_avail_quota(&self, id: ClientId) -> Result<QuotaMille, AllocError> {
        let p = self.clients.get(&id).ok_or(AllocError::UnknownClient(id))?;
        Ok(p.quota + self.slots[p.slot].quota_free())
    }

    /// Re-write a client's quota (vertical scaling). Fails if the slot lacks
    /// headroom. Returns the old quota.
    pub fn set_quota(&mut self, id: ClientId, quota: QuotaMille) -> Result<QuotaMille, AllocError> {
        let p = *self.clients.get(&id).ok_or(AllocError::UnknownClient(id))?;
        let slot = &mut self.slots[p.slot];
        let others: QuotaMille = slot
            .clients
            .iter()
            .filter(|(&c, _)| c != id)
            .map(|(_, &q)| q)
            .sum();
        if others + quota > QUOTA_FULL {
            return Err(AllocError::NoQuota {
                need: quota,
                free: QUOTA_FULL - others,
            });
        }
        let old = slot.clients.insert(id, quota).expect("client in slot");
        self.clients.insert(
            id,
            Placement {
                slot: p.slot,
                sm: p.sm,
                quota,
            },
        );
        Ok(old)
    }

    /// Best (sm, quota) a *new* pod could get on this GPU
    /// (`RetriveMaxAvailQuotaAndSM`, Algorithm 1 line 12): considers reusing
    /// each existing class and opening a new maximal slot. Returns the option
    /// with the largest sm×quota product (capacity-proportional).
    pub fn max_avail_sm_quota(&self) -> Option<(SmMille, QuotaMille)> {
        let mut best: Option<(SmMille, QuotaMille)> = None;
        let mut consider = |sm: SmMille, q: QuotaMille| {
            if sm == 0 || q == 0 {
                return;
            }
            let better = match best {
                None => true,
                Some((bs, bq)) => (sm as u64 * q as u64) > (bs as u64 * bq as u64),
            };
            if better {
                best = Some((sm, q));
            }
        };
        for s in &self.slots {
            consider(s.sm, s.quota_free());
        }
        // New slot: largest aligned free chunk, if a class is available.
        let free = (self.sm_free() / SM_STEP) * SM_STEP;
        if free > 0 {
            let classes = self.sm_classes();
            if classes.len() < MAX_SM_CLASSES {
                consider(free, QUOTA_FULL);
            } else {
                // Must reuse an existing class size that fits in free SM.
                for &c in &classes {
                    if c <= free {
                        consider(c, QUOTA_FULL);
                    }
                }
            }
        }
        best
    }

    /// Invariant check used by property tests.
    pub fn check_invariants(&self) -> Result<(), String> {
        if self.sm_allocated() > SM_FULL {
            return Err(format!("SM over-allocated: {}‰", self.sm_allocated()));
        }
        if self.sm_classes().len() > MAX_SM_CLASSES {
            return Err(format!("too many classes: {:?}", self.sm_classes()));
        }
        for (i, s) in self.slots.iter().enumerate() {
            if s.quota_used() > QUOTA_FULL {
                return Err(format!("slot {i} quota over-subscribed: {}‰", s.quota_used()));
            }
            if s.sm % SM_STEP != 0 {
                return Err(format!("slot {i} misaligned: {}‰", s.sm));
            }
        }
        for (&c, p) in &self.clients {
            let in_slot = self
                .slots
                .get(p.slot)
                .and_then(|s| s.clients.get(&c))
                .copied();
            if in_slot != Some(p.quota) {
                return Err(format!("client {c:?} placement desync: {p:?} vs {in_slot:?}"));
            }
            if self.slots[p.slot].sm != p.sm {
                return Err(format!("client {c:?} sm desync"));
            }
        }
        if self.mem_used > self.mem_cap + 1.0 {
            return Err("memory over-committed".into());
        }
        if self.host_mem_used < 0.0 {
            return Err("host memory underflow".into());
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn gpu() -> VGpu {
        VGpu::new("GPU-test-0", 16e9)
    }

    #[test]
    fn attach_detach_roundtrip() {
        let mut g = gpu();
        let p = g.attach(ClientId(1), 500, 600, 1e9).unwrap();
        assert_eq!(p.sm, 500);
        assert_eq!(g.sm_allocated(), 500);
        assert!((g.hgo() - 0.3).abs() < 1e-9);
        g.detach(ClientId(1), 1e9).unwrap();
        assert_eq!(g.sm_allocated(), 0);
        assert!(g.is_idle());
        g.check_invariants().unwrap();
    }

    #[test]
    fn same_class_pods_share_slot() {
        let mut g = gpu();
        g.attach(ClientId(1), 250, 400, 1e9).unwrap();
        g.attach(ClientId(2), 250, 400, 1e9).unwrap();
        // Same class, combined quota 800‰ ≤ 1000‰ ⇒ one slot.
        assert_eq!(g.slots().len(), 1);
        assert_eq!(g.sm_allocated(), 250);
        g.attach(ClientId(3), 250, 400, 1e9).unwrap();
        // 400+400+400 > 1000 ⇒ needs a second slot of the same class.
        assert_eq!(g.slots().len(), 2);
        assert_eq!(g.sm_allocated(), 500);
        g.check_invariants().unwrap();
    }

    #[test]
    fn alignment_class_limit_enforced() {
        let mut g = gpu();
        g.attach(ClientId(1), 100, 1000, 1e8).unwrap();
        g.attach(ClientId(2), 200, 1000, 1e8).unwrap();
        g.attach(ClientId(3), 300, 1000, 1e8).unwrap();
        // A fourth distinct size must be rejected even though SM is free.
        assert_eq!(
            g.attach(ClientId(4), 150, 1000, 1e8),
            Err(AllocError::ClassLimit(150))
        );
        // But reusing an existing class is fine.
        g.attach(ClientId(5), 100, 1000, 1e8).unwrap();
        g.check_invariants().unwrap();
    }

    #[test]
    fn misaligned_sm_rejected() {
        let mut g = gpu();
        assert_eq!(
            g.attach(ClientId(1), 123, 500, 1e8),
            Err(AllocError::Misaligned(123))
        );
        assert_eq!(
            g.attach(ClientId(1), 0, 500, 1e8),
            Err(AllocError::Misaligned(0))
        );
    }

    #[test]
    fn sm_exhaustion_rejected() {
        let mut g = gpu();
        g.attach(ClientId(1), 800, 1000, 1e8).unwrap();
        assert!(matches!(
            g.attach(ClientId(2), 800, 500, 1e8),
            Err(AllocError::NoSm { .. })
        ));
    }

    #[test]
    fn memory_exhaustion_rejected() {
        let mut g = gpu();
        g.attach(ClientId(1), 500, 500, 12e9).unwrap();
        assert!(matches!(
            g.attach(ClientId(2), 500, 500, 8e9),
            Err(AllocError::NoMemory { .. })
        ));
    }

    #[test]
    fn vertical_scaling_quota() {
        let mut g = gpu();
        g.attach(ClientId(1), 500, 300, 1e9).unwrap();
        g.attach(ClientId(2), 500, 300, 1e9).unwrap();
        assert_eq!(g.max_avail_quota(ClientId(1)).unwrap(), 700);
        g.set_quota(ClientId(1), 700).unwrap();
        assert!((g.hgo() - 0.5 * 1.0).abs() < 1e-9);
        // Now slot is full: client 2 cannot exceed 300.
        assert!(matches!(
            g.set_quota(ClientId(2), 400),
            Err(AllocError::NoQuota { .. })
        ));
        // Scale down frees headroom.
        g.set_quota(ClientId(1), 100).unwrap();
        g.set_quota(ClientId(2), 900).unwrap();
        g.check_invariants().unwrap();
    }

    #[test]
    fn max_avail_prefers_largest_capacity() {
        let mut g = gpu();
        g.attach(ClientId(1), 200, 900, 1e8).unwrap();
        // Options: reuse 200‰-slot with 100‰ quota, or open a new slot with
        // the remaining 800‰ SM at full quota ⇒ the latter wins.
        let (sm, q) = g.max_avail_sm_quota().unwrap();
        assert_eq!((sm, q), (800, 1000));
    }

    #[test]
    fn max_avail_respects_class_limit() {
        let mut g = gpu();
        g.attach(ClientId(1), 300, 1000, 1e8).unwrap();
        g.attach(ClientId(2), 200, 1000, 1e8).unwrap();
        g.attach(ClientId(3), 100, 1000, 1e8).unwrap();
        // 400‰ free but classes exhausted: best new-slot option must reuse an
        // existing class (300‰ fits).
        let (sm, q) = g.max_avail_sm_quota().unwrap();
        assert_eq!((sm, q), (300, 1000));
    }

    #[test]
    fn detach_reclaims_slot_and_class() {
        let mut g = gpu();
        g.attach(ClientId(1), 300, 1000, 1e8).unwrap();
        g.attach(ClientId(2), 200, 1000, 1e8).unwrap();
        g.detach(ClientId(1), 1e8).unwrap();
        assert_eq!(g.sm_classes(), vec![200]);
        assert_eq!(g.sm_free(), 800);
        // Class freed: a new size is admissible again.
        g.attach(ClientId(3), 450, 500, 1e8).unwrap();
        g.check_invariants().unwrap();
    }

    #[test]
    fn quota_free_saturates_on_overcommitted_slot() {
        // Regression: `QUOTA_FULL - quota_used()` underflow-panicked in debug
        // if a caller ever over-committed a slot. quota_free now saturates to
        // zero headroom (with the invariant debug_assert'ed).
        let mut clients = BTreeMap::new();
        clients.insert(ClientId(1), 800);
        clients.insert(ClientId(2), 700); // 1500‰ — an over-commit only a buggy caller produces
        let slot = Slot { sm: 500, clients };
        if cfg!(debug_assertions) {
            // The invariant assertion fires first in debug builds.
            let prev = std::panic::take_hook();
            std::panic::set_hook(Box::new(|_| {}));
            let r = std::panic::catch_unwind(|| slot.quota_free());
            std::panic::set_hook(prev);
            assert!(r.is_err(), "debug build must assert the invariant");
        } else {
            assert_eq!(slot.quota_free(), 0, "release build must saturate, not wrap");
        }
        // A full-but-not-over slot reports exactly zero either way.
        let mut full = BTreeMap::new();
        full.insert(ClientId(1), QUOTA_FULL);
        assert_eq!(Slot { sm: 500, clients: full }.quota_free(), 0);
    }

    #[test]
    fn default_constructor_is_reference_class() {
        let g = gpu();
        assert!(g.class().is_reference());
        assert_eq!(g.throughput(), 1.0);
        assert_eq!(g.mem_free(), 16e9);
    }

    #[test]
    fn class_constructor_takes_mem_cap_from_class() {
        let g = VGpu::with_class("GPU-a100-0", GpuClass::a100());
        assert_eq!(g.class().name, "a100");
        assert_eq!(g.mem_free(), GpuClass::a100().mem_cap);
        assert_eq!(g.throughput(), 2.0);
        // Allocation substrate is class-agnostic: same per-mille rules.
        let mut g = g;
        g.attach(ClientId(1), 500, 600, 1e9).unwrap();
        assert_eq!(g.sm_allocated(), 500);
        g.check_invariants().unwrap();
    }

    #[test]
    fn swap_tier_roundtrip_and_device_pressure() {
        let mut g = gpu();
        g.attach(ClientId(1), 500, 600, 10e9).unwrap();
        let free0 = g.mem_free();
        g.swap_out(4e9);
        assert_eq!(g.host_mem_used(), 4e9);
        assert!((g.mem_free() - (free0 + 4e9)).abs() < 1.0);
        // Promotion needs free device memory: fill it, then fail cleanly.
        let filler = g.mem_free() - 1e9;
        g.attach(ClientId(2), 250, 400, filler).unwrap();
        assert!(matches!(g.swap_in(4e9), Err(AllocError::NoMemory { .. })));
        assert_eq!(g.host_mem_used(), 4e9, "failed swap-in must not leak host bytes");
        g.detach(ClientId(2), filler).unwrap();
        g.swap_in(4e9).unwrap();
        assert_eq!(g.host_mem_used(), 0.0);
        g.release_host(1e9); // saturates at zero
        assert_eq!(g.host_mem_used(), 0.0);
        g.check_invariants().unwrap();
    }

    #[test]
    fn hgo_sums_over_slots() {
        let mut g = gpu();
        g.attach(ClientId(1), 500, 400, 1e8).unwrap();
        g.attach(ClientId(2), 250, 800, 1e8).unwrap();
        let expect = 0.5 * 0.4 + 0.25 * 0.8;
        assert!((g.hgo() - expect).abs() < 1e-9);
    }
}
