//! Discrete-event simulation harness: runs a scaling policy against a
//! workload trace on the vGPU substrate at paper scale (10 GPUs, hours of
//! trace, multiple functions) and produces the Fig. 6 / Fig. 7 data.
//!
//! The serving model per pod is the paper's: requests enter a per-function
//! FIFO; an idle, ready pod pulls up to its batch size and serves the batch in
//! `PerfModel::latency(g, b_actual, sm, quota)` seconds (current quota —
//! vertical re-writes apply from the next batch, the window-boundary
//! semantics of Fig. 2). Pods are billed for their slice while they hold it;
//! whole-GPU pods (KServe) are billed for the full GPU. Cold-starting pods
//! hold (and pay for) their slice but serve nothing until ready — which is
//! exactly why horizontal-only scaling hurts under bursts.

pub mod faults;

pub use faults::{
    fault_name_menu, fault_spec_from_name, fault_table, FaultKind, FaultPlan, FaultSpec,
    NO_FAULTS,
};

use crate::autoscaler::ScalingPolicy;
use crate::cluster::{
    Applied, ApplyError, ClusterState, FunctionSpec, GpuId, PodId, PodPhase, Reconfigurator,
    ScalingAction,
};
use crate::metrics::{BillingLedger, BillingMode, FunctionMetrics, Outcome, RunReport};
use crate::perf::PerfModel;
use crate::rapp::{CachedPredictor, LatencyPredictor, OraclePredictor};
use crate::simclock::EventQueue;
use crate::util::prng::Pcg64;
use crate::vgpu::GpuClass;
use crate::workload::Trace;
use std::collections::{BTreeMap, BTreeSet, HashMap, VecDeque};

/// Planner loop strategy for [`run_sim`]'s tick handler (see DESIGN.md
/// "Trace-scale workloads").
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum PlannerMode {
    /// O(active) per tick: a function is planned only while it is *active*
    /// — it had arrivals or queued work since its last plan, holds pods, or
    /// its policy asks for idle plans ([`ScalingPolicy::wants_idle_plan`]).
    /// Plan ticks skipped while fully quiescent are replayed through
    /// [`ScalingPolicy::note_skipped_idle_ticks`] at reactivation, so with
    /// `idle_sweep == 1` decisions are **byte-identical** to [`FullScan`]
    /// (pinned by `active_set_planner_matches_full_scan_bit_for_bit` and
    /// the CI stock-cell cmp).
    #[default]
    ActiveSet,
    /// The historical every-function-every-tick scan — the identity
    /// baseline the byte-identity tests compare against.
    FullScan,
}

/// Simulation tunables.
#[derive(Clone, Debug)]
pub struct SimConfig {
    pub n_gpus: usize,
    pub seed: u64,
    /// Autoscaler tick interval (seconds).
    pub tick: f64,
    /// Per-function queue cap; beyond it arrivals are dropped.
    pub max_queue: usize,
    /// Requests older than this at dispatch are dropped (client timeout).
    pub timeout: f64,
    /// Drain period after the trace ends.
    pub drain: f64,
    /// Backlog compensation: queued requests are folded into the demand
    /// signal as `queue_len / horizon` extra RPS (concurrency-based scaling,
    /// à la Knative; applied identically to every platform).
    pub backlog_horizon: f64,
    /// Billing mode applied by the run's ledger — [`BillingMode::WholeGpu`]
    /// for KServe-style exclusive allocation, [`BillingMode::FineGrained`]
    /// for the sm×quota slice. Platform registry specs carry this directly.
    pub billing: BillingMode,
    /// Fleet composition: one GPU per entry, in order. Empty (the default)
    /// means `n_gpus` reference-class (V100) devices — the pre-fleet
    /// homogeneous construction, byte-identical by definition.
    pub fleet: Vec<GpuClass>,
    /// Warm bootstrap: deploy pods for the trace's initial rate before the
    /// clock starts (every platform measured warm — the historical
    /// behaviour). `false` starts from an *empty* cluster so the first
    /// burst pays real cold starts (the `cold-start-storm` preset).
    pub warm_start: bool,
    /// Marks the run as exercising the lifecycle axis: the report exports
    /// TTFT percentiles and demotion/promotion counts. `false` (default)
    /// keeps the export byte-identical to the pre-lifecycle schema.
    pub lifecycle: bool,
    /// Fault injection (see [`faults`]). The default spec is inactive:
    /// zero fault events are scheduled, zero fault RNG draws happen, and
    /// the run is byte-identical to a pre-fault build.
    pub faults: FaultSpec,
    /// Workflows active in this run (see [`crate::workflow`]). Every stage
    /// function (named `workflow:stage`) must appear in the run's function
    /// list. Empty (the default) builds no routers, schedules no hop
    /// events, and keeps the run byte-identical to a pre-workflow build.
    pub workflows: Vec<crate::workflow::Workflow>,
    /// Planner loop strategy. The default [`PlannerMode::ActiveSet`] is
    /// byte-identical to the historical full scan at `idle_sweep == 1`.
    pub planner: PlannerMode,
    /// Lazy idle-sweep stride: an *idle* function (no arrivals, empty
    /// queue this tick) is planned only on ticks where
    /// `tick % idle_sweep == f_idx % idle_sweep`. `1` (default) plans idle
    /// functions every tick — exact. `> 1` staggers idle replans (scale-down
    /// may lag by up to `idle_sweep − 1` ticks — a documented approximation
    /// the 100k-function trace cells opt into).
    pub idle_sweep: u64,
}

impl Default for SimConfig {
    fn default() -> Self {
        SimConfig {
            n_gpus: 10,
            seed: 42,
            tick: 1.0,
            max_queue: 10_000,
            timeout: 30.0,
            drain: 60.0,
            backlog_horizon: 2.0,
            billing: BillingMode::FineGrained,
            fleet: Vec::new(),
            warm_start: true,
            lifecycle: false,
            faults: FaultSpec::default(),
            workflows: Vec::new(),
            planner: PlannerMode::default(),
            idle_sweep: 1,
        }
    }
}

impl SimConfig {
    /// The standard configuration for one scenario-matrix cell: default
    /// serving knobs, cell-specific cluster size / seed / billing mode.
    pub fn for_experiment(n_gpus: usize, seed: u64, billing: BillingMode) -> Self {
        SimConfig {
            n_gpus,
            seed,
            billing,
            ..SimConfig::default()
        }
    }

    /// Pin the run to an explicit fleet (one GPU per class entry, in
    /// order); `n_gpus` follows the fleet size.
    pub fn with_fleet(mut self, fleet: Vec<GpuClass>) -> Self {
        self.n_gpus = fleet.len();
        self.fleet = fleet;
        self
    }
}

/// Sentinel workflow tag: the request is a plain single-function request.
const NOT_WORKFLOW: u32 = u32::MAX;

#[derive(Clone, Copy, Debug)]
struct Request {
    arrival: f64,
    /// Workflow index this request belongs to, or [`NOT_WORKFLOW`] for
    /// plain requests (the default path tags every request that way).
    wf: u32,
    /// Pipeline origin id in the workflow's router (0 for plain requests).
    origin: u32,
}

#[derive(Clone, Debug)]
enum Ev {
    /// The next pending arrival of one function (a streaming cursor into the
    /// pre-drawn per-function timestamp run: at most one arrival event per
    /// function lives in the queue at any moment, so the heap stays
    /// O(duration/tick + in-flight) — ticks remain pre-pushed — instead of
    /// O(total requests)).
    Arrival { f_idx: usize },
    PodReady { pod: PodId },
    ServiceDone { pod: PodId, f_idx: usize, batch: Vec<Request> },
    Tick,
    End,
    /// Fault injection (never scheduled under the default inactive spec):
    /// the GPU dies — resident pods are evicted, their accounts closed at
    /// this instant, in-flight batches fail.
    GpuFailed { gpu: usize },
    /// The failed GPU rejoins placement.
    GpuRepaired { gpu: usize },
    /// One resident pod (picked deterministically at event time) crashes.
    PodCrash,
    /// A workflow payload copy lands at stage `to` after its hop latency
    /// (never scheduled when no workflows are configured).
    StageHop { wf: usize, origin: u32, to: usize },
}

/// Per-function streaming arrival cursor. The timestamps themselves are
/// drawn up-front in the seed's exact RNG order (one shared stream,
/// function-major — the draw order is part of the determinism contract and
/// cannot be lazily interleaved), but they live in flat, 8-byte-per-request
/// buffers; the event heap sees only the cursor head.
struct ArrivalCursor {
    times: Vec<f64>,
    next: usize,
}

impl ArrivalCursor {
    /// Draw every arrival of one function (identical draws, identical order
    /// to the seed's upfront pre-push).
    fn draw(trace: &Trace, function: &str, duration: usize, rng: &mut Pcg64) -> Self {
        let mut times = Vec::new();
        for sec in 0..duration {
            times.extend(trace.arrivals(function, sec, rng));
        }
        ArrivalCursor { times, next: 0 }
    }

    fn peek(&self) -> Option<f64> {
        self.times.get(self.next).copied()
    }

    /// Consume the head timestamp.
    fn advance(&mut self) -> f64 {
        let t = self.times[self.next];
        self.next += 1;
        t
    }
}

/// Per-run workflow routing state. With no workflows configured this is a
/// handful of empty vectors: nothing is routed, scheduled, or recorded, and
/// the event sequence is byte-identical to a pre-workflow build.
struct WfState {
    defs: Vec<crate::workflow::Workflow>,
    routers: Vec<crate::gateway::WorkflowRouter>,
    /// f_idx → (workflow index, stage index) for stage functions; `None`
    /// for plain single functions.
    of_fn: Vec<Option<(usize, usize)>>,
    /// Per workflow, per stage: the index in `functions` serving it.
    stage_fn: Vec<Vec<usize>>,
}

impl WfState {
    fn build(workflows: &[crate::workflow::Workflow], functions: &[FunctionSpec]) -> Self {
        let mut of_fn = vec![None; functions.len()];
        let mut stage_fn = Vec::with_capacity(workflows.len());
        for (w_idx, w) in workflows.iter().enumerate() {
            let mut fidx = Vec::with_capacity(w.stages.len());
            for s in 0..w.stages.len() {
                let name = w.stage_function_name(s);
                let i = functions
                    .iter()
                    .position(|f| f.name == name)
                    .unwrap_or_else(|| panic!("workflow stage function '{name}' not registered"));
                of_fn[i] = Some((w_idx, s));
                fidx.push(i);
            }
            stage_fn.push(fidx);
        }
        WfState {
            defs: workflows.to_vec(),
            routers: workflows.iter().map(crate::gateway::WorkflowRouter::new).collect(),
            of_fn,
            stage_fn,
        }
    }

    /// Close the origin behind a dropped/killed stage request (first
    /// failure wins; later stage copies of an already-failed origin no-op)
    /// and record the end-to-end outcome. Plain requests return untouched.
    fn fail_request(&mut self, r: &Request, now: f64, report: &mut RunReport, outcome: Outcome) {
        if r.wf == NOT_WORKFLOW {
            return;
        }
        let w = r.wf as usize;
        if let Some(e2e) = self.routers[w].fail(r.origin, now) {
            report.workflow(&self.defs[w].name).record(now - e2e, e2e, outcome);
        }
    }
}

/// Route a completed batch of workflow-stage requests onward: schedule a
/// hop event per outgoing edge, and record the end-to-end latency when the
/// last terminal stage of an origin finishes. Plain batches return at once.
fn route_batch(
    wfs: &mut WfState,
    f_idx: usize,
    now: f64,
    batch: &[Request],
    report: &mut RunReport,
    q: &mut EventQueue<Ev>,
    hops: &mut Vec<crate::gateway::StageHop>,
) {
    let Some((w, stage)) = wfs.of_fn[f_idx] else {
        return;
    };
    for r in batch {
        if let Some(e2e) = wfs.routers[w].route_completion(r.origin, stage, now, hops) {
            report.workflow(&wfs.defs[w].name).record(now - e2e, e2e, Outcome::Ok);
        }
        for h in hops.iter() {
            q.push_at(
                now + h.latency,
                Ev::StageHop { wf: w, origin: r.origin, to: h.to },
            );
        }
    }
}

/// Run one policy × trace experiment end-to-end; returns the report.
pub fn run_sim(
    policy: &mut dyn ScalingPolicy,
    functions: &[FunctionSpec],
    trace: &Trace,
    predictor: &dyn LatencyPredictor,
    perf: &PerfModel,
    cfg: &SimConfig,
) -> RunReport {
    let mut cluster = if cfg.fleet.is_empty() {
        ClusterState::new(cfg.n_gpus, perf.dev.mem_cap)
    } else {
        ClusterState::from_classes(&cfg.fleet)
    };
    for f in functions {
        cluster.register_function(f.clone());
    }
    let mut recon = Reconfigurator::new(&cluster, cfg.seed);
    let mut report = RunReport::new(policy.name());
    // Fleet composition for the report's per-class columns (uniform
    // reference-class runs carry {"v100": n}, which the exporters omit).
    for i in 0..cluster.n_gpus() {
        *report
            .fleet_gpus
            .entry(cluster.gpu(crate::cluster::GpuId(i)).class().name.clone())
            .or_insert(0) += 1;
    }
    // Workflow routing state (empty vectors on the default path) + the
    // per-workflow e2e SLOs the report judges violations against.
    let mut wfs = WfState::build(&cfg.workflows, functions);
    for w in &wfs.defs {
        report.workflow_slos.insert(w.name.clone(), w.e2e_slo);
    }
    // One accounting engine for the whole run: every pod-second is billed
    // exactly once, at the slice held during that second, under the run's
    // real billing mode (see metrics::ledger).
    let mut ledger = BillingLedger::new(cfg.billing, perf.dev.price_per_hour);
    // Quantized capacity caches: one for the policy's predictor (the
    // autoscaler hot path), one for the ground-truth service-time surface
    // the dispatch path evaluates per batch. Pod slices live on the
    // per-mille lattice, so cached results are bit-identical to uncached.
    let predictor = CachedPredictor::new(predictor);
    let serve_oracle = OraclePredictor { perf: perf.clone() };
    let serve = CachedPredictor::new(&serve_oracle);

    let mut rng = Pcg64::new(cfg.seed, 77);

    // Draw all arrival timestamps (seed-identical RNG order) into flat
    // per-function cursors; only each cursor's head enters the event heap.
    let duration = trace.duration();
    let mut arrivals: Vec<ArrivalCursor> = functions
        .iter()
        .map(|f| ArrivalCursor::draw(trace, &f.name, duration, &mut rng))
        .collect();

    // Scaler ticks + end-of-run are pre-scheduled (O(duration/tick) events —
    // cheap, and their low sequence numbers keep tick-vs-PodReady ties
    // resolving ticks-first, as they always have). Tick times are computed
    // as i·tick, not accumulated, so hours-long traces don't drift.
    let end_t = duration as f64 + cfg.drain;
    let n_ticks = (end_t / cfg.tick).ceil() as usize;
    // Compile the fault schedule before any event enters the queue. The
    // plan draws only from its own RNG streams, and an inactive spec
    // compiles to zero events — so the default path pushes exactly the
    // historical event sequence (identical sequence numbers, identical
    // tie-breaks).
    let mut fplan = FaultPlan::compile(&cfg.faults, cfg.seed, cluster.n_gpus(), end_t);
    report.faults_active = cfg.faults.is_active();
    let mut q: EventQueue<Ev> = EventQueue::with_capacity(
        n_ticks + 4 * functions.len() + 2 + fplan.events().len(),
    );
    let mut i = 1u64;
    loop {
        let t = i as f64 * cfg.tick;
        if t >= end_t {
            break;
        }
        q.push_at(t, Ev::Tick);
        i += 1;
    }
    q.push_at(end_t, Ev::End);
    for &(t, kind) in fplan.events() {
        let ev = match kind {
            FaultKind::GpuFails(gpu) => Ev::GpuFailed { gpu },
            FaultKind::GpuRepairs(gpu) => Ev::GpuRepaired { gpu },
            FaultKind::PodCrash => Ev::PodCrash,
        };
        q.push_at(t, ev);
    }
    // Prime the streaming cursors: one outstanding arrival per function.
    for (f_idx, cur) in arrivals.iter().enumerate() {
        if let Some(t0) = cur.peek() {
            q.push_at(t0, Ev::Arrival { f_idx });
        }
    }

    // Warm bootstrap: every platform deploys pods sized for the trace's
    // initial rate (the paper's platforms are warm when measurement starts;
    // at idle this degenerates to "one instance with minimal resources").
    // Cold-start-storm runs skip this entirely: the cluster starts empty
    // and the first burst pays real cold starts.
    if cfg.warm_start {
        for f in functions {
            let initial_rate = trace.rps_at(&f.name, 0).max(1.0);
            let actions = policy.plan(f, initial_rate, &cluster, &predictor, 0.0);
            for a in &actions {
                apply_action(
                    &mut cluster, &mut recon, &mut ledger, perf, a, 0.0, &mut report, &mut fplan,
                );
            }
            // Bootstrap pods start warm (deployment-time, not a runtime cold
            // start); they are already born DeviceResident.
            let ids: Vec<PodId> = cluster.pods_of(&f.name).iter().map(|p| p.id).collect();
            for id in ids {
                if let Some(p) = cluster.pod_mut(id) {
                    p.phase = PodPhase::Running;
                }
            }
        }
    }

    // Dense name → index map: the PodReady and pod-kill paths resolve a
    // pod's function in O(1) instead of an O(functions) scan.
    let fn_ix: HashMap<&str, usize> = functions
        .iter()
        .enumerate()
        .map(|(i, f)| (f.name.as_str(), i))
        .collect();

    // Active-set planner state (see [`PlannerMode`]). A function index is
    // in `active` while it holds pods, had arrivals/queue since its last
    // plan, or its policy still asks for idle plans. BTreeSet iteration is
    // ascending, so the due list is always a subset of the full scan *in
    // the full scan's order* — the identity argument then only needs
    // "skipped plans are no-ops" (guaranteed by `wants_idle_plan` +
    // `note_skipped_idle_ticks` replay).
    let mut active: BTreeSet<usize> = BTreeSet::new();
    for (f_idx, f) in functions.iter().enumerate() {
        if wfs.of_fn[f_idx].is_some() {
            continue; // workflow stages are co-planned every tick
        }
        if cluster.has_pods(&f.name) || policy.wants_idle_plan(f, 0.0) {
            active.insert(f_idx);
        }
    }
    // Last tick each function was planned at (tick *counter*, not sim
    // time), so reactivation knows exactly how many idle plan ticks were
    // skipped and can replay them.
    let mut planned_upto: Vec<u64> = vec![0; functions.len()];
    let mut tick_index: u64 = 0;
    // Reused due-list buffer for the tick handler.
    let mut due: Vec<usize> = Vec::new();

    // Sharded per-function request logs: a dense Vec indexed by f_idx on
    // the hot paths (no name hashing, no map walk per record); merged into
    // the report's name-keyed map once, after the event loop. Only
    // functions that recorded anything get an entry — preserving the
    // lazy-entry export shape `report.function()` always produced.
    let mut fn_metrics: Vec<FunctionMetrics> =
        functions.iter().map(|_| FunctionMetrics::default()).collect();

    // Per-function FIFO queues + per-pod busy state.
    let mut queues: Vec<VecDeque<Request>> = functions.iter().map(|_| VecDeque::new()).collect();
    let mut busy: BTreeSet<PodId> = BTreeSet::new();
    let mut pending_remove: BTreeSet<PodId> = BTreeSet::new();
    let mut arrivals_this_tick: Vec<u64> = vec![0; functions.len()];
    // Fault bookkeeping (all of it stays empty on the default path):
    // pods killed mid-batch and the instant their device died, GPUs
    // currently down and since when, and per-function outstanding replica
    // losses (for the time-to-restore-capacity samples).
    let mut killed_at: BTreeMap<PodId, f64> = BTreeMap::new();
    let mut down_since: BTreeMap<usize, f64> = BTreeMap::new();
    let mut lost: Vec<VecDeque<f64>> = functions.iter().map(|_| VecDeque::new()).collect();
    // Recycled service-batch buffers: ServiceDone returns its Vec here and
    // dispatch reuses it, so the steady state moves batches without
    // allocating per service completion.
    let mut batch_pool: Vec<Vec<Request>> = Vec::new();
    // Scratch buffer for workflow hop routing (stays empty without them).
    let mut hops: Vec<crate::gateway::StageHop> = Vec::new();
    // PodReady events are scheduled lazily at creation time.

    while let Some((now, ev)) = q.pop() {
        match ev {
            Ev::Arrival { f_idx } => {
                // Consume the cursor head (== now) and re-arm the cursor with
                // the function's next arrival, keeping exactly one arrival
                // event in flight per function.
                let arrival = arrivals[f_idx].advance();
                debug_assert_eq!(arrival, now);
                if let Some(tn) = arrivals[f_idx].peek() {
                    q.push_at(tn, Ev::Arrival { f_idx });
                }
                arrivals_this_tick[f_idx] += 1;
                if wfs.of_fn[f_idx].is_none() {
                    active.insert(f_idx); // traffic reactivates the planner
                }
                // A trace arrival at a workflow's entry stage opens a
                // pipeline origin: the e2e clock starts here and is charged
                // exactly once, however many hops follow.
                let (wf_tag, origin) = match wfs.of_fn[f_idx] {
                    Some((w, s)) if s == wfs.defs[w].entry() => {
                        (w as u32, wfs.routers[w].open(arrival))
                    }
                    _ => (NOT_WORKFLOW, 0),
                };
                let req = Request { arrival, wf: wf_tag, origin };
                if queues[f_idx].len() >= cfg.max_queue {
                    // Overflow drop at arrival: time-in-queue is zero, but
                    // record it through the same now-arrival formula as every
                    // other drop path.
                    fn_metrics[f_idx].record(arrival, now - arrival, Outcome::Dropped);
                    wfs.fail_request(&req, now, &mut report, Outcome::Dropped);
                } else {
                    queues[f_idx].push_back(req);
                    try_dispatch(
                        f_idx, now, &mut queues, &mut busy, &cluster, &serve, functions, &mut q,
                        cfg, &mut fn_metrics, &mut report, &mut batch_pool, &mut wfs,
                    );
                }
            }
            Ev::PodReady { pod } => {
                if let Some(p) = cluster.pod_mut(pod) {
                    if matches!(p.phase, PodPhase::ColdStarting { .. }) {
                        p.phase = PodPhase::Running;
                    }
                    let f_idx = *fn_ix.get(p.function.as_str()).expect("known function");
                    // A pod turning ready keeps its function planned (it is
                    // normally already active — it held this pod — but the
                    // insert is cheap and makes the invariant local).
                    if wfs.of_fn[f_idx].is_none() {
                        active.insert(f_idx);
                    }
                    // Recovery accounting: a replica turning ready restores
                    // capacity for the oldest outstanding loss of its
                    // function — the MTTR sample is loss → ready.
                    if let Some(t0) = lost[f_idx].pop_front() {
                        report
                            .mttr_samples
                            .entry(functions[f_idx].name.clone())
                            .or_default()
                            .push(now - t0);
                    }
                    try_dispatch(
                        f_idx, now, &mut queues, &mut busy, &cluster, &serve, functions, &mut q,
                        cfg, &mut fn_metrics, &mut report, &mut batch_pool, &mut wfs,
                    );
                }
            }
            Ev::ServiceDone { pod, f_idx, mut batch } => {
                busy.remove(&pod);
                if let Some(kill_t) = killed_at.remove(&pod) {
                    // The device died mid-batch: these requests failed at
                    // the failure instant; record the real time from
                    // arrival to the death, not to this (phantom)
                    // completion.
                    for r in &batch {
                        fn_metrics[f_idx].record(r.arrival, kill_t - r.arrival, Outcome::Failed);
                        wfs.fail_request(r, kill_t, &mut report, Outcome::Failed);
                    }
                    batch.clear();
                    batch_pool.push(batch);
                    continue;
                }
                for r in &batch {
                    fn_metrics[f_idx].record(r.arrival, now - r.arrival, Outcome::Ok);
                }
                route_batch(&mut wfs, f_idx, now, &batch, &mut report, &mut q, &mut hops);
                batch.clear();
                batch_pool.push(batch);
                if pending_remove.remove(&pod) {
                    // Deferred horizontal scale-down: the drained pod leaves
                    // now; the ledger bills its final slice-seconds and the
                    // action counts only on successful application.
                    apply_action(
                        &mut cluster,
                        &mut recon,
                        &mut ledger,
                        perf,
                        &ScalingAction::RemovePod { pod },
                        now,
                        &mut report,
                        &mut fplan,
                    );
                } else {
                    try_dispatch(
                        f_idx, now, &mut queues, &mut busy, &cluster, &serve, functions, &mut q,
                        cfg, &mut fn_metrics, &mut report, &mut batch_pool, &mut wfs,
                    );
                }
            }
            Ev::StageHop { wf, origin, to } => {
                // A payload copy lands; the stage joins (and enqueues) once
                // every inbound edge has arrived. Failed origins route
                // nothing — `arrive` refuses them.
                if !wfs.routers[wf].arrive(origin, to) {
                    continue;
                }
                let f_idx = wfs.stage_fn[wf][to];
                arrivals_this_tick[f_idx] += 1;
                let req = Request { arrival: now, wf: wf as u32, origin };
                if queues[f_idx].len() >= cfg.max_queue {
                    fn_metrics[f_idx].record(now, 0.0, Outcome::Dropped);
                    wfs.fail_request(&req, now, &mut report, Outcome::Dropped);
                } else {
                    queues[f_idx].push_back(req);
                    try_dispatch(
                        f_idx, now, &mut queues, &mut busy, &cluster, &serve, functions, &mut q,
                        cfg, &mut fn_metrics, &mut report, &mut batch_pool, &mut wfs,
                    );
                }
            }
            Ev::Tick => {
                tick_index += 1;
                // Build the due list. FullScan: every function, every tick
                // (the historical loop). ActiveSet: only the active subset
                // — BTreeSet iteration is ascending index order, i.e. the
                // full scan's order restricted to active functions.
                due.clear();
                match cfg.planner {
                    PlannerMode::FullScan => due.extend(0..functions.len()),
                    PlannerMode::ActiveSet => due.extend(active.iter().copied()),
                }
                for &f_idx in &due {
                    let f = &functions[f_idx];
                    if wfs.of_fn[f_idx].is_some() {
                        continue; // workflow stages are co-planned below
                    }
                    // Lazy idle sweep (idle_sweep > 1 only): a function
                    // with no arrivals and an empty queue this tick replans
                    // on a staggered cadence instead of every tick. Every
                    // swept tick provably observed 0.0 rps, so the replay
                    // below keeps filter state exact; only scale-*down*
                    // lags, by at most idle_sweep − 1 ticks.
                    if cfg.idle_sweep > 1
                        && arrivals_this_tick[f_idx] == 0
                        && queues[f_idx].is_empty()
                        && tick_index % cfg.idle_sweep != f_idx as u64 % cfg.idle_sweep
                    {
                        continue;
                    }
                    // Replay plan ticks skipped while quiescent (each one
                    // observed exactly 0.0 rps) so policy-internal state —
                    // the Kalman covariance in particular — is bit-identical
                    // to what the full scan would hold.
                    let missed = tick_index - 1 - planned_upto[f_idx];
                    if missed > 0 {
                        policy.note_skipped_idle_ticks(f, missed);
                    }
                    planned_upto[f_idx] = tick_index;
                    let observed = arrivals_this_tick[f_idx] as f64 / cfg.tick
                        + queues[f_idx].len() as f64 / cfg.backlog_horizon;
                    arrivals_this_tick[f_idx] = 0;
                    let actions = policy.plan(f, observed, &cluster, &predictor, now);
                    apply_plan(
                        &actions, now, &mut cluster, &mut recon, &mut ledger, perf, &mut report,
                        &mut fplan, &busy, &mut pending_remove, &mut q,
                    );
                    // New capacity may unblock the queue.
                    try_dispatch(
                        f_idx, now, &mut queues, &mut busy, &cluster, &serve, functions, &mut q,
                        cfg, &mut fn_metrics, &mut report, &mut batch_pool, &mut wfs,
                    );
                    // Deactivate fully quiescent functions (ActiveSet
                    // only): nothing queued, no pods left, and the policy
                    // no longer wants idle plans. Arrival / PodReady events
                    // reactivate.
                    if cfg.planner == PlannerMode::ActiveSet
                        && queues[f_idx].is_empty()
                        && !cluster.has_pods(&f.name)
                        && !policy.wants_idle_plan(f, now)
                    {
                        active.remove(&f_idx);
                    }
                }
                // Workflow stages: one co-scaling pass per workflow, all
                // stages planned together. HybridAutoscaler propagates the
                // demand downstream and grows the bottleneck stage first;
                // baseline policies fall back to fair independent per-stage
                // planning (the trait's default method).
                for w_idx in 0..wfs.defs.len() {
                    let fidx = wfs.stage_fn[w_idx].clone();
                    let observed: Vec<f64> = fidx
                        .iter()
                        .map(|&i| {
                            let o = arrivals_this_tick[i] as f64 / cfg.tick
                                + queues[i].len() as f64 / cfg.backlog_horizon;
                            arrivals_this_tick[i] = 0;
                            o
                        })
                        .collect();
                    let stage_fns: Vec<&FunctionSpec> =
                        fidx.iter().map(|&i| &functions[i]).collect();
                    let actions = policy.plan_workflow(
                        &wfs.defs[w_idx], &stage_fns, &observed, &cluster, &predictor, now,
                    );
                    apply_plan(
                        &actions, now, &mut cluster, &mut recon, &mut ledger, perf, &mut report,
                        &mut fplan, &busy, &mut pending_remove, &mut q,
                    );
                    for &i in &fidx {
                        try_dispatch(
                            i, now, &mut queues, &mut busy, &cluster, &serve, functions, &mut q,
                            cfg, &mut fn_metrics, &mut report, &mut batch_pool, &mut wfs,
                        );
                    }
                }
            }
            Ev::End => {
                // Drain queues: anything still waiting is a drop, recorded
                // with its real time-in-queue (not 0.0) so drop records are
                // comparable across the three drop paths.
                for f_idx in 0..functions.len() {
                    while let Some(r) = queues[f_idx].pop_front() {
                        fn_metrics[f_idx].record(r.arrival, now - r.arrival, Outcome::Dropped);
                        wfs.fail_request(&r, now, &mut report, Outcome::Dropped);
                    }
                }
                // Origins still open (mid-batch or mid-hop) never completed:
                // close each one exactly once as an end-of-run drop.
                for w_idx in 0..wfs.defs.len() {
                    let open: Vec<(u32, f64)> = wfs.routers[w_idx].open_origins().collect();
                    for (o, t0) in open {
                        wfs.routers[w_idx].fail(o, now);
                        report
                            .workflow(&wfs.defs[w_idx].name)
                            .record(t0, now - t0, Outcome::Dropped);
                    }
                }
                // GPUs still down at end of run: truncate their downtime
                // interval here (availability integrates over the run).
                for (_, &t0) in down_since.iter() {
                    report.gpu_downtime += now - t0;
                }
                down_since.clear();
                report.duration = now;
                report.event_queue_peak = q.high_water();
                report.lifecycle = cfg.lifecycle;
                break;
            }
            Ev::GpuFailed { gpu } => {
                let gid = GpuId(gpu);
                if !cluster.gpu_is_down(gid) {
                    cluster.set_gpu_down(gid, true);
                    down_since.insert(gpu, now);
                    report.gpu_failures += 1;
                    for pod in cluster.pods_on(gid) {
                        kill_pod(
                            pod, now, &mut cluster, &mut recon, &mut ledger, &mut report, &busy,
                            &mut killed_at, &mut pending_remove, &mut lost, &fn_ix,
                        );
                    }
                }
            }
            Ev::GpuRepaired { gpu } => {
                if let Some(t0) = down_since.remove(&gpu) {
                    cluster.set_gpu_down(GpuId(gpu), false);
                    report.gpu_downtime += now - t0;
                }
            }
            Ev::PodCrash => {
                // Deterministic victim choice among resident pods, in
                // BTreeMap (id) order; an empty cluster crashes nothing
                // and draws nothing.
                let victims: Vec<PodId> = cluster.pods().map(|p| p.id).collect();
                if !victims.is_empty() {
                    let v = victims[fplan.pick_victim(victims.len())];
                    kill_pod(
                        v, now, &mut cluster, &mut recon, &mut ledger, &mut report, &busy,
                        &mut killed_at, &mut pending_remove, &mut lost, &fn_ix,
                    );
                }
            }
        }
    }
    debug_assert!(cluster.check_invariants().is_ok());
    // Merge the sharded per-function logs into the report's name-keyed
    // map — one entry per *touched* function only, so exports (and their
    // byte-identity contracts) are unchanged from the lazy-entry era.
    for (f, m) in functions.iter().zip(fn_metrics) {
        if !m.is_empty() {
            report.functions.insert(f.name.clone(), m);
        }
    }
    report.reconfig_transients = fplan.transients();
    // Final settlement: bill every still-open pod account to end-of-run.
    report.costs = ledger.into_meter(report.duration);
    report
}

/// Kill one pod at a failure instant: close its billing account **at the
/// instant of death** (no pod-second billed past it, in either billing
/// mode), evict it through the Re-configurator's device bookkeeping, and
/// queue the loss for MTTR accounting. If the pod was mid-batch, the batch
/// is marked to fail when its (now phantom) `ServiceDone` event pops.
#[allow(clippy::too_many_arguments)]
fn kill_pod(
    pod: PodId,
    now: f64,
    cluster: &mut ClusterState,
    recon: &mut Reconfigurator,
    ledger: &mut BillingLedger,
    report: &mut RunReport,
    busy: &BTreeSet<PodId>,
    killed_at: &mut BTreeMap<PodId, f64>,
    pending_remove: &mut BTreeSet<PodId>,
    lost: &mut [VecDeque<f64>],
    fn_ix: &HashMap<&str, usize>,
) {
    let Some(p) = recon.evict_pod(cluster, pod) else {
        return;
    };
    ledger.close(pod, now);
    report.pods_lost += 1;
    pending_remove.remove(&pod);
    if busy.contains(&pod) {
        killed_at.insert(pod, now);
    }
    if let Some(&f_idx) = fn_ix.get(p.function.as_str()) {
        lost[f_idx].push_back(now);
    }
}

/// Apply one planning pass's actions: a busy pod drains before removal
/// (billing and the action counter fire when the removal actually applies);
/// everything else goes through the Re-configurator with post-success
/// accounting, and fresh pods schedule their ready events. Shared verbatim
/// by the per-function and per-workflow tick passes.
#[allow(clippy::too_many_arguments)]
fn apply_plan(
    actions: &[ScalingAction],
    now: f64,
    cluster: &mut ClusterState,
    recon: &mut Reconfigurator,
    ledger: &mut BillingLedger,
    perf: &PerfModel,
    report: &mut RunReport,
    fplan: &mut FaultPlan,
    busy: &BTreeSet<PodId>,
    pending_remove: &mut BTreeSet<PodId>,
    q: &mut EventQueue<Ev>,
) {
    for a in actions {
        match a {
            ScalingAction::RemovePod { pod } if busy.contains(pod) => {
                if let Some(p) = cluster.pod_mut(*pod) {
                    p.phase = PodPhase::Draining;
                }
                pending_remove.insert(*pod);
            }
            _ => {
                if let Some(applied) =
                    apply_action(cluster, recon, ledger, perf, a, now, report, fplan)
                {
                    match applied {
                        Applied::PodCreated { pod, ready_at }
                        | Applied::PodPromoted { pod, ready_at } => {
                            q.push_at(ready_at, Ev::PodReady { pod });
                        }
                        _ => {}
                    }
                }
            }
        }
    }
}

/// Apply an action through the Re-configurator, with ledger + counter
/// accounting **after** the mutation succeeds: rejected actions (allocation
/// races — the policy planned on a snapshot) bill nothing and count
/// nothing. Under an active fault plan each attempt may fail transiently
/// (retried with deterministic backoff inside `apply_with_faults`);
/// exhausted retry budgets count as a reconfiguration abort and leave the
/// cluster for the next tick's re-plan.
#[allow(clippy::too_many_arguments)]
fn apply_action(
    cluster: &mut ClusterState,
    recon: &mut Reconfigurator,
    ledger: &mut BillingLedger,
    perf: &PerfModel,
    action: &ScalingAction,
    now: f64,
    report: &mut RunReport,
    fplan: &mut FaultPlan,
) -> Option<Applied> {
    match recon.apply_with_faults(cluster, perf, action, now, fplan) {
        Ok(applied) => {
            crate::metrics::ledger::record_applied(report, ledger, cluster, &applied, now);
            Some(applied)
        }
        Err(ApplyError::Transient { .. }) => {
            report.reconfig_aborts += 1;
            None
        }
        Err(ApplyError::Rejected(_)) => None,
    }
}

/// Dispatch work to every idle, ready pod of `f_idx`. Service times come
/// from `serve` — the run's quantized cache over the ground-truth latency
/// surface (pod slices live on the per-mille lattice, so cached lookups are
/// exact). Batch buffers are recycled through `batch_pool` (ServiceDone
/// returns them), so steady-state dispatch allocates nothing.
#[allow(clippy::too_many_arguments)]
fn try_dispatch(
    f_idx: usize,
    now: f64,
    queues: &mut [VecDeque<Request>],
    busy: &mut BTreeSet<PodId>,
    cluster: &ClusterState,
    serve: &dyn LatencyPredictor,
    functions: &[FunctionSpec],
    q: &mut EventQueue<Ev>,
    cfg: &SimConfig,
    fm: &mut [FunctionMetrics],
    report: &mut RunReport,
    batch_pool: &mut Vec<Vec<Request>>,
    wfs: &mut WfState,
) {
    let f = &functions[f_idx];
    // Idle + ready pods, largest capacity first (capacity-weighted routing;
    // heterogeneous fleets weight by the hosting class's throughput — `× 1.0`
    // on the reference class, so uniform routing order is unchanged).
    let mut pods: Vec<(&crate::cluster::Pod, f64)> = cluster
        .pods_of(&f.name)
        .into_iter()
        .filter(|p| p.is_ready(now) && !busy.contains(&p.id))
        .map(|p| {
            let cap = crate::vgpu::sm_to_f64(p.sm)
                * crate::vgpu::quota_to_f64(p.quota)
                * cluster.gpu(p.gpu).throughput();
            (p, cap)
        })
        .collect();
    // `total_cmp` orders identically to `partial_cmp` on real capacities
    // and cannot panic if a degenerate config yields a NaN score.
    pods.sort_by(|a, b| b.1.total_cmp(&a.1));

    for (pod, _) in pods {
        // Expire timed-out requests first.
        while let Some(r) = queues[f_idx].front() {
            if now - r.arrival > cfg.timeout {
                let r = queues[f_idx].pop_front().unwrap();
                fm[f_idx].record(r.arrival, now - r.arrival, Outcome::Dropped);
                wfs.fail_request(&r, now, report, Outcome::Dropped);
            } else {
                break;
            }
        }
        if queues[f_idx].is_empty() {
            return;
        }
        let take = (pod.batch as usize).min(queues[f_idx].len());
        let mut batch = batch_pool.pop().unwrap_or_default();
        debug_assert!(batch.is_empty());
        batch.extend(queues[f_idx].drain(..take));
        // TTFT = arrival → dispatch wait: the time spent queueing, which is
        // where cold starts and swap-ins show up. Recorded on every run;
        // exported only by lifecycle runs.
        for r in &batch {
            fm[f_idx].record_ttft(now - r.arrival);
        }
        // Service time on the pod's own GPU class (factor 1.0 routes through
        // the reference surface verbatim).
        let service = serve.latency(
            crate::rapp::PredictQuery::new(
                &f.graph,
                take as u32,
                crate::vgpu::sm_to_f64(pod.sm),
                crate::vgpu::quota_to_f64(pod.quota),
            )
            .with_factor(cluster.gpu(pod.gpu).throughput()),
        );
        busy.insert(pod.id);
        q.push_at(
            now + service,
            Ev::ServiceDone {
                pod: pod.id,
                f_idx,
                batch,
            },
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::autoscaler::{HybridAutoscaler, HybridConfig};
    use crate::baselines::{FastGSharePolicy, KServePolicy};
    use crate::model::zoo::{zoo_graph, ZooModel};
    use crate::rapp::OraclePredictor;
    use crate::workload::{Preset, TraceGen};

    fn test_functions() -> Vec<FunctionSpec> {
        let perf = PerfModel::default();
        [ZooModel::ResNet50, ZooModel::MobileNetV2]
            .iter()
            .map(|&m| {
                let graph = zoo_graph(m);
                let baseline = perf.latency(&graph, 1, 1.0, 1.0);
                FunctionSpec {
                    name: graph.name.clone(),
                    slo: baseline * 5.0,
                    batch: 8,
                    graph,
                    artifact: None,
                }
            })
            .collect()
    }

    fn small_trace(functions: &[FunctionSpec]) -> Trace {
        let names: Vec<&str> = functions.iter().map(|f| f.name.as_str()).collect();
        TraceGen::preset(Preset::Standard, 3, 120, 150.0).generate(&names)
    }

    fn run(policy: &mut dyn ScalingPolicy, whole_gpu: bool) -> RunReport {
        let fns = test_functions();
        let trace = small_trace(&fns);
        let perf = PerfModel::default();
        let pred = OraclePredictor::default();
        let cfg = SimConfig {
            n_gpus: 8,
            billing: BillingMode::from_whole_gpu(whole_gpu),
            ..SimConfig::default()
        };
        run_sim(policy, &fns, &trace, &pred, &perf, &cfg)
    }

    #[test]
    fn hasgpu_serves_most_requests() {
        let mut p = HybridAutoscaler::new(HybridConfig::default());
        let r = run(&mut p, false);
        let total = r.total_served() + r.total_dropped();
        assert!(total > 1000, "trace produced {total} requests");
        let drop_rate = r.total_dropped() as f64 / total as f64;
        assert!(drop_rate < 0.05, "drop rate {drop_rate}");
        assert!(r.vertical_ups > 0, "hybrid scaler must use vertical scaling");
    }

    #[test]
    fn kserve_runs_and_costs_more_than_hasgpu() {
        let mut has = HybridAutoscaler::new(HybridConfig::default());
        let r_has = run(&mut has, false);
        let mut ks = KServePolicy::default();
        let r_ks = run(&mut ks, true);
        // Same workload, so compare per-1k cost over all functions.
        let c_has: f64 = r_has.costs.total_cost();
        let c_ks: f64 = r_ks.costs.total_cost();
        // The full paper-factor comparison lives in tests/sim_experiments.rs
        // (6 functions, duty-cycled trace); this smoke run only pins the
        // ordering.
        assert!(c_ks > c_has, "kserve ${c_ks} should exceed has-gpu ${c_has}");
    }

    #[test]
    fn fastgshare_runs_without_vertical_scaling() {
        let mut fg = FastGSharePolicy::default();
        let r = run(&mut fg, false);
        assert_eq!(r.vertical_ups, 0);
        assert_eq!(r.vertical_downs, 0);
        assert!(r.total_served() > 500);
    }

    #[test]
    fn deterministic_runs() {
        let mut a = HybridAutoscaler::new(HybridConfig::default());
        let mut b = HybridAutoscaler::new(HybridConfig::default());
        let ra = run(&mut a, false);
        let rb = run(&mut b, false);
        assert_eq!(ra.total_served(), rb.total_served());
        assert!((ra.costs.total_cost() - rb.costs.total_cost()).abs() < 1e-12);
    }

    #[test]
    fn event_queue_stays_o_in_flight_not_o_requests() {
        // The streaming arrival cursor keeps at most one arrival event per
        // function in the heap: the high-water mark must be bounded by
        // ticks-outstanding-at-start + in-flight work, and stay far below
        // the total request count the seed used to pre-push.
        let mut p = HybridAutoscaler::new(HybridConfig::default());
        let r = run(&mut p, false);
        let total = r.total_served() + r.total_dropped();
        assert!(total > 1000, "trace produced {total} requests");
        assert!(r.event_queue_peak > 0, "peak must be recorded");
        // Pre-pushed ticks dominate the bound: duration (120 s + 60 s drain)
        // at 1 Hz plus a small in-flight margin. The seed's pre-push put all
        // ~`total` arrivals in the heap up-front, so this bound is only
        // reachable with the streaming cursor.
        assert!(
            r.event_queue_peak < 500 && r.event_queue_peak < total / 2,
            "queue peak {} not O(in-flight) for {total} requests",
            r.event_queue_peak
        );
    }

    #[test]
    fn rejected_actions_leave_counters_and_ledger_untouched() {
        // ISSUE acceptance: plan onto a full GPU and assert scaling counters
        // stay flat on rejection (the seed counted before recon.apply).
        let fns = test_functions();
        let perf = PerfModel::default();
        let mut cluster = ClusterState::new(1, perf.dev.mem_cap);
        cluster.register_function(fns[0].clone());
        let mut recon = Reconfigurator::new(&cluster, 1);
        let mut ledger = BillingLedger::new(BillingMode::FineGrained, perf.dev.price_per_hour);
        let mut report = RunReport::new("test");
        let mut fplan = FaultPlan::compile(&FaultSpec::default(), 1, 1, 100.0);
        let create = |sm, quota| ScalingAction::CreatePod {
            function: fns[0].name.clone(),
            gpu: crate::cluster::GpuId(0),
            sm,
            quota,
            batch: fns[0].batch,
            new_gpu: true,
        };
        // Fill the only GPU.
        let applied = apply_action(
            &mut cluster,
            &mut recon,
            &mut ledger,
            &perf,
            &create(1000, 1000),
            0.0,
            &mut report,
            &mut fplan,
        );
        assert!(applied.is_some());
        assert_eq!(report.horizontal_ups, 1);
        assert_eq!(ledger.open_accounts(), 1);
        // A second pod cannot fit: the action is rejected and must not count
        // or bill.
        let rejected = apply_action(
            &mut cluster,
            &mut recon,
            &mut ledger,
            &perf,
            &create(1000, 1000),
            5.0,
            &mut report,
            &mut fplan,
        );
        assert!(rejected.is_none());
        assert_eq!(report.horizontal_ups, 1, "rejected create must not count");
        assert_eq!(report.vertical_ups + report.vertical_downs, 0);
        assert_eq!(report.horizontal_downs, 0);
        assert_eq!(ledger.open_accounts(), 1, "rejected create must not open an account");
        // A SetQuota on a nonexistent pod is likewise a no-op.
        let bad = apply_action(
            &mut cluster,
            &mut recon,
            &mut ledger,
            &perf,
            &ScalingAction::SetQuota { pod: PodId(999), quota: 500 },
            6.0,
            &mut report,
            &mut fplan,
        );
        assert!(bad.is_none());
        assert_eq!(report.vertical_ups + report.vertical_downs, 0);
    }

    #[test]
    fn end_of_run_drops_record_real_time_in_queue() {
        // The seed recorded latency 0.0 for end-of-run drops while timeout
        // drops recorded the real wait. All drop paths now record actual
        // time-in-queue.
        let fns = test_functions();
        let trace = {
            let names: Vec<&str> = fns.iter().map(|f| f.name.as_str()).collect();
            TraceGen::preset(Preset::Standard, 3, 30, 400.0).generate(&names)
        };
        let perf = PerfModel::default();
        let pred = OraclePredictor::default();
        // One GPU + huge timeout + huge queue: the overloaded functions pile
        // up a backlog that can only die as end-of-run drops.
        let cfg = SimConfig {
            n_gpus: 1,
            timeout: 1e9,
            max_queue: usize::MAX,
            drain: 5.0,
            ..SimConfig::default()
        };
        let mut ks = KServePolicy::default();
        let r = run_sim(&mut ks, &fns, &trace, &pred, &perf, &cfg);
        let dropped: Vec<f64> = r
            .functions
            .values()
            .flat_map(|m| m.records.iter())
            .filter(|rec| rec.outcome == Outcome::Dropped)
            .map(|rec| rec.latency)
            .collect();
        assert!(!dropped.is_empty(), "overload run must drop requests at end-of-run");
        assert!(
            dropped.iter().all(|&l| l > 0.0),
            "every end-of-run drop must carry its real wait"
        );
        // The waits are bounded by the run duration.
        assert!(dropped.iter().all(|&l| l <= r.duration));
    }

    #[test]
    fn mixed_fleet_run_tracks_composition_and_per_class_costs() {
        let fns = test_functions();
        let trace = small_trace(&fns);
        let perf = PerfModel::default();
        let pred = OraclePredictor::default();
        let fleet = vec![
            GpuClass::a100(),
            GpuClass::v100(),
            GpuClass::v100(),
            GpuClass::t4(),
            GpuClass::t4(),
            GpuClass::t4(),
        ];
        let cfg = SimConfig::for_experiment(0, 42, BillingMode::FineGrained).with_fleet(fleet);
        assert_eq!(cfg.n_gpus, 6);
        let mut p = HybridAutoscaler::new(HybridConfig::default());
        let r = run_sim(&mut p, &fns, &trace, &pred, &perf, &cfg);
        assert_eq!(r.fleet_gpus.get("a100"), Some(&1));
        assert_eq!(r.fleet_gpus.get("v100"), Some(&2));
        assert_eq!(r.fleet_gpus.get("t4"), Some(&3));
        assert!(r.total_served() > 500, "served {}", r.total_served());
        // Per-class billing sums to the run total.
        let class_total: f64 = r
            .costs
            .billed_classes()
            .map(|c| r.costs.class_cost_of(c))
            .sum();
        assert!((class_total - r.costs.total_cost()).abs() < 1e-9);
        assert!(r.costs.total_cost() > 0.0);
        // The export carries the fleet + class sections for mixed runs.
        let j = r.to_json();
        assert!(j.get("fleet_gpus").is_ok());
        assert!(j.get("class_costs").is_ok());
        // …and a uniform run omits them (byte-stability of the old export).
        let mut p2 = HybridAutoscaler::new(HybridConfig::default());
        let r2 = run_sim(&mut p2, &fns, &trace, &pred, &perf, &SimConfig::default());
        assert!(r2.to_json().get("fleet_gpus").is_err());
    }

    #[test]
    fn uniform_fleet_config_is_byte_identical_to_homogeneous_constructor() {
        // SimConfig::with_fleet(v100 × n) must reproduce the homogeneous
        // run to the last bit — the keystone the expt golden test builds on.
        let fns = test_functions();
        let trace = small_trace(&fns);
        let perf = PerfModel::default();
        let pred = OraclePredictor::default();
        let base = SimConfig {
            n_gpus: 8,
            ..SimConfig::default()
        };
        let fleet_cfg = base.clone().with_fleet(vec![GpuClass::v100(); 8]);
        let mut a = HybridAutoscaler::new(HybridConfig::default());
        let mut b = HybridAutoscaler::new(HybridConfig::default());
        let ra = run_sim(&mut a, &fns, &trace, &pred, &perf, &base);
        let rb = run_sim(&mut b, &fns, &trace, &pred, &perf, &fleet_cfg);
        assert_eq!(ra.total_served(), rb.total_served());
        assert_eq!(ra.total_dropped(), rb.total_dropped());
        assert_eq!(
            ra.costs.total_cost().to_bits(),
            rb.costs.total_cost().to_bits(),
            "uniform fleet must not perturb a single bit of cost"
        );
        assert_eq!(
            (ra.vertical_ups, ra.horizontal_ups, ra.horizontal_downs),
            (rb.vertical_ups, rb.horizontal_ups, rb.horizontal_downs)
        );
    }

    /// Run the same policy × trace under both planner modes and demand the
    /// full JSON export (every record-derived number) plus the billing
    /// total match to the last bit.
    fn assert_planner_modes_identical(
        mk_policy: &dyn Fn() -> Box<dyn ScalingPolicy>,
        fns: &[FunctionSpec],
        trace: &Trace,
        base: &SimConfig,
        what: &str,
    ) {
        let perf = PerfModel::default();
        let pred = OraclePredictor::default();
        let mut full_cfg = base.clone();
        full_cfg.planner = PlannerMode::FullScan;
        let mut act_cfg = base.clone();
        act_cfg.planner = PlannerMode::ActiveSet;
        let ra = run_sim(&mut *mk_policy(), fns, trace, &pred, &perf, &full_cfg);
        let rb = run_sim(&mut *mk_policy(), fns, trace, &pred, &perf, &act_cfg);
        assert_eq!(
            ra.to_json().to_string_pretty(),
            rb.to_json().to_string_pretty(),
            "{what}: active-set export must be byte-identical to full scan"
        );
        assert_eq!(
            ra.costs.total_cost().to_bits(),
            rb.costs.total_cost().to_bits(),
            "{what}: active-set cost must not perturb a single bit"
        );
    }

    #[test]
    fn active_set_planner_matches_full_scan_bit_for_bit() {
        let fns = test_functions();
        let trace = small_trace(&fns);
        let policies: Vec<(&str, Box<dyn Fn() -> Box<dyn ScalingPolicy>>)> = vec![
            ("has-gpu", Box::new(|| Box::new(HybridAutoscaler::new(HybridConfig::default())))),
            ("kserve", Box::new(|| Box::<KServePolicy>::default())),
            ("fastgshare", Box::new(|| Box::<FastGSharePolicy>::default())),
        ];
        for (name, mk) in &policies {
            for warm in [true, false] {
                let cfg = SimConfig {
                    n_gpus: 8,
                    warm_start: warm,
                    ..SimConfig::default()
                };
                assert_planner_modes_identical(
                    mk,
                    &fns,
                    &trace,
                    &cfg,
                    &format!("{name} warm={warm}"),
                );
            }
        }
        // The hard case: a cold cluster and a trace that is silent for its
        // first 60 s. The hybrid policy is quiescent through those ticks,
        // the active set is genuinely empty (real skips happen), and the
        // Kalman catch-up replay must reconstruct the full scan's filter
        // state exactly when traffic finally arrives.
        let mut gap = Trace::default();
        for f in &fns {
            let mut s = vec![0.0; 60];
            s.extend(vec![40.0; 60]);
            gap.series.insert(f.name.clone(), s);
        }
        let cold = SimConfig {
            n_gpus: 8,
            warm_start: false,
            ..SimConfig::default()
        };
        assert_planner_modes_identical(
            &|| Box::new(HybridAutoscaler::new(HybridConfig::default())),
            &fns,
            &gap,
            &cold,
            "has-gpu silent-head cold start",
        );
    }

    #[test]
    fn sampled_trace_cell_runs_at_population_scale() {
        use crate::workload::TraceSource;
        // A 2 000-function sampled population (heavy-tail popularity, mostly
        // idle) through the active-set planner with a lazy idle sweep: the
        // run must complete quickly, serve traffic, and stay deterministic.
        let perf = PerfModel::default();
        let src = TraceSource {
            seed: 11,
            duration: 30,
            total_rps: 60.0,
            functions: 2000,
            zipf_s: 1.2,
            day_period: 15.0,
            noise_sigma: 0.5,
            duty_cycle: 0.25,
        };
        let (fns, trace) = src.sample(&perf);
        assert_eq!(fns.len(), 2000);
        let cfg = SimConfig {
            n_gpus: 16,
            warm_start: false,
            idle_sweep: 8,
            drain: 10.0,
            ..SimConfig::default()
        };
        let pred = OraclePredictor::default();
        let run_once = || {
            let mut p = HybridAutoscaler::new(HybridConfig::default());
            run_sim(&mut p, &fns, &trace, &pred, &perf, &cfg)
        };
        let ra = run_once();
        let rb = run_once();
        assert!(ra.total_served() > 100, "served {}", ra.total_served());
        assert_eq!(ra.total_served(), rb.total_served());
        assert_eq!(ra.total_dropped(), rb.total_dropped());
        assert_eq!(ra.costs.total_cost().to_bits(), rb.costs.total_cost().to_bits());
        // The sharded logs merge only touched functions: far fewer entries
        // than the population, and every entry non-empty.
        assert!(ra.functions.len() < fns.len());
        assert!(ra.functions.values().all(|m| !m.is_empty()));
    }

    #[test]
    fn cold_start_storm_config_starts_empty_and_records_ttft() {
        let fns = test_functions();
        let trace = small_trace(&fns);
        // Finite staging/swap bandwidths: cold starts take real time.
        let perf = PerfModel::new(crate::perf::DeviceSpec {
            host_load_bw: 1e9,
            h2d_bw: 2e8,
            ..Default::default()
        });
        let pred = OraclePredictor::default();
        let cfg = SimConfig {
            n_gpus: 8,
            warm_start: false,
            lifecycle: true,
            ..SimConfig::default()
        };
        let mut p = HybridAutoscaler::new(HybridConfig::default());
        let r = run_sim(&mut p, &fns, &trace, &pred, &perf, &cfg);
        assert!(r.lifecycle);
        assert!(r.total_served() > 100, "served {}", r.total_served());
        let mut t = r.merged_ttft_summary();
        assert!(!t.is_empty());
        // Someone had to wait behind the initial cold start.
        assert!(t.percentile(100.0) > 0.0);
        assert!(r.to_json().get("ttft_p99").is_ok());
        // The default (warm, zero-latency) path keeps the old export shape.
        let mut p2 = HybridAutoscaler::new(HybridConfig::default());
        let r2 = run_sim(
            &mut p2,
            &fns,
            &trace,
            &pred,
            &PerfModel::default(),
            &SimConfig::default(),
        );
        assert!(!r2.lifecycle);
        assert!(r2.to_json().get("ttft_p99").is_err());
    }

    /// A trace of pure silence: the only pods are warm-start bootstraps, so
    /// billing is a single constant-rate account per pod — the fixture the
    /// fault-billing truncation tests lean on.
    fn zero_trace(fns: &[FunctionSpec], secs: usize) -> Trace {
        let mut t = Trace::default();
        for f in fns {
            t.series.insert(f.name.clone(), vec![0.0; secs]);
        }
        t
    }

    #[test]
    fn dispatch_order_survives_nan_headroom() {
        // Regression: the dispatch sort used `partial_cmp().unwrap()`, which
        // panics the whole run if any pod's headroom is NaN (a degenerate
        // class factor or predictor output). `total_cmp` — the comparator
        // try_dispatch now uses — gives NaN a fixed place in the descending
        // order instead of aborting.
        let mut pods = vec![(PodId(1), 1.0), (PodId(2), f64::NAN), (PodId(3), 2.0)];
        pods.sort_by(|a, b| b.1.total_cmp(&a.1));
        assert_eq!(
            pods.iter().map(|p| p.0).collect::<Vec<_>>(),
            vec![PodId(2), PodId(3), PodId(1)],
            "IEEE total order ranks +NaN above every number, deterministically"
        );
    }

    #[test]
    fn scripted_gpu_failure_bills_no_pod_seconds_past_death() {
        // Acceptance: zero pod-seconds billed past a device's death, in both
        // billing modes. One function, one GPU, no arrivals: the warm-start
        // pod accrues cost linearly, so the failed run's cost must be the
        // no-fault cost scaled by exactly t_fail / duration.
        let fns: Vec<FunctionSpec> = test_functions().into_iter().take(1).collect();
        let trace = zero_trace(&fns, 120);
        let perf = PerfModel::default();
        let pred = OraclePredictor::default();
        for whole_gpu in [false, true] {
            let base_cfg = SimConfig {
                n_gpus: 1,
                billing: BillingMode::from_whole_gpu(whole_gpu),
                ..SimConfig::default()
            };
            let mut fail_cfg = base_cfg.clone();
            fail_cfg.faults = FaultSpec {
                scripted_failures: vec![(50.0, 0)],
                ..FaultSpec::default()
            };
            let mut ks = KServePolicy::default();
            let r_base = run_sim(&mut ks, &fns, &trace, &pred, &perf, &base_cfg);
            let mut ks2 = KServePolicy::default();
            let r_fail = run_sim(&mut ks2, &fns, &trace, &pred, &perf, &fail_cfg);
            assert!(r_fail.faults_active);
            assert_eq!(r_fail.gpu_failures, 1);
            assert_eq!(r_fail.pods_lost, 1);
            // The device never comes back: downtime truncates at end-of-run.
            assert!((r_fail.gpu_downtime - (r_fail.duration - 50.0)).abs() < 1e-9);
            assert!(r_fail.availability() < 1.0);
            let ratio = r_fail.costs.total_cost() / r_base.costs.total_cost();
            assert!(
                (ratio - 50.0 / r_base.duration).abs() < 1e-9,
                "whole_gpu={whole_gpu}: cost ratio {ratio} != {} — pod-seconds \
                 billed past the failure instant",
                50.0 / r_base.duration
            );
        }
    }

    #[test]
    fn scripted_repair_restores_capacity_and_samples_mttr() {
        let fns: Vec<FunctionSpec> = test_functions().into_iter().take(1).collect();
        let trace = zero_trace(&fns, 120);
        let perf = PerfModel::default();
        let pred = OraclePredictor::default();
        let mut cfg = SimConfig {
            n_gpus: 1,
            ..SimConfig::default()
        };
        cfg.faults = FaultSpec {
            scripted_failures: vec![(50.0, 0)],
            scripted_repairs: vec![(70.0, 0)],
            ..FaultSpec::default()
        };
        let mut ks = KServePolicy::default();
        let r = run_sim(&mut ks, &fns, &trace, &pred, &perf, &cfg);
        // Downtime is exactly the failure→repair window.
        assert!((r.gpu_downtime - 20.0).abs() < 1e-9, "downtime {}", r.gpu_downtime);
        assert!(r.availability() > 0.0 && r.availability() < 1.0);
        // The replacement replica closes the loss: time-to-restore-capacity
        // can never undercut the outage itself.
        let mean = r.mttr_mean().expect("a replacement pod must restore capacity");
        assert!(mean >= 20.0, "mttr {mean} shorter than the outage");
        assert!(mean < r.duration);
    }

    #[test]
    fn chaos_runs_are_deterministic_and_lose_no_records() {
        let fns = test_functions();
        let trace = small_trace(&fns);
        let perf = PerfModel::default();
        let pred = OraclePredictor::default();
        let chaos = fault_spec_from_name("chaos-gpu-failures").expect("preset registered");
        let cfg = SimConfig {
            n_gpus: 8,
            faults: chaos,
            ..SimConfig::default()
        };
        let run_once = || {
            let mut p = HybridAutoscaler::new(HybridConfig::default());
            run_sim(&mut p, &fns, &trace, &pred, &perf, &cfg)
        };
        let ra = run_once();
        let rb = run_once();
        assert_eq!(
            (ra.total_served(), ra.total_dropped(), ra.total_failed(), ra.gpu_failures),
            (rb.total_served(), rb.total_dropped(), rb.total_failed(), rb.gpu_failures)
        );
        assert_eq!(ra.costs.total_cost().to_bits(), rb.costs.total_cost().to_bits());
        assert_eq!(ra.gpu_downtime.to_bits(), rb.gpu_downtime.to_bits());
        // Chaos must actually bite on this horizon (seeded, so this is a
        // fixed fact of the run, not a flake).
        assert!(ra.gpu_failures > 0);
        assert!(ra.availability() < 1.0);
        // Every arrival still ends in exactly one of Served/Dropped/Failed:
        // the arrival stream (PRNG stream 77) is independent of both fault
        // streams, so the no-fault run pins the expected record count.
        let mut p = HybridAutoscaler::new(HybridConfig::default());
        let r0 = run_sim(
            &mut p,
            &fns,
            &trace,
            &pred,
            &perf,
            &SimConfig {
                n_gpus: 8,
                ..SimConfig::default()
            },
        );
        let count = |r: &RunReport| r.functions.values().map(|m| m.records.len()).sum::<usize>();
        assert_eq!(count(&ra), count(&r0), "records lost or duplicated under faults");
        assert_eq!(
            count(&ra),
            ra.total_served() + ra.total_dropped() + ra.total_failed(),
            "an outcome path leaked records"
        );
    }

    #[test]
    fn empty_trace_serves_nothing_but_keeps_min_pods() {
        let fns = test_functions();
        let mut trace = Trace::default();
        for f in &fns {
            trace.series.insert(f.name.clone(), vec![0.0; 30]);
        }
        let perf = PerfModel::default();
        let pred = OraclePredictor::default();
        let mut p = HybridAutoscaler::new(HybridConfig::default());
        let r = run_sim(&mut p, &fns, &trace, &pred, &perf, &SimConfig::default());
        assert_eq!(r.total_served(), 0);
        // Keep-alive still accrues (small) cost.
        assert!(r.costs.total_cost() > 0.0);
    }

    /// Pipeline fixture: the built-in detector→classifier chain, its stage
    /// functions, and a trace that feeds *only* the entry stage (downstream
    /// stages receive hop arrivals, never trace arrivals).
    fn pipeline_setup() -> (crate::workflow::Workflow, Vec<FunctionSpec>, Trace) {
        let perf = PerfModel::default();
        let reg = crate::workflow::WorkflowRegistry::default();
        let wf = reg.get("pipeline-vision").expect("builtin").clone();
        let fns = wf.stage_functions(&perf);
        let entry = wf.stage_function_name(wf.entry());
        let trace =
            TraceGen::preset(Preset::PipelineVision, 3, 120, 40.0).generate(&[entry.as_str()]);
        (wf, fns, trace)
    }

    fn run_pipeline(policy: &mut dyn ScalingPolicy) -> (crate::workflow::Workflow, RunReport) {
        let (wf, fns, trace) = pipeline_setup();
        let perf = PerfModel::default();
        let pred = OraclePredictor::default();
        let cfg = SimConfig {
            n_gpus: 8,
            workflows: vec![wf.clone()],
            ..SimConfig::default()
        };
        let r = run_sim(policy, &fns, &trace, &pred, &perf, &cfg);
        (wf, r)
    }

    #[test]
    fn workflow_run_records_e2e_once_per_origin() {
        let mut p = HybridAutoscaler::new(HybridConfig::default());
        let (wf, r) = run_pipeline(&mut p);
        let m = r.workflow_e2e.get(&wf.name).expect("e2e metrics recorded");
        assert!(m.served() > 100, "e2e served {}", m.served());
        // Conservation: every entry arrival opened exactly one origin, and
        // every origin closed exactly once (complete, drop, or end-of-run).
        let entry = &r.functions[&wf.stage_function_name(wf.entry())];
        assert_eq!(m.records.len(), entry.records.len());
        // Both stages actually served traffic through the hop path.
        let classify = &r.functions[&wf.stage_function_name(1)];
        assert!(classify.served() > 100, "downstream served {}", classify.served());
        // e2e can never undercut the hop floor (charged exactly once).
        let mut e2e = m.latency_summary();
        let floor = wf.critical_path_hops();
        let lo = e2e.percentile(0.0);
        assert!(lo >= floor, "min e2e {lo} < hop floor {floor}");
        // Export gate: workflow keys present here, absent on a default run.
        assert!(r.to_json().get("workflows").is_ok());
        let mut p2 = HybridAutoscaler::new(HybridConfig::default());
        let r2 = run(&mut p2, false);
        assert!(r2.to_json().get("workflows").is_err());
    }

    #[test]
    fn workflow_runs_are_deterministic() {
        let mut a = HybridAutoscaler::new(HybridConfig::default());
        let mut b = HybridAutoscaler::new(HybridConfig::default());
        let (wf, ra) = run_pipeline(&mut a);
        let (_, rb) = run_pipeline(&mut b);
        assert_eq!(ra.total_served(), rb.total_served());
        assert_eq!(ra.costs.total_cost().to_bits(), rb.costs.total_cost().to_bits());
        let (ma, mb) = (&ra.workflow_e2e[&wf.name], &rb.workflow_e2e[&wf.name]);
        assert_eq!(ma.records.len(), mb.records.len());
        let p99 = |m: &crate::metrics::FunctionMetrics| {
            let mut s = m.latency_summary();
            s.p99().to_bits()
        };
        assert_eq!(p99(ma), p99(mb));
    }

    #[test]
    fn baseline_policies_serve_workflows_via_the_fair_fallback() {
        // KServe never implements plan_workflow; the trait's default fair
        // per-stage fallback must still serve the pipeline end to end.
        let mut ks = KServePolicy::default();
        let (wf, r) = run_pipeline(&mut ks);
        let m = r.workflow_e2e.get(&wf.name).expect("fallback still routes");
        assert!(m.served() > 50, "e2e served {}", m.served());
        assert_eq!(r.vertical_ups, 0, "kserve must stay horizontal-only");
    }
}
