//! The Hybrid Auto-Scaler: Kalman-filter workload prediction + the hybrid
//! vertical/horizontal scaling algorithm (paper §3.3, Algorithm 1).
//!
//! Per tick and per function the scaler:
//!
//! 1. estimates the next-interval RPS `R` with a scalar Kalman filter;
//! 2. computes current processing capability `C_f = Σ RaPP(f, b_i, s_i, q_i)`;
//! 3. **scale-up** (`R > C_f·α`): fills the gap ΔR *vertically first* — more
//!    quota to existing pods, largest SM partitions first (a smaller quota
//!    increment buys more throughput there) — then *horizontally*: a new pod
//!    on the used GPU with the lowest HGO, else on a fresh GPU with the most
//!    efficient (sm, quota) for ΔR;
//! 4. **scale-down** (`R < C_f·β`, after a cooldown): mirrored stepwise quota
//!    reduction, smallest SM partitions first, removing pods whose quota hits
//!    zero — but always retaining one pod (keep-alive at minimal quota, which
//!    eliminates scale-from-zero cold starts).
//!
//! The scaler emits [`ScalingAction`]s; the GPU Re-configurator applies them.

use crate::cluster::{ClusterState, FunctionSpec, Pod, PodPhase, PodState, ScalingAction};
use crate::rapp::{min_feasible_quota, LatencyPredictor, PredictQuery};
use crate::vgpu::{GpuClass, QuotaMille, SmMille, QUOTA_FULL, QUOTA_STEP, SM_FULL, SM_STEP};
use std::collections::{BTreeMap, HashMap};

/// Scalar Kalman filter for short-term RPS estimation (paper §3.3 equations,
/// with A = H = 1: a random-walk workload model).
#[derive(Clone, Debug)]
pub struct KalmanFilter {
    /// State transition (A) — 1.0 for random walk.
    pub a: f64,
    /// Observation model (H).
    pub h: f64,
    /// Process noise (Q): how fast the true rate drifts.
    pub q: f64,
    /// Measurement noise (D): how noisy per-tick RPS observations are.
    pub d: f64,
    /// Current estimate R and covariance P.
    x: f64,
    p: f64,
    initialized: bool,
}

impl KalmanFilter {
    pub fn new(process_noise: f64, measurement_noise: f64) -> Self {
        KalmanFilter {
            a: 1.0,
            h: 1.0,
            q: process_noise,
            d: measurement_noise,
            x: 0.0,
            p: 1.0,
            initialized: false,
        }
    }

    /// Feed the measured rate `r_t`; returns the filtered estimate `R` used
    /// as the prediction for the next interval.
    pub fn update(&mut self, r_t: f64) -> f64 {
        if !self.initialized {
            self.x = r_t.max(0.0);
            self.p = self.d;
            self.initialized = true;
            return self.x;
        }
        // Predict.
        let x_pred = self.a * self.x;
        let p_pred = self.a * self.p * self.a + self.q;
        // Update. A rate is non-negative: clamp the *stored* state, not just
        // the returned value, or a downward spike leaves `estimate()`
        // reporting a negative RPS until enough upward measurements drag the
        // hidden state back above zero.
        let k = p_pred * self.h / (self.h * p_pred * self.h + self.d);
        self.x = (x_pred + k * (r_t - self.h * x_pred)).max(0.0);
        self.p = (1.0 - k * self.h) * p_pred;
        self.x
    }

    pub fn estimate(&self) -> f64 {
        self.x
    }

    pub fn gain(&self) -> f64 {
        let p_pred = self.a * self.p * self.a + self.q;
        p_pred * self.h / (self.h * p_pred * self.h + self.d)
    }
}

/// Scaling policy interface shared by HAS-GPU and the baseline platforms.
pub trait ScalingPolicy: Send {
    /// The platform name this policy serves under — for registry-built
    /// policies this is the `PlatformSpec` name, so run reports key on the
    /// same strings as the scenario-matrix export.
    fn name(&self) -> &str;

    /// Plan scaling actions for one function given the *observed* RPS of the
    /// last interval. The harness applies the actions via the Re-configurator.
    fn plan(
        &mut self,
        f: &FunctionSpec,
        observed_rps: f64,
        cluster: &ClusterState,
        predictor: &dyn LatencyPredictor,
        now: f64,
    ) -> Vec<ScalingAction>;

    /// Plan every stage of a workflow in one pass. `stage_fns[s]` is the
    /// serving function of stage `s` (in stage order) and `observed_rps[s]`
    /// its measured arrival rate over the last interval.
    ///
    /// The default is the **fair single-function-per-stage fallback** the
    /// baseline platforms inherit: each stage is planned independently on
    /// its own observed rate, exactly as if it were an unrelated function —
    /// no pipeline knowledge, no demand propagation. [`HybridAutoscaler`]
    /// overrides this with the co-scaling pass (bottleneck-stage-first,
    /// upstream-throughput-propagated demand).
    fn plan_workflow(
        &mut self,
        _wf: &crate::workflow::Workflow,
        stage_fns: &[&FunctionSpec],
        observed_rps: &[f64],
        cluster: &ClusterState,
        predictor: &dyn LatencyPredictor,
        now: f64,
    ) -> Vec<ScalingAction> {
        let mut out = Vec::new();
        for (f, &r) in stage_fns.iter().zip(observed_rps) {
            out.extend(self.plan(f, r, cluster, predictor, now));
        }
        out
    }

    /// Whether this policy needs its periodic [`ScalingPolicy::plan`] call
    /// for `f` even when the function is **fully idle** — no pods, no queued
    /// requests, no arrivals since the last plan. The active-set planner
    /// loop in `run_sim` only skips a function's plan tick when this returns
    /// `false`; skipped ticks are later replayed through
    /// [`ScalingPolicy::note_skipped_idle_ticks`].
    ///
    /// Default `true`: a policy that mutates per-tick state on every call
    /// (EWMAs, idle clocks) or that creates capacity at zero demand
    /// (min-replica platforms) must never be skipped. Only policies whose
    /// idle plan is a provable no-op should override — see
    /// [`HybridAutoscaler`].
    fn wants_idle_plan(&self, _f: &FunctionSpec, _now: f64) -> bool {
        true
    }

    /// Replay `missed` skipped idle plan ticks for `f` before its next real
    /// plan. The caller guarantees every skipped tick observed a rate of
    /// exactly `0.0` (no arrivals, empty queue throughout) — so a policy
    /// can reproduce, bit for bit, the rate-tracking state it would have
    /// reached had it been called each tick. (Under a lazy idle sweep the
    /// function may have held pods during swept ticks; only the *observed
    /// rate* of the skipped calls is guaranteed, which is all the replay
    /// reconstructs.)
    /// Default: nothing to replay.
    fn note_skipped_idle_ticks(&mut self, _f: &FunctionSpec, _missed: u64) {}
}

/// Which scaling axes Algorithm 1 may exercise. `Both` is the paper's
/// hybrid algorithm; the single-axis restrictions power the
/// `has-vertical-only` / `has-horizontal-only` ablation platforms in the
/// scenario matrix — the *same* policy code under a config restriction,
/// never a fork, so ablation deltas measure exactly the removed axis.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum ScalingAxes {
    /// Vertical quota re-writes + horizontal replica scaling (Algorithm 1).
    #[default]
    Both,
    /// Quota re-writes only. A function with zero pods cannot scale
    /// vertically, so the bootstrap pod may still be created; after that no
    /// replica is ever added or removed.
    VerticalOnly,
    /// Replica adds/removes only; pod quotas are frozen at creation.
    HorizontalOnly,
}

impl ScalingAxes {
    pub fn vertical(self) -> bool {
        matches!(self, ScalingAxes::Both | ScalingAxes::VerticalOnly)
    }

    pub fn horizontal(self) -> bool {
        matches!(self, ScalingAxes::Both | ScalingAxes::HorizontalOnly)
    }
}

/// Tunables of Algorithm 1. `Copy` on purpose: `plan` snapshots the config
/// by value each call instead of cloning through an allocation.
#[derive(Clone, Copy, Debug)]
pub struct HybridConfig {
    /// Scale-up trigger threshold α (fraction of capacity considered "full").
    pub alpha: f64,
    /// Scale-down trigger threshold β.
    pub beta: f64,
    /// Vertical scaling step ΔI_q in quota per-mille.
    pub quota_step: QuotaMille,
    /// Minimum interval between scale-down operations (seconds).
    pub cooldown: f64,
    /// Keep-alive quota for the last retained pod.
    pub min_quota: QuotaMille,
    /// Default SM partition for brand-new pods when the predictor's
    /// efficiency search has no better answer.
    pub default_sm: SmMille,
    /// Kalman noise parameters (process, measurement).
    pub kalman: (f64, f64),
    /// A pod's predicted latency must stay ≤ slo × this margin; scale-down
    /// never shrinks a pod below its SLO-feasible quota.
    pub slo_margin: f64,
    /// New pods start at most at this quota so they retain vertical runway
    /// for the next burst (the whole point of quota-based vertical scaling).
    pub headroom_quota: QuotaMille,
    /// Which scaling axes the algorithm may exercise (`Both` = Algorithm 1;
    /// the single-axis values express the ablation platforms).
    pub scaling_axes: ScalingAxes,
    /// Idle keep-alive horizon (seconds). With the default
    /// (`f64::INFINITY`) surplus pods are deleted outright — the historical
    /// behaviour, byte-identical to pre-lifecycle plans. A finite horizon
    /// makes scale-down *demote* surplus resident pods to the host-cached
    /// swap tier instead of removing them (reactivation then costs one
    /// host→device swap, not a full cold start); parked pods idle longer
    /// than this horizon are reaped for real.
    pub keep_alive: f64,
}

impl Default for HybridConfig {
    fn default() -> Self {
        HybridConfig {
            alpha: 0.8,
            beta: 0.4,
            quota_step: QUOTA_STEP,
            cooldown: 30.0,
            min_quota: QUOTA_STEP,
            default_sm: 400,
            // Responsive filter: bursty serverless arrivals change faster
            // than per-tick measurement noise (gain ≈ 0.8).
            kalman: (16.0, 4.0),
            slo_margin: 0.75,
            headroom_quota: 600,
            scaling_axes: ScalingAxes::Both,
            keep_alive: f64::INFINITY,
        }
    }
}

/// Below this predicted rate the function is considered idle: the keep-alive
/// scale-down floor relaxes its SLO margin to exactly the SLO (1.0) so the
/// retained pod holds minimal resources without risking the first
/// reactivation request.
const NEAR_ZERO_RPS: f64 = 1e-3;

/// The paper's hybrid auto-scaler.
pub struct HybridAutoscaler {
    pub cfg: HybridConfig,
    /// Platform name this instance serves under ("has-gpu" for the stock
    /// policy; ablation platforms set their registry name via [`Self::named`]).
    name: String,
    /// Function-name interning: each function seen by `plan` gets a dense
    /// id on first sight, and the per-function hot state below is indexed
    /// by it. The name `String` is cloned once per function *lifetime*
    /// (at interning), never per tick — at 100k functions the old
    /// `BTreeMap<String, _>` entry-per-tick pattern was allocation churn.
    ids: HashMap<String, u32>,
    /// Kalman filter per interned function id.
    filters: Vec<KalmanFilter>,
    /// Last scale-down instant per interned id ([`NEVER_SCALED`] sentinel).
    last_scale_down: Vec<f64>,
    /// Reusable quota-lattice sweep buffers (quotas, latencies) — the
    /// candidate sweeps evaluate a whole lattice level per predictor pass
    /// without allocating per tick.
    q_buf: Vec<f64>,
    lat_buf: Vec<f64>,
}

/// `last_scale_down` sentinel for "never": far enough in the past that any
/// cooldown window has always expired (the historical `unwrap_or(-1e18)`).
const NEVER_SCALED: f64 = -1e18;

impl HybridAutoscaler {
    pub fn new(cfg: HybridConfig) -> Self {
        Self::named("has-gpu", cfg)
    }

    /// A hybrid scaler that self-reports `name` (the platform registry uses
    /// this so ablation variants report their own registry names).
    pub fn named(name: impl Into<String>, cfg: HybridConfig) -> Self {
        HybridAutoscaler {
            cfg,
            name: name.into(),
            ids: HashMap::new(),
            filters: Vec::new(),
            last_scale_down: Vec::new(),
            q_buf: Vec::new(),
            lat_buf: Vec::new(),
        }
    }

    /// Dense id for `name`, interning it (and allocating its filter slot)
    /// on first sight.
    fn fn_id(&mut self, name: &str) -> usize {
        if let Some(&i) = self.ids.get(name) {
            return i as usize;
        }
        let i = self.filters.len();
        self.ids.insert(name.to_string(), i as u32);
        self.filters
            .push(KalmanFilter::new(self.cfg.kalman.0, self.cfg.kalman.1));
        self.last_scale_down.push(NEVER_SCALED);
        i
    }

    /// Evaluate the whole quota lattice `{step, 2·step, …}` for one
    /// (function, sm, class factor) in a single
    /// [`LatencyPredictor::latency_batch`] pass (one lane-parallel sweep
    /// for plan-cached predictors, one table probe per level for the run
    /// cache), filling `self.lat_buf` so the bisections below read prewarmed
    /// values. The decision procedure stays [`min_feasible_quota`] over
    /// exactly these values, so answers are identical to per-point queries
    /// even off the monotone ideal.
    fn fill_latency_lattice(
        &mut self,
        f: &FunctionSpec,
        smf: f64,
        factor: f64,
        predictor: &dyn LatencyPredictor,
    ) {
        let step = self.cfg.quota_step.max(1);
        let n = (QUOTA_FULL / step) as usize;
        self.q_buf.clear();
        self.q_buf
            .extend((1..=n).map(|i| crate::vgpu::quota_to_f64(step * i as u32)));
        predictor.latency_batch(
            PredictQuery::new(&f.graph, f.batch, smf, 1.0).with_factor(factor),
            &self.q_buf,
            &mut self.lat_buf,
        );
    }

    /// Pod capacity C_{P_i} = RaPP(f, b_i, s_i, q_i) (items/s) on the pod's
    /// GPU class (`factor` = the hosting device's throughput factor).
    fn pod_capacity(
        f: &FunctionSpec,
        pod: &Pod,
        factor: f64,
        predictor: &dyn LatencyPredictor,
    ) -> f64 {
        predictor.capacity(
            PredictQuery::new(
                &f.graph,
                pod.batch,
                crate::vgpu::sm_to_f64(pod.sm),
                crate::vgpu::quota_to_f64(pod.quota),
            )
            .with_factor(factor),
        )
    }

    /// Smallest quota (in steps) at which a pod of partition `sm` on a GPU
    /// class with throughput `factor` meets the function SLO — the floor
    /// for vertical scale-down and the starting point for new-pod quota
    /// sizing. Falls back to full quota when the partition cannot meet the
    /// SLO at all. The whole lattice level is evaluated in one batched
    /// predictor pass, then the monotone-quota bisection runs over the
    /// prewarmed values — one row-batched forward per (function, sm, class)
    /// instead of O(log) scattered lookups.
    fn min_slo_quota(
        &mut self,
        f: &FunctionSpec,
        sm: SmMille,
        predictor: &dyn LatencyPredictor,
        margin: f64,
        factor: f64,
    ) -> QuotaMille {
        let smf = crate::vgpu::sm_to_f64(sm);
        self.fill_latency_lattice(f, smf, factor, predictor);
        let step = self.cfg.quota_step.max(1);
        let bound = f.slo * margin;
        let lat = &self.lat_buf;
        min_feasible_quota(step, QUOTA_FULL, |q| lat[(q / step - 1) as usize] <= bound)
            .unwrap_or(QUOTA_FULL)
    }

    /// The most efficient (sm, quota) for a required rate ΔR on an empty
    /// GPU of class throughput `factor` (`RaPPbyThroughput`, line 19): the
    /// cheapest slice (sm×quota) whose capacity covers ΔR and whose latency
    /// meets the function SLO; falls back to the highest-capacity slice if
    /// ΔR is unreachable.
    ///
    /// Capacity is monotone non-decreasing and latency monotone
    /// non-increasing in quota, so per SM class the cheapest feasible quota
    /// is `max(min quota covering ΔR, min SLO-feasible quota)` — one batched
    /// lattice pass + two bisections instead of the seed's full
    /// O(sm × quota) grid sweep.
    fn most_efficient_slice(
        &mut self,
        f: &FunctionSpec,
        delta_r: f64,
        predictor: &dyn LatencyPredictor,
        factor: f64,
    ) -> (SmMille, QuotaMille) {
        let step = self.cfg.quota_step.max(1);
        let mut best: Option<(f64, SmMille, QuotaMille)> = None; // (cost, sm, q)
        let mut fallback: (f64, SmMille, QuotaMille) = (0.0, SM_FULL, QUOTA_FULL);
        let mut sm = SM_STEP * 2; // 10% minimum sensible partition
        while sm <= SM_FULL {
            let smf = crate::vgpu::sm_to_f64(sm);
            // One row-batched pass evaluates this SM class's whole quota
            // lattice; the bisections below read the prewarmed values.
            self.fill_latency_lattice(f, smf, factor, predictor);
            let lat = &self.lat_buf;
            let cap_full = predictor.capacity(
                PredictQuery::new(&f.graph, f.batch, smf, crate::vgpu::quota_to_f64(QUOTA_FULL))
                    .with_factor(factor),
            );
            if cap_full > fallback.0 {
                fallback = (cap_full, sm, QUOTA_FULL);
            }
            let q_cap = min_feasible_quota(step, QUOTA_FULL, |q| {
                predictor.capacity(
                    PredictQuery::new(&f.graph, f.batch, smf, crate::vgpu::quota_to_f64(q))
                        .with_factor(factor),
                ) >= delta_r
            });
            let bound = f.slo * self.cfg.slo_margin;
            let q_slo = min_feasible_quota(step, QUOTA_FULL, |q| {
                lat[(q / step - 1) as usize] <= bound
            });
            // Prefer slices that meet ΔR + SLO while keeping vertical runway
            // (quota ≤ headroom cap) — larger partitions at moderate quota
            // can absorb the next burst by a quota re-write alone.
            if let (Some(qc), Some(qs)) = (q_cap, q_slo) {
                let q = qc.max(qs);
                let qf = crate::vgpu::quota_to_f64(q);
                // Re-verify the SLO at the quota actually selected: a learned
                // predictor's surface need not be perfectly monotone, and q
                // can exceed the bisected SLO point (capacity needs no
                // re-check — it is linear in quota by construction).
                if q <= self.cfg.headroom_quota
                    && predictor
                        .latency(PredictQuery::new(&f.graph, f.batch, smf, qf).with_factor(factor))
                        <= f.slo * self.cfg.slo_margin
                {
                    let cost = smf * qf;
                    if best.map_or(true, |(c, _, _)| cost < c) {
                        best = Some((cost, sm, q));
                    }
                }
            }
            sm += SM_STEP * 2;
        }
        match best {
            Some((_, s, q)) => (s, q),
            None => (fallback.1, fallback.2),
        }
    }
}

impl ScalingPolicy for HybridAutoscaler {
    fn name(&self) -> &str {
        &self.name
    }

    fn plan(
        &mut self,
        f: &FunctionSpec,
        observed_rps: f64,
        cluster: &ClusterState,
        predictor: &dyn LatencyPredictor,
        now: f64,
    ) -> Vec<ScalingAction> {
        // Copy, not clone: the config is plain-old-data and `plan` runs once
        // per function per tick.
        let cfg = self.cfg;
        // Kalman-filtered workload estimate (line 0: predicted RPS R),
        // indexed through the interned id — no String clone on the hot path.
        let id = self.fn_id(&f.name);
        let r = self.filters[id].update(observed_rps);

        let mut actions = Vec::new();
        // Non-draining *device-resident* pods participate in capacity
        // (cold-starting pods will be ready soon; counting them avoids
        // scale-up storms). Host-cached pods hold no device residency: they
        // contribute no capacity and are invisible to vertical scaling, but
        // scale-up prefers promoting one over paying a fresh cold start.
        // With the default infinite keep-alive no pod is ever parked, so
        // both lists — and every decision below — match the pre-lifecycle
        // planner exactly.
        let all_pods = cluster.pods_of(&f.name);
        let mut parked: Vec<&Pod> = all_pods
            .iter()
            .copied()
            .filter(|p| p.phase != PodPhase::Draining && p.state == PodState::HostCached)
            .collect();
        let mut pods: Vec<&Pod> = all_pods
            .into_iter()
            .filter(|p| p.phase != PodPhase::Draining && p.state != PodState::HostCached)
            .collect();
        // Reap parked pods that outlived the keep-alive horizon: the swap
        // tier is a grace window, not a permanent parking lot.
        if cfg.keep_alive.is_finite() {
            for pod in &parked {
                if now - pod.state_since > cfg.keep_alive {
                    actions.push(ScalingAction::RemovePod { pod: pod.id });
                }
            }
            parked.retain(|p| now - p.state_since <= cfg.keep_alive);
        }
        // Axis restrictions (ablation platforms). A function with zero pods
        // cannot scale vertically, so the bootstrap pod is always allowed —
        // vertical-only platforms still come up, then never add replicas.
        let vertical = cfg.scaling_axes.vertical();
        let horizontal = cfg.scaling_axes.horizontal() || pods.is_empty();
        // Line 1: C_f = Σ C_{P_i}, each pod judged on its own GPU class.
        let caps: BTreeMap<_, _> = pods
            .iter()
            .map(|p| {
                let factor = cluster.gpu(p.gpu).throughput();
                (p.id, Self::pod_capacity(f, p, factor, predictor))
            })
            .collect();
        let c_f: f64 = caps.values().sum();

        // Class feasibility for NEW pods of f (heterogeneous fleets): the
        // device must fit the model in memory and meet the SLO at full
        // resources under the class clock (judged at this policy's planning
        // margin). The cluster pickers fall back to the homogeneous rules
        // when no class qualifies, so on a uniform fleet this gate never
        // changes the choice. Feasibility depends only on the class — not
        // the GPU — so it is memoised per class name and the per-GPU scans
        // cost a tiny probe, not a predictor query per device.
        let mem_need = f.graph.memory_bytes(f.batch);
        let slo_bound = f.slo * cfg.slo_margin;
        // Feasibility depends only on the class's memory capacity and
        // throughput factor, so the memo keys on those two values directly
        // (bit patterns — classes are finitely many fixed constants), not on
        // a cloned class-name String per probe.
        let mut feas_cache: Vec<((u64, u64), bool)> = Vec::new();
        let mut class_ok = |c: &GpuClass| {
            let key = (c.mem_cap.to_bits(), c.throughput.to_bits());
            if let Some(&(_, ok)) = feas_cache.iter().find(|(k, _)| *k == key) {
                return ok;
            }
            let ok = mem_need <= c.mem_cap
                && predictor
                    .latency(PredictQuery::new(&f.graph, f.batch, 1.0, 1.0).with_factor(c.throughput))
                    <= slo_bound;
            feas_cache.push((key, ok));
            ok
        };

        // ---- Scaling up (lines 2-19) ----------------------------------
        if r > c_f * cfg.alpha {
            let mut delta_r = r - c_f * cfg.alpha;
            // Line 3: pods with more SMs first.
            pods.sort_by(|a, b| b.sm.cmp(&a.sm).then(a.id.0.cmp(&b.id.0)));
            // Vertical scale-up (lines 4-9).
            let vertical_pods: &[&Pod] = if vertical { &pods } else { &[] };
            for pod in vertical_pods {
                if delta_r <= 0.0 {
                    break;
                }
                let a_q = cluster
                    .gpu(pod.gpu)
                    .max_avail_quota(pod.client_id())
                    .unwrap_or(pod.quota);
                let base_cap = caps[&pod.id];
                let smf = crate::vgpu::sm_to_f64(pod.sm);
                let pod_factor = cluster.gpu(pod.gpu).throughput();
                let mut n = 0u32;
                let mut gained = 0.0;
                while pod.quota + cfg.quota_step * (n + 1) <= a_q && delta_r - gained > 0.0 {
                    n += 1;
                    let q_new = pod.quota + cfg.quota_step * n;
                    let cap_new = predictor.capacity(
                        PredictQuery::new(&f.graph, pod.batch, smf, crate::vgpu::quota_to_f64(q_new))
                            .with_factor(pod_factor),
                    );
                    gained = cap_new - base_cap;
                }
                if n > 0 {
                    actions.push(ScalingAction::SetQuota {
                        pod: pod.id,
                        quota: pod.quota + cfg.quota_step * n,
                    });
                    delta_r -= gained;
                }
            }
            // Promote parked pods before creating anything: resuming a
            // host-cached replica costs one host→device swap instead of a
            // full cold start, so every parked pod of f is cheaper capacity
            // than any CreatePod. Largest SM partitions first, mirroring the
            // vertical preference above.
            if horizontal && !parked.is_empty() {
                parked.sort_by(|a, b| b.sm.cmp(&a.sm).then(a.id.0.cmp(&b.id.0)));
                for pod in &parked {
                    if delta_r <= 0.0 {
                        break;
                    }
                    let factor = cluster.gpu(pod.gpu).throughput();
                    actions.push(ScalingAction::PromotePod { pod: pod.id });
                    delta_r -= Self::pod_capacity(f, pod, factor, predictor);
                }
            }
            // Horizontal scale-up to a used GPU (lines 10-17), extended for
            // heterogeneous fleets: cheapest feasible class first, tie-broken
            // by the lowest HGO — which on a uniform fleet degenerates to
            // exactly Algorithm 1's least-occupied choice.
            if delta_r > 0.0 && horizontal {
                if let Some(gpu) = cluster.cheapest_feasible_used_gpu(&mut class_ok) {
                    // The picker falls back to an infeasible used GPU when no
                    // used class qualifies. If a *feasible idle* device
                    // exists, skip the doomed in-place create (it would eat
                    // ΔR, get rejected by the Re-configurator, and starve the
                    // new-GPU branch forever) and let the idle branch take
                    // it. Single-class fleets can never hit this: an
                    // infeasible chosen class means the idle GPUs share the
                    // same infeasible class, so the homogeneous behaviour is
                    // untouched.
                    let chosen_ok = class_ok(cluster.gpu(gpu).class());
                    let feasible_idle_waiting = !chosen_ok
                        && cluster.idle_gpus().any(|g| class_ok(cluster.gpu(g).class()));
                    let factor = cluster.gpu(gpu).throughput();
                    let slot = if feasible_idle_waiting {
                        None // fall through to the new-GPU branch
                    } else {
                        cluster.gpu(gpu).max_avail_sm_quota()
                    };
                    if let Some((s_max, q_max)) = slot {
                        let smf = crate::vgpu::sm_to_f64(s_max);
                        let c_max = predictor.capacity(
                            PredictQuery::new(&f.graph, f.batch, smf, crate::vgpu::quota_to_f64(q_max))
                                .with_factor(factor),
                        );
                        if c_max > delta_r {
                            // Find the smallest quota step covering ΔR (lines
                            // 15-17), never below the SLO-feasible floor —
                            // a bisection over the monotone capacity axis.
                            let floor =
                                self.min_slo_quota(f, s_max, predictor, cfg.slo_margin, factor);
                            let q_need = min_feasible_quota(cfg.quota_step, q_max, |q| {
                                predictor.capacity(
                                    PredictQuery::new(&f.graph, f.batch, smf, crate::vgpu::quota_to_f64(q))
                                        .with_factor(factor),
                                ) >= delta_r
                            });
                            let quota = match q_need {
                                Some(q) => q.max(floor).min(q_max),
                                // No lattice quota under q_max covers ΔR:
                                // take everything available.
                                None => q_max,
                            };
                            actions.push(ScalingAction::CreatePod {
                                function: f.name.clone(),
                                gpu,
                                sm: s_max,
                                quota,
                                batch: f.batch,
                                new_gpu: false,
                            });
                            delta_r -= predictor.capacity(
                                PredictQuery::new(&f.graph, f.batch, smf, crate::vgpu::quota_to_f64(quota))
                                    .with_factor(factor),
                            );
                        }
                    }
                }
            }
            // Horizontal scale-up to a new GPU (lines 18-19): cheapest
            // feasible idle class, sized by the class-aware efficiency
            // search (uniform fleet: first idle GPU, reference surface).
            if delta_r > 0.0 && horizontal {
                if let Some(gpu) = cluster.cheapest_feasible_idle_gpu(&mut class_ok) {
                    let factor = cluster.gpu(gpu).throughput();
                    let (sm, quota) = self.most_efficient_slice(f, delta_r, predictor, factor);
                    actions.push(ScalingAction::CreatePod {
                        function: f.name.clone(),
                        gpu,
                        sm,
                        quota,
                        batch: f.batch,
                        new_gpu: true,
                    });
                }
                // Cluster exhausted: nothing more we can do this tick.
            }
            return actions;
        }

        // ---- Scaling down (lines 20-26) --------------------------------
        let last_down = self.last_scale_down[id];
        if r < c_f * cfg.beta && now - last_down >= cfg.cooldown && !pods.is_empty() {
            // Keep enough capacity that r stays below the scale-up trigger:
            // target C such that r ≈ C·(α+β)/2 (centred in the hysteresis band).
            let target = r / ((cfg.alpha + cfg.beta) / 2.0).max(1e-6);

            let mut c_remaining = c_f;
            // Line 21: fewer SMs first.
            pods.sort_by(|a, b| a.sm.cmp(&b.sm).then(a.id.0.cmp(&b.id.0)));
            let mut remaining_pods = pods.len();
            for pod in pods.iter() {
                if c_remaining <= target {
                    break;
                }
                let base_cap = caps[&pod.id];
                let smf = crate::vgpu::sm_to_f64(pod.sm);
                let pod_factor = cluster.gpu(pod.gpu).throughput();
                // SLO feasibility floor: never shrink a pod into a config
                // whose service latency would breach the function SLO.
                // The floor stays SLO-feasible even when idle: a keep-alive
                // pod must serve the first reactivation request within the
                // SLO (this is what eliminates FaST-GShare's cold-start
                // violations). When traffic is (near-)zero the margin is
                // relaxed to exactly the SLO — minimal keep-alive resources
                // without risking the first request.
                let margin = if r < NEAR_ZERO_RPS { 1.0 } else { cfg.slo_margin };
                // The quota floor only matters when vertical scaling may
                // shrink quotas; horizontal-only skips the lattice sweep.
                let floor = if vertical {
                    self.min_slo_quota(f, pod.sm, predictor, margin, pod_factor)
                        .max(cfg.min_quota)
                } else {
                    cfg.min_quota
                };
                // Reduce stepwise while capacity stays above target (line 22).
                let mut n = 0u32;
                let mut freed = 0.0;
                while vertical && pod.quota >= floor + cfg.quota_step * (n + 1) {
                    let q_new = pod.quota - cfg.quota_step * (n + 1);
                    let cap_new = predictor.capacity(
                        PredictQuery::new(&f.graph, pod.batch, smf, crate::vgpu::quota_to_f64(q_new))
                            .with_factor(pod_factor),
                    );
                    if c_remaining - (base_cap - cap_new) < target {
                        break;
                    }
                    n += 1;
                    freed = base_cap - cap_new;
                }
                // At least one pod is always retained (keep-alive: avoids the
                // cold start of scaling from zero, line 20's R_min clause).
                let keep_alive = remaining_pods == 1;
                // With vertical scaling a pod must sit at its floor before
                // removal; horizontal-only cannot shrink quotas, so any
                // surplus pod is a removal candidate.
                let at_removal_gate = if vertical { pod.quota <= floor } else { true };
                if horizontal && at_removal_gate && !keep_alive {
                    // Quota would hit zero: horizontal scale-down (lines 23-24)
                    // — but only if capacity after removal still covers r.
                    if c_remaining - base_cap >= r.max(0.0) || base_cap <= 0.0 {
                        // A finite keep-alive horizon parks the surplus pod
                        // in host memory instead of deleting it; the reaper
                        // at the top of plan() deletes it for real once it
                        // idles past the horizon.
                        if cfg.keep_alive.is_finite() {
                            actions.push(ScalingAction::DemotePod { pod: pod.id });
                        } else {
                            actions.push(ScalingAction::RemovePod { pod: pod.id });
                        }
                        c_remaining -= base_cap;
                        remaining_pods -= 1;
                    }
                } else if n > 0 {
                    actions.push(ScalingAction::SetQuota {
                        pod: pod.id,
                        quota: (pod.quota - cfg.quota_step * n).max(floor),
                    });
                    c_remaining -= freed;
                }
            }
            if !actions.is_empty() {
                self.last_scale_down[id] = now;
            }
        }
        actions
    }

    /// HAS-GPU is quiescent for a fully idle function iff its filter state
    /// is exactly zero (or the function was never planned): every skipped
    /// plan would observe `0.0`, keep `x ≡ 0.0`, find no pods to reap or
    /// shrink, and emit no action — a provable no-op whose only effect (the
    /// filter covariance walk) [`Self::note_skipped_idle_ticks`] replays
    /// bit-for-bit. A positive estimate means the next plan could still
    /// bootstrap a pod, so the function must keep its tick.
    fn wants_idle_plan(&self, f: &FunctionSpec, _now: f64) -> bool {
        match self.ids.get(f.name.as_str()) {
            Some(&id) => self.filters[id as usize].estimate() != 0.0,
            None => false,
        }
    }

    /// Sequential zero-rate updates — not a closed form — so the covariance
    /// path is bit-identical to having been called every tick.
    fn note_skipped_idle_ticks(&mut self, f: &FunctionSpec, missed: u64) {
        let id = self.fn_id(&f.name);
        for _ in 0..missed {
            self.filters[id].update(0.0);
        }
    }

    /// HAS-GPU's workflow co-scaling pass.
    ///
    /// Two deviations from the independent-stage fallback, together
    /// enforcing the co-scaling invariant — *a downstream stage's capacity
    /// never starves an upstream stage's achieved throughput*:
    ///
    /// 1. **Topological demand propagation.** Every admitted origin
    ///    eventually executes each reachable stage once, so a stage's true
    ///    demand is at least the achieved throughput of any upstream stage
    ///    feeding it. Demand is propagated forward over the DAG
    ///    (`demand[s] = max(observed[s], max over incoming demand)`) before
    ///    planning, so a downstream stage scales *ahead* of the wave instead
    ///    of reacting one hop-latency late per stage.
    /// 2. **Bottleneck-stage-first ordering.** Stages plan in ascending
    ///    capacity/demand margin. The most starved stage claims free quota
    ///    headroom and devices first — its vertical quota growth happens
    ///    before any other stage's horizontal add can consume the headroom
    ///    (and within each stage Algorithm 1 itself grows quota before
    ///    adding replicas).
    fn plan_workflow(
        &mut self,
        wf: &crate::workflow::Workflow,
        stage_fns: &[&FunctionSpec],
        observed_rps: &[f64],
        cluster: &ClusterState,
        predictor: &dyn LatencyPredictor,
        now: f64,
    ) -> Vec<ScalingAction> {
        let n = stage_fns.len().min(observed_rps.len());
        let mut demand: Vec<f64> = observed_rps[..n].to_vec();
        // Forward edges make ascending stage order topological.
        for s in 0..n {
            for e in wf.edges.iter().filter(|e| e.to == s) {
                if e.from < n && demand[e.from] > demand[s] {
                    demand[s] = demand[e.from];
                }
            }
        }
        // Capacity margin per stage over the same pod population plan()
        // judges (non-draining, device-resident).
        let mut order: Vec<usize> = (0..n).collect();
        let margin: Vec<f64> = (0..n)
            .map(|s| {
                let f = stage_fns[s];
                let cap: f64 = cluster
                    .pods_of(&f.name)
                    .iter()
                    .filter(|p| p.phase != PodPhase::Draining && p.state != PodState::HostCached)
                    .map(|p| {
                        let factor = cluster.gpu(p.gpu).throughput();
                        Self::pod_capacity(f, p, factor, predictor)
                    })
                    .sum();
                cap / demand[s].max(1e-9)
            })
            .collect();
        order.sort_by(|&a, &b| {
            margin[a]
                .partial_cmp(&margin[b])
                .unwrap_or(std::cmp::Ordering::Equal)
                .then(a.cmp(&b))
        });
        let mut out = Vec::new();
        for &s in &order {
            out.extend(self.plan(stage_fns[s], demand[s], cluster, predictor, now));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::reconfigurator::{place_pod, Reconfigurator};
    use crate::cluster::GpuId;
    use crate::model::zoo::{zoo_graph, ZooModel};
    use crate::perf::PerfModel;
    use crate::rapp::OraclePredictor;

    fn setup() -> (ClusterState, Reconfigurator, PerfModel, FunctionSpec) {
        let mut c = ClusterState::new(6, 16e9);
        let spec = FunctionSpec {
            name: "resnet50".into(),
            graph: zoo_graph(ZooModel::ResNet50),
            slo: 0.25,
            batch: 8,
            artifact: None,
        };
        c.register_function(spec.clone());
        let r = Reconfigurator::new(&c, 1);
        (c, r, PerfModel::default(), spec)
    }

    #[test]
    fn kalman_converges_to_constant_signal() {
        let mut kf = KalmanFilter::new(1.0, 16.0);
        let mut est = 0.0;
        for _ in 0..100 {
            est = kf.update(50.0);
        }
        assert!((est - 50.0).abs() < 1.0, "est {est}");
    }

    #[test]
    fn kalman_tracks_ramp_with_lag() {
        let mut kf = KalmanFilter::new(2.0, 8.0);
        let mut last = 0.0;
        for t in 0..200 {
            last = kf.update(t as f64);
        }
        // Tracks a ramp with bounded lag.
        assert!(last > 185.0 && last < 200.0, "est {last}");
    }

    #[test]
    fn kalman_state_never_goes_negative_on_downward_spike() {
        // Regression: update() used to clamp only the *returned* value, so a
        // downward spike left the stored state negative and estimate()
        // reported a negative RPS afterwards.
        let mut kf = KalmanFilter::new(16.0, 4.0); // responsive: gain ≈ 0.8
        for _ in 0..5 {
            kf.update(10.0);
        }
        let spiked = kf.update(-500.0); // pathological measurement
        assert_eq!(spiked, 0.0, "clamped at the spike itself");
        assert!(
            kf.estimate() >= 0.0,
            "stored state must persist the clamp, got {}",
            kf.estimate()
        );
        // Recovery resumes from 0, not from a hidden negative state.
        let next = kf.update(10.0);
        assert!(next > 0.0 && next <= 10.0, "recovery estimate {next}");
    }

    #[test]
    fn kalman_smooths_noise() {
        let mut kf = KalmanFilter::new(0.5, 25.0);
        let mut rng = crate::util::prng::Pcg64::seeded(1);
        let mut errs_raw = 0.0;
        let mut errs_kf = 0.0;
        for _ in 0..500 {
            let obs = 40.0 + rng.normal_ms(0.0, 5.0);
            let est = kf.update(obs);
            errs_raw += (obs - 40.0f64).abs();
            errs_kf += (est - 40.0f64).abs();
        }
        assert!(errs_kf < errs_raw * 0.6, "kf {errs_kf} raw {errs_raw}");
    }

    #[test]
    fn skipped_idle_ticks_replay_identically() {
        // The active-set planner contract: planning a quiescent function
        // every tick with observed 0.0 must leave the policy in bit-identical
        // state to skipping those ticks and replaying them through
        // note_skipped_idle_ticks.
        let (c, _recon, _pm, spec) = setup(); // no pods placed
        let pred = OraclePredictor::default();

        let mut full = HybridAutoscaler::new(HybridConfig::default());
        for t in 1..=7 {
            let a = full.plan(&spec, 0.0, &c, &pred, t as f64);
            assert!(a.is_empty(), "idle plan must be a no-op, got {a:?}");
            assert!(
                !full.wants_idle_plan(&spec, t as f64),
                "zero-estimate function stays quiescent"
            );
        }
        let a = full.plan(&spec, 20.0, &c, &pred, 8.0);

        let mut lazy = HybridAutoscaler::new(HybridConfig::default());
        assert!(
            !lazy.wants_idle_plan(&spec, 0.0),
            "never-planned function is quiescent"
        );
        lazy.note_skipped_idle_ticks(&spec, 7);
        let b = lazy.plan(&spec, 20.0, &c, &pred, 8.0);

        assert_eq!(a, b, "reactivation actions diverge");
        assert!(!a.is_empty(), "traffic resumption must bootstrap a pod");
        let (kf_full, kf_lazy) = (&full.filters[0], &lazy.filters[0]);
        assert_eq!(kf_full.estimate().to_bits(), kf_lazy.estimate().to_bits());
        assert_eq!(kf_full.gain().to_bits(), kf_lazy.gain().to_bits());
        // A positive estimate ends quiescence on both paths.
        assert!(full.wants_idle_plan(&spec, 9.0));
        assert!(lazy.wants_idle_plan(&spec, 9.0));
    }

    #[test]
    fn scale_up_prefers_vertical() {
        let (mut c, mut recon, pm, spec) = setup();
        let pod =
            place_pod(&mut recon, &mut c, &pm, "resnet50", GpuId(0), 500, 300, 8, 0.0).unwrap();
        let pred = OraclePredictor::default();
        let mut hs = HybridAutoscaler::new(HybridConfig::default());
        let cap = pred.capacity(PredictQuery::new(&spec.graph, 8, 0.5, 0.3));
        // Demand slightly above capacity: a quota bump suffices.
        let actions = hs.plan(&spec, cap * 1.3, &c, &pred, 10.0);
        assert!(
            matches!(actions.as_slice(), [ScalingAction::SetQuota { pod: p, quota }] if *p == pod && *quota > 300),
            "{actions:?}"
        );
    }

    #[test]
    fn scale_up_goes_horizontal_when_vertical_exhausted() {
        let (mut c, mut recon, pm, spec) = setup();
        // Pod already at full quota on its slot.
        place_pod(&mut recon, &mut c, &pm, "resnet50", GpuId(0), 500, 1000, 8, 0.0).unwrap();
        let pred = OraclePredictor::default();
        let mut hs = HybridAutoscaler::new(HybridConfig::default());
        let cap = pred.capacity(PredictQuery::new(&spec.graph, 8, 0.5, 1.0));
        let actions = hs.plan(&spec, cap * 1.5, &c, &pred, 10.0);
        assert!(
            actions
                .iter()
                .any(|a| matches!(a, ScalingAction::CreatePod { .. })),
            "{actions:?}"
        );
        // The new pod lands on the used GPU (lowest HGO among used) if it has
        // room, or a new GPU otherwise — GPU-0 has 500‰ SM free, so used GPU.
        if let Some(ScalingAction::CreatePod { gpu, new_gpu, .. }) = actions
            .iter()
            .find(|a| matches!(a, ScalingAction::CreatePod { .. }))
        {
            assert_eq!(*gpu, GpuId(0));
            assert!(!new_gpu);
        }
    }

    #[test]
    fn burst_spills_to_new_gpu() {
        let (mut c, mut recon, pm, spec) = setup();
        // Fill GPU-0 completely.
        place_pod(&mut recon, &mut c, &pm, "resnet50", GpuId(0), 1000, 1000, 8, 0.0).unwrap();
        let pred = OraclePredictor::default();
        let mut hs = HybridAutoscaler::new(HybridConfig::default());
        let cap = pred.capacity(PredictQuery::new(&spec.graph, 8, 1.0, 1.0));
        let actions = hs.plan(&spec, cap * 3.0, &c, &pred, 10.0);
        let create = actions
            .iter()
            .find_map(|a| match a {
                ScalingAction::CreatePod { gpu, new_gpu, .. } => Some((*gpu, *new_gpu)),
                _ => None,
            })
            .expect("must create a pod");
        assert!(create.1, "should be a new GPU: {actions:?}");
        assert_ne!(create.0, GpuId(0));
    }

    #[test]
    fn no_action_inside_hysteresis_band() {
        let (mut c, mut recon, pm, spec) = setup();
        place_pod(&mut recon, &mut c, &pm, "resnet50", GpuId(0), 500, 500, 8, 0.0).unwrap();
        let pred = OraclePredictor::default();
        let mut hs = HybridAutoscaler::new(HybridConfig::default());
        let cap = pred.capacity(PredictQuery::new(&spec.graph, 8, 0.5, 0.5));
        // R = 0.6·C: between β=0.4 and α=0.8 ⇒ no actions.
        let actions = hs.plan(&spec, cap * 0.6, &c, &pred, 10.0);
        assert!(actions.is_empty(), "{actions:?}");
    }

    #[test]
    fn scale_down_reduces_quota_then_respects_cooldown() {
        let (mut c, mut recon, pm, spec) = setup();
        let pod =
            place_pod(&mut recon, &mut c, &pm, "resnet50", GpuId(0), 500, 1000, 8, 0.0).unwrap();
        let pred = OraclePredictor::default();
        let mut hs = HybridAutoscaler::new(HybridConfig::default());
        let cap = pred.capacity(PredictQuery::new(&spec.graph, 8, 0.5, 1.0));
        // Feed the filter a steady low rate so the estimate is low.
        for t in 0..20 {
            let _ = hs.plan(&spec, cap * 0.05, &c, &pred, t as f64);
        }
        let actions = hs.plan(&spec, cap * 0.05, &c, &pred, 100.0);
        let down = actions.iter().find_map(|a| match a {
            ScalingAction::SetQuota { pod: p, quota } if *p == pod => Some(*quota),
            _ => None,
        });
        assert!(down.is_some() && down.unwrap() < 1000, "{actions:?}");
        // Immediately after, cooldown blocks another scale-down.
        let again = hs.plan(&spec, cap * 0.05, &c, &pred, 101.0);
        assert!(again.is_empty(), "{again:?}");
    }

    #[test]
    fn last_pod_is_kept_alive() {
        let (mut c, mut recon, pm, spec) = setup();
        let pod =
            place_pod(&mut recon, &mut c, &pm, "resnet50", GpuId(0), 250, 200, 8, 0.0).unwrap();
        let pred = OraclePredictor::default();
        let mut hs = HybridAutoscaler::new(HybridConfig::default());
        for t in 0..50 {
            let actions = hs.plan(&spec, 0.0, &c, &pred, t as f64 * 40.0);
            // The single pod must never be removed (keep-alive, avoids cold
            // start from zero).
            assert!(
                !actions
                    .iter()
                    .any(|a| matches!(a, ScalingAction::RemovePod { pod: p } if *p == pod)),
                "{actions:?}"
            );
        }
    }

    #[test]
    fn idle_keep_alive_floor_relaxes_margin_to_exact_slo() {
        // At (near-)zero predicted traffic the scale-down floor uses margin
        // 1.0 (exactly the SLO) instead of cfg.slo_margin — the keep-alive
        // pod pins the minimal SLO-feasible quota.
        let (mut c, mut recon, pm, mut spec) = setup();
        let pod =
            place_pod(&mut recon, &mut c, &pm, "resnet50", GpuId(0), 500, 1000, 8, 0.0).unwrap();
        let pred = OraclePredictor::default();
        // Pick an SLO between the q=0.3 and q=0.4 latencies so the margin-1.0
        // floor and the default-margin floor land on different lattice steps.
        spec.slo = pred.latency(PredictQuery::new(&spec.graph, 8, 0.5, 0.35));
        let mut hs = HybridAutoscaler::new(HybridConfig::default());
        let relaxed_floor = hs.min_slo_quota(&spec, 500, &pred, 1.0, 1.0).max(hs.cfg.min_quota);
        let strict_floor = hs
            .min_slo_quota(&spec, 500, &pred, hs.cfg.slo_margin, 1.0)
            .max(hs.cfg.min_quota);
        assert!(
            relaxed_floor < strict_floor,
            "setup must distinguish margins: relaxed {relaxed_floor} strict {strict_floor}"
        );
        // Converge the filter to zero, wait out the cooldown, then scale down.
        let mut quota = 1000;
        for t in 0..60 {
            for a in hs.plan(&spec, 0.0, &c, &pred, t as f64 * 40.0) {
                if let ScalingAction::SetQuota { pod: p, quota: q } = a {
                    assert_eq!(p, pod);
                    recon
                        .apply(&mut c, &pm, &ScalingAction::SetQuota { pod: p, quota: q }, 0.0)
                        .unwrap();
                    quota = q;
                }
            }
        }
        assert_eq!(
            quota, relaxed_floor,
            "keep-alive quota must settle at the margin-1.0 floor"
        );
    }

    #[test]
    fn cached_plan_invokes_predictor_5x_less() {
        // ISSUE acceptance: the quantized capacity cache must cut underlying
        // predictor invocations on the plan tick by ≥5x. Identical demand
        // each tick ⇒ the uncached path re-runs its sweeps every tick while
        // the cached path serves them from the lattice table.
        use crate::rapp::{CachedPredictor, CountingPredictor};
        let (mut c, mut recon, pm, spec) = setup();
        // Full-quota pod: vertical scale-up is exhausted, so each tick walks
        // the horizontal paths (min_slo_quota + most_efficient_slice).
        place_pod(&mut recon, &mut c, &pm, "resnet50", GpuId(0), 500, 1000, 8, 0.0).unwrap();
        let demand =
            OraclePredictor::default().capacity(PredictQuery::new(&spec.graph, 8, 0.5, 1.0)) * 40.0;
        let ticks = 20;

        let raw = CountingPredictor::new(OraclePredictor::default());
        let mut s1 = HybridAutoscaler::new(HybridConfig::default());
        for t in 0..ticks {
            let _ = s1.plan(&spec, demand, &c, &raw, t as f64);
        }
        let uncached = raw.invocations();

        let counted = CountingPredictor::new(OraclePredictor::default());
        let cache = CachedPredictor::new(&counted);
        let mut s2 = HybridAutoscaler::new(HybridConfig::default());
        for t in 0..ticks {
            let _ = s2.plan(&spec, demand, &c, &cache, t as f64);
        }
        let cached = counted.invocations();
        assert!(cached > 0, "the cache must still consult the predictor once");
        assert!(
            uncached >= 5 * cached,
            "cache saves too little: uncached {uncached} vs cached {cached}"
        );
    }

    #[test]
    fn lattice_prewarmed_floor_matches_pointwise_bisection() {
        // min_slo_quota now evaluates the lattice in one batched pass and
        // bisects the prewarmed values; the answer must equal the seed's
        // per-point bisection for any margin and SM class.
        let (_c, _r, _pm, spec) = setup();
        let pred = OraclePredictor::default();
        let mut hs = HybridAutoscaler::new(HybridConfig::default());
        for &sm in &[200u32, 500, 1000] {
            let smf = crate::vgpu::sm_to_f64(sm);
            for &margin in &[0.75, 1.0] {
                let want = min_feasible_quota(hs.cfg.quota_step, QUOTA_FULL, |q| {
                    pred.latency(PredictQuery::new(
                        &spec.graph,
                        spec.batch,
                        smf,
                        crate::vgpu::quota_to_f64(q),
                    )) <= spec.slo * margin
                })
                .unwrap_or(QUOTA_FULL);
                assert_eq!(hs.min_slo_quota(&spec, sm, &pred, margin, 1.0), want, "sm={sm}");
            }
        }
    }

    #[test]
    fn vertical_only_bootstraps_then_never_goes_horizontal() {
        let (mut c, mut recon, pm, spec) = setup();
        let pred = OraclePredictor::default();
        let cfg = HybridConfig {
            scaling_axes: ScalingAxes::VerticalOnly,
            ..HybridConfig::default()
        };
        let mut hs = HybridAutoscaler::named("has-vertical-only", cfg);
        assert_eq!(hs.name(), "has-vertical-only");
        // Zero pods: the bootstrap pod is the one permitted horizontal act.
        let boot = hs.plan(&spec, 20.0, &c, &pred, 0.0);
        assert!(
            boot.iter().any(|a| matches!(a, ScalingAction::CreatePod { .. })),
            "bootstrap must create the first pod: {boot:?}"
        );
        // With a pod at full quota (vertical runway exhausted), even huge
        // demand must not add replicas.
        place_pod(&mut recon, &mut c, &pm, "resnet50", GpuId(0), 500, 1000, 8, 0.0).unwrap();
        let cap = pred.capacity(PredictQuery::new(&spec.graph, 8, 0.5, 1.0));
        for t in 1..20 {
            let actions = hs.plan(&spec, cap * 10.0, &c, &pred, t as f64);
            assert!(
                !actions.iter().any(|a| matches!(a, ScalingAction::CreatePod { .. })),
                "{actions:?}"
            );
            assert!(
                !actions.iter().any(|a| matches!(a, ScalingAction::RemovePod { .. })),
                "{actions:?}"
            );
        }
    }

    #[test]
    fn vertical_only_still_scales_quota_up() {
        let (mut c, mut recon, pm, spec) = setup();
        let pod =
            place_pod(&mut recon, &mut c, &pm, "resnet50", GpuId(0), 500, 300, 8, 0.0).unwrap();
        let pred = OraclePredictor::default();
        let cfg = HybridConfig {
            scaling_axes: ScalingAxes::VerticalOnly,
            ..HybridConfig::default()
        };
        let mut hs = HybridAutoscaler::new(cfg);
        let cap = pred.capacity(PredictQuery::new(&spec.graph, 8, 0.5, 0.3));
        let actions = hs.plan(&spec, cap * 1.3, &c, &pred, 10.0);
        assert!(
            matches!(actions.as_slice(), [ScalingAction::SetQuota { pod: p, quota }] if *p == pod && *quota > 300),
            "{actions:?}"
        );
    }

    #[test]
    fn horizontal_only_never_rewrites_quota() {
        let (mut c, mut recon, pm, spec) = setup();
        // Pod with vertical headroom a hybrid scaler would use first.
        place_pod(&mut recon, &mut c, &pm, "resnet50", GpuId(0), 500, 300, 8, 0.0).unwrap();
        let pred = OraclePredictor::default();
        let cfg = HybridConfig {
            scaling_axes: ScalingAxes::HorizontalOnly,
            ..HybridConfig::default()
        };
        let mut hs = HybridAutoscaler::named("has-horizontal-only", cfg);
        let cap = pred.capacity(PredictQuery::new(&spec.graph, 8, 0.5, 0.3));
        let actions = hs.plan(&spec, cap * 1.5, &c, &pred, 10.0);
        assert!(
            !actions.iter().any(|a| matches!(a, ScalingAction::SetQuota { .. })),
            "{actions:?}"
        );
        assert!(
            actions.iter().any(|a| matches!(a, ScalingAction::CreatePod { .. })),
            "must scale out instead: {actions:?}"
        );
    }

    #[test]
    fn horizontal_only_scale_down_removes_surplus_pods_without_quota_writes() {
        let (mut c, mut recon, pm, spec) = setup();
        let p1 =
            place_pod(&mut recon, &mut c, &pm, "resnet50", GpuId(0), 250, 400, 8, 0.0).unwrap();
        let p2 =
            place_pod(&mut recon, &mut c, &pm, "resnet50", GpuId(0), 250, 400, 8, 0.0).unwrap();
        let pred = OraclePredictor::default();
        let cfg = HybridConfig {
            scaling_axes: ScalingAxes::HorizontalOnly,
            ..HybridConfig::default()
        };
        let mut hs = HybridAutoscaler::new(cfg);
        // Converge the filter to idle, then let the cooldown expire.
        let mut removed = Vec::new();
        for t in 0..60 {
            for a in hs.plan(&spec, 0.0, &c, &pred, t as f64 * 40.0) {
                match a {
                    ScalingAction::RemovePod { pod } => {
                        recon
                            .apply(&mut c, &pm, &ScalingAction::RemovePod { pod }, 0.0)
                            .unwrap();
                        removed.push(pod);
                    }
                    ScalingAction::SetQuota { .. } => {
                        panic!("horizontal-only must not rewrite quotas")
                    }
                    other => panic!("unexpected {other:?}"),
                }
            }
        }
        // Exactly one of the two pods goes; keep-alive retains the other.
        assert_eq!(removed.len(), 1, "{removed:?}");
        assert!(removed[0] == p1 || removed[0] == p2);
    }

    #[test]
    fn both_axes_config_is_the_default_and_permits_everything() {
        let cfg = HybridConfig::default();
        assert_eq!(cfg.scaling_axes, ScalingAxes::Both);
        assert!(ScalingAxes::Both.vertical() && ScalingAxes::Both.horizontal());
        assert!(ScalingAxes::VerticalOnly.vertical() && !ScalingAxes::VerticalOnly.horizontal());
        assert!(!ScalingAxes::HorizontalOnly.vertical());
        assert!(ScalingAxes::HorizontalOnly.horizontal());
    }

    #[test]
    fn min_slo_quota_floor_rises_on_slower_classes() {
        // A slower class clock needs more quota to make the same SLO; a
        // faster one needs less (or equal, on the lattice).
        let (_c, _r, _pm, spec) = setup();
        let pred = OraclePredictor::default();
        let mut hs = HybridAutoscaler::new(HybridConfig::default());
        let f_t4 = hs.min_slo_quota(&spec, 500, &pred, 1.0, 0.4);
        let f_ref = hs.min_slo_quota(&spec, 500, &pred, 1.0, 1.0);
        let f_a100 = hs.min_slo_quota(&spec, 500, &pred, 1.0, 2.0);
        assert!(f_t4 >= f_ref && f_ref >= f_a100, "{f_t4} {f_ref} {f_a100}");
        assert!(f_t4 > f_a100, "the class clock must move the floor");
    }

    #[test]
    fn new_gpu_placement_prefers_cheapest_feasible_class() {
        use crate::cluster::ClusterState;
        use crate::vgpu::GpuClass;
        let mut c = ClusterState::from_classes(&[GpuClass::a100(), GpuClass::t4()]);
        let mut spec = setup().3;
        c.register_function(spec.clone());
        let pred = OraclePredictor::default();
        let mut hs = HybridAutoscaler::new(HybridConfig::default());
        // Loose SLO: every class is feasible — the T4 wins on price.
        spec.slo = 10.0;
        let actions = hs.plan(&spec, 20.0, &c, &pred, 0.0);
        match actions.as_slice() {
            [ScalingAction::CreatePod { gpu, new_gpu, .. }] => {
                assert_eq!(*gpu, GpuId(1), "cheapest feasible class is the t4");
                assert!(new_gpu);
            }
            other => panic!("{other:?}"),
        }
        // SLO between the two class clocks: the T4 cannot meet it even at
        // full resources, so placement pays up for the A100.
        let lat_a100 =
            pred.latency(PredictQuery::new(&spec.graph, spec.batch, 1.0, 1.0).with_factor(2.0));
        let lat_t4 =
            pred.latency(PredictQuery::new(&spec.graph, spec.batch, 1.0, 1.0).with_factor(0.4));
        assert!(lat_t4 > lat_a100);
        spec.slo = (lat_a100 + lat_t4) / 2.0 / hs.cfg.slo_margin;
        let mut hs2 = HybridAutoscaler::new(HybridConfig::default());
        let actions = hs2.plan(&spec, 20.0, &c, &pred, 0.0);
        match actions.as_slice() {
            [ScalingAction::CreatePod { gpu, .. }] => {
                assert_eq!(*gpu, GpuId(0), "slo-infeasible t4 must be skipped");
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn infeasible_used_class_defers_to_feasible_idle_gpu() {
        // Regression: when every used GPU's class is SLO-infeasible but a
        // feasible idle device exists, the used-GPU fallback must not eat
        // ΔR with a doomed in-place create — the new pod belongs on the
        // feasible idle GPU.
        use crate::cluster::ClusterState;
        use crate::vgpu::GpuClass;
        let mut slug = GpuClass::t4();
        slug.name = "slug".into();
        slug.throughput = 0.01; // cannot meet any sane SLO even at full GPU
        let mut c = ClusterState::from_classes(&[slug, GpuClass::v100()]);
        let spec = setup().3; // slo 0.25
        c.register_function(spec.clone());
        let mut recon = Reconfigurator::new(&c, 1);
        let pm = PerfModel::default();
        // The only running pod sits on the infeasible class at full quota
        // (vertical runway exhausted), so scale-up must go horizontal.
        place_pod(&mut recon, &mut c, &pm, "resnet50", GpuId(0), 500, 1000, 8, 0.0).unwrap();
        let pred = OraclePredictor::default();
        let mut hs = HybridAutoscaler::new(HybridConfig::default());
        let actions = hs.plan(&spec, 500.0, &c, &pred, 10.0);
        let (gpu, new_gpu) = actions
            .iter()
            .find_map(|a| match a {
                ScalingAction::CreatePod { gpu, new_gpu, .. } => Some((*gpu, *new_gpu)),
                _ => None,
            })
            .expect("must scale out somewhere");
        assert_eq!(gpu, GpuId(1), "the feasible idle v100 must win: {actions:?}");
        assert!(new_gpu);
    }

    #[test]
    fn class_memory_gate_skips_devices_too_small_for_the_model() {
        use crate::cluster::ClusterState;
        use crate::vgpu::GpuClass;
        // A "tiny" class that cannot even hold the model: memory feasibility
        // must route placement to the bigger class despite the lower price.
        let mut tiny = GpuClass::t4();
        tiny.name = "tiny".into();
        tiny.mem_cap = 1e6; // 1 MB
        let mut c = ClusterState::from_classes(&[GpuClass::v100(), tiny]);
        let mut spec = setup().3;
        spec.slo = 10.0; // loose: only memory separates the classes
        c.register_function(spec.clone());
        let pred = OraclePredictor::default();
        let mut hs = HybridAutoscaler::new(HybridConfig::default());
        let actions = hs.plan(&spec, 20.0, &c, &pred, 0.0);
        match actions.as_slice() {
            [ScalingAction::CreatePod { gpu, .. }] => assert_eq!(*gpu, GpuId(0)),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn uniform_fleet_plans_are_identical_through_the_class_aware_path() {
        // The byte-identity keystone at the decision level: a cluster built
        // from an explicit uniform-v100 fleet must produce exactly the same
        // actions as the homogeneous constructor, tick for tick.
        use crate::cluster::ClusterState;
        use crate::vgpu::GpuClass;
        let (mut c_old, mut r_old, pm, spec) = setup();
        let mut c_new = ClusterState::from_classes(&vec![GpuClass::v100(); 6]);
        c_new.register_function(spec.clone());
        let mut r_new = Reconfigurator::new(&c_new, 1);
        let pred = OraclePredictor::default();
        let mut hs_old = HybridAutoscaler::new(HybridConfig::default());
        let mut hs_new = HybridAutoscaler::new(HybridConfig::default());
        for t in 0..60 {
            // A demand sweep that exercises bootstrap, vertical, horizontal
            // up and the scale-down path.
            let demand = match t % 12 {
                0..=3 => 40.0 * (t as f64 + 1.0),
                4..=7 => 900.0,
                _ => 0.0,
            };
            let a_old = hs_old.plan(&spec, demand, &c_old, &pred, t as f64);
            let a_new = hs_new.plan(&spec, demand, &c_new, &pred, t as f64);
            assert_eq!(a_old, a_new, "tick {t}");
            for a in &a_old {
                let _ = r_old.apply(&mut c_old, &pm, a, t as f64);
            }
            for a in &a_new {
                let _ = r_new.apply(&mut c_new, &pm, a, t as f64);
            }
        }
    }

    #[test]
    fn most_efficient_slice_meets_demand_cheaply() {
        let (_c, _r, _pm, spec) = setup();
        let pred = OraclePredictor::default();
        let mut hs = HybridAutoscaler::new(HybridConfig::default());
        let small = hs.most_efficient_slice(&spec, 5.0, &pred, 1.0);
        let big = hs.most_efficient_slice(&spec, 300.0, &pred, 1.0);
        let cost = |s: (SmMille, QuotaMille)| (s.0 as u64) * (s.1 as u64);
        assert!(cost(small) < cost(big), "small {small:?} big {big:?}");
        // The small slice really covers 5 rps.
        let cap = pred.capacity(PredictQuery::new(
            &spec.graph,
            spec.batch,
            crate::vgpu::sm_to_f64(small.0),
            crate::vgpu::quota_to_f64(small.1),
        ));
        assert!(cap >= 5.0);
    }

    #[test]
    fn default_keep_alive_is_infinite_and_plans_match_pre_lifecycle() {
        // Identity keystone at the planner level: the default config must
        // never emit Demote/Promote and must remove surplus pods outright,
        // exactly as before the lifecycle landed.
        let cfg = HybridConfig::default();
        assert!(cfg.keep_alive.is_infinite());
        let (mut c, mut recon, pm, spec) = setup();
        place_pod(&mut recon, &mut c, &pm, "resnet50", GpuId(0), 250, 400, 8, 0.0).unwrap();
        place_pod(&mut recon, &mut c, &pm, "resnet50", GpuId(0), 250, 400, 8, 0.0).unwrap();
        let pred = OraclePredictor::default();
        let mut hs = HybridAutoscaler::new(cfg);
        let mut removed = 0;
        for t in 0..60 {
            for a in hs.plan(&spec, 0.0, &c, &pred, t as f64 * 40.0) {
                assert!(
                    !matches!(
                        a,
                        ScalingAction::DemotePod { .. } | ScalingAction::PromotePod { .. }
                    ),
                    "default config must never touch the swap tier: {a:?}"
                );
                if matches!(a, ScalingAction::RemovePod { .. }) {
                    removed += 1;
                }
                let _ = recon.apply(&mut c, &pm, &a, t as f64 * 40.0);
            }
        }
        assert_eq!(removed, 1, "surplus pod is deleted, not parked");
    }

    #[test]
    fn finite_keep_alive_demotes_surplus_then_reaps_parked_pods() {
        let (mut c, mut recon, pm, spec) = setup();
        let p1 =
            place_pod(&mut recon, &mut c, &pm, "resnet50", GpuId(0), 250, 400, 8, 0.0).unwrap();
        let p2 =
            place_pod(&mut recon, &mut c, &pm, "resnet50", GpuId(0), 250, 400, 8, 0.0).unwrap();
        let pred = OraclePredictor::default();
        let cfg = HybridConfig {
            scaling_axes: ScalingAxes::HorizontalOnly,
            keep_alive: 100.0,
            ..HybridConfig::default()
        };
        let mut hs = HybridAutoscaler::new(cfg);
        // Idle traffic at t=0: the surplus pod is demoted, not removed.
        let first = hs.plan(&spec, 0.0, &c, &pred, 0.0);
        let parked = match first.as_slice() {
            [ScalingAction::DemotePod { pod }] => *pod,
            other => panic!("expected a single demotion, got {other:?}"),
        };
        assert!(parked == p1 || parked == p2);
        recon
            .apply(&mut c, &pm, &ScalingAction::DemotePod { pod: parked }, 0.0)
            .unwrap();
        // Inside the horizon the parked pod survives and the resident pod is
        // retained by keep-alive.
        let mid = hs.plan(&spec, 0.0, &c, &pred, 60.0);
        assert!(mid.is_empty(), "{mid:?}");
        // Past the horizon the reaper deletes the parked pod for real.
        let late = hs.plan(&spec, 0.0, &c, &pred, 150.0);
        assert!(
            late.iter()
                .any(|a| matches!(a, ScalingAction::RemovePod { pod } if *pod == parked)),
            "{late:?}"
        );
    }

    #[test]
    fn scale_up_promotes_parked_pod_before_creating() {
        let (mut c, mut recon, pm, spec) = setup();
        place_pod(&mut recon, &mut c, &pm, "resnet50", GpuId(0), 500, 600, 8, 0.0).unwrap();
        let p2 =
            place_pod(&mut recon, &mut c, &pm, "resnet50", GpuId(0), 500, 400, 8, 0.0).unwrap();
        recon
            .apply(&mut c, &pm, &ScalingAction::DemotePod { pod: p2 }, 0.0)
            .unwrap();
        let pred = OraclePredictor::default();
        let mut hs = HybridAutoscaler::new(HybridConfig {
            keep_alive: 300.0,
            ..HybridConfig::default()
        });
        // Demand just above the resident pod's capacity: vertical runway on
        // GPU-0 is exhausted (quota 600+400 committed), so the gap must be
        // covered horizontally — and the parked replica is the cheapest way.
        let cap1 = pred.capacity(PredictQuery::new(&spec.graph, 8, 0.5, 0.6));
        let actions = hs.plan(&spec, cap1, &c, &pred, 10.0);
        assert!(
            actions
                .iter()
                .any(|a| matches!(a, ScalingAction::PromotePod { pod } if *pod == p2)),
            "{actions:?}"
        );
        assert!(
            !actions
                .iter()
                .any(|a| matches!(a, ScalingAction::CreatePod { .. })),
            "the parked pod covers the gap — no cold start needed: {actions:?}"
        );
    }

    #[test]
    fn parked_pods_are_invisible_to_capacity_and_vertical_scaling() {
        let (mut c, mut recon, pm, spec) = setup();
        let p1 =
            place_pod(&mut recon, &mut c, &pm, "resnet50", GpuId(0), 500, 400, 8, 0.0).unwrap();
        recon
            .apply(&mut c, &pm, &ScalingAction::DemotePod { pod: p1 }, 0.0)
            .unwrap();
        let pred = OraclePredictor::default();
        let mut hs = HybridAutoscaler::new(HybridConfig {
            keep_alive: 300.0,
            ..HybridConfig::default()
        });
        // The only pod is parked ⇒ C_f = 0 and any demand triggers scale-up;
        // the parked pod must come back via PromotePod, never SetQuota.
        let actions = hs.plan(&spec, 5.0, &c, &pred, 10.0);
        assert!(
            actions
                .iter()
                .any(|a| matches!(a, ScalingAction::PromotePod { pod } if *pod == p1)),
            "{actions:?}"
        );
        assert!(
            !actions
                .iter()
                .any(|a| matches!(a, ScalingAction::SetQuota { .. })),
            "host-cached pods must not receive quota writes: {actions:?}"
        );
    }

    fn workflow_setup() -> (ClusterState, Reconfigurator, PerfModel, crate::workflow::Workflow) {
        let wf = crate::workflow::WorkflowRegistry::default()
            .get("pipeline-vision")
            .unwrap()
            .clone();
        let pm = PerfModel::default();
        let mut c = ClusterState::new(6, 16e9);
        for f in wf.stage_functions(&pm) {
            c.register_function(f);
        }
        let r = Reconfigurator::new(&c, 1);
        (c, r, pm, wf)
    }

    #[test]
    fn default_plan_workflow_is_the_independent_stage_fallback() {
        // Baselines inherit the trait default: per-stage planning on the raw
        // observed rates, identical to planning each stage as an unrelated
        // function — no demand propagation, no reordering.
        let (c, _r, pm, wf) = workflow_setup();
        let fns = wf.stage_functions(&pm);
        let refs: Vec<&FunctionSpec> = fns.iter().collect();
        let pred = OraclePredictor::default();
        let mut base = crate::baselines::KServePolicy::default();
        let got = base.plan_workflow(&wf, &refs, &[30.0, 0.0], &c, &pred, 0.0);
        let mut base2 = crate::baselines::KServePolicy::default();
        let mut want = base2.plan(&fns[0], 30.0, &c, &pred, 0.0);
        want.extend(base2.plan(&fns[1], 0.0, &c, &pred, 0.0));
        assert_eq!(got, want);
    }

    #[test]
    fn co_scaling_propagates_upstream_demand_downstream() {
        // The classifier stage observed zero arrivals (the wave has not
        // reached it yet), but the detector is pulling 40 rps — the hybrid
        // pass must scale the classifier for the propagated demand anyway.
        let (c, _r, pm, wf) = workflow_setup();
        let fns = wf.stage_functions(&pm);
        let refs: Vec<&FunctionSpec> = fns.iter().collect();
        let pred = OraclePredictor::default();
        let mut hs = HybridAutoscaler::new(HybridConfig::default());
        let actions = hs.plan_workflow(&wf, &refs, &[40.0, 0.0], &c, &pred, 0.0);
        for f in &fns {
            let made = actions.iter().any(|a| {
                matches!(a, ScalingAction::CreatePod { function, .. } if function == &f.name)
            });
            assert!(
                made,
                "stage '{}' must bootstrap under propagated demand: {actions:?}",
                f.name
            );
        }
        // The independent fallback would have left the zero-observed
        // classifier unscaled.
        let mut hs2 = HybridAutoscaler::new(HybridConfig::default());
        let solo = hs2.plan(&fns[1], 0.0, &c, &pred, 0.0);
        assert!(solo.is_empty(), "{solo:?}");
    }

    #[test]
    fn co_scaling_plans_the_bottleneck_stage_first() {
        // Detector has a running pod; classifier has none (capacity 0 ⇒ the
        // workflow bottleneck). The classifier's actions must come first so
        // its vertical/bootstrap growth claims headroom before any other
        // stage's horizontal add.
        let (mut c, mut recon, pm, wf) = workflow_setup();
        let fns = wf.stage_functions(&pm);
        let detector = fns[0].name.clone();
        place_pod(&mut recon, &mut c, &pm, &detector, GpuId(0), 500, 1000, 8, 0.0).unwrap();
        let refs: Vec<&FunctionSpec> = fns.iter().collect();
        let pred = OraclePredictor::default();
        let mut hs = HybridAutoscaler::new(HybridConfig::default());
        let cap = pred.capacity(PredictQuery::new(&fns[0].graph, 8, 0.5, 1.0));
        let actions = hs.plan_workflow(&wf, &refs, &[cap * 2.0, 0.0], &c, &pred, 0.0);
        let first_create = actions
            .iter()
            .find_map(|a| match a {
                ScalingAction::CreatePod { function, .. } => Some(function.clone()),
                _ => None,
            })
            .expect("both stages need pods");
        assert_eq!(
            first_create, fns[1].name,
            "the zero-capacity classifier is the bottleneck and plans first: {actions:?}"
        );
    }
}
