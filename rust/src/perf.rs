//! The calibrated ground-truth performance model.
//!
//! On the authors' testbed the "performance model" is silicon: a V100 whose
//! latency surface over (model, batch, SM partition, time quota) is sampled by
//! profiling. Our substitute is an explicit **roofline + token-window
//! dilation** model with the same qualitative structure (validated in Fig. 4's
//! bench against real token-scheduler runs):
//!
//! * per-op kernel time `t = max(flops / (peak · sm_eff · η), bytes / (bw · sm))
//!   + t_launch` — compute roofline vs. memory roofline vs. fixed launch cost;
//! * **occupancy cap**: small batches cannot fill a large SM partition
//!   (`sm_eff = min(sm, occupancy(work))`) — reproducing "for smaller batch
//!   sizes, allocating additional SMs does not improve performance";
//! * **quota dilation at kernel granularity**: a pod holding quota `q`
//!   receives a fresh `q·W` token budget at each window boundary (no debt
//!   carry-over — cgroups-CFS-style). A kernel may *launch* whenever the
//!   budget is positive and is never preempted. Many small kernels therefore
//!   dilate to ≈ `T/q`, while long kernels (large batch on a starved SM
//!   partition) overrun whole windows "for free" and latency pins to ≈ `T`
//!   regardless of quota — exactly Fig. 4's observation that raising the
//!   quota stops helping when SMs are insufficient.
//!
//! The exact formulas below are a **cross-language contract** mirrored by
//! `python/compile/perfsim.py`; `artifacts/golden/perf_golden.json` pins both
//! implementations to the same numbers (tested on each side).

use crate::model::{OpGraph, OpKind};

/// V100-16GB-like device constants (paper testbed).
#[derive(Clone, Copy, Debug)]
pub struct DeviceSpec {
    /// Peak f32 throughput in FLOP/s at full GPU.
    pub peak_flops: f64,
    /// HBM bandwidth in B/s at full GPU.
    pub mem_bw: f64,
    /// Device memory capacity in bytes.
    pub mem_cap: f64,
    /// Fixed kernel launch + driver overhead per op (s); not SM-scaled.
    pub t_launch: f64,
    /// Token-window length in seconds (cgroups-period analogue, Fig. 2).
    pub window: f64,
    /// Hourly price in $ for the whole GPU (Google Cloud V100, §4.3).
    pub price_per_hour: f64,
    /// Cold-load bandwidth (storage/network → host memory, B/s): the
    /// `Cold → HostCached` staging step. `INFINITY` (the default) makes the
    /// step take **exactly** 0 s (`x / INFINITY == 0.0` in IEEE 754), which
    /// is the byte-identity contract for the pre-lifecycle export.
    pub host_load_bw: f64,
    /// Host↔device swap bandwidth (B/s): the `HostCached ↔ DeviceResident`
    /// transfer. `INFINITY` (default) ⇒ exactly 0 s, same contract.
    pub h2d_bw: f64,
}

impl Default for DeviceSpec {
    fn default() -> Self {
        DeviceSpec {
            peak_flops: 14.0e12,
            mem_bw: 900.0e9,
            mem_cap: 16.0e9,
            t_launch: 6.0e-6,
            window: 0.005,
            price_per_hour: 2.48,
            host_load_bw: f64::INFINITY,
            h2d_bw: f64::INFINITY,
        }
    }
}

/// Per-op-kind peak-FLOP efficiency η (MXU/SM utilisation of a well-tuned
/// kernel; dense linear algebra runs far closer to peak than elementwise).
/// Contract constant — mirrored in perfsim.py.
pub fn kind_efficiency(kind: OpKind) -> f64 {
    match kind {
        OpKind::Conv2d => 0.62,
        OpKind::Dense | OpKind::MatMul => 0.70,
        OpKind::Attention => 0.55,
        OpKind::BatchNorm | OpKind::LayerNorm => 0.18,
        OpKind::Relu | OpKind::Add => 0.15,
        OpKind::Gelu | OpKind::Softmax => 0.20,
        OpKind::Pool => 0.25,
        OpKind::Embed => 0.10,
    }
}

/// FLOP count at which one op saturates the full GPU (occupancy model):
/// below this, extra SMs go idle. Contract constant.
pub const SATURATION_FLOPS: f64 = 0.5e9;
/// Minimum useful SM fraction for any op (even tiny kernels occupy one SM).
pub const MIN_OCCUPANCY: f64 = 0.05;

/// The ground-truth latency surface.
#[derive(Clone, Debug, Default)]
pub struct PerfModel {
    pub dev: DeviceSpec,
}

impl PerfModel {
    pub fn new(dev: DeviceSpec) -> Self {
        PerfModel { dev }
    }

    /// The finite-bandwidth device profile the `cold-start-storm` cells run
    /// under: ~1 GB/s host model load (fetch + init at cold start) and
    /// ~200 MB/s effective host→device swap bandwidth (pinned-memory DMA
    /// shared across tenants — the Torpor/FaaSwap operating point). Every
    /// other device parameter stays at the reference default, so only the
    /// lifecycle latencies differ from [`PerfModel::default`].
    pub fn with_swap_tier() -> Self {
        PerfModel::new(DeviceSpec {
            host_load_bw: 1e9,
            h2d_bw: 2e8,
            ..DeviceSpec::default()
        })
    }

    /// Total execution time of one (stage-aggregated) op node at batch `b` on
    /// SM fraction `sm`, full quota — roofline over the node's aggregate
    /// work, with occupancy judged **per underlying kernel** and launch
    /// overhead paid per kernel.
    pub fn op_time(&self, op: &crate::model::OpNode, batch: u32, sm: f64) -> f64 {
        debug_assert!((0.0..=1.0).contains(&sm) && sm > 0.0);
        let k = op.kernels.max(1) as f64;
        let flops = op.flops * batch as f64;
        let bytes = op.bytes * batch as f64 + 4.0 * op.params;
        // Occupancy: how much of the GPU one constituent kernel can fill.
        let occupancy = ((flops / k) / SATURATION_FLOPS).clamp(MIN_OCCUPANCY, 1.0);
        let sm_eff = sm.min(occupancy);
        let t_compute = flops / (self.dev.peak_flops * sm_eff * kind_efficiency(op.kind));
        // Memory bandwidth scales with the SM partition (MPS partitions share
        // HBM roughly proportionally), floored at a 10% minimum share.
        let t_memory = bytes / (self.dev.mem_bw * sm.max(0.1));
        t_compute.max(t_memory) + k * self.dev.t_launch
    }

    /// Raw graph execution time (sequential op schedule) at full quota.
    pub fn raw_graph_time(&self, g: &OpGraph, batch: u32, sm: f64) -> f64 {
        g.nodes.iter().map(|op| self.op_time(op, batch, sm)).sum()
    }

    /// End-to-end inference latency under a time quota `q`: simulate the
    /// token window at kernel granularity (no-debt semantics — see module
    /// docs). `q = 1` ⇒ latency = raw time. Delegates to the class surface
    /// at factor 1.0 — `d / 1.0` is exact in IEEE 754, so this is the
    /// historical reference surface to the bit (pinned by
    /// `class_factor_one_is_bit_identical_to_reference_surface`), and the
    /// window-replay mechanics live in exactly one place.
    pub fn latency(&self, g: &OpGraph, batch: u32, sm: f64, q: f64) -> f64 {
        self.latency_class(g, batch, sm, q, 1.0)
    }

    /// Steady-state throughput capacity (items/s) of a pod running
    /// back-to-back batches: the pod holds fraction `q` of its partition's
    /// time, so capacity = batch · q / t_raw. Delegates to the class
    /// surface at factor 1.0, mirroring [`PerfModel::latency`] — `d / 1.0`
    /// is exact in IEEE 754, and the capacity formula lives in exactly one
    /// place ([`PerfModel::capacity_class`]).
    pub fn capacity(&self, g: &OpGraph, batch: u32, sm: f64, q: f64) -> f64 {
        self.capacity_class(g, batch, sm, q, 1.0)
    }

    /// [`PerfModel::latency`] on a device class with relative throughput
    /// `factor` (see [`crate::vgpu::GpuClass`]): every kernel's execution
    /// time scales by `1/factor` while the token **window stays the
    /// scheduler constant** — so quota dilation mechanics are identical on
    /// every class, only the kernel clock changes. `factor = 1.0` is
    /// bit-identical to [`PerfModel::latency`] (`d / 1.0` is exact),
    /// pinned by test.
    pub fn latency_class(&self, g: &OpGraph, batch: u32, sm: f64, q: f64, factor: f64) -> f64 {
        debug_assert!((0.0..=1.0).contains(&q) && q > 0.0);
        debug_assert!(factor > 0.0);
        let w = self.dev.window;
        let mut now = 0.0f64;
        let mut budget = q * w;
        let mut boundary = w;
        for op in &g.nodes {
            let k = op.kernels.max(1);
            let d = self.op_time(op, batch, sm) / k as f64 / factor;
            for _ in 0..k {
                if boundary <= now {
                    let skipped = ((now - boundary) / w).floor() + 1.0;
                    boundary += skipped * w;
                    budget = q * w;
                }
                if budget <= 0.0 {
                    now = boundary;
                    boundary += w;
                    budget = q * w;
                }
                now += d;
                budget -= d;
            }
        }
        now
    }

    /// Raw graph time on a class with throughput `factor` (all kernels
    /// scale uniformly, so this is exactly the reference time / factor).
    pub fn raw_graph_time_class(&self, g: &OpGraph, batch: u32, sm: f64, factor: f64) -> f64 {
        self.raw_graph_time(g, batch, sm) / factor
    }

    /// [`PerfModel::capacity`] on a class with throughput `factor`.
    pub fn capacity_class(&self, g: &OpGraph, batch: u32, sm: f64, q: f64, factor: f64) -> f64 {
        let t_raw = self.raw_graph_time_class(g, batch, sm, factor);
        batch as f64 * q / t_raw
    }

    /// `Cold → HostCached` staging time: pull the model's weights from
    /// storage/network into host memory. Exactly 0.0 under the default
    /// infinite bandwidth (`bytes / INFINITY == 0.0`).
    pub fn cold_load_time(&self, g: &OpGraph) -> f64 {
        4.0 * g.total_params() / self.dev.host_load_bw
    }

    /// `HostCached → DeviceResident` swap time on a device class with
    /// relative throughput `factor` (faster classes have faster
    /// interconnects, mirroring [`PerfModel::latency_class`]'s clock rule).
    /// Exactly 0.0 under the default infinite bandwidth for every factor
    /// (`0.0 / factor == 0.0`).
    pub fn swap_time_class(&self, g: &OpGraph, factor: f64) -> f64 {
        debug_assert!(factor > 0.0);
        4.0 * g.total_params() / self.dev.h2d_bw / factor
    }

    /// Device-memory check for placing (model, batch) on a GPU.
    pub fn fits_memory(&self, g: &OpGraph, batch: u32, free_bytes: f64) -> bool {
        g.memory_bytes(batch) <= free_bytes.min(self.dev.mem_cap)
    }

    /// Memory check against an explicit device capacity (heterogeneous
    /// fleets: each [`crate::vgpu::GpuClass`] carries its own `mem_cap`).
    pub fn fits_memory_cap(&self, g: &OpGraph, batch: u32, free_bytes: f64, cap: f64) -> bool {
        g.memory_bytes(batch) <= free_bytes.min(cap)
    }

    /// $-cost of running a (sm, q) slice for `dur` seconds (§4.3 accounting:
    /// actual GPU resources × time).
    pub fn slice_cost(&self, sm: f64, q: f64, dur: f64) -> f64 {
        self.dev.price_per_hour / 3600.0 * sm * q * dur
    }

    /// The 6 SM profiling points RaPP uses for operator runtime features
    /// (paper §3.2: "six distinct SM configurations" at full quota).
    pub const PROFILE_SMS: [f64; 6] = [0.1, 0.2, 0.35, 0.5, 0.75, 1.0];
    /// The 5 quota profiling points for graph runtime features
    /// ("five distinct quota configurations" at full SM).
    pub const PROFILE_QUOTAS: [f64; 5] = [0.2, 0.4, 0.6, 0.8, 1.0];
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::zoo::{zoo_graph, ZooModel};
    use crate::model::OpNode;

    fn pm() -> PerfModel {
        PerfModel::default()
    }

    #[test]
    fn latency_decreases_with_sm_until_occupancy() {
        let g = zoo_graph(ZooModel::ResNet152);
        let pm = pm();
        // Large batch: more SMs keep helping.
        let l20 = pm.latency(&g, 32, 0.2, 1.0);
        let l50 = pm.latency(&g, 32, 0.5, 1.0);
        let l100 = pm.latency(&g, 32, 1.0, 1.0);
        assert!(l20 > l50 && l50 > l100, "{l20} {l50} {l100}");
        // Small batch: occupancy cap makes 50% ≈ 100%.
        let s50 = pm.latency(&g, 1, 0.5, 1.0);
        let s100 = pm.latency(&g, 1, 1.0, 1.0);
        assert!((s50 - s100) / s50 < 0.12, "small-batch SM insensitivity: {s50} vs {s100}");
    }

    #[test]
    fn latency_decreases_with_quota_and_saturates() {
        let g = zoo_graph(ZooModel::ResNet152);
        let pm = pm();
        let l_q2 = pm.latency(&g, 4, 0.5, 0.2);
        let l_q6 = pm.latency(&g, 4, 0.5, 0.6);
        let l_q10 = pm.latency(&g, 4, 0.5, 1.0);
        assert!(l_q2 > l_q6 && l_q6 >= l_q10);
        // q=1 equals raw time exactly.
        assert!((l_q10 - pm.raw_graph_time(&g, 4, 0.5)).abs() < 1e-12);
    }

    #[test]
    fn quota_gain_saturates_when_sm_starved() {
        // Paper Fig. 4: large batch + tiny SM ⇒ kernels are long relative to
        // the token window, so raising the quota barely helps; at ample SM a
        // medium batch spans many windows of small kernels and quota pays off.
        let g = zoo_graph(ZooModel::ResNet152);
        let pm = pm();
        let starved_gain = pm.latency(&g, 32, 0.1, 0.3) / pm.latency(&g, 32, 0.1, 1.0);
        let ample_gain = pm.latency(&g, 8, 1.0, 0.3) / pm.latency(&g, 8, 1.0, 1.0);
        assert!(
            starved_gain < ample_gain * 0.75,
            "starved {starved_gain} ample {ample_gain}"
        );
    }

    #[test]
    fn latency_equals_raw_time_at_full_quota() {
        let pm = pm();
        for m in [ZooModel::ResNet50, ZooModel::BertTiny, ZooModel::MobileNetV2] {
            let g = zoo_graph(m);
            for &(b, sm) in &[(1u32, 1.0f64), (8, 0.5), (32, 0.2)] {
                let l = pm.latency(&g, b, sm, 1.0);
                let raw = pm.raw_graph_time(&g, b, sm);
                assert!((l - raw).abs() / raw < 1e-9, "{m:?} b{b} sm{sm}: {l} vs {raw}");
            }
        }
    }

    #[test]
    fn latency_monotone_in_quota() {
        let pm = pm();
        let g = zoo_graph(ZooModel::ResNet50);
        for &(b, sm) in &[(1u32, 0.5f64), (8, 0.5), (16, 1.0)] {
            let mut prev = f64::INFINITY;
            for q in [0.1, 0.2, 0.4, 0.6, 0.8, 1.0] {
                let l = pm.latency(&g, b, sm, q);
                assert!(l <= prev * 1.001, "b{b} sm{sm} q{q}: {l} > {prev}");
                assert!(l >= pm.raw_graph_time(&g, b, sm) - 1e-12);
                prev = l;
            }
        }
    }

    #[test]
    fn small_job_with_low_quota_dilates_towards_t_over_q() {
        // Many small kernels (mobilenet b=1, full SM): t_raw ≈ 1-2 ms spans
        // several 5 ms windows at q = 0.05 and dilates roughly as t/q.
        let pm = pm();
        let g = zoo_graph(ZooModel::MobileNetV2);
        let raw = pm.raw_graph_time(&g, 4, 1.0);
        let l = pm.latency(&g, 4, 1.0, 0.1);
        assert!(l > 2.0 * raw, "raw={raw} dilated={l}");
        assert!(l < 20.0 * raw, "raw={raw} dilated={l}");
    }

    #[test]
    fn capacity_matches_paper_definition() {
        let g = zoo_graph(ZooModel::ResNet50);
        let pm = pm();
        let c = pm.capacity(&g, 8, 0.5, 0.5);
        let t_raw = pm.raw_graph_time(&g, 8, 0.5);
        assert!((c - 8.0 * 0.5 / t_raw).abs() < 1e-9);
        // Capacity is monotone in both resources.
        assert!(pm.capacity(&g, 8, 0.5, 0.8) > c);
        assert!(pm.capacity(&g, 8, 0.8, 0.5) > c);
    }

    #[test]
    fn memory_bound_op_ignores_extra_sm_beyond_bw() {
        let pm = pm();
        // Embed: tiny flops, big bytes — bandwidth roofline dominates.
        let op = OpNode::simple(OpKind::Embed, 1e3, 50e6, 0.0);
        let t_half = pm.op_time(&op, 1, 0.5);
        let expected = 50e6 / (pm.dev.mem_bw * 0.5) + pm.dev.t_launch;
        assert!((t_half - expected).abs() / expected < 1e-9);
    }

    #[test]
    fn launch_overhead_floors_tiny_ops() {
        let pm = pm();
        let op = OpNode::simple(OpKind::Relu, 10.0, 80.0, 0.0);
        assert!(pm.op_time(&op, 1, 1.0) >= pm.dev.t_launch);
    }

    #[test]
    fn resnet50_absolute_latency_plausible() {
        // Sanity anchor: resnet50 b=1 on a full V100 is ~5-10 ms in practice.
        let g = zoo_graph(ZooModel::ResNet50);
        let ms = pm().latency(&g, 1, 1.0, 1.0) * 1e3;
        assert!((1.0..25.0).contains(&ms), "resnet50 b1 full GPU = {ms} ms");
    }

    #[test]
    fn class_factor_one_is_bit_identical_to_reference_surface() {
        // The uniform-fleet byte-identity contract: factor 1.0 must be the
        // *same bits* as the factor-less surface at every lattice point.
        let pm = pm();
        for m in [ZooModel::ResNet50, ZooModel::BertTiny, ZooModel::MobileNetV2] {
            let g = zoo_graph(m);
            for &(b, sm, q) in &[(1u32, 1.0f64, 1.0f64), (8, 0.5, 0.6), (32, 0.2, 0.3)] {
                assert_eq!(
                    pm.latency_class(&g, b, sm, q, 1.0).to_bits(),
                    pm.latency(&g, b, sm, q).to_bits(),
                    "{m:?} b{b} sm{sm} q{q}"
                );
                assert_eq!(
                    pm.raw_graph_time_class(&g, b, sm, 1.0).to_bits(),
                    pm.raw_graph_time(&g, b, sm).to_bits()
                );
                assert_eq!(
                    pm.capacity_class(&g, b, sm, q, 1.0).to_bits(),
                    pm.capacity(&g, b, sm, q).to_bits()
                );
            }
        }
    }

    #[test]
    fn class_factor_scales_latency_and_capacity_monotonically() {
        let pm = pm();
        let g = zoo_graph(ZooModel::ResNet50);
        let slow = pm.latency_class(&g, 8, 0.5, 0.6, 0.4); // T4-like
        let base = pm.latency_class(&g, 8, 0.5, 0.6, 1.0);
        let fast = pm.latency_class(&g, 8, 0.5, 0.6, 2.0); // A100-like
        assert!(slow > base && base > fast, "{slow} {base} {fast}");
        // At full quota there is no window blocking, so scaling is exact.
        let raw = pm.latency_class(&g, 8, 0.5, 1.0, 1.0);
        let raw2 = pm.latency_class(&g, 8, 0.5, 1.0, 2.0);
        assert!((raw2 - raw / 2.0).abs() / raw < 1e-9);
        assert!(
            pm.capacity_class(&g, 8, 0.5, 0.6, 2.0) > pm.capacity_class(&g, 8, 0.5, 0.6, 1.0)
        );
        // Low quota + slow class: window dilation still bounds below by raw/q.
        let dilated = pm.latency_class(&g, 8, 0.5, 0.2, 0.4);
        assert!(dilated >= pm.raw_graph_time_class(&g, 8, 0.5, 0.4) - 1e-12);
    }

    #[test]
    fn fits_memory_cap_respects_class_capacity() {
        let pm = pm();
        let g = zoo_graph(ZooModel::Vgg16);
        let need = g.memory_bytes(8);
        assert!(pm.fits_memory_cap(&g, 8, 40e9, 40e9));
        assert!(!pm.fits_memory_cap(&g, 8, 40e9, need / 2.0));
        assert!(!pm.fits_memory_cap(&g, 8, need / 2.0, 40e9));
    }

    #[test]
    fn default_lifecycle_latencies_are_exactly_zero() {
        // The byte-identity contract: infinite default bandwidths make the
        // staging and swap terms *bit-exact* zero, so `ready_at + 0.0` is
        // the historical `ready_at` to the bit, for every class factor.
        let pm = pm();
        for m in [ZooModel::ResNet50, ZooModel::BertTiny, ZooModel::Vgg16] {
            let g = zoo_graph(m);
            assert_eq!(pm.cold_load_time(&g).to_bits(), 0.0f64.to_bits());
            for f in [0.4, 1.0, 2.0] {
                assert_eq!(pm.swap_time_class(&g, f).to_bits(), 0.0f64.to_bits());
            }
        }
    }

    #[test]
    fn finite_bandwidth_lifecycle_latencies_scale_with_class() {
        let pm = PerfModel::new(DeviceSpec {
            host_load_bw: 1e9,
            h2d_bw: 2e8,
            ..Default::default()
        });
        let g = zoo_graph(ZooModel::ResNet50);
        let bytes = 4.0 * g.total_params();
        assert!((pm.cold_load_time(&g) - bytes / 1e9).abs() < 1e-12);
        let base = pm.swap_time_class(&g, 1.0);
        assert!((base - bytes / 2e8).abs() < 1e-9);
        // Faster class ⇒ proportionally faster swap.
        assert!((pm.swap_time_class(&g, 2.0) - base / 2.0).abs() < 1e-9);
        assert!(pm.swap_time_class(&g, 0.4) > base);
    }

    #[test]
    fn cost_accounting_linear() {
        let pm = pm();
        let c = pm.slice_cost(0.5, 0.5, 3600.0);
        assert!((c - 2.48 * 0.25).abs() < 1e-9);
    }
}
