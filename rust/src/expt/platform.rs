//! The open platform registry: [`PlatformSpec`] descriptors replace the old
//! closed `Platform` enum.
//!
//! A *platform* is everything the scenario matrix varies between cells of
//! one (preset, seed) column: the scaling policy, the billing mode, and the
//! latency predictor the policy plans with. The seed hard-coded three
//! variants in `match` arms; the registry makes the comparison surface
//! data — the stock trio and the paper's ablation platforms ship
//! pre-registered, and callers can [`PlatformRegistry::register`] their own
//! comparators (an ESG-style pipeline scheduler, a Torpor-style SLO-aware
//! policy, …) without touching `expt` internals.
//!
//! **Name stability contract:** a spec's `name` is the key used in
//! `BENCH_sim.json` cells, summary rows, and headline ratios. Names of
//! registered platforms are part of the export schema and must never be
//! reused for a different configuration; renaming one is a schema change.
//! The stock trio (`has-gpu`, `kserve`, `fast-gshare`) keeps its exact
//! enum-era output bytes — pinned by `rust/tests/expt_golden.rs`.

use crate::autoscaler::{HybridAutoscaler, HybridConfig, ScalingAxes, ScalingPolicy};
use crate::baselines::{FastGSharePolicy, KServePolicy, TorporPolicy};
use crate::metrics::BillingMode;
use crate::perf::PerfModel;
use crate::rapp::dippm::DippmPredictor;
use crate::rapp::features::FeatureMode;
use crate::rapp::{LatencyPredictor, OraclePredictor, RappPredictor, RappWeights};
use crate::util::bench::ascii_table;
use std::fmt;
use std::path::PathBuf;
use std::sync::{Arc, OnceLock};

/// Which latency predictor drives a platform's scaling decisions (the serve
/// path always uses the ground-truth surface; this selects the *planning*
/// model, paper Fig. 5's comparison axis).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PredictorSel {
    /// The ground-truth `PerfModel` ("perfectly profiled" upper bound).
    Oracle,
    /// The trained RaPP GAT+MLP (runtime-prior features).
    Rapp,
    /// The DIPPM static-feature baseline from [`crate::rapp::dippm`].
    Dippm,
}

/// Deterministic weight seeds for the no-artifacts fallback (see
/// [`PredictorSel::build`]).
const RAPP_FALLBACK_SEED: u64 = 0x4A;
const DIPPM_FALLBACK_SEED: u64 = 0xD1;

fn artifacts_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

/// Resolve one learned-weights source exactly once per process: trained
/// weights from `rust/artifacts/<file>` when present, the deterministic
/// seeded fallback when absent. Caching here (a) avoids re-reading and
/// re-parsing the JSON for every grid cell and (b) guarantees every cell
/// of a run sees the *same* weights even if the artifacts file appears or
/// vanishes mid-run — cells stay pure functions of their coordinates.
///
/// A file that exists but fails to load is a hard error (panic): silently
/// degrading a trained platform to untrained weights would export garbage
/// under the same registry name, violating the name stability contract.
fn cached_weights(
    slot: &'static OnceLock<RappWeights>,
    file: &str,
    fallback_mode: FeatureMode,
    fallback_seed: u64,
) -> RappWeights {
    slot.get_or_init(|| {
        let path = artifacts_dir().join(file);
        if path.exists() {
            match RappWeights::load(&path) {
                Ok(w) => w,
                Err(e) => panic!(
                    "weights at {} are present but unloadable (refusing to \
                     silently fall back to untrained weights): {e}",
                    path.display()
                ),
            }
        } else {
            RappWeights::random(fallback_mode, 32, fallback_seed)
        }
    })
    .clone()
}

static RAPP_WEIGHTS: OnceLock<RappWeights> = OnceLock::new();
static DIPPM_WEIGHTS: OnceLock<RappWeights> = OnceLock::new();

impl PredictorSel {
    pub fn name(self) -> &'static str {
        match self {
            PredictorSel::Oracle => "oracle",
            PredictorSel::Rapp => "rapp",
            PredictorSel::Dippm => "dippm",
        }
    }

    /// Build a fresh predictor instance for one cell. Learned predictors
    /// take their trained weights from `rust/artifacts/` when present (read
    /// and parsed once per process, see [`cached_weights`]); when the file
    /// is absent they fall back to *deterministic* seeded random weights —
    /// decision quality degrades (which is exactly what the predictor
    /// ablation measures against the oracle), but every cell remains a pure
    /// function of its coordinates, preserving the `--jobs`-independence
    /// and cross-run reproducibility guarantees. A present-but-unloadable
    /// weights file panics rather than degrading silently.
    pub fn build(self) -> Box<dyn LatencyPredictor> {
        match self {
            PredictorSel::Oracle => Box::new(OraclePredictor::default()),
            PredictorSel::Rapp => Box::new(RappPredictor::new(
                cached_weights(
                    &RAPP_WEIGHTS,
                    "rapp_weights.json",
                    FeatureMode::Full,
                    RAPP_FALLBACK_SEED,
                ),
                PerfModel::default(),
            )),
            PredictorSel::Dippm => Box::new(
                DippmPredictor::new(
                    cached_weights(
                        &DIPPM_WEIGHTS,
                        "dippm_weights.json",
                        FeatureMode::StaticOnly,
                        DIPPM_FALLBACK_SEED,
                    ),
                    PerfModel::default(),
                )
                .expect("dippm weights must be static-only mode"),
            ),
        }
    }
}

/// Registry grouping, used by the CLI group tokens (`all`, `ablations`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PlatformGroup {
    /// The paper's §4.3 comparison trio. The `all` group token.
    Stock,
    /// Single-axis / static-predictor ablations. The `ablations` group token.
    Ablation,
    /// Caller-registered comparators.
    Custom,
}

impl PlatformGroup {
    pub fn name(self) -> &'static str {
        match self {
            PlatformGroup::Stock => "stock",
            PlatformGroup::Ablation => "ablation",
            PlatformGroup::Custom => "custom",
        }
    }
}

/// A fresh, stateful scaling policy per cell (cells stay independent).
pub type PolicyFactory = Arc<dyn Fn() -> Box<dyn ScalingPolicy> + Send + Sync>;

/// Descriptor of one serving platform under comparison: stable name, policy
/// factory, billing mode, predictor selector, and (for hybrid-family
/// platforms) the `HybridConfig` the factory instantiates — the ablations
/// are config restrictions of the same policy, never forks.
#[derive(Clone)]
pub struct PlatformSpec {
    /// Stable registry key; exported verbatim in `BENCH_sim.json` (see the
    /// name stability contract in the module docs).
    pub name: String,
    /// One-line description for `--help` and the `platforms` subcommand.
    pub about: String,
    pub group: PlatformGroup,
    pub billing: BillingMode,
    pub predictor: PredictorSel,
    /// Present on hybrid-family platforms: the exact config the factory
    /// builds, introspectable so tests can assert ablations differ from the
    /// stock policy *only* in the intended knob.
    pub hybrid: Option<HybridConfig>,
    factory: PolicyFactory,
}

impl fmt::Debug for PlatformSpec {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_struct("PlatformSpec")
            .field("name", &self.name)
            .field("group", &self.group)
            .field("billing", &self.billing)
            .field("predictor", &self.predictor)
            .field("hybrid", &self.hybrid)
            .finish_non_exhaustive()
    }
}

/// Human label for a billing mode (CLI tables and error messages).
pub fn billing_label(mode: BillingMode) -> &'static str {
    match mode {
        BillingMode::FineGrained => "fine-grained",
        BillingMode::WholeGpu => "whole-gpu",
    }
}

impl PlatformSpec {
    /// Fully custom descriptor. `factory` must return a *fresh* policy on
    /// every call (policies are stateful and cells must stay independent)
    /// whose [`ScalingPolicy::name`] equals the spec name —
    /// [`PlatformRegistry::register`] enforces the agreement.
    pub fn new<F>(
        name: impl Into<String>,
        about: impl Into<String>,
        billing: BillingMode,
        predictor: PredictorSel,
        factory: F,
    ) -> Self
    where
        F: Fn() -> Box<dyn ScalingPolicy> + Send + Sync + 'static,
    {
        PlatformSpec {
            name: name.into(),
            about: about.into(),
            group: PlatformGroup::Custom,
            billing,
            predictor,
            hybrid: None,
            factory: Arc::new(factory),
        }
    }

    /// A hybrid-family platform: `HybridAutoscaler` under `cfg`, billed
    /// fine-grained, planning with the oracle predictor by default. The
    /// policy self-reports the platform name (so `RunReport.platform`
    /// matches the registry key even for ablation variants).
    pub fn hybrid(name: impl Into<String>, about: impl Into<String>, cfg: HybridConfig) -> Self {
        let name = name.into();
        let factory_name = name.clone();
        let factory_cfg = cfg.clone();
        PlatformSpec {
            name,
            about: about.into(),
            group: PlatformGroup::Custom,
            billing: BillingMode::FineGrained,
            predictor: PredictorSel::Oracle,
            hybrid: Some(cfg),
            factory: Arc::new(move || {
                Box::new(HybridAutoscaler::named(factory_name.clone(), factory_cfg.clone()))
                    as Box<dyn ScalingPolicy>
            }),
        }
    }

    pub fn with_group(mut self, group: PlatformGroup) -> Self {
        self.group = group;
        self
    }

    pub fn with_predictor(mut self, predictor: PredictorSel) -> Self {
        self.predictor = predictor;
        self
    }

    pub fn with_billing(mut self, billing: BillingMode) -> Self {
        self.billing = billing;
        self
    }

    /// A fresh scaling policy for one cell.
    pub fn policy(&self) -> Box<dyn ScalingPolicy> {
        (self.factory)()
    }

    /// A fresh planning predictor for one cell.
    pub fn build_predictor(&self) -> Box<dyn LatencyPredictor> {
        self.predictor.build()
    }
}

/// Ordered collection of [`PlatformSpec`]s. Registration order is the
/// canonical matrix order: group tokens (`all`, `ablations`) expand in this
/// order, so the stock trio enumerates exactly as the old enum's
/// `ALL_PLATFORMS` did.
#[derive(Clone, Debug)]
pub struct PlatformRegistry {
    specs: Vec<PlatformSpec>,
}

impl Default for PlatformRegistry {
    /// The stock trio plus the paper-motivated ablations, in canonical
    /// order: `has-gpu`, `kserve`, `fast-gshare`, `has-vertical-only`,
    /// `has-horizontal-only`, `has-dippm`.
    fn default() -> Self {
        let mut reg = PlatformRegistry::empty();
        let stock = |s: PlatformSpec| s.with_group(PlatformGroup::Stock);
        let ablation = |s: PlatformSpec| s.with_group(PlatformGroup::Ablation);
        reg.register(stock(PlatformSpec::hybrid(
            "has-gpu",
            "hybrid vertical+horizontal auto-scaling (paper Algorithm 1)",
            HybridConfig::default(),
        )))
        .unwrap();
        reg.register(stock(PlatformSpec::new(
            "kserve",
            "whole-GPU pods, horizontal-only (mainstream GPU serverless)",
            BillingMode::WholeGpu,
            PredictorSel::Oracle,
            || Box::new(KServePolicy::default()),
        )))
        .unwrap();
        reg.register(stock(PlatformSpec::new(
            "fast-gshare",
            "fixed fine-grained slice per function, horizontal-only",
            BillingMode::FineGrained,
            PredictorSel::Oracle,
            || Box::new(FastGSharePolicy::default()),
        )))
        .unwrap();
        reg.register(ablation(PlatformSpec::hybrid(
            "has-vertical-only",
            "HAS-GPU restricted to quota re-writes (no replica scaling)",
            HybridConfig {
                scaling_axes: ScalingAxes::VerticalOnly,
                ..HybridConfig::default()
            },
        )))
        .unwrap();
        reg.register(ablation(PlatformSpec::hybrid(
            "has-horizontal-only",
            "HAS-GPU restricted to replica scaling (quotas frozen at creation)",
            HybridConfig {
                scaling_axes: ScalingAxes::HorizontalOnly,
                ..HybridConfig::default()
            },
        )))
        .unwrap();
        reg.register(ablation(
            PlatformSpec::hybrid(
                "has-dippm",
                "HAS-GPU planning with the static-feature DIPPM predictor",
                HybridConfig::default(),
            )
            .with_predictor(PredictorSel::Dippm),
        ))
        .unwrap();
        // A fourth comparison point, deliberately *outside* the stock and
        // ablation groups so the `all`/`ablations` tokens — and every
        // existing export built from them — keep their exact cell sets.
        reg.register(PlatformSpec::new(
            "torpor-like",
            "fixed slices with a host-memory swap tier: idle replicas parked, swapped in on demand",
            BillingMode::FineGrained,
            PredictorSel::Oracle,
            || Box::new(TorporPolicy::default()),
        ))
        .unwrap();
        reg
    }
}

impl PlatformRegistry {
    /// An empty registry (build your own comparison surface from scratch).
    pub fn empty() -> Self {
        PlatformRegistry { specs: Vec::new() }
    }

    /// Append a spec. Names are case-insensitive keys; duplicates are
    /// rejected (the name stability contract forbids silent redefinition),
    /// as are names the CLI could never select: the reserved group tokens
    /// (`all`, `ablations`) and names containing the list separator `,`.
    pub fn register(&mut self, spec: PlatformSpec) -> anyhow::Result<()> {
        anyhow::ensure!(!spec.name.is_empty(), "platform name must be non-empty");
        anyhow::ensure!(
            spec.name.trim() == spec.name,
            "platform name '{}' must not have surrounding whitespace \
             (lookups trim their query, so the entry would be unreachable)",
            spec.name
        );
        anyhow::ensure!(
            !["all", "ablations"]
                .iter()
                .any(|r| spec.name.eq_ignore_ascii_case(r)),
            "platform name '{}' is a reserved group token",
            spec.name
        );
        anyhow::ensure!(
            !spec.name.contains(','),
            "platform name '{}' must not contain ',' (the CLI list separator)",
            spec.name
        );
        anyhow::ensure!(
            self.get(&spec.name).is_none(),
            "platform '{}' is already registered",
            spec.name
        );
        // RunReport keys on the policy's self-reported name while the grid
        // keys on the registry name; they must agree or a run's report and
        // its cell would claim different platforms.
        let reported = spec.policy().name().to_string();
        anyhow::ensure!(
            reported == spec.name,
            "platform '{}': its policy factory self-reports '{reported}' — wrap the policy so \
             `ScalingPolicy::name()` returns the registry key",
            spec.name
        );
        self.specs.push(spec);
        Ok(())
    }

    /// Case-insensitive lookup.
    pub fn get(&self, name: &str) -> Option<&PlatformSpec> {
        self.specs
            .iter()
            .find(|s| s.name.eq_ignore_ascii_case(name.trim()))
    }

    pub fn specs(&self) -> &[PlatformSpec] {
        &self.specs
    }

    pub fn names(&self) -> Vec<&str> {
        self.specs.iter().map(|s| s.name.as_str()).collect()
    }

    pub fn group_names(&self, group: PlatformGroup) -> Vec<&str> {
        self.specs
            .iter()
            .filter(|s| s.group == group)
            .map(|s| s.name.as_str())
            .collect()
    }

    /// Expand a `--platforms` token list into canonical registry names:
    /// each token is a platform name or a group (`all` = stock, `ablations`
    /// = ablation entries), matched case-insensitively; duplicates collapse
    /// to their first occurrence. Unknown tokens error with the full menu.
    pub fn resolve(&self, tokens: &[String]) -> anyhow::Result<Vec<String>> {
        anyhow::ensure!(!tokens.is_empty(), "need at least one platform");
        let mut out: Vec<String> = Vec::new();
        let mut push = |name: &str, out: &mut Vec<String>| {
            if !out.iter().any(|n| n == name) {
                out.push(name.to_string());
            }
        };
        for tok in tokens {
            let t = tok.trim();
            if t.eq_ignore_ascii_case("all") {
                for n in self.group_names(PlatformGroup::Stock) {
                    push(n, &mut out);
                }
            } else if t.eq_ignore_ascii_case("ablations") {
                for n in self.group_names(PlatformGroup::Ablation) {
                    push(n, &mut out);
                }
            } else if let Some(spec) = self.get(t) {
                let name = spec.name.clone();
                push(&name, &mut out);
            } else {
                anyhow::bail!(
                    "unknown platform '{t}' (expected one of: {}, or groups: all = stock trio, \
                     ablations = ablation set)",
                    self.names().join(", ")
                );
            }
        }
        anyhow::ensure!(!out.is_empty(), "need at least one platform");
        Ok(out)
    }

    /// One-line inventory for `--help` text.
    pub fn cli_help(&self) -> String {
        format!(
            "comma list of platform names/groups; names: {}; groups: all = {}, ablations = {}",
            self.names().join(", "),
            self.group_names(PlatformGroup::Stock).join("+"),
            self.group_names(PlatformGroup::Ablation).join("+"),
        )
    }

    /// The `has-gpu platforms` inventory table.
    pub fn table(&self) -> String {
        let rows: Vec<Vec<String>> = self
            .specs
            .iter()
            .map(|s| {
                vec![
                    s.name.clone(),
                    s.group.name().to_string(),
                    billing_label(s.billing).to_string(),
                    s.predictor.name().to_string(),
                    s.about.clone(),
                ]
            })
            .collect();
        ascii_table(&["platform", "group", "billing", "predictor", "description"], &rows)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_registry_has_stock_trio_then_ablations_in_canonical_order() {
        let reg = PlatformRegistry::default();
        assert_eq!(
            reg.names(),
            vec![
                "has-gpu",
                "kserve",
                "fast-gshare",
                "has-vertical-only",
                "has-horizontal-only",
                "has-dippm",
                "torpor-like"
            ]
        );
        assert_eq!(
            reg.group_names(PlatformGroup::Stock),
            vec!["has-gpu", "kserve", "fast-gshare"]
        );
        assert_eq!(
            reg.group_names(PlatformGroup::Ablation),
            vec!["has-vertical-only", "has-horizontal-only", "has-dippm"]
        );
    }

    #[test]
    fn stock_specs_reproduce_the_enum_era_configuration() {
        let reg = PlatformRegistry::default();
        let has = reg.get("has-gpu").unwrap();
        assert_eq!(has.billing, BillingMode::FineGrained);
        assert_eq!(has.predictor, PredictorSel::Oracle);
        assert_eq!(has.hybrid.as_ref().unwrap().scaling_axes, ScalingAxes::Both);
        let ks = reg.get("kserve").unwrap();
        assert_eq!(ks.billing, BillingMode::WholeGpu);
        assert_eq!(ks.predictor, PredictorSel::Oracle);
        assert!(ks.hybrid.is_none());
        let fg = reg.get("fast-gshare").unwrap();
        assert_eq!(fg.billing, BillingMode::FineGrained);
        // Policies self-report their registry names.
        for s in reg.specs() {
            assert_eq!(s.policy().name(), s.name, "{}", s.name);
        }
    }

    #[test]
    fn ablations_differ_from_stock_only_in_the_intended_knob() {
        let reg = PlatformRegistry::default();
        let stock = reg.get("has-gpu").unwrap().hybrid.clone().unwrap();
        let vert = reg.get("has-vertical-only").unwrap().hybrid.clone().unwrap();
        let horiz = reg.get("has-horizontal-only").unwrap().hybrid.clone().unwrap();
        assert_eq!(vert.scaling_axes, ScalingAxes::VerticalOnly);
        assert_eq!(horiz.scaling_axes, ScalingAxes::HorizontalOnly);
        // Every other knob matches the stock config.
        for cfg in [&vert, &horiz] {
            assert_eq!(cfg.alpha, stock.alpha);
            assert_eq!(cfg.beta, stock.beta);
            assert_eq!(cfg.quota_step, stock.quota_step);
            assert_eq!(cfg.cooldown, stock.cooldown);
            assert_eq!(cfg.min_quota, stock.min_quota);
            assert_eq!(cfg.default_sm, stock.default_sm);
            assert_eq!(cfg.kalman, stock.kalman);
            assert_eq!(cfg.slo_margin, stock.slo_margin);
            assert_eq!(cfg.headroom_quota, stock.headroom_quota);
        }
        let dippm = reg.get("has-dippm").unwrap();
        assert_eq!(dippm.predictor, PredictorSel::Dippm);
        assert_eq!(dippm.hybrid.as_ref().unwrap().scaling_axes, ScalingAxes::Both);
    }

    #[test]
    fn lookup_and_resolution_are_case_insensitive() {
        let reg = PlatformRegistry::default();
        assert_eq!(reg.get("KServe").unwrap().name, "kserve");
        assert_eq!(reg.get(" HAS-GPU ").unwrap().name, "has-gpu");
        let names = reg.resolve(&["ALL".to_string()]).unwrap();
        assert_eq!(names, vec!["has-gpu", "kserve", "fast-gshare"]);
        let one = reg.resolve(&["Has-Vertical-Only".to_string()]).unwrap();
        assert_eq!(one, vec!["has-vertical-only"]);
    }

    #[test]
    fn resolve_expands_groups_and_dedupes_preserving_order() {
        let reg = PlatformRegistry::default();
        let full = reg
            .resolve(&["all".to_string(), "ablations".to_string()])
            .unwrap();
        assert_eq!(full.len(), 6, "{full:?}");
        assert_eq!(full[0], "has-gpu");
        assert_eq!(full[3], "has-vertical-only");
        // Duplicates collapse to first occurrence.
        let dup = reg
            .resolve(&["kserve".to_string(), "all".to_string()])
            .unwrap();
        assert_eq!(dup, vec!["kserve", "has-gpu", "fast-gshare"]);
    }

    #[test]
    fn unknown_platform_error_lists_the_registry() {
        let reg = PlatformRegistry::default();
        let err = reg.resolve(&["gke".to_string()]).unwrap_err().to_string();
        for name in reg.names() {
            assert!(err.contains(name), "error must list '{name}': {err}");
        }
        assert!(err.contains("all"), "{err}");
        assert!(err.contains("ablations"), "{err}");
        assert!(reg.resolve(&[]).is_err());
    }

    #[test]
    fn duplicate_registration_rejected_case_insensitively() {
        let mut reg = PlatformRegistry::default();
        let dup = PlatformSpec::hybrid("HAS-GPU", "shadow", HybridConfig::default());
        assert!(reg.register(dup).is_err());
        // Reserved group tokens and CLI-unreachable names are rejected too.
        for bad in ["all", "Ablations", " all ", "a,b", "padded ", ""] {
            let spec = PlatformSpec::hybrid(bad, "unreachable", HybridConfig::default());
            assert!(reg.register(spec).is_err(), "'{bad}' must be rejected");
        }
        // A factory whose policy self-reports a different name is rejected:
        // RunReport would otherwise claim another platform's key.
        let mismatch = PlatformSpec::new(
            "shadow-kserve",
            "mislabelled comparator",
            BillingMode::WholeGpu,
            PredictorSel::Oracle,
            || Box::new(KServePolicy::default()),
        );
        assert!(reg.register(mismatch).is_err());
        // A self-consistent custom platform registers and resolves.
        let custom = PlatformSpec::hybrid(
            "my-platform",
            "caller-registered comparator",
            HybridConfig {
                alpha: 0.9,
                ..HybridConfig::default()
            },
        );
        reg.register(custom).unwrap();
        assert_eq!(reg.get("my-platform").unwrap().group, PlatformGroup::Custom);
        assert_eq!(
            reg.resolve(&["my-platform".to_string()]).unwrap(),
            vec!["my-platform"]
        );
    }

    #[test]
    fn torpor_like_registers_outside_the_group_tokens() {
        let reg = PlatformRegistry::default();
        let tp = reg.get("torpor-like").unwrap();
        assert_eq!(tp.group, PlatformGroup::Custom);
        assert_eq!(tp.billing, BillingMode::FineGrained);
        assert_eq!(tp.predictor, PredictorSel::Oracle);
        assert!(tp.hybrid.is_none());
        assert_eq!(tp.policy().name(), "torpor-like");
        // Neither group token drags it into pre-existing exports…
        let full = reg
            .resolve(&["all".to_string(), "ablations".to_string()])
            .unwrap();
        assert!(!full.contains(&"torpor-like".to_string()), "{full:?}");
        // …but it resolves by name alongside them.
        let with = reg
            .resolve(&["all".to_string(), "torpor-like".to_string()])
            .unwrap();
        assert_eq!(with.last().map(String::as_str), Some("torpor-like"));
    }

    #[test]
    fn predictor_selectors_build_working_predictors() {
        use crate::model::zoo::{zoo_graph, ZooModel};
        use crate::rapp::PredictQuery;
        let g = zoo_graph(ZooModel::MobileNetV2);
        for sel in [PredictorSel::Oracle, PredictorSel::Rapp, PredictorSel::Dippm] {
            let p = sel.build();
            let l = p.latency(PredictQuery::new(&g, 4, 0.5, 0.5));
            assert!(l.is_finite() && l > 0.0, "{sel:?} latency {l}");
            // Deterministic across fresh builds (artifacts or seeded fallback).
            assert_eq!(sel.build().latency(PredictQuery::new(&g, 4, 0.5, 0.5)), l, "{sel:?}");
        }
    }

    #[test]
    fn registry_table_and_help_cover_every_platform() {
        let reg = PlatformRegistry::default();
        let table = reg.table();
        let help = reg.cli_help();
        for name in reg.names() {
            assert!(table.contains(name), "table missing {name}");
            assert!(help.contains(name), "help missing {name}");
        }
        assert!(table.contains("whole-gpu"));
    }
}
